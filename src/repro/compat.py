"""Small cross-version JAX compatibility shims.

``shard_map`` moved from ``jax.experimental.shard_map`` (replication check
flag ``check_rep``) to ``jax.shard_map`` (flag ``check_vma``); ``shard_map``
here accepts ``check=False``-style usage via :data:`SHARD_MAP_CHECK_KW`.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
    SHARD_MAP_CHECK_KW = "check_vma"
else:  # pragma: no cover - exercised on jax<=0.4 only
    from jax.experimental.shard_map import shard_map  # type: ignore
    SHARD_MAP_CHECK_KW = "check_rep"


def shard_map_unchecked(fn, **kwargs):
    """``shard_map`` with the per-version replication check disabled."""
    return shard_map(fn, **kwargs, **{SHARD_MAP_CHECK_KW: False})


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict across jaxlib versions
    (older jaxlibs return a list with one dict per module)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    return ca or {}
