from . import ops, ref
