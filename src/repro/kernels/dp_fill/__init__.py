from . import autotune, ops, ref
