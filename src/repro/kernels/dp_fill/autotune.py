"""``block_rows`` autotuner for the fused DP band-fill kernel.

The fused fill (``impl="pallas_fused"``) tiles each band's rows into
``(block_rows, W)`` VMEM blocks.  The best tile height depends on the
machine and on the problem shape (row count vs the saturation-capped band
width), so this module measures a short calibration fill over a small
candidate grid and persists the winner through the solver cache
(:mod:`repro.core.solver_cache`) — the same content-addressed
:mod:`repro.store` tier the DP Solutions use (winner entries carry the
``"autotune"`` envelope kind), with the same corruption semantics: a
truncated, garbled, or wrong-shaped entry is treated as a miss and simply
recalibrated.

Calibration is deliberately tiny (a deterministic synthetic chain, sizes
clamped to ``CALIBRATION_L``/``CALIBRATION_S``) and keyed by power-of-two
buckets of ``(L, S)`` plus the dispatch mode, so one measurement serves a
whole neighborhood of problem sizes.

Knobs:

- ``REPRO_DP_BLOCK_ROWS=<n>`` — pin the tile height, no measurement;
- ``REPRO_DP_AUTOTUNE=1`` — calibrate (once per bucket, then cached);
  unset/0 keeps the static :data:`~repro.kernels.dp_fill.kernel
  .DEFAULT_BLOCK_ROWS`, so CI and cold paths never pay the calibration.
"""

from __future__ import annotations

import os
import time
from typing import Iterable, Tuple

import jax
import numpy as np

from ...core import solver_cache
from . import kernel

#: Tile heights the calibration sweeps.  Small is deliberate: the fused
#: kernel's per-step work is O(block_rows · W), and the row counts of real
#: chains (L ≤ a few hundred) do not reward a finer grid.
CANDIDATE_BLOCK_ROWS: Tuple[int, ...] = (8, 32, 128, 256)

#: Calibration fill size ceilings.  Interpret mode executes the kernel in
#: Python, so its calibration chain must stay tiny; compiled dispatch is
#: fast enough to calibrate near the real problem size, where the large
#: tile-height candidates actually differ.
CALIBRATION_L_INTERPRET = 12
CALIBRATION_S_INTERPRET = 32
CALIBRATION_L_COMPILED = 384
CALIBRATION_S_COMPILED = 512

_VERSION = 2

#: Process-local memo of calibrated choices (keyed by :func:`cache_key`) —
#: bounds calibration to once per process even when the persistent solver
#: cache is disabled (``REPRO_SOLVER_CACHE=0``).
_memo: dict = {}


def _bucket(n: int) -> int:
    """Smallest power of two >= n (problems in one bucket share a choice)."""
    return 1 << max(0, int(n - 1).bit_length())


def cache_key(L: int, S: int, interpret: bool) -> str:
    mode = "interpret" if interpret else f"compiled-{jax.default_backend()}"
    lb, sb = _bucket(max(L, 1)), _bucket(max(S, 1))
    return f"dp-fill-autotune-v{_VERSION}-{mode}-L{lb}-S{sb}"


def _calibration_chain(L: int, S: int, interpret: bool):
    """Deterministic f32-exact chain at the (mode-clamped) calibration
    sizes."""
    from ...core.chain import Chain

    cap_l = CALIBRATION_L_INTERPRET if interpret else CALIBRATION_L_COMPILED
    cap_s = CALIBRATION_S_INTERPRET if interpret else CALIBRATION_S_COMPILED
    Lc = max(1, min(L, cap_l))
    Sc = max(4, min(S, cap_s))
    rng = np.random.default_rng(0)
    n = Lc + 1
    ch = Chain.make(
        uf=rng.integers(1, 5, n).astype(float),
        ub=rng.integers(1, 5, n).astype(float),
        wa=rng.integers(1, 4, n).astype(float),
        wabar=rng.integers(1, 6, n).astype(float),
    )
    return ch.discretize(float(Sc), Sc), Sc


def measure(
    L: int,
    S: int,
    interpret: bool,
    candidates: Iterable[int] = CANDIDATE_BLOCK_ROWS,
    repeats: int = 2,
) -> dict:
    """Time the fused two-tier fill per candidate under the given dispatch
    mode; returns the timing dict (``block_rows`` holds the winner).

    Candidates are deduplicated by their *effective* tile height
    ``min(candidate, calibration L)`` — the fill clamps ``block_rows`` to
    the row count, so without this, every candidate above the calibration
    length would measure the identical configuration and the "winner" among
    them would be timer noise.
    """
    from . import ops

    dchain, Sc = _calibration_chain(L, S, interpret)
    Lc = dchain.length
    effective = sorted({min(int(c), Lc) for c in candidates})
    previous = ops._INTERPRET[0]
    ops.set_interpret(interpret)
    timings = {}
    try:
        for br in effective:
            best = None
            for _ in range(max(1, repeats)):
                t0 = time.perf_counter()
                ops.fill_two_tier_fused(dchain, Sc, block_rows=br)
                dt = time.perf_counter() - t0
                best = dt if best is None else min(best, dt)
            timings[int(br)] = best
    finally:
        ops.set_interpret(previous)
    winner = min(timings, key=timings.get)
    return {"version": _VERSION, "block_rows": int(winner), "timings": timings}


def _valid_entry(entry) -> bool:
    """Guards against a *decodable but wrong-shaped* cache value (the store
    tier already quarantines undecodable bytes as a miss)."""
    return (
        isinstance(entry, dict)
        and entry.get("version") == _VERSION
        and isinstance(entry.get("block_rows"), int)
        and entry["block_rows"] >= 1
    )


def autotune_block_rows(
    L: int,
    S: int,
    *,
    interpret: bool,
    candidates: Iterable[int] = CANDIDATE_BLOCK_ROWS,
    cache: bool = True,
) -> int:
    """The calibrated tile height for an ``(L, S)``-sized fill; measured at
    most once per ``(bucket, dispatch-mode)`` and persisted via the solver
    cache's disk store.  A corrupted or stale persisted entry recalibrates
    (and is overwritten), mirroring :mod:`repro.core.solver_cache`."""
    from ...obs import metrics as _obs

    sc = solver_cache.get_cache()
    key = cache_key(L, S, interpret)
    if cache:
        if key in _memo:
            return _memo[key]
        if sc.enabled:
            entry = sc.get(key)
            if _valid_entry(entry):
                _memo[key] = entry["block_rows"]
                _obs.counter("dp_autotune.cache_hits").inc()
                _obs.gauge("dp_autotune.block_rows").set(entry["block_rows"])
                return entry["block_rows"]
    result = measure(L, S, interpret, candidates=candidates)
    if cache:
        _memo[key] = result["block_rows"]
        if sc.enabled:
            sc.put(key, result, kind="autotune")
    _obs.counter("dp_autotune.calibrations").inc()
    _obs.gauge("dp_autotune.block_rows").set(result["block_rows"])
    return result["block_rows"]


def resolve_block_rows(L: int, S: int, *, interpret: bool) -> int:
    """The fused fill's tile height: pinned by ``REPRO_DP_BLOCK_ROWS``,
    calibrated when ``REPRO_DP_AUTOTUNE`` is truthy, else the static
    default (no measurement on cold paths)."""
    pinned = os.environ.get("REPRO_DP_BLOCK_ROWS")
    if pinned:
        try:
            return max(1, int(pinned))
        except ValueError:
            raise ValueError(
                f"cannot parse REPRO_DP_BLOCK_ROWS={pinned!r}: expected a "
                f"positive integer tile height, e.g. 128"
            ) from None
    flag = os.environ.get("REPRO_DP_AUTOTUNE", "0").lower()
    if flag not in ("0", "false", "off", ""):
        return autotune_block_rows(L, S, interpret=interpret)
    return kernel.DEFAULT_BLOCK_ROWS
