"""Pure-jnp oracles for the DP band-fill reductions (kernel parity tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def band_min_two_tier(r: jax.Array, lm: jax.Array) -> jax.Array:
    """``min_j (r[j] + lm[j])`` over the stacked split axis."""
    return jnp.min(r + lm, axis=0)


def band_min_offload(
    r: jax.Array,
    r3: jax.Array,
    lmb: jax.Array,
    lme: jax.Array,
    lmb3: jax.Array,
    toff: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """The three offload-band accumulators, reduced in one shot."""
    cb = jnp.min(r + lmb, axis=0)
    ce = jnp.min(r + lme, axis=0)
    c3 = jnp.min(jnp.maximum(r3, toff[None]) + lmb3, axis=0)
    return cb, ce, c3
