"""Band-fill drivers for ``impl="pallas"`` / ``impl="pallas_fused"`` — the
solver-side dispatch seam.

These mirror the numpy banded fills of :mod:`repro.core.dp_kernels` exactly
(same companion tables, same thresholds, same saturated m-column pruning,
same C2 fall plane) but hand the DP's hot loop to the Pallas kernels in
:mod:`.kernel`:

- ``fill_two_tier`` / ``fill_offload`` (``impl="pallas"``) keep the band
  recursion on the host — companion tables are republished after each band,
  one kernel launch per length (O(L) dispatches per fill);
- ``fill_two_tier_fused`` / ``fill_offload_fused`` (``impl="pallas_fused"``)
  stage the *whole* recursion as ONE ``pallas_call``: the host builds the
  base case, thresholds, and clamped integer operands, dispatches once, and
  unpacks the returned table(s) — companion rebuild happens in-kernel, and
  the device buffers are sized by the ``O(cap_d)`` saturation bound (the
  widest unsaturated band), with the saturated tail broadcast on the host
  after the fact.  ``block_rows`` (the row-tile height) resolves through
  :mod:`.autotune` when not given.

Dispatch seam: on a TPU backend the kernels run jitted; everywhere else they
fall back to Pallas interpret mode automatically, so both impls are runnable
(slowly) in CPU CI — that is what the parity suite
``tests/test_dp_fill_pallas.py`` exercises.  ``set_interpret`` overrides the
automatic choice, matching the other kernel packages.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from ...core import dp_kernels
from ...core.dp_kernels import (
    COST_DTYPE,
    INFEASIBLE,
    BandedTable,
    _build_lm_band,
    _build_r_band,
    _fall_plane,
    _FillCtx,
    _INF32,
    _views,
)
from . import kernel

_INTERPRET: list = [None]


def set_interpret(flag: Optional[bool]) -> None:
    """``True`` forces interpret mode, ``False`` forces compiled dispatch,
    ``None`` restores the automatic choice (compiled on TPU, interpret
    elsewhere)."""
    _INTERPRET[0] = flag if flag is None else bool(flag)


def interpret_mode() -> bool:
    if _INTERPRET[0] is not None:
        return _INTERPRET[0]
    return jax.default_backend() != "tpu"


def fill_two_tier(dchain, S: int, allow_fall: bool = True,
                  v: Optional[dict] = None,
                  prune: Optional[bool] = None) -> BandedTable:
    """Two-tier band fill with the split reduction on the Pallas kernel.
    Band-exact against :func:`repro.core.dp_kernels.fill_two_tier` on
    f32-exact chains (same adds, same mins — IEEE min does not round)."""
    if v is None:
        v = _views(dchain)
    L = dchain.length
    ctx = _FillCtx(v, L, S)
    tab = BandedTable(L, S)
    ctx.base_case(tab)
    caps = (dp_kernels.saturation_caps(v, S, allow_fall)
            if dp_kernels._resolve_prune(prune) else None)
    interpret = interpret_mode()
    S1 = ctx.S1
    off = tab.off
    R = np.full((int(off[-1]), S1), INFEASIBLE, dtype=COST_DTYPE)
    Lm = np.empty((int(off[-1]), S1), dtype=COST_DTYPE)
    _build_r_band(ctx, R, tab, 0, clamp_tail=False)
    _build_lm_band(ctx, Lm, tab, 0)
    for d in range(1, L + 1):
        ns = L + 1 - d
        W = dp_kernels.band_width(caps, d, S)
        ma, mn = ctx.thresholds(d)
        # stack the d split planes for this band; the kernel min-reduces them
        rs = np.empty((d, ns, W), dtype=COST_DTYPE)
        ls = np.empty((d, ns, W), dtype=COST_DTYPE)
        for j in range(d):                  # split sp = s + 1 + j
            base = int(off[d - 1 - j]) + 1 + j
            rs[j] = R[base:base + ns, :W]
            ls[j] = Lm[off[j]:off[j] + ns, :W]
        resfull = tab.band(d)[:, 1:]
        res = resfull[:, :W]
        res[:] = np.asarray(
            kernel.band_min_two_tier(rs, ls, interpret=interpret))
        res[ctx.ms[None, :W] < mn[:, None]] = _INF32
        if allow_fall:
            c2 = np.empty((ns, W), dtype=COST_DTYPE)
            _fall_plane(ctx, tab, d, ns, ma, c2)
            np.minimum(res, c2, out=res)
        if W <= S:
            resfull[:, W:] = resfull[:, W - 1:W]   # saturated tail
        _build_r_band(ctx, R, tab, d, clamp_tail=False)
        _build_lm_band(ctx, Lm, tab, d)
    return tab


def fill_offload(dchain, S: int, allow_fall: bool = True,
                 v: Optional[dict] = None, prune: Optional[bool] = None
                 ) -> Tuple[BandedTable, BandedTable]:
    """Offload (three-tier) band fill on the Pallas kernel: the C3 stall is
    folded into the kernel's ``max(X, T_off)`` and all three accumulators
    ride one pass over the split planes."""
    if v is None:
        v = _views(dchain)
    L = dchain.length
    ctx = _FillCtx(v, L, S)
    tb, te = BandedTable(L, S), BandedTable(L, S)
    ctx.base_case(tb)
    ctx.base_case(te)
    caps = (dp_kernels.saturation_caps(v, S, allow_fall)
            if dp_kernels._resolve_prune(prune) else None)
    interpret = interpret_mode()
    host = dchain.chain.host
    host_on = host is not None and host.enabled
    tpre32 = dchain.chain.prefetch_times().astype(COST_DTYPE)
    S1, S2 = ctx.S1, ctx.S2
    flat_b = tb.data.reshape(-1)
    offb = tb.off
    slice_c3 = host_on and ctx.wa_uncapped
    ncells = int(offb[-1])
    R = np.full((ncells, S1 + (ctx.wcap if slice_c3 else 0)),
                INFEASIBLE, dtype=COST_DTYPE)
    Lmb = np.empty((ncells, S1), dtype=COST_DTYPE)
    Lme = np.empty((ncells, S1), dtype=COST_DTYPE)
    Lmb3 = np.empty((ncells, S1), dtype=COST_DTYPE) if host_on else None
    _build_r_band(ctx, R, tb, 0, clamp_tail=slice_c3)
    _build_lm_band(ctx, Lmb, tb, 0)
    _build_lm_band(ctx, Lme, te, 0)
    toffP = (dchain.chain.offload_times()
             + np.asarray(v["CUM_UF"][:L + 1])).astype(COST_DTYPE)

    def build_lmb3(d: int) -> None:
        ns_ = L + 1 - d
        lo = int(offb[d])
        np.add(Lmb[lo:lo + ns_], tpre32[:ns_, None], out=Lmb3[lo:lo + ns_])

    if host_on:
        build_lmb3(0)
    for d in range(1, L + 1):
        ns = L + 1 - d
        W = dp_kernels.band_width(caps, d, S)
        ma, mn = ctx.thresholds(d)
        rs = np.empty((d, ns, W), dtype=COST_DTYPE)
        lbs = np.empty((d, ns, W), dtype=COST_DTYPE)
        les = np.empty((d, ns, W), dtype=COST_DTYPE)
        if host_on:
            r3s = np.empty((d, ns, W), dtype=COST_DTYPE)
            lb3s = np.empty((d, ns, W), dtype=COST_DTYPE)
            wacol = ctx.WA[:ns].astype(np.int32)[:, None]
            par_groups = [(w, ps[:np.searchsorted(ps, ns)])
                          for w, ps in ctx.groups]
            ifi = np.empty((ns, W), dtype=np.int32)
        for j in range(d):                  # split sp = s + 1 + j
            base = int(offb[d - 1 - j]) + 1 + j
            lo = int(offb[j])
            rs[j] = R[base:base + ns, :W]
            lbs[j] = Lmb[lo:lo + ns, :W]
            les[j] = Lme[lo:lo + ns, :W]
            if not host_on:
                continue
            lb3s[j] = Lmb3[lo:lo + ns, :W]
            # C3 right plane: R read at the parent-side column offset
            # WA[s-1] (slots of the offloaded input reclaimed); the kernel
            # folds the stall max on top
            if slice_c3:
                Rblk = R[base:base + ns]
                for w0, rows in par_groups:
                    if len(rows):
                        r3s[j, rows] = Rblk[rows, w0:w0 + W]
            else:
                np.add(ctx.raw_wa[1 + j:1 + j + ns, :W], wacol, out=ifi)
                np.clip(ifi, -1, S, out=ifi)
                ifi += 1
                ifi += ctx.is2[:ns, None]
                np.take(flat_b[base * S2:], ifi, out=r3s[j])
                r3s[j] += ctx.CUM32[1 + j:1 + j + ns, None]
        resb_full = tb.band(d)[:, 1:]
        rese_full = te.band(d)[:, 1:]
        resb = resb_full[:, :W]
        rese = rese_full[:, :W]
        if host_on:
            ob, oe, o3 = kernel.band_min_offload(
                rs, r3s, lbs, les, lb3s, toffP[:ns, None],
                interpret=interpret)
            resb[:] = np.asarray(ob)
            rese[:] = np.asarray(oe)
            c3acc = np.array(o3)        # writable copy (the mask edits it)
        else:
            resb[:] = np.asarray(
                kernel.band_min_two_tier(rs, lbs, interpret=interpret))
            rese[:] = np.asarray(
                kernel.band_min_two_tier(rs, les, interpret=interpret))
            c3acc = None
        infeas = ctx.ms[None, :W] < mn[:, None]
        resb[infeas] = _INF32
        rese[infeas] = _INF32
        if allow_fall:
            c2 = np.empty((ns, W), dtype=COST_DTYPE)
            _fall_plane(ctx, te, d, ns, ma, c2)         # C2 child is embedded
            np.minimum(resb, c2, out=resb)
            np.minimum(rese, c2, out=rese)
        if host_on:
            c3acc[infeas] = _INF32
            np.minimum(resb, c3acc, out=resb)
        if W <= S:
            resb_full[:, W:] = resb_full[:, W - 1:W]   # saturated tail
            rese_full[:, W:] = rese_full[:, W - 1:W]
        _build_r_band(ctx, R, tb, d, clamp_tail=slice_c3)
        _build_lm_band(ctx, Lmb, tb, d)
        _build_lm_band(ctx, Lme, te, d)
        if host_on:
            build_lmb3(d)
    return tb, te


# ---------------------------------------------------------------------------
# Fused single-dispatch fills (impl="pallas_fused")
# ---------------------------------------------------------------------------

_ICLAMP = kernel._INT_CLAMP


class _FusedOperands:
    """Host-side staging for the fused kernels: the padded initial table,
    the band offsets, the clamped integer vectors, and the per-band
    thresholds — everything the recursion needs, computed before the single
    dispatch.

    Row padding: every in-kernel tile is a *static*-height dynamic slice, so
    the padded lanes of small bands read/write rows past the band.  Those
    rows always belong to later bands (or to this pad margin) and are
    rewritten by their own band's step before any read, so garbage there is
    harmless — the pad only has to keep the slices in bounds:
    ``2L + block_rows`` rows cover the deepest read
    (``off[d-1-j] + 1 + j + row_tiles·BR``).

    Width: ``W`` is the widest unsaturated band
    (:func:`repro.core.dp_kernels.band_width` at ``d = L`` — the caps are
    monotone), i.e. the ``O(cap_d)`` VMEM sizing bound.  Columns the banded
    fill would broadcast are computed directly in-kernel; by the saturation
    invariant the values are bit-identical, so the host-side unpack can
    broadcast the ``[W, S]`` tail from column ``W - 1``.
    """

    def __init__(self, ctx, caps, BR: int):
        L, S = ctx.L, ctx.S
        self.L, self.S = L, S
        self.W = dp_kernels.band_width(caps, L, S)
        sizes = np.array([L + 1 - d for d in range(L + 1)], dtype=np.int64)
        off = np.concatenate([[0], np.cumsum(sizes)])
        self.ncells = int(off[-1])
        self.nrows = self.ncells + 2 * L + BR
        self.off = off.astype(np.int32)
        vec = 2 * L + BR + 2

        def pad_to(a, n, fill=0):
            out = np.full(n, fill, dtype=a.dtype)
            out[: len(a)] = a
            return out

        self.wa = pad_to(np.clip(ctx.WA, 0, _ICLAMP).astype(np.int32), vec)
        self.wb = pad_to(np.clip(ctx.WB, 0, _ICLAMP).astype(np.int32), vec)
        self.cum = pad_to(ctx.CUM32, vec)
        self.uf = pad_to(ctx.UF32, vec)
        self.ub = pad_to(ctx.UB32, vec)
        rt = -(-max(L, 1) // BR)
        self.mn = np.zeros((max(L, 1), rt * BR), dtype=np.int32)
        self.ma = np.zeros((max(L, 1), rt * BR), dtype=np.int32)
        for d in range(1, L + 1):
            ma_d, mn_d = ctx.thresholds(d)
            ns = L + 1 - d
            self.mn[d - 1, :ns] = np.clip(mn_d, 0, _ICLAMP)
            self.ma[d - 1, :ns] = np.clip(ma_d, 0, _ICLAMP)
        self.vec = vec

    def initial_table(self, tab: BandedTable) -> np.ndarray:
        t0 = np.full((self.nrows, self.W), INFEASIBLE, dtype=COST_DTYPE)
        t0[: self.ncells] = tab.data[:, 1 : 1 + self.W]
        return t0

    def unpack(self, dev, tab: BandedTable) -> BandedTable:
        W, S = self.W, self.S
        tab.data[:, 1 : 1 + W] = np.asarray(dev)[: self.ncells]
        if W <= S:
            tab.data[:, 1 + W :] = tab.data[:, W : W + 1]  # saturated tail
        return tab


def _resolve_block_rows(block_rows, L: int, S: int, interpret: bool) -> int:
    if block_rows is not None:
        return int(block_rows)
    from . import autotune
    return autotune.resolve_block_rows(L, S, interpret=interpret)


def fill_two_tier_fused(dchain, S: int, allow_fall: bool = True,
                        v: Optional[dict] = None, prune: Optional[bool] = None,
                        block_rows: Optional[int] = None) -> BandedTable:
    """Two-tier band fill in ONE device dispatch: the entire band recursion
    (split reduction, thresholds, C2 fall plane, companion rebuild) runs
    inside a single ``pallas_call`` — no per-band host loop.  Band-exact
    against :func:`repro.core.dp_kernels.fill_two_tier` on f32-exact
    chains."""
    if v is None:
        v = _views(dchain)
    L = dchain.length
    ctx = _FillCtx(v, L, S)
    tab = BandedTable(L, S)
    ctx.base_case(tab)
    if L == 0:
        return tab
    caps = (dp_kernels.saturation_caps(v, S, allow_fall)
            if dp_kernels._resolve_prune(prune) else None)
    interpret = interpret_mode()
    BR = max(1, min(_resolve_block_rows(block_rows, L, S, interpret), L))
    ops_ = _FusedOperands(ctx, caps, BR)
    dev = kernel.fused_fill_two_tier(
        ops_.initial_table(tab), ops_.off, ops_.wa, ops_.wb, ops_.cum,
        ops_.uf, ops_.ub, ops_.mn, ops_.ma, L=L, W=ops_.W, block_rows=BR,
        allow_fall=allow_fall, interpret=interpret)
    return ops_.unpack(dev, tab)


def fill_offload_fused(dchain, S: int, allow_fall: bool = True,
                       v: Optional[dict] = None, prune: Optional[bool] = None,
                       block_rows: Optional[int] = None
                       ) -> Tuple[BandedTable, BandedTable]:
    """Offload (three-tier) band fill in ONE device dispatch: both cost
    tables and all four companion buffers stay device-resident across the
    whole recursion, the C3 stall folded to ``max(X, T_off)`` in-kernel."""
    if v is None:
        v = _views(dchain)
    L = dchain.length
    ctx = _FillCtx(v, L, S)
    tb, te = BandedTable(L, S), BandedTable(L, S)
    ctx.base_case(tb)
    ctx.base_case(te)
    if L == 0:
        return tb, te
    caps = (dp_kernels.saturation_caps(v, S, allow_fall)
            if dp_kernels._resolve_prune(prune) else None)
    interpret = interpret_mode()
    BR = max(1, min(_resolve_block_rows(block_rows, L, S, interpret), L))
    ops_ = _FusedOperands(ctx, caps, BR)
    host = dchain.chain.host
    host_on = host is not None and host.enabled
    if host_on:
        toff = (dchain.chain.offload_times()
                + np.asarray(v["CUM_UF"][:L + 1])).astype(COST_DTYPE)
        tpre = dchain.chain.prefetch_times().astype(COST_DTYPE)
    else:
        toff = np.zeros(L + 1, dtype=COST_DTYPE)
        tpre = np.zeros(L + 1, dtype=COST_DTYPE)
    pad = np.zeros(ops_.vec, dtype=COST_DTYPE)
    toff_p, tpre_p = pad.copy(), pad.copy()
    toff_p[: L + 1], tpre_p[: L + 1] = toff, tpre
    devb, deve = kernel.fused_fill_offload(
        ops_.initial_table(tb), ops_.initial_table(te), ops_.off, ops_.wa,
        ops_.wb, ops_.cum, ops_.uf, ops_.ub, ops_.mn, ops_.ma, toff_p,
        tpre_p, L=L, W=ops_.W, block_rows=BR, allow_fall=allow_fall,
        host_on=host_on, interpret=interpret)
    ops_.unpack(devb, tb)
    ops_.unpack(deve, te)
    return tb, te
