"""Band-fill drivers for ``impl="pallas"`` — the solver-side dispatch seam.

These mirror the numpy banded fills of :mod:`repro.core.dp_kernels` exactly
(same companion tables, same thresholds, same saturated m-column pruning,
same C2 fall plane) but hand the per-band split reduction — the DP's
O(L·band) hot loop — to the Pallas kernels in :mod:`.kernel`.  The band
recursion itself stays on the host: companion tables are republished after
each band, one kernel launch per length.

Dispatch seam: on a TPU backend the kernels run jitted; everywhere else they
fall back to Pallas interpret mode automatically, so ``impl="pallas"`` is
runnable (slowly) in CPU CI — that is what the parity suite
``tests/test_dp_fill_pallas.py`` exercises.  ``set_interpret`` overrides the
automatic choice, matching the other kernel packages.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from ...core import dp_kernels
from ...core.dp_kernels import (
    COST_DTYPE,
    INFEASIBLE,
    BandedTable,
    _build_lm_band,
    _build_r_band,
    _fall_plane,
    _FillCtx,
    _INF32,
    _views,
)
from . import kernel

_INTERPRET: list = [None]


def set_interpret(flag: Optional[bool]) -> None:
    """``True`` forces interpret mode, ``False`` forces compiled dispatch,
    ``None`` restores the automatic choice (compiled on TPU, interpret
    elsewhere)."""
    _INTERPRET[0] = flag if flag is None else bool(flag)


def interpret_mode() -> bool:
    if _INTERPRET[0] is not None:
        return _INTERPRET[0]
    return jax.default_backend() != "tpu"


def fill_two_tier(dchain, S: int, allow_fall: bool = True,
                  v: Optional[dict] = None,
                  prune: Optional[bool] = None) -> BandedTable:
    """Two-tier band fill with the split reduction on the Pallas kernel.
    Band-exact against :func:`repro.core.dp_kernels.fill_two_tier` on
    f32-exact chains (same adds, same mins — IEEE min does not round)."""
    if v is None:
        v = _views(dchain)
    L = dchain.length
    ctx = _FillCtx(v, L, S)
    tab = BandedTable(L, S)
    ctx.base_case(tab)
    caps = (dp_kernels.saturation_caps(v, S, allow_fall)
            if dp_kernels._resolve_prune(prune) else None)
    interpret = interpret_mode()
    S1 = ctx.S1
    off = tab.off
    R = np.full((int(off[-1]), S1), INFEASIBLE, dtype=COST_DTYPE)
    Lm = np.empty((int(off[-1]), S1), dtype=COST_DTYPE)
    _build_r_band(ctx, R, tab, 0, clamp_tail=False)
    _build_lm_band(ctx, Lm, tab, 0)
    for d in range(1, L + 1):
        ns = L + 1 - d
        W = dp_kernels.band_width(caps, d, S)
        ma, mn = ctx.thresholds(d)
        # stack the d split planes for this band; the kernel min-reduces them
        rs = np.empty((d, ns, W), dtype=COST_DTYPE)
        ls = np.empty((d, ns, W), dtype=COST_DTYPE)
        for j in range(d):                  # split sp = s + 1 + j
            base = int(off[d - 1 - j]) + 1 + j
            rs[j] = R[base:base + ns, :W]
            ls[j] = Lm[off[j]:off[j] + ns, :W]
        resfull = tab.band(d)[:, 1:]
        res = resfull[:, :W]
        res[:] = np.asarray(
            kernel.band_min_two_tier(rs, ls, interpret=interpret))
        res[ctx.ms[None, :W] < mn[:, None]] = _INF32
        if allow_fall:
            c2 = np.empty((ns, W), dtype=COST_DTYPE)
            _fall_plane(ctx, tab, d, ns, ma, c2)
            np.minimum(res, c2, out=res)
        if W <= S:
            resfull[:, W:] = resfull[:, W - 1:W]   # saturated tail
        _build_r_band(ctx, R, tab, d, clamp_tail=False)
        _build_lm_band(ctx, Lm, tab, d)
    return tab


def fill_offload(dchain, S: int, allow_fall: bool = True,
                 v: Optional[dict] = None, prune: Optional[bool] = None
                 ) -> Tuple[BandedTable, BandedTable]:
    """Offload (three-tier) band fill on the Pallas kernel: the C3 stall is
    folded into the kernel's ``max(X, T_off)`` and all three accumulators
    ride one pass over the split planes."""
    if v is None:
        v = _views(dchain)
    L = dchain.length
    ctx = _FillCtx(v, L, S)
    tb, te = BandedTable(L, S), BandedTable(L, S)
    ctx.base_case(tb)
    ctx.base_case(te)
    caps = (dp_kernels.saturation_caps(v, S, allow_fall)
            if dp_kernels._resolve_prune(prune) else None)
    interpret = interpret_mode()
    host = dchain.chain.host
    host_on = host is not None and host.enabled
    tpre32 = dchain.chain.prefetch_times().astype(COST_DTYPE)
    S1, S2 = ctx.S1, ctx.S2
    flat_b = tb.data.reshape(-1)
    offb = tb.off
    slice_c3 = host_on and ctx.wa_uncapped
    ncells = int(offb[-1])
    R = np.full((ncells, S1 + (ctx.wcap if slice_c3 else 0)),
                INFEASIBLE, dtype=COST_DTYPE)
    Lmb = np.empty((ncells, S1), dtype=COST_DTYPE)
    Lme = np.empty((ncells, S1), dtype=COST_DTYPE)
    Lmb3 = np.empty((ncells, S1), dtype=COST_DTYPE) if host_on else None
    _build_r_band(ctx, R, tb, 0, clamp_tail=slice_c3)
    _build_lm_band(ctx, Lmb, tb, 0)
    _build_lm_band(ctx, Lme, te, 0)
    toffP = (dchain.chain.offload_times()
             + np.asarray(v["CUM_UF"][:L + 1])).astype(COST_DTYPE)

    def build_lmb3(d: int) -> None:
        ns_ = L + 1 - d
        lo = int(offb[d])
        np.add(Lmb[lo:lo + ns_], tpre32[:ns_, None], out=Lmb3[lo:lo + ns_])

    if host_on:
        build_lmb3(0)
    for d in range(1, L + 1):
        ns = L + 1 - d
        W = dp_kernels.band_width(caps, d, S)
        ma, mn = ctx.thresholds(d)
        rs = np.empty((d, ns, W), dtype=COST_DTYPE)
        lbs = np.empty((d, ns, W), dtype=COST_DTYPE)
        les = np.empty((d, ns, W), dtype=COST_DTYPE)
        if host_on:
            r3s = np.empty((d, ns, W), dtype=COST_DTYPE)
            lb3s = np.empty((d, ns, W), dtype=COST_DTYPE)
            wacol = ctx.WA[:ns].astype(np.int32)[:, None]
            par_groups = [(w, ps[:np.searchsorted(ps, ns)])
                          for w, ps in ctx.groups]
            ifi = np.empty((ns, W), dtype=np.int32)
        for j in range(d):                  # split sp = s + 1 + j
            base = int(offb[d - 1 - j]) + 1 + j
            lo = int(offb[j])
            rs[j] = R[base:base + ns, :W]
            lbs[j] = Lmb[lo:lo + ns, :W]
            les[j] = Lme[lo:lo + ns, :W]
            if not host_on:
                continue
            lb3s[j] = Lmb3[lo:lo + ns, :W]
            # C3 right plane: R read at the parent-side column offset
            # WA[s-1] (slots of the offloaded input reclaimed); the kernel
            # folds the stall max on top
            if slice_c3:
                Rblk = R[base:base + ns]
                for w0, rows in par_groups:
                    if len(rows):
                        r3s[j, rows] = Rblk[rows, w0:w0 + W]
            else:
                np.add(ctx.raw_wa[1 + j:1 + j + ns, :W], wacol, out=ifi)
                np.clip(ifi, -1, S, out=ifi)
                ifi += 1
                ifi += ctx.is2[:ns, None]
                np.take(flat_b[base * S2:], ifi, out=r3s[j])
                r3s[j] += ctx.CUM32[1 + j:1 + j + ns, None]
        resb_full = tb.band(d)[:, 1:]
        rese_full = te.band(d)[:, 1:]
        resb = resb_full[:, :W]
        rese = rese_full[:, :W]
        if host_on:
            ob, oe, o3 = kernel.band_min_offload(
                rs, r3s, lbs, les, lb3s, toffP[:ns, None],
                interpret=interpret)
            resb[:] = np.asarray(ob)
            rese[:] = np.asarray(oe)
            c3acc = np.array(o3)        # writable copy (the mask edits it)
        else:
            resb[:] = np.asarray(
                kernel.band_min_two_tier(rs, lbs, interpret=interpret))
            rese[:] = np.asarray(
                kernel.band_min_two_tier(rs, les, interpret=interpret))
            c3acc = None
        infeas = ctx.ms[None, :W] < mn[:, None]
        resb[infeas] = _INF32
        rese[infeas] = _INF32
        if allow_fall:
            c2 = np.empty((ns, W), dtype=COST_DTYPE)
            _fall_plane(ctx, te, d, ns, ma, c2)         # C2 child is embedded
            np.minimum(resb, c2, out=resb)
            np.minimum(rese, c2, out=rese)
        if host_on:
            c3acc[infeas] = _INF32
            np.minimum(resb, c3acc, out=resb)
        if W <= S:
            resb_full[:, W:] = resb_full[:, W - 1:W]   # saturated tail
            rese_full[:, W:] = rese_full[:, W - 1:W]
        _build_r_band(ctx, R, tb, d, clamp_tail=slice_c3)
        _build_lm_band(ctx, Lmb, tb, d)
        _build_lm_band(ctx, Lme, te, d)
        if host_on:
            build_lmb3(d)
    return tb, te
