"""Length-banded DP band fill — Pallas TPU kernel.

The checkpointing DP's hot path is, per sub-chain length ``d``, a min
reduction over ``d`` split candidates, where the candidate of split offset
``j`` is one elementwise add of two pre-shifted companion-table planes (see
:mod:`repro.core.dp_kernels`):

    cand_j = R[band d-1-j, rows j+1..j+ns] + Lm[band j, rows 1..ns]

The kernel runs that reduction on a grid of ``(row_tiles, d)`` with the
split dimension innermost: each grid step streams one split's
``(block_rows, W)`` companion tiles into VMEM, adds them on the VPU, and
min-accumulates into the output tile (initialized at ``j == 0`` — the
standard revisited-output accumulation pattern; TPU grids iterate the last
dimension sequentially, so the running minimum is race-free).  The offload
variant carries three accumulators (input-bare C1, input-embedded C1, and
the C3 offload plane whose PCIe stall is pre-folded into a
``max(X, T_off)``) so the three-tier fill costs one extra pass over the same
tiles rather than three kernels.

Exactness: every operation is an f32 add / min / max of the same operand
pairs the numpy banded fill uses, and IEEE min/max do not round — on chains
whose quantities are exactly representable in f32 the result is bit-equal to
``impl="banded"`` in any evaluation order (asserted by
``tests/test_dp_fill_pallas.py``).

The driver in :mod:`.ops` stages one band per call; companion tables are
rebuilt on the host between bands (the recursion is sequential in ``d``).
Keeping the whole band loop device-resident is the natural next step once
the dispatch seam (this module) is proven.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Rows per VMEM tile.  At the default S=500 discretization a (256, 501) f32
#: tile is ~0.5 MB; with two inputs and one output per step (five inputs and
#: three outputs for the offload variant) the working set stays well under
#: the ~16 MB VMEM budget.
DEFAULT_BLOCK_ROWS = 256


def _pad_rows(x: jnp.ndarray, rows: int, value: float) -> jnp.ndarray:
    pad = rows - x.shape[1]
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, pad), (0, 0)), constant_values=value)


def _band_min_kernel(r_ref, lm_ref, o_ref):
    j = pl.program_id(1)
    cand = r_ref[0] + lm_ref[0]

    @pl.when(j == 0)
    def _():
        o_ref[...] = cand

    @pl.when(j != 0)
    def _():
        o_ref[...] = jnp.minimum(o_ref[...], cand)


def band_min_two_tier(
    r: jax.Array,
    lm: jax.Array,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Two-tier C1 reduction: ``min_j (r[j] + lm[j])``.

    ``r``/``lm``: ``(d, ns, W)`` stacked per-split companion planes (``r``
    pre-shifted by the split's memory cost, ``+inf`` where out of budget).
    Returns the ``(ns, W)`` running minimum.
    """
    d, ns, w = r.shape
    block_rows = min(block_rows, ns)
    ns_pad = pl.cdiv(ns, block_rows) * block_rows
    r = _pad_rows(r, ns_pad, jnp.inf)
    lm = _pad_rows(lm, ns_pad, 0.0)
    grid = (ns_pad // block_rows, d)
    out = pl.pallas_call(
        _band_min_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_rows, w), lambda i, j: (j, i, 0)),
            pl.BlockSpec((1, block_rows, w), lambda i, j: (j, i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, w), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ns_pad, w), r.dtype),
        interpret=interpret,
    )(r, lm)
    return out[:ns]


def _band_min_offload_kernel(
    r_ref, r3_ref, lmb_ref, lme_ref, lmb3_ref, toff_ref, ob_ref, oe_ref, o3_ref
):
    j = pl.program_id(1)
    r = r_ref[0]
    cb = r + lmb_ref[0]
    ce = r + lme_ref[0]
    # C3: X + max(T_off - X, 0) = max(X, T_off); the prefetch charge is
    # pre-added to the left-child companion lmb3
    c3 = jnp.maximum(r3_ref[0], toff_ref[...]) + lmb3_ref[0]

    @pl.when(j == 0)
    def _():
        ob_ref[...] = cb
        oe_ref[...] = ce
        o3_ref[...] = c3

    @pl.when(j != 0)
    def _():
        ob_ref[...] = jnp.minimum(ob_ref[...], cb)
        oe_ref[...] = jnp.minimum(oe_ref[...], ce)
        o3_ref[...] = jnp.minimum(o3_ref[...], c3)


def band_min_offload(
    r: jax.Array,
    r3: jax.Array,
    lmb: jax.Array,
    lme: jax.Array,
    lmb3: jax.Array,
    toff: jax.Array,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Offload-band reduction: three accumulators over the same split loop.

    ``r``: shared pre-shifted right-child planes (C1, both input states);
    ``r3``: the C3 right-child planes read at the parent-side column offset
    (hidden work ``X`` in the CUM-shifted domain); ``lmb``/``lme``/``lmb3``:
    left-child companions (bare / embedded / bare-with-prefetch-charge);
    ``toff``: ``(ns, 1)`` CUM-shifted offload times.  Returns
    ``(min C1_bare, min C1_embedded, min C3)``, each ``(ns, W)``.
    """
    d, ns, w = r.shape
    block_rows = min(block_rows, ns)
    ns_pad = pl.cdiv(ns, block_rows) * block_rows
    r = _pad_rows(r, ns_pad, jnp.inf)
    r3 = _pad_rows(r3, ns_pad, jnp.inf)
    lmb = _pad_rows(lmb, ns_pad, 0.0)
    lme = _pad_rows(lme, ns_pad, 0.0)
    lmb3 = _pad_rows(lmb3, ns_pad, 0.0)
    pad = ns_pad - toff.shape[0]
    if pad:
        toff = jnp.pad(toff, ((0, pad), (0, 0)))
    grid = (ns_pad // block_rows, d)
    plane = pl.BlockSpec((1, block_rows, w), lambda i, j: (j, i, 0))
    out = pl.BlockSpec((block_rows, w), lambda i, j: (i, 0))
    shape = jax.ShapeDtypeStruct((ns_pad, w), r.dtype)
    ob, oe, o3 = pl.pallas_call(
        _band_min_offload_kernel,
        grid=grid,
        in_specs=[
            plane,
            plane,
            plane,
            plane,
            plane,
            pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[out, out, out],
        out_shape=[shape, shape, shape],
        interpret=interpret,
    )(r, r3, lmb, lme, lmb3, toff)
    return ob[:ns], oe[:ns], o3[:ns]
