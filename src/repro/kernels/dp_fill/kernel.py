"""Length-banded DP band fill — Pallas TPU kernel.

The checkpointing DP's hot path is, per sub-chain length ``d``, a min
reduction over ``d`` split candidates, where the candidate of split offset
``j`` is one elementwise add of two pre-shifted companion-table planes (see
:mod:`repro.core.dp_kernels`):

    cand_j = R[band d-1-j, rows j+1..j+ns] + Lm[band j, rows 1..ns]

The kernel runs that reduction on a grid of ``(row_tiles, d)`` with the
split dimension innermost: each grid step streams one split's
``(block_rows, W)`` companion tiles into VMEM, adds them on the VPU, and
min-accumulates into the output tile (initialized at ``j == 0`` — the
standard revisited-output accumulation pattern; TPU grids iterate the last
dimension sequentially, so the running minimum is race-free).  The offload
variant carries three accumulators (input-bare C1, input-embedded C1, and
the C3 offload plane whose PCIe stall is pre-folded into a
``max(X, T_off)``) so the three-tier fill costs one extra pass over the same
tiles rather than three kernels.

Exactness: every operation is an f32 add / min / max of the same operand
pairs the numpy banded fill uses, and IEEE min/max do not round — on chains
whose quantities are exactly representable in f32 the result is bit-equal to
``impl="banded"`` in any evaluation order (asserted by
``tests/test_dp_fill_pallas.py``).

Two kernel families live here:

- the **per-band** kernels (``band_min_two_tier`` / ``band_min_offload``,
  ``impl="pallas"``): the driver in :mod:`.ops` stages one band per call and
  rebuilds companion tables on the host between bands — O(L) dispatches and
  host↔device round-trips per fill;
- the **fused** kernels (``fused_fill_two_tier`` / ``fused_fill_offload``,
  ``impl="pallas_fused"``): ONE ``pallas_call`` runs the entire band
  recursion device-side on a ``(L, row_tiles)`` grid (both dimensions iterate
  sequentially on TPU, ``row_tiles`` innermost).  The cost table(s) and the
  companion tables ``R``/``Lm`` are revisited whole-array output blocks that
  persist across grid steps; at each band's first row tile the companions of
  the just-written band are rebuilt *in-kernel* (per-row shift via a
  clamped ``take_along_axis`` gather plus the ``CUM32`` bake-in), so the host
  never re-publishes anything mid-fill.  Buffers are sized by the
  ``O(cap_d)`` saturation bound of
  :func:`repro.core.dp_kernels.saturation_caps` — the column width is the
  widest unsaturated band, not ``S + 1`` — and the saturated tail is
  broadcast once on the host after the single dispatch.  ``block_rows``
  picks the row-tile height (see :mod:`.autotune`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COST_DT = jnp.float32

#: Rows per VMEM tile.  At the default S=500 discretization a (256, 501) f32
#: tile is ~0.5 MB; with two inputs and one output per step (five inputs and
#: three outputs for the offload variant) the working set stays well under
#: the ~16 MB VMEM budget.
DEFAULT_BLOCK_ROWS = 256


def _pad_rows(x: jnp.ndarray, rows: int, value: float) -> jnp.ndarray:
    pad = rows - x.shape[1]
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, pad), (0, 0)), constant_values=value)


def _band_min_kernel(r_ref, lm_ref, o_ref):
    j = pl.program_id(1)
    cand = r_ref[0] + lm_ref[0]

    @pl.when(j == 0)
    def _():
        o_ref[...] = cand

    @pl.when(j != 0)
    def _():
        o_ref[...] = jnp.minimum(o_ref[...], cand)


def band_min_two_tier(
    r: jax.Array,
    lm: jax.Array,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Two-tier C1 reduction: ``min_j (r[j] + lm[j])``.

    ``r``/``lm``: ``(d, ns, W)`` stacked per-split companion planes (``r``
    pre-shifted by the split's memory cost, ``+inf`` where out of budget).
    Returns the ``(ns, W)`` running minimum.
    """
    d, ns, w = r.shape
    block_rows = min(block_rows, ns)
    ns_pad = pl.cdiv(ns, block_rows) * block_rows
    r = _pad_rows(r, ns_pad, jnp.inf)
    lm = _pad_rows(lm, ns_pad, 0.0)
    grid = (ns_pad // block_rows, d)
    out = pl.pallas_call(
        _band_min_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_rows, w), lambda i, j: (j, i, 0)),
            pl.BlockSpec((1, block_rows, w), lambda i, j: (j, i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, w), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ns_pad, w), r.dtype),
        interpret=interpret,
    )(r, lm)
    return out[:ns]


def _band_min_offload_kernel(
    r_ref, r3_ref, lmb_ref, lme_ref, lmb3_ref, toff_ref, ob_ref, oe_ref, o3_ref
):
    j = pl.program_id(1)
    r = r_ref[0]
    cb = r + lmb_ref[0]
    ce = r + lme_ref[0]
    # C3: X + max(T_off - X, 0) = max(X, T_off); the prefetch charge is
    # pre-added to the left-child companion lmb3
    c3 = jnp.maximum(r3_ref[0], toff_ref[...]) + lmb3_ref[0]

    @pl.when(j == 0)
    def _():
        ob_ref[...] = cb
        oe_ref[...] = ce
        o3_ref[...] = c3

    @pl.when(j != 0)
    def _():
        ob_ref[...] = jnp.minimum(ob_ref[...], cb)
        oe_ref[...] = jnp.minimum(oe_ref[...], ce)
        o3_ref[...] = jnp.minimum(o3_ref[...], c3)


def band_min_offload(
    r: jax.Array,
    r3: jax.Array,
    lmb: jax.Array,
    lme: jax.Array,
    lmb3: jax.Array,
    toff: jax.Array,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Offload-band reduction: three accumulators over the same split loop.

    ``r``: shared pre-shifted right-child planes (C1, both input states);
    ``r3``: the C3 right-child planes read at the parent-side column offset
    (hidden work ``X`` in the CUM-shifted domain); ``lmb``/``lme``/``lmb3``:
    left-child companions (bare / embedded / bare-with-prefetch-charge);
    ``toff``: ``(ns, 1)`` CUM-shifted offload times.  Returns
    ``(min C1_bare, min C1_embedded, min C3)``, each ``(ns, W)``.
    """
    d, ns, w = r.shape
    block_rows = min(block_rows, ns)
    ns_pad = pl.cdiv(ns, block_rows) * block_rows
    r = _pad_rows(r, ns_pad, jnp.inf)
    r3 = _pad_rows(r3, ns_pad, jnp.inf)
    lmb = _pad_rows(lmb, ns_pad, 0.0)
    lme = _pad_rows(lme, ns_pad, 0.0)
    lmb3 = _pad_rows(lmb3, ns_pad, 0.0)
    pad = ns_pad - toff.shape[0]
    if pad:
        toff = jnp.pad(toff, ((0, pad), (0, 0)))
    grid = (ns_pad // block_rows, d)
    plane = pl.BlockSpec((1, block_rows, w), lambda i, j: (j, i, 0))
    out = pl.BlockSpec((block_rows, w), lambda i, j: (i, 0))
    shape = jax.ShapeDtypeStruct((ns_pad, w), r.dtype)
    ob, oe, o3 = pl.pallas_call(
        _band_min_offload_kernel,
        grid=grid,
        in_specs=[
            plane,
            plane,
            plane,
            plane,
            plane,
            pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[out, out, out],
        out_shape=[shape, shape, shape],
        interpret=interpret,
    )(r, r3, lmb, lme, lmb3, toff)
    return ob[:ns], oe[:ns], o3[:ns]


# ---------------------------------------------------------------------------
# Fused device-resident fill (impl="pallas_fused") — one pallas_call per fill
# ---------------------------------------------------------------------------

_INT_CLAMP = 1 << 30  # matches _FillCtx.raw_wa's int32-overflow clamp


def _whole(x: jnp.ndarray) -> pl.BlockSpec:
    """Whole-array block revisited at every grid step (index_map constant) —
    the buffer persists across the sequential band recursion."""
    nd = x.ndim if hasattr(x, "ndim") else len(x.shape)
    return pl.BlockSpec(tuple(x.shape), lambda d, i, _n=nd: (0,) * _n)


def _shifted_gather(blk, idx, w):
    """``out[r, c] = blk[r, idx[r, c]]`` with ``idx < 0`` reading ``+inf``
    (the sentinel semantics) and reads beyond the buffer clamping to the
    last stored column (equal to column ``S`` by the saturation invariant)."""
    g = jnp.take_along_axis(blk, jnp.clip(idx, 0, w - 1), axis=1)
    return jnp.where(idx < 0, jnp.float32(jnp.inf), g)


def _fused_two_tier_kernel(
    t0_ref,
    off_ref,
    wa_ref,
    wb_ref,
    cum_ref,
    uf_ref,
    ub_ref,
    mn_ref,
    ma_ref,
    t_ref,
    r_ref,
    lm_ref,
    *,
    L,
    W,
    BR,
    allow_fall,
):
    d = pl.program_id(0) + 1
    i = pl.program_id(1)
    r0 = i * BR
    ns = L + 1 - d
    NS0 = L + 1
    inf = jnp.float32(jnp.inf)

    @pl.when((d == 1) & (i == 0))
    def _init():
        t_ref[...] = t0_ref[...]

    @pl.when(i == 0)
    def _rebuild():
        # companions of the just-written band d-1 (rows beyond that band are
        # overwritten with garbage here, and rewritten by their own band's
        # rebuild before any read — see the ops driver for the argument)
        start = off_ref[d - 1]
        blk = t_ref[pl.ds(start, NS0), :]
        cum = cum_ref[pl.ds(0, NS0)][:, None]
        cols = jax.lax.broadcasted_iota(jnp.int32, (NS0, W), 1)
        idx = cols - wa_ref[pl.ds(0, NS0)][:, None]
        r_ref[pl.ds(start, NS0), :] = _shifted_gather(blk, idx, W) + cum
        lm_ref[pl.ds(start, NS0), :] = blk - cum

    @pl.when(r0 < ns)
    def _compute():
        cols = jax.lax.broadcasted_iota(jnp.int32, (BR, W), 1)

        def split(j, acc):
            # split sp = s + 1 + j: right child rows of band d-1-j, left
            # child rows of band j — both plain pre-shifted companion reads
            rrow = off_ref[d - 1 - j] + 1 + j + r0
            cand = r_ref[pl.ds(rrow, BR), :] + lm_ref[pl.ds(off_ref[j] + r0, BR), :]
            return jnp.minimum(acc, cand)

        acc = jax.lax.fori_loop(0, d, split, jnp.full((BR, W), inf, COST_DT))
        mn = pl.load(mn_ref, (pl.ds(d - 1, 1), pl.ds(r0, BR)))[0][:, None]
        res = jnp.where(cols < mn, inf, acc)
        if allow_fall:
            # C2: u_f^s + C[s+1, t][m - wabar^s] + u_b^s, masked by m_all
            blk = t_ref[pl.ds(off_ref[d - 1] + 1 + r0, BR), :]
            idx = cols - wb_ref[pl.ds(1 + r0, BR)][:, None]
            uf = uf_ref[pl.ds(1 + r0, BR)][:, None]
            ub = ub_ref[pl.ds(1 + r0, BR)][:, None]
            c2 = (_shifted_gather(blk, idx, W) + uf) + ub
            ma = pl.load(ma_ref, (pl.ds(d - 1, 1), pl.ds(r0, BR)))[0][:, None]
            res = jnp.minimum(res, jnp.where(cols < ma, inf, c2))
        t_ref[pl.ds(off_ref[d] + r0, BR), :] = res


def fused_fill_two_tier(
    t0,
    off,
    wa,
    wb,
    cum,
    uf,
    ub,
    mn,
    ma,
    *,
    L,
    W,
    block_rows,
    allow_fall,
    interpret=False,
):
    """Single-dispatch two-tier band fill.

    ``t0``: ``(nrows, W)`` initial table — the base-case band at rows
    ``off[0]..off[1])``, ``+inf`` elsewhere (``nrows`` is padded past the
    cell count so every dynamically-sliced tile stays in bounds; see the
    ops driver).  Integer operands are pre-clamped int32 (the caller mirrors
    ``_FillCtx``'s ``1 << 30`` overflow clamp).  Returns the filled table;
    the ``R``/``Lm`` companion buffers are device scratch published as
    outputs only because revisited output blocks are the one Pallas buffer
    kind guaranteed to persist across grid steps.
    """
    NSMAX = max(L, 1)
    BR = max(1, min(block_rows, NSMAX))
    grid = (L, pl.cdiv(NSMAX, BR))
    shape = jax.ShapeDtypeStruct(t0.shape, t0.dtype)
    kernel_fn = functools.partial(
        _fused_two_tier_kernel, L=L, W=W, BR=BR, allow_fall=allow_fall
    )
    t, _, _ = pl.pallas_call(
        kernel_fn,
        grid=grid,
        in_specs=[_whole(x) for x in (t0, off, wa, wb, cum, uf, ub, mn, ma)],
        out_specs=[_whole(t0)] * 3,
        out_shape=[shape, shape, shape],
        interpret=interpret,
    )(t0, off, wa, wb, cum, uf, ub, mn, ma)
    return t


def _fused_offload_kernel(
    t0b_ref,
    t0e_ref,
    off_ref,
    wa_ref,
    wb_ref,
    cum_ref,
    uf_ref,
    ub_ref,
    mn_ref,
    ma_ref,
    toff_ref,
    tpre_ref,
    tb_ref,
    te_ref,
    r_ref,
    lmb_ref,
    lme_ref,
    lmb3_ref,
    *,
    L,
    W,
    BR,
    allow_fall,
    host_on,
):
    d = pl.program_id(0) + 1
    i = pl.program_id(1)
    r0 = i * BR
    ns = L + 1 - d
    NS0 = L + 1
    inf = jnp.float32(jnp.inf)

    @pl.when((d == 1) & (i == 0))
    def _init():
        tb_ref[...] = t0b_ref[...]
        te_ref[...] = t0e_ref[...]

    @pl.when(i == 0)
    def _rebuild():
        start = off_ref[d - 1]
        blkb = tb_ref[pl.ds(start, NS0), :]
        blke = te_ref[pl.ds(start, NS0), :]
        cum = cum_ref[pl.ds(0, NS0)][:, None]
        cols = jax.lax.broadcasted_iota(jnp.int32, (NS0, W), 1)
        idx = cols - wa_ref[pl.ds(0, NS0)][:, None]
        r_ref[pl.ds(start, NS0), :] = _shifted_gather(blkb, idx, W) + cum
        lmb = blkb - cum
        lmb_ref[pl.ds(start, NS0), :] = lmb
        lme_ref[pl.ds(start, NS0), :] = blke - cum
        if host_on:
            # C3 left companion with the prefetch charge pre-added
            lmb3_ref[pl.ds(start, NS0), :] = lmb + tpre_ref[pl.ds(0, NS0)][:, None]

    @pl.when(r0 < ns)
    def _compute():
        cols = jax.lax.broadcasted_iota(jnp.int32, (BR, W), 1)
        wa_s = wa_ref[pl.ds(r0, BR)][:, None]  # WA[s-1], s = r0+rr+1
        toff = toff_ref[pl.ds(r0, BR)][:, None]

        def split(j, accs):
            accb, acce, acc3 = accs
            rrow = off_ref[d - 1 - j] + 1 + j + r0
            lrow = off_ref[j] + r0
            r = r_ref[pl.ds(rrow, BR), :]
            accb = jnp.minimum(accb, r + lmb_ref[pl.ds(lrow, BR), :])
            acce = jnp.minimum(acce, r + lme_ref[pl.ds(lrow, BR), :])
            if host_on:
                # C3 right segment: the offloaded input's slots are
                # reclaimed, so the shift is WA[sp-1] - WA[s-1]; the clamp
                # ladder mirrors _FillCtx.raw_wa (int32-safe, clip to S,
                # sentinel below 0) and the PCIe stall folds into the max
                blkb = tb_ref[pl.ds(rrow, BR), :]
                wa_sp = wa_ref[pl.ds(1 + j + r0, BR)][:, None]
                raw = jnp.clip(cols - wa_sp, -_INT_CLAMP, W - 1)
                idx3 = jnp.clip(raw + wa_s, -1, W - 1)
                c3 = _shifted_gather(blkb, idx3, W)
                c3 = c3 + cum_ref[pl.ds(1 + j + r0, BR)][:, None]
                c3 = jnp.maximum(c3, toff)
                c3 = c3 + lmb3_ref[pl.ds(lrow, BR), :]
                acc3 = jnp.minimum(acc3, c3)
            return accb, acce, acc3

        start_acc = jnp.full((BR, W), inf, COST_DT)
        accb, acce, acc3 = jax.lax.fori_loop(
            0, d, split, (start_acc, start_acc, start_acc)
        )
        mn = pl.load(mn_ref, (pl.ds(d - 1, 1), pl.ds(r0, BR)))[0][:, None]
        infeas = cols < mn
        resb = jnp.where(infeas, inf, accb)
        rese = jnp.where(infeas, inf, acce)
        if allow_fall:
            # C2 child is embedded: gather from the Ce table
            blk = te_ref[pl.ds(off_ref[d - 1] + 1 + r0, BR), :]
            idx = cols - wb_ref[pl.ds(1 + r0, BR)][:, None]
            uf = uf_ref[pl.ds(1 + r0, BR)][:, None]
            ub = ub_ref[pl.ds(1 + r0, BR)][:, None]
            c2 = (_shifted_gather(blk, idx, W) + uf) + ub
            ma = pl.load(ma_ref, (pl.ds(d - 1, 1), pl.ds(r0, BR)))[0][:, None]
            c2 = jnp.where(cols < ma, inf, c2)
            resb = jnp.minimum(resb, c2)
            rese = jnp.minimum(rese, c2)
        if host_on:
            resb = jnp.minimum(resb, jnp.where(infeas, inf, acc3))
        tb_ref[pl.ds(off_ref[d] + r0, BR), :] = resb
        te_ref[pl.ds(off_ref[d] + r0, BR), :] = rese


def fused_fill_offload(
    t0b,
    t0e,
    off,
    wa,
    wb,
    cum,
    uf,
    ub,
    mn,
    ma,
    toff,
    tpre,
    *,
    L,
    W,
    block_rows,
    allow_fall,
    host_on,
    interpret=False,
):
    """Single-dispatch offload (three-tier) band fill: two cost tables and
    four companion buffers carried device-side, the C3 stall pre-folded to
    ``max(X, T_off)`` — returns ``(Cb, Ce)`` filled tables."""
    NSMAX = max(L, 1)
    BR = max(1, min(block_rows, NSMAX))
    grid = (L, pl.cdiv(NSMAX, BR))
    shape = jax.ShapeDtypeStruct(t0b.shape, t0b.dtype)
    kernel_fn = functools.partial(
        _fused_offload_kernel, L=L, W=W, BR=BR, allow_fall=allow_fall, host_on=host_on
    )
    ins = (t0b, t0e, off, wa, wb, cum, uf, ub, mn, ma, toff, tpre)
    tb, te, _, _, _, _ = pl.pallas_call(
        kernel_fn,
        grid=grid,
        in_specs=[_whole(x) for x in ins],
        out_specs=[_whole(t0b)] * 6,
        out_shape=[shape] * 6,
        interpret=interpret,
    )(*ins)
    return tb, te
