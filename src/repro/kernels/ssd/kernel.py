"""Mamba2 SSD intra-chunk computation — Pallas TPU kernel.

This is the TPU adaptation of the SSD "block decomposition" (arXiv:2405.21060
§6): for each (batch, head, chunk) the kernel computes, entirely in VMEM,

- the *diagonal* (within-chunk) output block
    ``y = ((C·Bᵀ) ⊙ L ⊙ dt) · x``       — two (Q×Q)/(Q×P) MXU matmuls,
- the chunk's *state contribution*
    ``S_c = (B ⊙ decay ⊙ dt)ᵀ · x``      — one (N×Q)·(Q×P) MXU matmul,

leaving only the tiny inter-chunk scan over S/Q chunk states to XLA (a
sequential O(S/Q) recurrence with (H,P,N)-sized state, negligible FLOPs).
The CUDA version streams warps over the sequence; on TPU the same math maps
onto the 128×128 systolic array with Q=chunk as the contracting tile.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref):
    # blocks: x (1,1,Q,P), dt (1,1,Q), a (1,), b/c (1,Q,N) [per-group, shared
    # across the heads mapped to it], outputs y (1,1,Q,P), s (1,1,P,N)
    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q,)
    a = a_ref[0].astype(jnp.float32)             # scalar
    bm = b_ref[0, 0].astype(jnp.float32)         # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)         # (Q, N)

    da = dt * a                                  # (Q,)
    cs = jnp.cumsum(da)                          # within-chunk cumsum
    Q = x.shape[0]
    # L[i, j] = exp(cs_i - cs_j) for j <= i else 0
    li = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(lj <= li, jnp.exp(cs[:, None] - cs[None, :]), 0.0)

    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    w = scores * L * dt[None, :]
    y_ref[0, 0] = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    decay = jnp.exp(cs[-1] - cs)                 # (Q,)
    bw = bm * (decay * dt)[:, None]              # (Q, N)
    s_ref[0, 0] = jax.lax.dot_general(
        x, bw, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(s_ref.dtype)  # (P, N)


def ssd_chunk_blocks(x: jax.Array, dt: jax.Array, A: jax.Array,
                     Bm: jax.Array, Cm: jax.Array,
                     interpret: bool = False):
    """Intra-chunk terms.  Shapes (already chunked by ops.py):
    x: (BH, nc, Q, P), dt: (BH, nc, Q), A: (BH,), Bm/Cm: (BH, nc, Q, N) —
    heads pre-broadcast to groups.  Returns (y_diag, states):
    y_diag (BH, nc, Q, P) f32, states (BH, nc, P, N) f32."""
    BH, nc, Q, P = x.shape
    N = Bm.shape[-1]
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, c: (b,)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
