"""jit'd SSD wrapper: kernel for intra-chunk terms + XLA inter-chunk scan.

Differentiable via recompute-from-inputs VJP against the pure-jnp chunked
oracle (flash-style: no (Q×Q) residuals stored)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_chunk_blocks

_INTERPRET = [False]


def set_interpret(flag: bool) -> None:
    _INTERPRET[0] = bool(flag)


def _forward(x, dt, A, Bm, Cm, chunk, init_state):
    B_, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    if S % chunk:
        pad = chunk - S % chunk
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bp = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cp = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, st = _forward(xp, dtp, A, Bp, Cp, chunk, init_state)
        return y[:, :S], st
    nc = S // chunk
    rep = H // G
    # (B,S,H,P) -> (B*H, nc, Q, P); B/C broadcast per-head
    xk = x.transpose(0, 2, 1, 3).reshape(B_ * H, nc, chunk, P)
    dtk = dt.transpose(0, 2, 1).reshape(B_ * H, nc, chunk)
    Ak = jnp.broadcast_to(A[None, :], (B_, H)).reshape(B_ * H)
    Bk = jnp.repeat(Bm.transpose(0, 2, 1, 3), rep, axis=1).reshape(
        B_ * H, nc, chunk, N)
    Ck = jnp.repeat(Cm.transpose(0, 2, 1, 3), rep, axis=1).reshape(
        B_ * H, nc, chunk, N)

    y_diag, states = ssd_chunk_blocks(xk, dtk, Ak, Bk, Ck,
                                      interpret=_INTERPRET[0])

    # inter-chunk recurrence (tiny, sequential over nc)
    da = dtk.astype(jnp.float32) * Ak[:, None, None].astype(jnp.float32)
    cs = jnp.cumsum(da, axis=-1)                       # (BH, nc, Q)
    chunk_decay = jnp.exp(cs[..., -1])                 # (BH, nc)
    if init_state is None:
        st0 = jnp.zeros((B_ * H, P, N), jnp.float32)
    else:
        st0 = init_state.reshape(B_ * H, P, N).astype(jnp.float32)

    def step(carry, inp):
        dec, s_c = inp
        new = carry * dec[:, None, None] + s_c
        return new, carry

    final, prev = jax.lax.scan(step, st0,
                               (chunk_decay.T, states.transpose(1, 0, 2, 3)))
    prev = prev.transpose(1, 0, 2, 3)                  # (BH, nc, P, N)

    y_off = jnp.einsum("bcqn,bcpn,bcq->bcqp", Ck.astype(jnp.float32), prev,
                       jnp.exp(cs))
    y = (y_diag + y_off).reshape(B_, H, nc * chunk, P).transpose(0, 2, 1, 3)
    return y.astype(x.dtype), final.reshape(B_, H, P, N)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Same contract as models.mamba2.ssd_chunked (the oracle)."""
    return _forward(x, dt, A, Bm, Cm, chunk, init_state)


def _fwd(x, dt, A, Bm, Cm, chunk, init_state):
    out = _forward(x, dt, A, Bm, Cm, chunk, init_state)
    return out, (x, dt, A, Bm, Cm, init_state)


def _bwd(chunk, res, g):
    x, dt, A, Bm, Cm, init_state = res
    from . import ref

    def f(x_, dt_, A_, B_, C_, st_):
        return ref.ssd_chunked(x_, dt_, A_, B_, C_, chunk, st_)

    _, vjp = jax.vjp(f, x, dt, A, Bm, Cm, init_state)
    return vjp(g)


ssd_chunked.defvjp(_fwd, _bwd)
