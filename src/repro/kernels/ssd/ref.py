"""Oracles for the SSD kernel: (a) the chunked pure-jnp algorithm (shared
with ``models.mamba2``), (b) a naive O(S·N) sequential recurrence used to
validate the chunked math itself."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunked(x, dt, A, Bm, Cm, chunk, init_state=None):
    from ...models.mamba2 import ssd_chunked as _impl
    return _impl(x, dt, A, Bm, Cm, chunk, init_state, use_kernel=False)


def ssd_naive(x, dt, A, Bm, Cm, init_state=None):
    """Sequential SSM recurrence: the ground truth for all SSD variants.

    x: (B,S,H,P), dt: (B,S,H), A: (H,), Bm/Cm: (B,S,G,N) with G | H.
    """
    B_, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Br = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)
    Cr = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    A = A.astype(jnp.float32)
    state = (jnp.zeros((B_, H, P, N), jnp.float32)
             if init_state is None else init_state.astype(jnp.float32))

    def step(state, t):
        xt, dtt, Bt, Ct = t
        decay = jnp.exp(dtt * A[None])                      # (B,H)
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dtt, xt, Bt)
        y = jnp.einsum("bhpn,bhn->bhp", state, Ct)
        return state, y

    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          Br.transpose(1, 0, 2, 3), Cr.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
