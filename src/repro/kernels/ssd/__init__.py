from . import ops, ref
