"""Oracle: materialize-everything cross-entropy from hidden states."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def xent_from_hidden(h: jax.Array, w: jax.Array, labels: jax.Array,
                     mask=None, z_loss: float = 0.0) -> jax.Array:
    """h: (B,S,d), w: (d,V), labels: (B,S). Full-logits reference."""
    logits = (h @ w.astype(h.dtype)).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - gold
    if z_loss:
        loss = loss + z_loss * lse ** 2
    if mask is not None:
        return (loss * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss.mean()
