"""Blockwise (vocab-chunked) cross-entropy: never materializes the full
(B,S,V) logits tensor — the single largest activation for 150k-vocab models.

Forward: a ``lax.scan`` over vocab blocks maintaining a running
(max, sum-exp, gold-logit) triple; backward (custom VJP): a second scan
recomputing each logits block and accumulating ``dh``/``dW`` — so peak
memory is O(B·S·block) instead of O(B·S·V).  This is the paper's trade
(recompute to bound memory) applied *inside* the loss stage, which the rotor
profile consistently flags as the fattest ``ω_ā`` in the chain.

A direct Pallas realization of the same loop is in this package's
``kernel.py`` sibling modules' style, but the XLA scan already achieves the
memory bound; the kernel variant was not needed to hit it (see DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _lse_scan(h2: jax.Array, w: jax.Array, labels1: jax.Array, block: int):
    """h2: (T,d), w: (d,V), labels1: (T,). Returns (lse (T,), gold (T,))."""
    T, d = h2.shape
    V = w.shape[1]
    nb = -(-V // block)
    Vp = nb * block
    wp = jnp.pad(w, ((0, 0), (0, Vp - V))) if Vp != V else w
    wb = wp.reshape(d, nb, block).transpose(1, 0, 2)        # (nb, d, block)

    def step(carry, inp):
        m, s, gold = carry
        wblk, j = inp
        logits = (h2 @ wblk.astype(h2.dtype)).astype(jnp.float32)  # (T, blk)
        col = j * block + jnp.arange(block)
        logits = jnp.where(col[None, :] < V, logits, -jnp.inf)
        bm = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m, bm)
        s = s * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits - m_new[:, None]), axis=-1)
        hit = (labels1[:, None] == col[None, :])
        gold = gold + jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
        return (m_new, s, gold), None

    init = (jnp.full((T,), -jnp.inf, jnp.float32),
            jnp.zeros((T,), jnp.float32), jnp.zeros((T,), jnp.float32))
    (m, s, gold), _ = jax.lax.scan(step, init, (wb, jnp.arange(nb)))
    return m + jnp.log(s), gold


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def blockwise_xent(h: jax.Array, w: jax.Array, labels: jax.Array,
                   mask=None, block: int = 8192, z_loss: float = 0.0
                   ) -> jax.Array:
    loss, _ = _value_aux(h, w, labels, mask, block, z_loss)
    return loss


def _value_aux(h, w, labels, mask, block, z_loss):
    B, S, d = h.shape
    T = B * S
    h2 = h.reshape(T, d)
    labels1 = labels.reshape(T)
    lse, gold = _lse_scan(h2, w, labels1, block)
    per_tok = lse - gold
    if z_loss:
        per_tok = per_tok + z_loss * lse ** 2
    if mask is not None:
        m1 = mask.reshape(T).astype(jnp.float32)
        denom = jnp.maximum(m1.sum(), 1.0)
        loss = (per_tok * m1).sum() / denom
        wgt = m1 / denom
    else:
        loss = per_tok.mean()
        wgt = jnp.full((T,), 1.0 / T, jnp.float32)
    return loss, (lse, wgt)


def _fwd(h, w, labels, mask, block, z_loss):
    loss, (lse, wgt) = _value_aux(h, w, labels, mask, block, z_loss)
    return loss, (h, w, labels, mask, lse, wgt)


def _bwd(block, z_loss, res, g):
    import numpy as np

    h, w, labels, mask, lse, wgt = res
    B, S, d = h.shape
    T = B * S
    h2 = h.reshape(T, d)
    labels1 = labels.reshape(T)
    V = w.shape[1]
    nb = -(-V // block)
    Vp = nb * block
    wp = jnp.pad(w, ((0, 0), (0, Vp - V))) if Vp != V else w
    wb = wp.reshape(d, nb, block).transpose(1, 0, 2)
    coef = (g * wgt).astype(jnp.float32)                     # (T,)
    zcoef = (jnp.ones_like(lse) + 2.0 * z_loss * lse if z_loss
             else jnp.ones_like(lse))

    def step(dh, inp):
        wblk, j = inp
        logits = (h2 @ wblk.astype(h2.dtype)).astype(jnp.float32)
        col = j * block + jnp.arange(block)
        valid = col[None, :] < V
        p = jnp.where(valid, jnp.exp(logits - lse[:, None]), 0.0)
        hit = (labels1[:, None] == col[None, :]).astype(jnp.float32)
        dlogits = coef[:, None] * (p * zcoef[:, None] - hit)  # (T, blk)
        dh = dh + (dlogits @ wblk.astype(jnp.float32).T)
        dwblk = h2.astype(jnp.float32).T @ dlogits            # (d, blk)
        return dh, dwblk

    dh, dwb = jax.lax.scan(step, jnp.zeros((T, d), jnp.float32),
                           (wb, jnp.arange(nb)))
    dw = dwb.transpose(1, 0, 2).reshape(d, Vp)[:, :V]
    d_labels = np.zeros(labels.shape, dtype=jax.dtypes.float0)
    d_mask = None if mask is None else jnp.zeros_like(mask)
    return (dh.reshape(B, S, d).astype(h.dtype), dw.astype(w.dtype),
            d_labels, d_mask)


blockwise_xent.defvjp(_fwd, _bwd)


def token_chunked_xent(h: jax.Array, w: jax.Array, labels: jax.Array,
                       mask=None, block: int = 4096, z_loss: float = 0.0
                       ) -> jax.Array:
    """Token-block-chunked xent: scan over token blocks with a checkpointed
    body, so only O(block × V) logits are ever live and the backward
    rematerializes per block.  Unlike the vocab-chunked variant this keeps
    the vocab dim contiguous, so under GSPMD the per-block matmul stays
    TP-sharded on the model axis (vocab-chunking would serialize TP)."""
    B, S, d = h.shape
    T = B * S
    h2 = h.reshape(T, d)
    lab = labels.reshape(T)
    m1 = (mask.reshape(T).astype(jnp.float32) if mask is not None
          else jnp.ones((T,), jnp.float32))
    block = min(block, T)
    pad = (-T) % block
    if pad:
        h2 = jnp.pad(h2, ((0, pad), (0, 0)))
        lab = jnp.pad(lab, (0, pad))
        m1 = jnp.pad(m1, (0, pad))
    nb = h2.shape[0] // block
    hb = h2.reshape(nb, block, d)
    lb = lab.reshape(nb, block)
    mb = m1.reshape(nb, block)

    @jax.checkpoint
    def body(carry, inp):
        lsum, msum = carry
        hblk, lblk, mblk = inp
        logits = (hblk @ w.astype(hblk.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lblk[:, None], axis=-1)[:, 0]
        per = lse - gold
        if z_loss:
            per = per + z_loss * lse ** 2
        return (lsum + jnp.sum(per * mblk), msum + jnp.sum(mblk)), None

    (lsum, msum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hb, lb, mb))
    return lsum / jnp.maximum(msum, 1.0)
