from . import ops, ref
