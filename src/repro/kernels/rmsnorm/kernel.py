"""Fused RMSNorm — Pallas TPU kernel.

One grid step normalizes a (block_rows × d) tile held in VMEM; the reduction
runs in f32 on the VPU, the scale multiply is fused so the tile is read from
HBM exactly once (vs 2 reads + 1 write for the unfused norm→mul pair).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)[None, :]).astype(o_ref.dtype)


def rms_norm_2d(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
                block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (N, d) — callers flatten leading dims; d should be lane-aligned."""
    N, d = x.shape
    block_rows = min(block_rows, N)
    grid = (pl.cdiv(N, block_rows),)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, scale)
