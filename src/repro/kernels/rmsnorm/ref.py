"""Pure-jnp oracle for the fused RMSNorm kernel."""

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)
