"""jit'd wrapper: flattens leading dims, pads rows, differentiable via
recompute-from-inputs VJP (residual = x and scale only)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import rms_norm_2d

_INTERPRET = [False]


def set_interpret(flag: bool) -> None:
    _INTERPRET[0] = bool(flag)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    lead = x.shape[:-1]
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    pad = (-n) % 8
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = rms_norm_2d(x2, scale, eps, interpret=_INTERPRET[0])
    return out[:n].reshape(*lead, d)


def _fwd(x, scale, eps):
    return rms_norm(x, scale, eps), (x, scale)


def _bwd(eps, res, g):
    x, scale = res
    _, vjp = jax.vjp(lambda x_, s_: ref.rms_norm(x_, s_, eps), x, scale)
    return vjp(g)


rms_norm.defvjp(_fwd, _bwd)
