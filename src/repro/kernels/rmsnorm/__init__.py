from . import ops, ref
