from . import ops, ref
