"""Pure-jnp oracle for the flash-attention kernel (GQA, causal)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              causal: bool = True) -> jax.Array:
    """q: (B, S, H, D); k/v: (B, S, K, D); K divides H. Returns (B, S, H, D)."""
    B, Sq, H, D = q.shape
    Skv, K = k.shape[1], k.shape[2]
    g = H // K
    qg = q.reshape(B, Sq, K, g, D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) / math.sqrt(D)
    if causal:
        mask = jnp.arange(Skv)[None, :] <= jnp.arange(Sq)[:, None]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, D)
