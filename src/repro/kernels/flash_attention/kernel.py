"""Causal GQA flash attention — Pallas TPU kernel.

TPU adaptation notes (vs the CUDA flash-attention algorithm):
- the KV loop lives in the *grid* (not a warp-level loop); running max /
  denominator / accumulator persist across grid steps in VMEM scratch,
- tile shapes are MXU-aligned: (block_q × head_dim) and (block_kv × head_dim)
  with head_dim padded to a multiple of 128 by ``ops.py``,
- GQA is expressed through the K/V BlockSpec index maps (q-head → kv-head
  ``h // group``), so grouped heads re-read the same KV tile from HBM→VMEM
  instead of materializing repeated K/V.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks); the causal upper
triangle is skipped via ``pl.when`` (no FLOPs, tiles still mapped).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, block_q: int, block_kv: int, seq_len: int,
                  causal: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_kv
    # causal: skip blocks strictly above the diagonal (no FLOPs spent there)
    pred = (k_start <= q_start + block_q - 1) if causal else (ik >= 0)

    @pl.when(pred)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < seq_len
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                            # (bq, bkv)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array,
                         causal: bool = True, block_q: int = 128,
                         block_kv: int = 128, kv_len: int | None = None,
                         interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, K, Skv, D) with K | H. Sq/Skv padded by ops;
    ``kv_len`` is the true (pre-padding) KV length used for masking."""
    B, H, Sq, D = q.shape
    K, Skv = k.shape[1], k.shape[2]
    group = H // K
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Skv, block_kv)
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                               block_kv=block_kv, seq_len=kv_len or Skv,
                               causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denominator
        ],
        interpret=interpret,
    )(q, k, v)
