"""jit'd public wrapper for the flash-attention kernel.

Layout adaptation ((B,S,H,D) model layout → (B,H,S,D) kernel layout), padding
to MXU-aligned tiles, and a memory-efficient backward: the custom VJP
recomputes attention from (q, k, v) with the pure-jnp reference — i.e. flash
semantics (no (S×S) residual ever stored), which is exactly the paper's
``F_ck``-style saving applied inside the attention op.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .kernel import flash_attention_bhsd

_INTERPRET = [False]  # flipped by tests / CPU runs


def set_interpret(flag: bool) -> None:
    _INTERPRET[0] = bool(flag)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if not pad:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 128,
                    block_kv: int = 128) -> jax.Array:
    """q: (B, S, H, D); k/v: (B, S, K, D). Returns (B, S, H, D)."""
    return _forward(q, k, v, causal, block_q, block_kv)


def _forward(q, k, v, causal, block_q, block_kv):
    B, S, H, D = q.shape
    qt = _pad_to(q.transpose(0, 2, 1, 3), 2, block_q)
    kt = _pad_to(k.transpose(0, 2, 1, 3), 2, block_kv)
    vt = _pad_to(v.transpose(0, 2, 1, 3), 2, block_kv)
    Dp = max(128, D)
    if D < Dp:
        qt = _pad_to(qt, 3, Dp)
        kt = _pad_to(kt, 3, Dp)
        vt = _pad_to(vt, 3, Dp)
        # padding D changes the softmax scale baked into the kernel; rescale q
        qt = qt * jnp.asarray((Dp / D) ** 0.5, qt.dtype)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, block_q=block_q,
                               block_kv=block_kv, kv_len=S,
                               interpret=_INTERPRET[0])
    return out[:, :, :S, :D].transpose(0, 2, 1, 3)


def _fwd(q, k, v, causal, block_q, block_kv):
    return _forward(q, k, v, causal, block_q, block_kv), (q, k, v)


def _bwd(causal, block_q, block_kv, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.attention(q_, k_, v_, causal),
                     q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
