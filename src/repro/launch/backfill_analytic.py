"""Backfill analytic roofline terms into existing dry-run JSON records
(no recompilation needed — the analytic model is config-derived).

Usage: PYTHONPATH=src python -m repro.launch.backfill_analytic [DIR]
"""

from __future__ import annotations

import glob
import json
import sys
from types import SimpleNamespace


def mesh_stub(mesh_str: str):
    if mesh_str == "2x16x16":
        return SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16},
                               size=512)
    return SimpleNamespace(shape={"data": 16, "model": 16}, size=256)


def backfill(path: str) -> bool:
    from ..configs import get_config
    from ..configs.shapes import SHAPES
    from ..core.solver import tree_to_schedule
    from ..launch.analytic import decode_terms, prefill_terms, train_terms
    from ..launch.steps import plan_rotor_tree
    from ..models.lm import StagedLM

    with open(path) as f:
        rec = json.load(f)
    ov = dict(rec.get("overrides") or {})
    if "layer_kinds" in ov:
        ov["layer_kinds"] = tuple(ov["layer_kinds"])
    cfg = get_config(rec["arch"], **ov)
    shape = SHAPES[rec["shape"]]
    mesh = mesh_stub(rec["mesh"])
    model = StagedLM(cfg)
    if shape.kind == "train":
        policy = rec.get("policy") or "none"
        tree, chain = plan_rotor_tree(model, __import__(
            "repro.configs.shapes", fromlist=["input_specs"]).input_specs(
            cfg, shape), mesh, None, policy)
        if chain is None:
            from ..launch.steps import plan_chain
            chain = plan_chain(model, __import__(
                "repro.configs.shapes", fromlist=["input_specs"]).input_specs(
                cfg, shape), mesh, None)
        sched = tree_to_schedule(tree, chain.length) if tree is not None else None
        analytic = train_terms(cfg, shape, mesh, model, chain, sched)
        # also refresh the model-peak record for train cells
        from ..core.schedule import Schedule, simulate
        s = sched or Schedule.store_all(chain.length)
        rec.setdefault("memory", {})["model_peak_activations"] = float(
            simulate(chain, s).peak_mem)
        if tree is not None:
            from ..core.rematerialize import count_checkpoint_scopes
            rec["rotor"] = {"ck_scopes": count_checkpoint_scopes(tree)}
    elif shape.kind == "decode":
        analytic = decode_terms(cfg, shape, mesh, model)
    else:
        analytic = prefill_terms(cfg, shape, mesh, model)
    terms = {k: analytic[k] for k in ("compute_s", "memory_s", "collective_s")}
    analytic["dominant"] = max(terms, key=terms.get).replace("_s", "")
    rec["analytic"] = analytic
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return True


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    n = 0
    for path in sorted(glob.glob(f"{d}/*.json")):
        try:
            backfill(path)
            n += 1
        except Exception as e:  # noqa: BLE001
            print(f"[backfill] {path}: {type(e).__name__}: {e}")
    print(f"[backfill] updated {n} records")


if __name__ == "__main__":
    main()
