"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains reduced configs end-to-end; on a TPU pod the
same entrypoint builds the (pod, data, model) mesh from the slice topology
and runs the identical code path (shardings flow from the logical rules).

Recommended production XLA flags (recorded here; they are TPU-only):
  --xla_tpu_enable_async_collective_fusion=true
  --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true
  --xla_tpu_overlap_compute_collective_tc=true
  --xla_enable_async_all_gather=true
(compute/communication overlap for the FSDP all-gathers and DP reduces.)
"""

from __future__ import annotations

import argparse
import json

import jax

from ..configs import get_config, smoke_config
from ..distributed.fault_tolerance import elastic_plan
from ..runtime.train_loop import TrainLoopConfig, run_training


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--policy", default=None,
                    help="remat policy: none|full|periodic:K|rotor:auto|"
                         "rotor:BYTES|revolve:BYTES|"
                         "optimal_offload:BYTES[:BW] (each maps onto a "
                         "repro.plan.PlanRequest — see README 'Planning "
                         "API')")
    ap.add_argument("--num-slots", type=int, default=None,
                    help="DP discretization slots (default: plan default)")
    ap.add_argument("--solver-impl", default=None,
                    choices=("banded", "pallas", "pallas_fused", "reference"),
                    help="DP fill kernels: banded numpy, the per-band Pallas"
                         " kernel, the fused single-dispatch Pallas fill"
                         " (both jit on TPU, interpret on CPU), or the seed"
                         " float64 path (default: banded / REPRO_DP_IMPL)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--override", default=None, help="JSON config overrides")
    args = ap.parse_args(argv)

    ov = json.loads(args.override) if args.override else {}
    cfg = smoke_config(args.arch, **ov) if args.smoke else get_config(args.arch, **ov)

    n = len(jax.devices())
    (data, model_par), axes, accum = elastic_plan(n, args.model_parallel,
                                                  args.global_batch)
    mesh = jax.make_mesh((data, model_par), axes)
    print(f"[train] arch={cfg.name} mesh={dict(mesh.shape)} "
          f"devices={n} accum={accum}")

    loop = TrainLoopConfig(steps=args.steps, global_batch=args.global_batch,
                           seq_len=args.seq_len, lr=args.lr,
                           policy=args.policy, num_slots=args.num_slots,
                           solver_impl=args.solver_impl,
                           ckpt_dir=args.ckpt_dir,
                           ckpt_every=args.ckpt_every)
    out = run_training(cfg, loop, mesh=mesh)
    print(f"[train] done: {len(out['losses'])} steps, "
          f"loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f}, "
          f"{out['tokens_per_s']:.0f} tok/s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
