import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=" + os.environ.get("REPRO_DRYRUN_DEVICES", "512") + " --xla_disable_hlo_passes=optimization-barrier-expander,cse,dot-merger").strip()
# The disable_hlo_passes keep jax.checkpoint's optimization barriers alive on
# the CPU backend so compiled FLOPs honestly include rematerialization
# recompute (the TPU backend preserves remat without these; CPU strips it and
# CSEs the recompute away — see DESIGN.md §Dry-run-on-CPU caveats).

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh and record memory/cost/collective analysis (§Dry-run).

MUST be run as a script / subprocess (the XLA_FLAGS line above executes
before any jax import — jax locks the device count on first init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-4b \
      --shape train_4k [--multi-pod] [--policy rotor:auto] [--out DIR]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             policy: str | None, out_dir: str, overrides: dict | None = None,
             tag: str = "") -> dict:
    import jax
    from ..configs import get_config
    from ..configs.shapes import SHAPES, applicable
    from ..distributed.sharding import axis_rules
    from ..launch.mesh import make_production_mesh
    from ..launch.roofline import analyze
    from ..launch.steps import build_cell
    from ..models.flops import model_flops_per_step

    assert applicable(arch, shape_name), f"{arch} × {shape_name} not assigned"
    t0 = time.time()
    cfg = get_config(arch, **(overrides or {}))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    with axis_rules(mesh):
        jitted, args, rules, extra = build_cell(cfg, shape, policy=policy,
                                                mesh=mesh)
        with axis_rules(mesh, rules):
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    print(compiled.memory_analysis())   # proves it fits
    ca = compiled.cost_analysis()
    print({k: ca[k] for k in ("flops", "bytes accessed")
           if ca and k in ca})          # FLOPs/bytes for §Roofline
    hlo = compiled.as_text()
    mf = model_flops_per_step(cfg, shape.global_batch,
                              1 if shape.kind == "decode" else shape.seq_len,
                              train=(shape.kind == "train"))
    roof = analyze(compiled, mesh.size, mf, hlo_text=hlo)

    # model-based per-device peak (the number that must fit 16 GiB): the CPU
    # backend's buffer assignment is not memory-minimizing (no remat-aware
    # scheduling), so memory_analysis is an un-scheduled upper bound; the
    # rotor simulator gives the exact model peak for the planned schedule.
    model_mem = None
    if extra.get("chain") is not None:
        from ..core.schedule import Schedule, simulate
        from ..core.solver import tree_to_schedule
        chain = extra["chain"]
        sched = (tree_to_schedule(extra["tree"], chain.length)
                 if extra.get("tree") is not None
                 else Schedule.store_all(chain.length))
        act_peak = simulate(chain, sched).peak_mem
        import jax as _jax
        import numpy as _np
        from ..models.lm import StagedLM
        pspec = _jax.eval_shape(StagedLM(cfg).init, _jax.random.PRNGKey(0))
        p_bytes = sum(int(_np.prod(l.shape)) * _np.dtype(l.dtype).itemsize
                      for l in _jax.tree.leaves(pspec))
        states = p_bytes * 6 / mesh.size  # bf16 p+g, f32 m+v (ZeRO-3 sharded)
        model_mem = {"activation_peak_bytes": float(act_peak),
                     "param_opt_grad_bytes": float(states),
                     "total_bytes": float(act_peak + states)}

    # analytic roofline terms (primary: immune to HloCostAnalysis's
    # while-body-once counting; see launch/analytic.py docstring)
    from ..launch.analytic import decode_terms, prefill_terms, train_terms
    from ..models.lm import StagedLM as _SLM
    from ..core.solver import tree_to_schedule as _t2s
    _model = _SLM(cfg)
    if shape.kind == "train":
        _sched = (_t2s(extra["tree"], extra["chain"].length)
                  if extra.get("tree") is not None else None)
        analytic = train_terms(cfg, shape, mesh, _model, extra["chain"],
                               _sched)
    elif shape.kind == "decode":
        analytic = decode_terms(cfg, shape, mesh, _model)
    else:
        analytic = prefill_terms(cfg, shape, mesh, _model)
    terms = {k: analytic[k] for k in ("compute_s", "memory_s", "collective_s")}
    analytic["dominant"] = max(terms, key=terms.get).replace("_s", "")

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": ("2x16x16" if multi_pod else "16x16"),
        "n_devices": mesh.size,
        "analytic": analytic,
        "policy": policy or cfg.remat_policy,
        "overrides": overrides or {},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "temp_bytes": int(ma.temp_size_in_bytes),
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes": int(ma.temp_size_in_bytes + ma.argument_size_in_bytes
                              + ma.output_size_in_bytes - ma.alias_size_in_bytes),
            "model_peak": model_mem,
        },
        "roofline": roof.to_json(),
        "rotor": None,
        # the full planning artifact (strategy, budget, predicted makespan,
        # device/host peaks, op counts) — repro.plan.MemoryPlan.stats()
        "plan": (extra["plan"].stats() if extra.get("plan") is not None
                 else None),
    }
    # process-wide observability counters accumulated while planning and
    # compiling this cell (solver cache traffic, DP fill wall times, ...)
    from ..obs import metrics as _obs_metrics
    rec["metrics"] = _obs_metrics.snapshot()
    if extra.get("tree") is not None:
        from ..core.rematerialize import count_checkpoint_scopes
        rec["rotor"] = {"ck_scopes": count_checkpoint_scopes(extra["tree"])}
    name = f"{arch}__{shape_name}__{rec['mesh']}{tag}.json"
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)
    hbm = 16 * 1024**3
    print(f"[dryrun] {arch} × {shape_name} × {rec['mesh']}: "
          f"peak={rec['memory']['peak_bytes']/2**30:.2f} GiB/dev "
          f"({'FITS' if rec['memory']['peak_bytes'] <= hbm else 'OVER'} 16GiB) "
          f"dominant={roof.dominant} "
          f"terms(c/m/x)=({roof.compute_s:.4f}/{roof.memory_s:.4f}/"
          f"{roof.collective_s:.4f})s lower={t_lower:.0f}s "
          f"compile={t_compile:.0f}s", flush=True)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--policy", default="rotor:auto",
                    help="remat policy for train cells (rotor:auto = the "
                         "paper's optimal persistent schedule under the "
                         "per-device activation budget; none|full|periodic:K)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--override", default=None,
                    help="JSON dict of ModelConfig overrides (perf iters)")
    ap.add_argument("--tag", default="", help="suffix for the output file")
    args = ap.parse_args(argv)

    overrides = json.loads(args.override) if args.override else None
    from ..configs import ARCHS
    from ..configs.shapes import SHAPES, applicable

    cells = ([(args.arch, args.shape)] if not args.all else
             [(a, s) for a in ARCHS for s in SHAPES if applicable(a, s)])
    failures = 0
    for arch, shape in cells:
        try:
            run_cell(arch, shape, args.multi_pod, args.policy, args.out,
                     overrides, args.tag)
        except Exception:
            failures += 1
            print(f"[dryrun] FAILED {arch} × {shape}", flush=True)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
