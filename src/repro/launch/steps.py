"""Builders for the jit-able production steps (train / prefill / decode) with
full sharding trees and the rotor remat plan wired in."""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core import solver_cache
from ..core.chain import Chain
from ..core.policies import resolve_policy
from ..plan import MemoryPlan, two_tier_fallback
from ..distributed.sharding import (DEFAULT_RULES, LONG_CONTEXT_RULES,
                                    spec_for)
from ..models.flops import stage_flops
from ..models.lm import StagedLM
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from .mesh import HBM_BYTES, PEAK_FLOPS_BF16


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------

def _match_axes(spec_tree: Any, axes_tree: Any):
    """Zip a ShapeDtypeStruct tree with its logical-axes tree (same paths)."""
    sflat = jax.tree_util.tree_flatten_with_path(spec_tree)[0]
    aflat = jax.tree_util.tree_flatten_with_path(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))[0]
    if len(sflat) != len(aflat):
        raise ValueError(f"axes tree mismatch: {len(sflat)} vs {len(aflat)}")
    for (sp, leaf), (ap, ax) in zip(sflat, aflat):
        if jax.tree_util.keystr(sp) != jax.tree_util.keystr(ap):
            raise ValueError(f"axes path mismatch {sp} vs {ap}")
        yield leaf, ax


def shard_tree(spec_tree: Any, axes_tree: Any, mesh, rules) -> Any:
    """ShapeDtypeStructs annotated with NamedShardings per logical axes."""
    out = []
    for leaf, ax in _match_axes(spec_tree, axes_tree):
        ns = NamedSharding(mesh, spec_for(ax, leaf.shape, mesh, rules))
        out.append(jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=ns))
    treedef = jax.tree_util.tree_structure(spec_tree)
    return jax.tree_util.tree_unflatten(treedef, out)


def sharding_of(tree: Any) -> Any:
    return jax.tree.map(lambda l: l.sharding, tree)


def batch_axes(cfg, kind: str) -> Dict[str, tuple]:
    if kind == "decode":
        tok = (("act_batch", None, None) if cfg.modality == "audio_embed"
               else ("act_batch", None))
        return {"tokens": tok}
    ax: Dict[str, tuple] = {}
    if cfg.modality == "text":
        ax["tokens"] = ("act_batch", "act_seq")
    elif cfg.modality == "audio_embed":
        ax["embeds"] = ("act_batch", "act_seq", None)
    else:
        ax["image_embeds"] = ("act_batch", None, None)
        ax["tokens"] = ("act_batch", "act_seq")
    if kind == "train":
        ax["labels"] = ("act_batch", "act_seq")
        ax["loss_mask"] = ("act_batch", "act_seq")
    return ax


def opt_axes(param_axes: Any) -> Dict[str, Any]:
    return {"mu": param_axes, "nu": param_axes, "count": ()}


# ---------------------------------------------------------------------------
# rotor planning at scale
# ---------------------------------------------------------------------------

def activation_budget_bytes(params_spec: Any, n_devices: int,
                            hbm: int = HBM_BYTES, slack: float = 0.9) -> float:
    """Per-device activation budget = HBM − (params + grads + Adam moments),
    assuming full (FSDP×TP) sharding of all three (ZeRO-3 via GSPMD)."""
    p_bytes = sum(int(math.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
                  for l in jax.tree.leaves(params_spec))
    per_dev_states = p_bytes * (1 + 1 + 4) / n_devices  # bf16 p+g, f32 m+v
    return max(hbm * slack - per_dev_states, hbm * 0.05)


def plan_chain(model: StagedLM, batch_specs: Dict, mesh, rules) -> Chain:
    """Analytic rotor chain for (model × shape × mesh): per-device activation
    sizes from eval_shape ÷ DP shard factor, times from analytic FLOPs."""
    from ..core.planner import profile_stages_analytic

    cfg = model.cfg
    some = next(iter(batch_specs.values()))
    B = some.shape[0]
    S = (batch_specs["tokens"].shape[1] if cfg.modality != "audio_embed"
         else batch_specs["embeds"].shape[1])
    if cfg.modality == "vlm":
        S = S + cfg.prefix_len
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            dp *= mesh.shape[ax]
    factor = dp if B % dp == 0 else 1
    fwd, bwd = stage_flops(cfg, B, S)
    params_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    stage_specs = model.stage_params(params_spec)
    chain = profile_stages_analytic(
        model.stage_fns(), stage_specs, batch_specs,
        peak_flops=PEAK_FLOPS_BF16, activation_shard_factor=factor,
        flops_fwd=fwd, flops_bwd=bwd)
    # the head stage's residuals (logits) additionally shard on the model
    # axis when the vocab divides it — fold that into its per-device sizes
    tp = mesh.shape.get("model", 1)
    if tp > 1 and cfg.vocab_size % tp == 0:
        chain.wabar[-1] /= tp
    return chain


def plan_training(model: StagedLM, batch_specs: Dict, mesh, rules,
                  policy: Optional[str] = None, *,
                  num_slots: Optional[int] = None,
                  impl: Optional[str] = None,
                  jit_only: bool = False
                  ) -> Tuple[Optional[MemoryPlan], Optional[Chain]]:
    """Resolve the remat policy for (model × shape × mesh) into a
    :class:`~repro.plan.MemoryPlan` (None = store-all, no remat).

    ``num_slots``/``impl`` thread uniformly into the underlying
    :class:`~repro.plan.PlanRequest` (None = the plan defaults) — this is
    the one place launch-side solver knobs are configured.

    ``jit_only=True`` is the XLA-path contract: host DMA cannot be expressed
    from a remat tree, so an offload-bearing plan is degraded to the best
    two-tier plan at the same device budget (the eager runtime path — see
    ``runtime/train_loop.py`` — runs the true offload schedule instead).
    """
    cfg = model.cfg
    policy = policy if policy is not None else cfg.remat_policy
    if policy == "none":
        return None, None
    chain = plan_chain(model, batch_specs, mesh, rules)
    plan = resolve_policy(
        policy, chain, num_slots=num_slots, impl=impl,
        # only 'auto' budgets need the parameter footprint — trace lazily
        auto_budget=lambda: activation_budget_bytes(
            jax.eval_shape(model.init, jax.random.PRNGKey(0)), mesh.size))
    if jit_only and plan.uses_offload:
        print("[plan] offload plan needs the host tier; jitted two-tier "
              "fallback at the same device budget", flush=True)
        plan = two_tier_fallback(plan, chain)
    return plan, chain


def plan_rotor_tree(model: StagedLM, batch_specs: Dict, mesh, rules,
                    policy: Optional[str] = None):
    """Back-compat wrapper: resolve the policy into a jit-expressible
    schedule tree (None = store-all).  New code should use
    :func:`plan_training` and keep the full :class:`MemoryPlan`."""
    plan, chain = plan_training(model, batch_specs, mesh, rules, policy,
                                jit_only=True)
    return (plan.tree if plan is not None else None), chain


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def make_train_step(model: StagedLM, opt_cfg: AdamWConfig, tree,
                    lr_fn=None, grad_accum: int = 1):
    """``grad_accum > 1`` scans over microbatches (leading-dim split of the
    global batch), accumulating f32 gradients before one optimizer step —
    the knob the elastic-restart plan uses to keep the global batch constant
    when the data axis shrinks, and the generic lever when per-device
    activation memory is tight even after rotor."""

    def loss_of(p, b):
        return model.loss_fn(p, b, tree=tree)

    def train_step(params, opt_state, batch, step):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape(grad_accum, a.shape[0] // grad_accum,
                                    *a.shape[1:]), batch)

            def body(carry, mb):
                lsum, gsum = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                gsum = jax.tree.map(
                    lambda s, x: s + x.astype(jnp.float32), gsum, g)
                return (lsum + l, gsum), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (lsum, gsum), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = lsum / grad_accum
            grads = jax.tree.map(lambda g, p: (g / grad_accum).astype(p.dtype),
                                 gsum, params)
        lr = lr_fn(step) if lr_fn is not None else None
        new_p, new_o, metrics = adamw_update(opt_cfg, grads, opt_state,
                                             params, lr)
        metrics["loss"] = loss
        return new_p, new_o, metrics
    return train_step


def make_prefill_step(model: StagedLM):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_serve_step(model: StagedLM):
    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return serve_step


# ---------------------------------------------------------------------------
# fully-wired lowering helper (used by dryrun + launch scripts)
# ---------------------------------------------------------------------------

def build_cell(arch_cfg, shape_spec, mesh, policy: Optional[str] = None,
               opt_cfg: Optional[AdamWConfig] = None):
    """Returns (jitted fn, example args as sharded ShapeDtypeStructs)."""
    from ..configs.shapes import input_specs

    from ..distributed.sharding import DECODE_RULES

    cfg = arch_cfg
    model = StagedLM(cfg)
    if shape_spec.name == "long_500k":
        rules = LONG_CONTEXT_RULES
    elif shape_spec.kind in ("decode", "prefill"):
        rules = DECODE_RULES
    else:
        rules = DEFAULT_RULES
    params_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sds = shard_tree(params_spec, model.param_axes(), mesh, rules)
    batch_specs = input_specs(cfg, shape_spec)
    batch_sds = shard_tree(batch_specs, batch_axes(cfg, shape_spec.kind),
                           mesh, rules)

    if shape_spec.kind == "train":
        plan, chain = plan_training(model, batch_specs, mesh, rules, policy,
                                    jit_only=True)
        tree = plan.tree if plan is not None else None
        st = solver_cache.stats()
        if st["hits"] or st["misses"]:
            # repeated launches and budget sweeps are served from the
            # persistent solver cache — the DP fill is skipped on hits
            print(f"[rotor] solver cache: {st['hits']} hits / "
                  f"{st['misses']} misses ({st['disk_hits']} from disk)",
                  flush=True)
        opt_cfg = opt_cfg or AdamWConfig()
        opt_spec = jax.eval_shape(adamw_init, params_spec)
        opt_sds = shard_tree(opt_spec, opt_axes(model.param_axes()), mesh,
                             rules)
        step_sds = jax.ShapeDtypeStruct((), jnp.int32,
                                        sharding=NamedSharding(mesh, P()))
        fn = make_train_step(model, opt_cfg, tree)
        rep = NamedSharding(mesh, P())
        out_shardings = (sharding_of(params_sds), sharding_of(opt_sds),
                         {"loss": rep, "grad_norm": rep, "param_norm": rep})
        jitted = jax.jit(fn, donate_argnums=(0, 1),
                         out_shardings=out_shardings)
        args = (params_sds, opt_sds, batch_sds, step_sds)
        return jitted, args, rules, {"tree": tree, "chain": chain,
                                     "plan": plan}

    if shape_spec.kind == "prefill":
        fn = make_prefill_step(model)
        cache_spec = jax.eval_shape(
            functools.partial(model.init_cache, shape_spec.global_batch,
                              shape_spec.seq_len))
        cache_shard = sharding_of(shard_tree(cache_spec, model.cache_axes(),
                                             mesh, rules))
        rep = NamedSharding(mesh, P())
        logits_shard = rep
        jitted = jax.jit(fn, out_shardings=(logits_shard, cache_shard))
        return jitted, (params_sds, batch_sds), rules, {}

    # decode
    fn = make_serve_step(model)
    cache_spec = jax.eval_shape(
        functools.partial(model.init_cache, shape_spec.global_batch,
                          shape_spec.seq_len))
    cache_sds = shard_tree(cache_spec, model.cache_axes(), mesh, rules)
    rep = NamedSharding(mesh, P())
    jitted = jax.jit(fn, donate_argnums=(1,),
                     out_shardings=(rep, sharding_of(cache_sds)))
    return jitted, (params_sds, cache_sds, batch_sds["tokens"]), rules, {}
