"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; tests and benches must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1, axes=("data", "model")):
    """Whatever this host offers (1 device on CPU; 8 under the test flag)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_parallel, model_parallel), axes)


# TPU v5e hardware constants used by the roofline (§Roofline).
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
HBM_BYTES = 16 * 1024 ** 3    # 16 GiB per chip
