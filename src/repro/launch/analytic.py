"""Analytic roofline terms — the primary §Roofline numbers.

Why analytic: XLA-CPU's ``HloCostAnalysis`` counts ``while``-loop bodies
ONCE (measured: an 8-iteration scan of d=256 matmuls reports 1.19 MFLOP vs
4.19 MFLOP true — see EXPERIMENTS.md §Caveats), so for scan-based models the
compiled FLOPs/bytes/collectives are under-counted by ~layers-per-chunk.
The cost model below is exact under the paper's execution model:

- **compute**: per-stage analytic FLOPs (``models/flops.py``, 2·N·M·K math)
  × the *schedule's* per-stage execution counts (recompute included — this
  is where rotor's time-for-memory trade shows up), ÷ chips ÷ peak.
- **memory**: per-device HBM traffic = activation stream (each forward op
  reads ``ω_a``/writes its output, each backward reads ``ā`` + writes δ and
  parameter gradients) + per-execution parameter reads (post-all-gather TP
  shard) + optimizer state read/write; decode adds the KV/SSM cache read.
- **collective**: FSDP all-gathers (param shard × executions), gradient
  reduce-scatter + cross-pod all-reduce, MoE all-to-alls (dispatch buffer ×
  2 directions × executions), and the logits-reduction for vocab-sharded
  heads.
"""

from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import numpy as np

from ..core.schedule import BWD, F_ALL, F_CK, F_NONE, Schedule
from ..models.flops import _layer_flops, stage_flops
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def _bytes_of_tree(tree) -> int:
    return sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


def _axis(mesh, name) -> int:
    return mesh.shape.get(name, 1)


def train_terms(cfg, shape, mesh, model, chain, schedule: Optional[Schedule]
                ) -> Dict[str, float]:
    B, S = shape.global_batch, shape.seq_len
    n_dev = mesh.size
    dp = _axis(mesh, "pod") * _axis(mesh, "data")
    tp = _axis(mesh, "model")
    if B % dp:
        dp = 1
    fwd_flops, bwd_flops = stage_flops(cfg, B, S)
    sched = schedule or Schedule.store_all(chain.length)
    fwd_counts: Dict[int, int] = {}
    for kind, l in sched.ops:
        if kind in (F_ALL, F_CK, F_NONE):
            fwd_counts[l] = fwd_counts.get(l, 0) + 1

    # --- compute ---------------------------------------------------------
    total_flops = 0.0
    inner = 1.0 if cfg.scan_layer_remat in ("full", "save_moe") else 0.0
    for l in range(1, chain.length + 2):
        c = fwd_counts.get(l, 1)
        total_flops += c * fwd_flops[l - 1]
        # backward = 2×fwd (+1×fwd replay if inner per-layer remat)
        total_flops += (2.0 + inner) * fwd_flops[l - 1]
    compute_s = total_flops / n_dev / PEAK_FLOPS_BF16

    # --- memory traffic (per device) --------------------------------------
    params_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    stage_specs = model.stage_params(params_spec)
    stage_pbytes = [_bytes_of_tree(s) for s in stage_specs]
    p_total = _bytes_of_tree(params_spec)
    traffic = 0.0
    for kind, l in sched.ops:
        pb = stage_pbytes[l - 1] / tp  # post-all-gather TP-local weights
        if kind in (F_ALL, F_CK, F_NONE):
            out = chain.wabar[l - 1] if kind == F_ALL else (
                chain.wa[l] if l <= chain.length else 0.0)
            traffic += chain.wa[l - 1] + out + pb
        else:  # backward: read ā + δ + params, write δ + param grads
            traffic += (chain.wabar[l - 1] + 2 * chain.wdelta[l - 1] + 2 * pb)
    # optimizer: p(read+write) bf16 + m,v f32 (read+write), grads read — all
    # fully sharded (ZeRO-3): 2·2 + 2·8 + 2 = 22 bytes/param ÷ n_dev
    traffic += 22.0 * (p_total / 2) / n_dev
    memory_s = traffic / HBM_BW

    # --- collectives (per device) ------------------------------------------
    coll = 0.0
    fsdp = dp
    for kind, l in sched.ops:
        shard = stage_pbytes[l - 1] / n_dev
        if fsdp > 1:
            coll += shard * (fsdp - 1)  # all-gather the FSDP dim per use
    # gradient reduce-scatter (ring: ~shard×(dp-1) per device) + pod reduce
    coll += (p_total / n_dev) * (fsdp - 1)
    # MoE all-to-alls: dispatch+return, fwd / bwd / inner-remat replay
    n_moe = sum(1 for k in cfg.layer_kinds if k == "moe")
    if n_moe and cfg.num_experts % tp == 0 and tp > 1:
        Tl = B * S // dp
        cap = -(-max(4, math.ceil(Tl * cfg.moe_top_k / cfg.num_experts
                                  * cfg.moe_capacity_factor)) // 8) * 8
        buf = cfg.num_experts * cap * cfg.d_model * 2  # bf16
        passes = 2 + 2 + (2 if cfg.scan_layer_remat == "full" else 0)
        coll += n_moe * buf * passes * (tp - 1) / tp
    collective_s = coll / ICI_BW
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s,
            "flops_per_device": total_flops / n_dev,
            "hbm_bytes_per_device": traffic,
            "collective_bytes_per_device": coll}


def decode_terms(cfg, shape, mesh, model) -> Dict[str, float]:
    B, S = shape.global_batch, shape.seq_len
    n_dev = mesh.size
    dp = _axis(mesh, "pod") * _axis(mesh, "data")
    if B % dp:
        dp = 1
    tp = _axis(mesh, "model")
    params_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_bytes = _bytes_of_tree(params_spec)
    cache_spec = jax.eval_shape(lambda: model.init_cache(B, S))
    c_bytes = _bytes_of_tree(cache_spec)
    # flops: one token through active params + attention over the cache
    flops = 2.0 * cfg.active_params() * B
    for kind, start, length in cfg.chunks:
        if kind in ("dense", "moe"):
            flops += length * (_layer_flops(cfg, "dense", B, 1, kv_len=S)
                               - _layer_flops(cfg, "dense", B, 1, kv_len=1))
    compute_s = flops / n_dev / PEAK_FLOPS_BF16
    # memory: read the resident param shard + the whole cache; the cache
    # write-back is only the new token's slice (the cache buffer is donated
    # and aliased in place on TPU)
    traffic = p_bytes / n_dev + c_bytes / n_dev * (1.0 + 1.0 / max(S, 1))
    memory_s = traffic / HBM_BW
    # collectives: per-layer activation all-reduce for TP (y partial sums)
    n_layers = cfg.num_layers
    coll = n_layers * B / dp * cfg.d_model * 2 * 2 * (tp - 1) / tp
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll / ICI_BW,
            "flops_per_device": flops / n_dev,
            "hbm_bytes_per_device": traffic,
            "collective_bytes_per_device": coll}


def prefill_terms(cfg, shape, mesh, model) -> Dict[str, float]:
    B, S = shape.global_batch, shape.seq_len
    n_dev = mesh.size
    fwd_flops, _ = stage_flops(cfg, B, S)
    flops = float(sum(fwd_flops))
    params_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_bytes = _bytes_of_tree(params_spec)
    cache_spec = jax.eval_shape(lambda: model.init_cache(B, S))
    c_bytes = _bytes_of_tree(cache_spec)
    act = B * S * cfg.d_model * 2 * (2 * cfg.num_layers)  # stream in/out
    traffic = (p_bytes + act + c_bytes) / n_dev
    tp = _axis(mesh, "model")
    coll = (p_bytes / n_dev) * (mesh.size / tp - 1)  # FSDP gathers
    return {"compute_s": flops / n_dev / PEAK_FLOPS_BF16,
            "memory_s": traffic / HBM_BW,
            "collective_s": coll / ICI_BW,
            "flops_per_device": flops / n_dev,
            "hbm_bytes_per_device": traffic,
            "collective_bytes_per_device": coll}
