"""Serving launcher: ``python -m repro.launch.serve --arch <id> [--smoke]``:
prefill a batch of prompts and greedy-decode with the jitted one-token step."""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from ..configs import get_config, smoke_config
from ..models.lm import StagedLM
from ..runtime.serve_loop import ServeLoopConfig, run_serving


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--override", default=None)
    args = ap.parse_args(argv)

    ov = json.loads(args.override) if args.override else {}
    cfg = smoke_config(args.arch, **ov) if args.smoke else get_config(args.arch, **ov)
    if cfg.modality != "text":
        print(f"[serve] {cfg.name} is {cfg.modality}; serving the text-token "
              "decoder path requires token inputs — using random tokens for "
              "the backbone" if cfg.modality == "vlm" else
              "[serve] audio backbone: decoding over codec tokens")
    model = StagedLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    if cfg.modality == "vlm":
        # serve the gemma decoder without an image prefix (text-only mode)
        import dataclasses
        cfg = dataclasses.replace(cfg, prefix_len=0, modality="text")
        model = StagedLM(cfg)

    loop = ServeLoopConfig(max_new_tokens=args.max_new_tokens,
                           max_len=args.prompt_len + args.max_new_tokens + 1)
    if cfg.modality == "audio_embed":
        print("[serve] audio arch: skipping (frontend stub has no tokenizer)")
        return 0
    out = run_serving(cfg, params, prompts, loop, model=model)
    print(f"[serve] prefill {out['prefill_s']*1e3:.1f} ms, "
          f"decode {out['decode_tokens_per_s']:.1f} tok/s")
    print("[serve] sample generation:", out["generations"][0][:12].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
