"""Roofline-term extraction from a compiled dry-run artifact (§Roofline).

- compute term     = per-device HLO FLOPs / 197 TFLOP/s (bf16, v5e)
- memory term      = per-device HLO bytes-accessed / 819 GB/s
- collective term  = per-device collective operand bytes / 50 GB/s per link

``cost_analysis`` gives FLOPs/bytes of the per-device SPMD module directly;
collective bytes are not in cost_analysis, so we parse the compiled HLO text
and sum operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (including the async ``-start`` forms).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(1, int(m.group(2)))  # [n_groups, group_size]<=[...]
    return 1


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum of operand bytes per collective kind (per-device module).

    Compiled-HLO operands are printed without inline shapes, so operand sizes
    are derived from the *result* shape: all-reduce / all-to-all /
    collective-permute results equal their operands; an all-gather result is
    ``group_size ×`` its operand; a reduce-scatter result is ``1/group_size``
    of its operand.
    """
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        result_bytes = 0
        for sm in _SHAPE_RE.finditer(line[m.start():m.end()]):
            result_bytes += _shape_bytes(sm.group(1), sm.group(2))
        g = _group_size(line)
        if kind == "all-gather":
            operand = result_bytes / g
        elif kind == "reduce-scatter":
            operand = result_bytes * g
        else:
            operand = result_bytes
        out[kind] += float(operand)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device
    bytes_accessed: float        # per-device
    collective_bytes: float      # per-device
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float           # 6·N_active·D (global)
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs · chips)
    per_device_peak_bytes: Optional[float] = None
    collective_breakdown: Optional[Dict[str, float]] = None

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def analyze(compiled, n_devices: int, model_flops: float,
            hlo_text: Optional[str] = None) -> Roofline:
    from ..compat import cost_analysis_dict
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes_from_hlo(text)
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = byts / HBM_BW
    collective_s = coll["total"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops / max(flops * n_devices, 1.0)
    peak = None
    try:
        ma = compiled.memory_analysis()
        peak = float(ma.temp_size_in_bytes + ma.argument_size_in_bytes
                     + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    except Exception:
        pass
    return Roofline(flops=flops, bytes_accessed=byts,
                    collective_bytes=coll["total"], compute_s=compute_s,
                    memory_s=memory_s, collective_s=collective_s,
                    dominant=dominant, model_flops=model_flops,
                    useful_ratio=useful, per_device_peak_bytes=peak,
                    collective_breakdown=coll)
