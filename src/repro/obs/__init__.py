"""`repro.obs` — observability for planned execution.

Three layers, one loop:

- :mod:`repro.obs.trace` — a lightweight span recorder both executors emit
  per-op spans into; exports Chrome/Perfetto ``trace.json`` and the
  :meth:`~repro.plan.MemoryPlan.timeline` schema so predicted and measured
  timelines render side by side.
- :mod:`repro.obs.metrics` — a process-wide counters/gauges/histograms
  registry (JSON snapshot) wired into the hot seams: solver-cache
  hits/misses/evictions, DP fill wall time per impl, autotuner calibration
  decisions, host-buffer pin-pool occupancy, offload stall time, train-loop
  step time/loss, serving KV residency.
- :mod:`repro.obs.drift` — compare a plan's simulator-predicted
  makespan/peaks/stall against a measured trace, report per-layer drift,
  and feed measured per-layer times back into the chain cost model
  (:meth:`Chain.calibrate <repro.core.chain.Chain.calibrate>` → re-plan →
  convergence).

Everything here is stdlib + numpy only at import time (jax is touched
lazily, only to fence traced ops), so the numpy core can report without
dragging in an accelerator runtime.
"""

from . import metrics
from .drift import DriftReport, LayerDrift, calibrate_from_trace, compare
from .trace import (
    Span,
    Tracer,
    measured_stage_times,
    validate_perfetto,
    validate_trace_file,
)

__all__ = [
    "metrics",
    "Span",
    "Tracer",
    "measured_stage_times",
    "validate_perfetto",
    "validate_trace_file",
    "DriftReport",
    "LayerDrift",
    "compare",
    "calibrate_from_trace",
]
