"""Process-wide metrics registry: counters, gauges, histograms.

The hot seams of the planning/execution stack report here — solver-cache
hits/misses/evictions, DP fill wall time per impl, autotuner calibration
decisions, host-buffer pin-pool occupancy, offload stall time, train-loop
step time and loss, serving KV residency (``serve.kv_bytes`` is *logical*
residency tracking the cache position, ``serve.kv_bytes_allocated`` the
padded allocation, ``serve.decode_tokens`` live tokens only, and the
KV-residency policies add ``serve.kv_transfer_bytes`` /
``serve.kv_stall_seconds``).  The registry is deliberately
dependency-free (stdlib only) so the numpy core and jax-free modules can
import it without dragging in an accelerator runtime.

Usage::

    from repro.obs import metrics
    metrics.counter("solver_cache.hits").inc()
    metrics.gauge("host_buffer.bytes_in_use").set(pool.bytes_in_use)
    with metrics.histogram("dp_fill.banded.seconds").time():
        fill()
    snap = metrics.snapshot()          # JSON-serializable dict

All operations are thread-safe and O(1); a disabled registry (env
``REPRO_METRICS=0``) turns every operation into a no-op so instrumented
hot loops pay only an attribute check.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Optional

_FALSEY = {"0", "off", "false", "no"}


class Counter:
    """Monotonically increasing count (plus a value sum for byte counters)."""

    __slots__ = ("name", "count", "total", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self.count += 1
            self.total += n

    @property
    def value(self) -> float:
        return self.total

    def to_json(self) -> Dict[str, Any]:
        return {"type": "counter", "count": self.count, "total": self.total}


class Gauge:
    """Last-write-wins value, tracking its max over the process lifetime."""

    __slots__ = ("name", "value", "max", "updates", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self.max = 0.0
        self.updates = 0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)
            self.max = max(self.max, self.value)
            self.updates += 1

    def to_json(self) -> Dict[str, Any]:
        return {
            "type": "gauge",
            "value": self.value,
            "max": self.max,
            "updates": self.updates,
        }


class Histogram:
    """Streaming summary of observed samples: count / sum / min / max / last.

    No buckets — the consumers here want wall-time aggregates, not
    percentiles, and a fixed-size summary keeps ``observe`` allocation-free.
    """

    __slots__ = ("name", "count", "total", "min", "max", "last", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.last = 0.0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)
            self.last = v

    def time(self) -> "_Timer":
        """Context manager observing the block's wall time in seconds."""
        return _Timer(self)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def to_json(self) -> Dict[str, Any]:
        return {
            "type": "histogram",
            "count": self.count,
            "total": self.total,
            "mean": self.mean,
            "last": self.last,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
        }


class _Timer:
    __slots__ = ("_hist", "_t0")

    def __init__(self, hist: Histogram):
        self._hist = hist

    def __enter__(self) -> "_Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._hist.observe(time.perf_counter() - self._t0)


class _Noop:
    """Stands in for any metric when the registry is disabled."""

    __slots__ = ()

    def inc(self, n: float = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def time(self) -> "_NoopTimer":
        return _NOOP_TIMER


class _NoopTimer:
    __slots__ = ()

    def __enter__(self) -> "_NoopTimer":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NOOP = _Noop()
_NOOP_TIMER = _NoopTimer()


class MetricsRegistry:
    """Thread-safe name → metric map with a JSON snapshot."""

    def __init__(self, enabled: Optional[bool] = None):
        if enabled is None:
            flag = os.environ.get("REPRO_METRICS", "1").strip().lower()
            enabled = flag not in _FALSEY
        self.enabled = enabled
        self._metrics: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, cls):
        if not self.enabled:
            return _NOOP
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}"
                )
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get_or_create(name, Histogram)

    def get(self, name: str):
        """The registered metric, or ``None`` (never creates)."""
        with self._lock:
            return self._metrics.get(name)

    def value(self, name: str, default: float = 0.0) -> float:
        """Scalar reading of a metric: counter count, gauge value,
        histogram count; ``default`` when absent."""
        m = self.get(name)
        if m is None:
            return default
        if isinstance(m, Counter):
            return m.count
        if isinstance(m, Gauge):
            return m.value
        return m.count

    def snapshot(self) -> Dict[str, Any]:
        """JSON-serializable dump of every registered metric."""
        with self._lock:
            items = sorted(self._metrics.items())
        return {name: m.to_json() for name, m in items}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)

    def reset(self) -> None:
        """Drop every registered metric (tests / bench isolation)."""
        with self._lock:
            self._metrics.clear()


# ---------------------------------------------------------------------------
# process-wide default registry
# ---------------------------------------------------------------------------

_default: Optional[MetricsRegistry] = None
_default_lock = threading.Lock()


def registry() -> MetricsRegistry:
    global _default
    with _default_lock:
        if _default is None:
            _default = MetricsRegistry()
        return _default


def reset() -> None:
    """Drop the process-wide registry; the next use rebuilds from the env."""
    global _default
    with _default_lock:
        _default = None


def counter(name: str) -> Counter:
    return registry().counter(name)


def gauge(name: str) -> Gauge:
    return registry().gauge(name)


def histogram(name: str) -> Histogram:
    return registry().histogram(name)


def value(name: str, default: float = 0.0) -> float:
    return registry().value(name, default)


def snapshot() -> Dict[str, Any]:
    return registry().snapshot()


def save(path: str) -> None:
    registry().save(path)
