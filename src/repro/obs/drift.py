"""Plan-vs-actual drift: compare a :class:`~repro.plan.plan.MemoryPlan`'s
simulator-predicted numbers against a measured execution trace, and close
the loop by feeding measured per-layer times back into the
:class:`~repro.core.chain.Chain` cost model.

The paper's whole value proposition is a *predicted* optimal schedule; this
module is how the prediction is held to account.  The workflow mirrors
Dynamic Tensor Rematerialization's measured-cost grounding:

1. execute the plan with a :class:`~repro.obs.trace.Tracer` attached
   (``plan.execute(..., tracer=tr)`` or a traced ``plan.bind``),
2. ``report = drift.compare(plan, tr)`` — per-layer and aggregate drift,
3. ``chain2 = drift.calibrate_from_trace(plan.chain, tr)`` — the chain
   re-priced with measured forward/backward times
   (:meth:`Chain.calibrate`),
4. re-plan on ``chain2`` and compare again: predicted and measured
   converge because the simulator now sums *measured* per-op costs.

Zero-drift sanity: replaying the plan's own predicted timeline
(``Tracer.from_timeline(plan.timeline())``) through :func:`compare` yields
a report with ``makespan_ratio == 1`` and per-layer drift 0 — asserted in
the test suite.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Union

from ..core.chain import Chain
from .trace import Span, Tracer, measured_stage_times


def _spans_of(trace: Union[Tracer, Sequence[Span]]) -> List[Span]:
    return list(trace.spans if isinstance(trace, Tracer) else trace)


def _ratio(measured: float, predicted: float) -> float:
    if predicted <= 0:
        return float("inf") if measured > 0 else 1.0
    return measured / predicted


@dataclasses.dataclass
class LayerDrift:
    """Predicted vs measured compute times for one paper stage."""

    stage: int  # paper stage l (1..L+1)
    uf_predicted: float
    uf_measured: float  # nan when the trace holds no sample
    ub_predicted: float
    ub_measured: float

    @property
    def fwd_ratio(self) -> float:
        return _ratio(self.uf_measured, self.uf_predicted)

    @property
    def bwd_ratio(self) -> float:
        return _ratio(self.ub_measured, self.ub_predicted)

    def to_json(self) -> Dict[str, Any]:
        return {
            "stage": self.stage,
            "uf_predicted": self.uf_predicted,
            "uf_measured": self.uf_measured,
            "ub_predicted": self.ub_predicted,
            "ub_measured": self.ub_measured,
            "fwd_ratio": self.fwd_ratio,
            "bwd_ratio": self.bwd_ratio,
        }


@dataclasses.dataclass
class DriftReport:
    """Aggregate + per-layer drift of one executed plan.

    ``makespan_ratio`` is measured/predicted (1.0 = the simulator was
    exact); ``layer_mape`` is the mean absolute percentage error over every
    per-stage time the trace sampled (the paper §5.3 reports 7.8% on GPU).
    Peak fields are ``None`` when the executor did not record memory.
    """

    predicted_makespan: float
    measured_makespan: float
    layers: List[LayerDrift]
    predicted_stall: float = 0.0
    measured_stall: Optional[float] = None
    predicted_device_peak: Optional[float] = None
    measured_device_peak: Optional[float] = None
    predicted_host_peak: Optional[float] = None
    measured_host_peak: Optional[float] = None
    span_count: int = 0

    @property
    def makespan_ratio(self) -> float:
        return _ratio(self.measured_makespan, self.predicted_makespan)

    @property
    def layer_mape(self) -> float:
        """Mean |measured - predicted| / predicted over sampled stage times,
        in percent; ``nan`` when nothing was sampled."""
        errs = []
        for ld in self.layers:
            pairs = (
                (ld.uf_measured, ld.uf_predicted),
                (ld.ub_measured, ld.ub_predicted),
            )
            for meas, pred in pairs:
                if math.isnan(meas) or pred <= 0:
                    continue
                errs.append(abs(meas - pred) / pred)
        if not errs:
            return float("nan")
        return 100.0 * sum(errs) / len(errs)

    def to_json(self) -> Dict[str, Any]:
        return {
            "predicted_makespan_s": self.predicted_makespan,
            "measured_makespan_s": self.measured_makespan,
            "makespan_ratio": self.makespan_ratio,
            "layer_mape_percent": self.layer_mape,
            "predicted_stall_s": self.predicted_stall,
            "measured_stall_s": self.measured_stall,
            "predicted_device_peak": self.predicted_device_peak,
            "measured_device_peak": self.measured_device_peak,
            "predicted_host_peak": self.predicted_host_peak,
            "measured_host_peak": self.measured_host_peak,
            "span_count": self.span_count,
            "layers": [ld.to_json() for ld in self.layers],
        }

    def summary(self) -> str:
        head = (
            f"DriftReport: predicted {self.predicted_makespan:.4f}s, "
            f"measured {self.measured_makespan:.4f}s "
            f"(x{self.makespan_ratio:.2f})"
        )
        lines = [head]
        mape = self.layer_mape
        if not math.isnan(mape):
            msg = f"  per-layer time MAPE: {mape:.1f}% over {self.span_count} spans"
            lines.append(msg)
        if self.measured_stall is not None:
            msg = (
                f"  transfer stall: predicted {self.predicted_stall:.4f}s, "
                f"measured {self.measured_stall:.4f}s"
            )
            lines.append(msg)
        worst = [
            ld
            for ld in self.layers
            if not math.isnan(ld.uf_measured) and ld.uf_predicted > 0
        ]
        if worst:
            w = max(worst, key=lambda ld: abs(math.log(max(ld.fwd_ratio, 1e-12))))
            msg = (
                f"  worst forward drift: stage {w.stage} "
                f"(predicted {w.uf_predicted:.2e}s, measured "
                f"{w.uf_measured:.2e}s)"
            )
            lines.append(msg)
        return "\n".join(lines)


def compare(plan, trace: Union[Tracer, Sequence[Span]]) -> DriftReport:
    """Drift of one executed plan: ``plan`` is a
    :class:`~repro.plan.plan.MemoryPlan` (needs a profiled chain for the
    per-layer rows), ``trace`` the tracer (or span list) its execution
    filled."""
    spans = _spans_of(trace)
    chain: Optional[Chain] = plan.chain
    if spans:
        t0 = min(s.t_start for s in spans)
        t1 = max(s.t_end for s in spans)
        measured_makespan = t1 - t0
    else:
        measured_makespan = 0.0
    measured_stall = None
    stall_samples = [s for s in spans if s.op == "Prefetch"]
    if stall_samples:
        measured_stall = sum(s.duration for s in stall_samples)
    layers: List[LayerDrift] = []
    if chain is not None:
        uf_m, ub_m = measured_stage_times(spans, chain.length)
        for i in range(chain.length + 1):
            layers.append(
                LayerDrift(
                    stage=i + 1,
                    uf_predicted=float(chain.uf[i]),
                    uf_measured=uf_m[i],
                    ub_predicted=float(chain.ub[i]),
                    ub_measured=ub_m[i],
                )
            )
    dev_peaks = [s.device_mem for s in spans if s.device_mem is not None]
    host_peaks = [s.host_mem for s in spans if s.host_mem is not None]
    return DriftReport(
        predicted_makespan=float(plan.expected_time),
        measured_makespan=measured_makespan,
        layers=layers,
        predicted_stall=float(plan.transfer_stall),
        measured_stall=measured_stall,
        predicted_device_peak=float(plan.peak_device_mem),
        measured_device_peak=max(dev_peaks) if dev_peaks else None,
        predicted_host_peak=float(plan.peak_host_mem),
        measured_host_peak=max(host_peaks) if host_peaks else None,
        span_count=len(spans),
    )


def calibrate_from_trace(chain: Chain, trace: Union[Tracer, Sequence[Span]]) -> Chain:
    """The chain re-priced with measured per-stage times
    (:meth:`Chain.calibrate`): stages the trace never sampled keep their
    modeled costs.  Feed the result back into ``build_plan`` to re-plan on
    measured ground truth."""
    spans = _spans_of(trace)
    uf, ub = measured_stage_times(spans, chain.length)
    return chain.calibrate(uf=uf, ub=ub)
