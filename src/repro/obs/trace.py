"""Lightweight span recorder for planned execution.

Both executors — the op-faithful eager walker
(:func:`repro.offload.executor.execute_offload_schedule`, reached through
``core.executor.execute_schedule`` / ``plan.execute``) and the jitted
nested-remat binding (:class:`repro.plan.plan.BoundPlan`, behind an opt-in
flag) — emit one :class:`Span` per schedule op into a :class:`Tracer`:
op kind (``Fall``/``Fck``/``Fnone``/``B``/``Foff``/``Prefetch``, plus
``Decode`` from the serving loop and ``Step`` from the train loop), op
index, bytes moved/produced where cheap to know, and wall time.

The recorder is deliberately dumb: ``record`` appends a dataclass to a
list.  All interpretation lives in the exporters —

- :meth:`Tracer.to_perfetto` — Chrome/Perfetto ``trace.json`` (the
  ``chrome://tracing`` / https://ui.perfetto.dev event format), one complete
  ``"X"`` event per span, one track per span category;
- :meth:`Tracer.to_timeline` — the :meth:`repro.plan.MemoryPlan.timeline`
  schema (``op``/``arg``/``t_start``/``t_end``/``device_mem``/``host_mem``)
  so a *measured* timeline renders side by side with the simulator's
  *predicted* one and feeds :mod:`repro.obs.drift` directly.

Timestamps are ``time.perf_counter`` seconds relative to the tracer's
epoch (its construction, or the first span).  ``sync=True`` (the default)
fences each traced op with ``jax.block_until_ready`` so a span's wall time
covers the op's real device work, not just its Python dispatch — this is
the opt-in cost of tracing; untraced runs are untouched.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence

#: span categories, used as Perfetto track (tid) names
CAT_FORWARD = "forward"
CAT_BACKWARD = "backward"
CAT_TRANSFER = "transfer"
CAT_STEP = "step"
CAT_DECODE = "decode"

#: track order in the Perfetto export ("misc" catches unknown op kinds)
_CATEGORIES = (CAT_FORWARD, CAT_BACKWARD, CAT_TRANSFER, CAT_STEP, CAT_DECODE)

_OP_CATEGORY = {
    "Fall": CAT_FORWARD,
    "Fck": CAT_FORWARD,
    "Fnone": CAT_FORWARD,
    "B": CAT_BACKWARD,
    "Foff": CAT_TRANSFER,
    "Prefetch": CAT_TRANSFER,
    "Step": CAT_STEP,
    "Decode": CAT_DECODE,
}


def category_of(op: str) -> str:
    return _OP_CATEGORY.get(op, "misc")


@dataclasses.dataclass
class Span:
    """One timed operation: ``[t_start, t_end]`` in tracer-epoch seconds."""

    op: str  # op kind (Fall/Fck/Fnone/B/Foff/Prefetch/...)
    arg: Any  # op index (stage l or activation i)
    t_start: float
    t_end: float
    bytes: Optional[int] = None  # bytes produced/moved, when known
    device_mem: Optional[float] = None
    host_mem: Optional[float] = None
    extra: Optional[Dict[str, Any]] = None

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start

    @property
    def category(self) -> str:
        return category_of(self.op)


class Tracer:
    """Append-only span recorder with Perfetto / timeline exporters.

    ``enabled=False`` makes every call a no-op (so call sites can thread one
    tracer object unconditionally); ``sync`` asks instrumented executors to
    fence each op with ``jax.block_until_ready`` before closing its span.
    """

    def __init__(self, enabled: bool = True, sync: bool = True, name: str = "repro"):
        self.enabled = enabled
        self.sync = sync
        self.name = name
        self.spans: List[Span] = []
        self._epoch = time.perf_counter()

    # -- recording ---------------------------------------------------------

    def now(self) -> float:
        return time.perf_counter() - self._epoch

    def record(self, op: str, arg: Any, t_start: float, t_end: float, **kw) -> None:
        """Append a span with explicit epoch-relative times."""
        if not self.enabled:
            return
        self.spans.append(Span(op, arg, t_start, t_end, **kw))

    def span(self, op: str, arg: Any = None, **kw) -> "_SpanCtx":
        """Context manager measuring the block as one span."""
        return _SpanCtx(self, op, arg, kw)

    def fence(self, value: Any) -> None:
        """Block on a jax value (when ``sync``), so the enclosing span's end
        time covers the device work.  Accepts arbitrary pytrees; silently
        skips non-jax values so CPU/numpy paths trace too."""
        if not (self.enabled and self.sync) or value is None:
            return
        try:
            import jax

            jax.block_until_ready(value)
        except Exception:
            pass

    def clear(self) -> None:
        self.spans.clear()
        self._epoch = time.perf_counter()

    def __len__(self) -> int:
        return len(self.spans)

    # -- exporters ---------------------------------------------------------

    @property
    def makespan(self) -> float:
        """Wall time covered by the recorded spans, in seconds."""
        if not self.spans:
            return 0.0
        t0 = min(s.t_start for s in self.spans)
        t1 = max(s.t_end for s in self.spans)
        return t1 - t0

    def to_perfetto(self) -> Dict[str, Any]:
        """Chrome trace-event JSON: one complete ("X") event per span, with
        microsecond timestamps, grouped into one named track per category."""
        tids = {}
        events: List[Dict[str, Any]] = []
        for cat in _CATEGORIES + ("misc",):
            tids[cat] = len(tids) + 1
        for cat, tid in tids.items():
            meta = {"name": "thread_name", "ph": "M", "pid": 1, "tid": tid}
            meta["args"] = {"name": cat}
            events.append(meta)
        for s in self.spans:
            args: Dict[str, Any] = {"arg": s.arg}
            if s.bytes is not None:
                args["bytes"] = s.bytes
            if s.device_mem is not None:
                args["device_mem"] = s.device_mem
            if s.host_mem is not None:
                args["host_mem"] = s.host_mem
            if s.extra:
                args.update(s.extra)
            events.append(
                {
                    "name": f"{s.op}^{s.arg}" if s.arg is not None else s.op,
                    "cat": s.category,
                    "ph": "X",
                    "pid": 1,
                    "tid": tids.get(s.category, tids["misc"]),
                    "ts": s.t_start * 1e6,
                    "dur": max(s.duration, 0.0) * 1e6,
                    "args": args,
                }
            )
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"tracer": self.name},
        }

    def to_timeline(self) -> List[Dict[str, Any]]:
        """The measured timeline in the exact
        :meth:`repro.plan.MemoryPlan.timeline` schema (memory fields are
        ``None`` unless the executor recorded them)."""
        rows = []
        for s in self.spans:
            rows.append(
                {
                    "op": s.op,
                    "arg": s.arg,
                    "t_start": s.t_start,
                    "t_end": s.t_end,
                    "device_mem": s.device_mem,
                    "host_mem": s.host_mem,
                }
            )
        return rows

    def save(self, path: str) -> None:
        """Write the Perfetto ``trace.json`` (load at ui.perfetto.dev)."""
        with open(path, "w") as f:
            json.dump(self.to_perfetto(), f)

    # -- constructors ------------------------------------------------------

    @staticmethod
    def from_timeline(
        rows: Iterable[Dict[str, Any]], name: str = "simulator"
    ) -> "Tracer":
        """A tracer replaying a predicted timeline
        (:meth:`repro.plan.MemoryPlan.timeline` rows) as spans — the bridge
        that lets :mod:`repro.obs.drift` compare simulator against
        simulator (zero drift by construction) or render a predicted
        timeline through the same Perfetto exporter."""
        tr = Tracer(name=name)
        for r in rows:
            tr.record(
                r["op"],
                r["arg"],
                float(r["t_start"]),
                float(r["t_end"]),
                device_mem=r.get("device_mem"),
                host_mem=r.get("host_mem"),
            )
        return tr


class _SpanCtx:
    __slots__ = ("_tr", "_op", "_arg", "_kw", "_t0")

    def __init__(self, tracer: Tracer, op: str, arg: Any, kw: Dict[str, Any]):
        self._tr = tracer
        self._op = op
        self._arg = arg
        self._kw = kw

    def __enter__(self) -> "_SpanCtx":
        self._t0 = self._tr.now()
        return self

    def __exit__(self, *exc) -> None:
        self._tr.record(self._op, self._arg, self._t0, self._tr.now(), **self._kw)


# ---------------------------------------------------------------------------
# validation (CI artifact check + tests)
# ---------------------------------------------------------------------------


def validate_perfetto(doc: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Validate a Perfetto trace document: returns the complete ("X")
    events, raising ``ValueError`` on an empty, malformed, or
    non-monotone trace.  Used by the CI smoke step and the schema tests."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("not a Chrome trace document (no traceEvents)")
    events = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    if not events:
        raise ValueError("trace has no complete ('X') span events")
    last_ts = None
    for e in events:
        for key in ("name", "ts", "dur", "pid", "tid"):
            if key not in e:
                raise ValueError(f"span event missing {key!r}: {e}")
        ts, dur = float(e["ts"]), float(e["dur"])
        if dur < 0:
            raise ValueError(f"negative duration: {e}")
        if last_ts is not None and ts + 1e-9 < last_ts:
            raise ValueError(f"non-monotone span start: {ts} after {last_ts}")
        last_ts = ts
    return events


def validate_trace_file(path: str) -> int:
    """Validate a ``trace.json`` on disk; returns the span count."""
    with open(path) as f:
        doc = json.load(f)
    return len(validate_perfetto(doc))


# ---------------------------------------------------------------------------
# measured per-stage times (consumed by repro.obs.drift / Chain.calibrate)
# ---------------------------------------------------------------------------


def measured_stage_times(spans: Sequence[Span], length: int):
    """Aggregate spans into per-stage mean forward/backward wall times.

    Returns ``(uf, ub)`` — two float lists of length ``length + 1`` (stage
    ``l`` of the paper at index ``l - 1``, loss stage last), ``nan`` where
    the trace holds no sample — exactly the shape
    :meth:`repro.core.chain.Chain.calibrate` consumes.  Forward samples
    pool every execution of the stage (``Fall``/``Fck``/``Fnone``,
    recomputes included); backward samples come from ``B`` spans.
    """
    n = length + 1
    fwd_sum = [0.0] * n
    fwd_cnt = [0] * n
    bwd_sum = [0.0] * n
    bwd_cnt = [0] * n
    for s in spans:
        if s.op in ("Fall", "Fck", "Fnone"):
            stage = int(s.arg)
            if 1 <= stage <= n:
                fwd_sum[stage - 1] += s.duration
                fwd_cnt[stage - 1] += 1
        elif s.op == "B":
            stage = int(s.arg)
            if 1 <= stage <= n:
                bwd_sum[stage - 1] += s.duration
                bwd_cnt[stage - 1] += 1
    nan = float("nan")
    uf = [fwd_sum[i] / fwd_cnt[i] if fwd_cnt[i] else nan for i in range(n)]
    ub = [bwd_sum[i] / bwd_cnt[i] if bwd_cnt[i] else nan for i in range(n)]
    return uf, ub
