"""AdamW in pure JAX (pytree-generic), with global-norm clipping.

Moments are kept in f32 regardless of param dtype (bf16 params train
stably with f32 first/second moments); under the production mesh the moment
trees inherit the params' (FSDP × TP) sharding, i.e. ZeRO-3-style placement
comes from GSPMD for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads: Any, state: dict, params: Any,
                 lr: Optional[jax.Array] = None) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
    count = state["count"] + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)
    lr = cfg.lr if lr is None else lr

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        step = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # decay matrices, not norms/bias
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["mu"])
    flat_v = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm,
               "param_norm": global_norm(params)}
    return new_p, {"mu": new_m, "nu": new_v, "count": count}, metrics
