from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from .schedules import cosine_schedule, linear_warmup_cosine
