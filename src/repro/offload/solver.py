"""Offload-aware optimal persistent checkpointing — the three-tier DP.

Extends the paper's recursion (core/solver.py) with a third saving: park the
sub-chain input ``a^{s-1}`` in host RAM, reclaiming its device slots while the
right segment runs, and pay the PCIe cost only where it is not hidden by
compute.  The branch (``C3``) mirrors the structure of ``C1``:

.. math::

    C3(s,t,m) = \\min_{s'} \\Big[ X + \\max(T_{off}(a^{s-1}) - X,\\, 0)
                + T_{pre}(a^{s-1}) + C_b(s, s'-1, m) \\Big],
    \\quad X = \\sum_{k=s}^{s'-1} u_f^k + C_b(s', t,\\,
              m + w_{a^{s-1}} - w_{a^{s'-1}})

The offload is launched asynchronously at the start of the group, so it
overlaps the whole forward stream *and* the right segment (``X``); only the
residue stalls.  The prefetch is issued once the right segment finishes (its
target slots only exist from then on) and is charged in full.

Because an input can only be offloaded while it exists as a *bare* device
activation — after ``F_all^s`` the child's input lives embedded inside
``ā^s`` and its bytes cannot be reclaimed — the DP carries one extra state
bit: ``C_b`` (input bare, all three branches) vs ``C_e`` (input embedded,
two-tier branches only).  ``C2`` children are embedded; ``C1``/``C3`` right
children are bare; left children inherit the parent's bit (same input).

With no host model (or zero bandwidth) every ``C3`` candidate is +inf and the
tables reduce exactly to the two-tier DP — ``solve_optimal_offload`` then
simply delegates to ``core.solver.solve_optimal``.

Like the two-tier solver, the fill runs on the banded split-batched kernels
of :mod:`repro.core.dp_kernels` by default (the C3 branch is one more batched
candidate plane; ``impl="reference"`` keeps the seed per-cell float64 path).
``impl="pallas"`` stages the same recursion on the per-band Pallas kernel
(three accumulators per pass, C3 stall pre-folded to ``max(X, T_off)``) and
``impl="pallas_fused"`` runs it as ONE ``pallas_call`` with both cost tables
and all four companion buffers device-resident — see
:mod:`repro.kernels.dp_fill`.  Results are memoized through
:mod:`repro.core.solver_cache`.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

import numpy as np

from ..core import dp_kernels, solver_cache
from ..core.chain import Chain
from ..obs import metrics as _obs
from ..core.schedule import (BWD, F_ALL, F_CK, F_NONE, F_OFF, PREFETCH,
                             Schedule, simulate)
from ..core.solver import (INFEASIBLE, AllNode, CkNode, Leaf, Solution,
                           _m_all, _m_none, _resolve_impl, _shift, _views)
from ..core.solver import solve_optimal as _solve_optimal_two_tier


@dataclasses.dataclass
class OffNode:
    """``F_off^{s-1}`` first: the group input ``a^{s-1}`` is parked in host
    RAM while ``[s, sp-1]`` is streamed with ``F_∅`` and ``[sp, t]`` is
    solved; a ``Prefetch`` restores it before ``[s, sp-1]`` is re-solved."""
    s: int
    sp: int
    right: "Tree"   # sub-chain [sp, t]
    left: "Tree"    # sub-chain [s, sp-1], executed after the prefetch


Tree = Union[Leaf, AllNode, CkNode, OffNode]


def tree_uses_offload(tree) -> bool:
    """True if any node of the recursion tree is an ``OffNode``."""
    if isinstance(tree, OffNode):
        return True
    if isinstance(tree, AllNode):
        return tree_uses_offload(tree.rest)
    if isinstance(tree, CkNode):
        return tree_uses_offload(tree.right) or tree_uses_offload(tree.left)
    return False


# ---------------------------------------------------------------------------
# Reference DP tables — one (C, choice, split) triple per input-state bit
# ---------------------------------------------------------------------------

class _OffloadTables:
    """``b``: input bare (offloadable); ``e``: input embedded in an ā."""

    def __init__(self, L: int, S: int):
        self.L, self.S = L, S
        shape = (L + 2, L + 2, S + 1)
        self.Cb = np.full(shape, INFEASIBLE, dtype=np.float64)
        self.Ce = np.full(shape, INFEASIBLE, dtype=np.float64)
        # choice: 0 = infeasible, 1 = Ck, 2 = All, 3 = Offload
        self.chb = np.zeros(shape, dtype=np.int8)
        self.che = np.zeros(shape, dtype=np.int8)
        self.spb = np.zeros(shape, dtype=np.int16)
        self.spe = np.zeros(shape, dtype=np.int16)

    @property
    def nbytes(self) -> int:
        return (self.Cb.nbytes + self.Ce.nbytes + self.chb.nbytes
                + self.che.nbytes + self.spb.nbytes + self.spe.nbytes)


def _fill_tables_offload(dchain, tables: _OffloadTables,
                         allow_fall: bool = True,
                         prune: Optional[bool] = None) -> None:
    v = _views(dchain)
    L, S = tables.L, tables.S
    ms = np.arange(S + 1)
    Cb, Ce = tables.Cb, tables.Ce
    host = dchain.chain.host
    # transfer times use *continuous* sizes (times are never discretized)
    t_off = dchain.chain.offload_times()
    t_pre = dchain.chain.prefetch_times()
    caps = (dp_kernels.saturation_caps(v, S, allow_fall)
            if dp_kernels._resolve_prune(prune) else None)

    # base cases: a single stage is F_all^s; B^s in both input states
    for s in range(1, L + 2):
        feas = ms >= _m_all(v, s, s)
        for C, ch in ((Cb, tables.chb), (Ce, tables.che)):
            C[s, s, feas] = v["UF"][s] + v["UB"][s]
            ch[s, s, feas] = 2

    for d in range(1, L + 1):
        W = dp_kernels.band_width(caps, d, S)
        msW = ms[:W]
        for s in range(1, L + 2 - d):
            t = s + d
            sps = np.arange(s + 1, t + 1)
            m_none = _m_none(v, s, t)

            # shared across branches: the right segment is always entered
            # with a bare input (produced by the F_∅ stream).  All reads
            # below are column-aligned on [0, W) — a negative (memory-gain)
            # C3 shift clamps to column W-1, which the saturation invariant
            # makes equal to column S, so slicing stays exact.
            right = np.empty((len(sps), W), dtype=np.float64)
            fwds = np.empty(len(sps))
            for k, sp in enumerate(sps):
                fwds[k] = v["CUM_UF"][sp - 1] - v["CUM_UF"][s - 1]
                right[k] = fwds[k] + _shift(Cb[sp, t, :W],
                                            int(v["WA"][sp - 1]))

            # --- C2: F_all^s first; the child's input is embedded in ā^s --
            c2 = None
            if allow_fall:
                c2 = (v["UF"][s] + _shift(Ce[s + 1, t, :W],
                                          int(v["WABAR"][s])) + v["UB"][s])
                c2[msW < _m_all(v, s, t)] = INFEASIBLE

            # --- C3 right segments: budget gains the reclaimed input slots
            cand3 = None
            if host is not None and host.enabled and np.isfinite(t_off[s - 1]):
                cand3 = np.empty((len(sps), W), dtype=np.float64)
                for k, sp in enumerate(sps):
                    hidden = fwds[k] + _shift(
                        Cb[sp, t, :W],
                        int(v["WA"][sp - 1]) - int(v["WA"][s - 1]))
                    stall = np.maximum(0.0, t_off[s - 1] - hidden)
                    cand3[k] = hidden + stall + t_pre[s - 1]

            for C, CH, SP, bare in ((Cb, tables.chb, tables.spb, True),
                                    (Ce, tables.che, tables.spe, False)):
                # --- C1: F_ck^s first; left child keeps this input state --
                cand1 = np.empty_like(right)
                for k, sp in enumerate(sps):
                    cand1[k] = right[k] + C[s, sp - 1, :W]
                best1 = np.argmin(cand1, axis=0)
                c1 = cand1[best1, msW]
                c1[msW < m_none] = INFEASIBLE

                best = c1
                ch = np.zeros(W, dtype=np.int8)
                ch[np.isfinite(c1)] = 1
                sp_arr = np.where(ch == 1, sps[best1], 0).astype(np.int16)

                if c2 is not None:
                    use2 = c2 < best
                    best = np.where(use2, c2, best)
                    ch[use2 & np.isfinite(c2)] = 2
                    sp_arr[use2] = 0

                if bare and cand3 is not None:
                    full3 = np.empty_like(cand3)
                    for k, sp in enumerate(sps):
                        full3[k] = cand3[k] + Cb[s, sp - 1, :W]
                    best3 = np.argmin(full3, axis=0)
                    c3 = full3[best3, msW]
                    c3[msW < m_none] = INFEASIBLE
                    use3 = c3 < best
                    best = np.where(use3, c3, best)
                    ch[use3 & np.isfinite(c3)] = 3
                    sp_arr[use3] = sps[best3][use3]

                C[s, t, :W] = best
                ch[~np.isfinite(best)] = 0
                CH[s, t, :W] = ch
                SP[s, t, :W] = sp_arr
                if W <= S:
                    C[s, t, W:] = C[s, t, W - 1]
                    CH[s, t, W:] = CH[s, t, W - 1]
                    SP[s, t, W:] = SP[s, t, W - 1]


# ---------------------------------------------------------------------------
# Reconstruction
# ---------------------------------------------------------------------------

def _rebuild(v: dict, dchain, tables: _OffloadTables, s: int, t: int, m: int,
             bare: bool) -> Tuple[List, Tree]:
    """Reference-path reconstruction (``v`` computed once, threaded through)."""
    S = tables.S
    CH = tables.chb if bare else tables.che
    SP = tables.spb if bare else tables.spe
    ch = CH[s, t, m]
    if ch == 0:
        raise ValueError(f"infeasible sub-problem ({s},{t},{m},"
                         f"{'bare' if bare else 'embedded'})")
    if s == t:
        return [(F_ALL, s), (BWD, s)], Leaf(s)
    if ch == 2:
        ops_rest, tree_rest = _rebuild(
            v, dchain, tables, s + 1, t, m - int(v["WABAR"][s]), bare=False)
        return ([(F_ALL, s)] + ops_rest + [(BWD, s)], AllNode(s, tree_rest))
    sp = int(SP[s, t, m])
    if ch == 1:
        ops = [(F_CK, s)] + [(F_NONE, j) for j in range(s + 1, sp)]
        ops_right, tree_right = _rebuild(
            v, dchain, tables, sp, t, m - int(v["WA"][sp - 1]), bare=True)
        ops_left, tree_left = _rebuild(v, dchain, tables, s, sp - 1, m,
                                       bare=bare)
        return ops + ops_right + ops_left, CkNode(s, sp, tree_right, tree_left)
    # ch == 3: offload the group input, stream everything with F_∅
    assert bare, "offload branch reconstructed from an embedded-input state"
    ops = [(F_OFF, s - 1)] + [(F_NONE, j) for j in range(s, sp)]
    m_right = min(m + int(v["WA"][s - 1]) - int(v["WA"][sp - 1]), S)
    ops_right, tree_right = _rebuild(v, dchain, tables, sp, t, m_right,
                                     bare=True)
    ops_left, tree_left = _rebuild(v, dchain, tables, s, sp - 1, m, bare=True)
    ops = ops + ops_right + [(PREFETCH, s - 1)] + ops_left
    return ops, OffNode(s, sp, tree_right, tree_left)


def _rebuild_banded(v: dict, tb, te, toffP, tpre32, s: int, t: int, m: int,
                    bare: bool, allow_fall: bool) -> Tuple[List, Tree]:
    """Banded-path reconstruction via per-cell choice recomputation.
    ``toffP`` is the CUM-shifted offload-time vector (see choose_offload)."""
    S = tb.S
    ch, sp = dp_kernels.choose_offload(v, tb, te, toffP, tpre32, s, t, m,
                                       bare, allow_fall)
    if ch == 0:
        raise ValueError(f"infeasible sub-problem ({s},{t},{m},"
                         f"{'bare' if bare else 'embedded'})")
    if s == t:
        return [(F_ALL, s), (BWD, s)], Leaf(s)
    if ch == 2:
        ops_rest, tree_rest = _rebuild_banded(
            v, tb, te, toffP, tpre32, s + 1, t, m - int(v["WABAR"][s]),
            bare=False, allow_fall=allow_fall)
        return ([(F_ALL, s)] + ops_rest + [(BWD, s)], AllNode(s, tree_rest))
    if ch == 1:
        ops = [(F_CK, s)] + [(F_NONE, j) for j in range(s + 1, sp)]
        ops_right, tree_right = _rebuild_banded(
            v, tb, te, toffP, tpre32, sp, t, m - int(v["WA"][sp - 1]),
            bare=True, allow_fall=allow_fall)
        ops_left, tree_left = _rebuild_banded(
            v, tb, te, toffP, tpre32, s, sp - 1, m, bare=bare,
            allow_fall=allow_fall)
        return ops + ops_right + ops_left, CkNode(s, sp, tree_right, tree_left)
    assert bare, "offload branch reconstructed from an embedded-input state"
    ops = [(F_OFF, s - 1)] + [(F_NONE, j) for j in range(s, sp)]
    m_right = min(m + int(v["WA"][s - 1]) - int(v["WA"][sp - 1]), S)
    ops_right, tree_right = _rebuild_banded(
        v, tb, te, toffP, tpre32, sp, t, m_right, bare=True,
        allow_fall=allow_fall)
    ops_left, tree_left = _rebuild_banded(
        v, tb, te, toffP, tpre32, s, sp - 1, m, bare=True,
        allow_fall=allow_fall)
    ops = ops + ops_right + [(PREFETCH, s - 1)] + ops_left
    return ops, OffNode(s, sp, tree_right, tree_left)


def tree_to_schedule(tree: Tree, length: int) -> Schedule:
    """Flatten a (possibly offload-bearing) recursion tree into ops."""
    ops: List = []

    def rec(node: Tree):
        if isinstance(node, Leaf):
            ops.extend([(F_ALL, node.s), (BWD, node.s)])
        elif isinstance(node, AllNode):
            ops.append((F_ALL, node.s))
            rec(node.rest)
            ops.append((BWD, node.s))
        elif isinstance(node, CkNode):
            ops.append((F_CK, node.s))
            ops.extend((F_NONE, j) for j in range(node.s + 1, node.sp))
            rec(node.right)
            rec(node.left)
        elif isinstance(node, OffNode):
            ops.append((F_OFF, node.s - 1))
            ops.extend((F_NONE, j) for j in range(node.s, node.sp))
            rec(node.right)
            ops.append((PREFETCH, node.s - 1))
            rec(node.left)
        else:
            raise TypeError(f"unknown tree node {node!r}")

    rec(tree)
    return Schedule(length, ops)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def _solve_offload(chain: Chain, dchain, mem_limit: float, num_slots: int,
                   allow_fall: bool, impl: str, m_use_fn) -> Solution:
    """Shared fill + rebuild for the two offload entry points.  ``m_use_fn``
    maps the top-level feasibility row to ``(m, reported_budget)`` or None."""
    L, S = dchain.length, num_slots
    v = _views(dchain)
    if impl == "reference":
        tables = _OffloadTables(L, S)
        with _obs.histogram("dp_fill.reference.offload_seconds").time():
            _fill_tables_offload(dchain, tables, allow_fall=allow_fall)
        top = tables.Cb[1, L + 1]
        table_bytes = tables.nbytes
    else:
        tb, te = dp_kernels.fill_tables_offload(dchain, S, impl=impl,
                                                allow_fall=allow_fall, v=v)
        top = tb.row(1, L + 1)
        table_bytes = tb.nbytes + te.nbytes
    picked = m_use_fn(top)
    if picked is None:
        return Solution(False, INFEASIBLE, None, None, mem_limit, num_slots,
                        0, table_bytes)
    m_use, budget = picked
    if impl == "reference":
        ops, tree = _rebuild(v, dchain, tables, 1, L + 1, m_use, bare=True)
        expected = float(top[m_use])
    else:
        toffP = (dchain.chain.offload_times()
                 + np.asarray(v["CUM_UF"][:L + 1])
                 ).astype(dp_kernels.COST_DTYPE)
        tpre32 = dchain.chain.prefetch_times().astype(dp_kernels.COST_DTYPE)
        ops, tree = _rebuild_banded(v, tb, te, toffP, tpre32, 1, L + 1,
                                    m_use, bare=True, allow_fall=allow_fall)
        expected = None
    sched = Schedule(L, ops)
    if expected is None:
        expected = float(simulate(chain, sched).time)
    return Solution(True, expected, sched, tree, budget, num_slots, m_use,
                    table_bytes)


def solve_optimal_offload(chain: Chain, mem_limit: float,
                          num_slots: int = 500, allow_fall: bool = True,
                          impl: Optional[str] = None,
                          cache: bool = True) -> Solution:
    """Optimal persistent three-tier schedule under ``mem_limit`` *device*
    memory.  Host memory is assumed abundant (simulate the schedule with
    ``host_mem_limit`` to check the host peak).

    Falls back to the two-tier ``solve_optimal`` when the chain has no host
    model or the host link has zero bandwidth — the result is then identical
    by construction.
    """
    if chain.host is None or not chain.host.enabled:
        return _solve_optimal_two_tier(chain, mem_limit, num_slots=num_slots,
                                       allow_fall=allow_fall, impl=impl,
                                       cache=cache)
    impl = _resolve_impl(impl)
    dchain = chain.discretize(mem_limit, num_slots)
    m_top = num_slots - int(dchain.wa[0])

    def pick(top):
        if m_top < 0 or not np.isfinite(top[m_top]):
            return None
        return m_top, mem_limit

    def solve() -> Solution:
        sol = _solve_offload(chain, dchain, mem_limit, num_slots, allow_fall,
                             impl, pick)
        if not sol.feasible:
            sol = dataclasses.replace(sol, slots_used=max(m_top, 0))
        return sol

    return solver_cache.memoize_solve("solve_optimal_offload", impl, chain,
                                      dchain, num_slots, allow_fall, cache,
                                      solve)


def solve_min_device_memory(chain: Chain, num_slots: int = 500,
                            allow_fall: bool = True,
                            impl: Optional[str] = None,
                            cache: bool = True) -> Solution:
    """Smallest feasible *device* budget in the three-tier model — the floor
    below the two-tier ``solve_min_memory`` that offloading unlocks."""
    if chain.host is None or not chain.host.enabled:
        from ..core.solver import solve_min_memory
        return solve_min_memory(chain, num_slots=num_slots,
                                allow_fall=allow_fall, impl=impl, cache=cache)
    impl = _resolve_impl(impl)
    peak = simulate(chain, Schedule.store_all(chain.length)).peak_mem
    dchain = chain.discretize(peak, num_slots)
    w0 = int(dchain.wa[0])

    def pick(top):
        feasible = np.where(np.isfinite(top))[0]
        if len(feasible) == 0:
            return None
        m_min = int(feasible[0])
        return m_min, (m_min + w0) * dchain.slot_size  # physical incl. a^0

    return solver_cache.memoize_solve(
        "solve_min_device_memory", impl, chain, dchain, num_slots,
        allow_fall, cache,
        lambda: _solve_offload(chain, dchain, peak, num_slots, allow_fall,
                               impl, pick))
