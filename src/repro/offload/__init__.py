"""Three-tier storage for optimal checkpointing: device activations, device
full-history residuals, and asynchronous host-RAM copies.

The subsystem extends the paper's two-saving computation model (``F_ck`` /
``F_all``) with an offload tier priced by :class:`repro.core.chain
.HostTransferModel`:

- :mod:`repro.offload.solver`      — the offload-aware DP (``solve_optimal_
  offload``) over ``(s, t, m_device)`` with a ``C3`` branch that parks a
  sub-chain input in host RAM, plus ``OffNode`` recursion trees;
- :mod:`repro.offload.host_buffer` — the pinned host staging pool with LRU
  accounting used by the executor;
- :mod:`repro.offload.executor`    — eager execution of offload schedules
  against real JAX arrays via ``jax.device_put``.
"""

from .host_buffer import HostBuffer, HostBufferStats
from .solver import (OffNode, solve_min_device_memory, solve_optimal_offload,
                     tree_to_schedule, tree_uses_offload)
from .executor import execute_offload_schedule

__all__ = [
    "HostBuffer", "HostBufferStats", "OffNode", "execute_offload_schedule",
    "solve_min_device_memory", "solve_optimal_offload", "tree_to_schedule",
    "tree_uses_offload",
]
