"""Eager execution of offload schedules against real JAX arrays.

Mirrors ``core/executor.py`` (the paper-faithful op walker) and adds the two
host-tier ops:

- ``F_off^i``    → copy the live ``a^i`` into the host pool.  On an
  accelerator backend this is ``jax.device_put`` onto the CPU device (an
  async D2H DMA under JAX's effect ordering); on a CPU-only backend it is an
  explicit ``np.asarray`` materialization, so the copy is real either way.
  The device array is left untouched — it is consumed by the following
  ``F_∅``/``B`` exactly as the schedule says.
- ``Prefetch^i`` → pop the host copy and ``jax.device_put`` it back, donating
  the host buffer (its bytes are released from the pool on the spot).

The host pool is a :class:`repro.offload.host_buffer.HostBuffer`; pass one in
to bound host memory or read back byte-exact peak accounting.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.schedule import BWD, F_ALL, F_CK, F_NONE, F_OFF, PREFETCH, Schedule
from ..obs import metrics
from ..obs.trace import Tracer
from .host_buffer import HostBuffer


def _tree_bytes(tree: Any) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        nb = getattr(leaf, "nbytes", None)
        if nb is not None:
            total += int(nb)
    return total


def default_host_device():
    """The CPU device to park offloaded copies on, or ``None`` when the
    default backend *is* the CPU (then host copies are numpy arrays, which
    live outside the device allocator and are still genuine copies)."""
    try:
        cpus = jax.devices("cpu")
    except RuntimeError:
        return None
    if jax.default_backend() == "cpu":
        return None
    return cpus[0]


def _to_host(value: Any, host_device):
    if host_device is not None:
        return jax.tree.map(lambda a: jax.device_put(a, host_device), value)
    # np.asarray may alias the device buffer on CPU backends; force a copy so
    # the "host tier" is genuinely distinct storage
    return jax.tree.map(lambda a: np.array(a, copy=True), value)


def _to_device(value: Any, device, donate: bool):
    if device is not None:
        return jax.tree.map(
            lambda a: jax.device_put(a, device, donate=donate), value)
    return jax.tree.map(jnp.asarray, value)


def execute_offload_schedule(
    schedule: Schedule,
    stages: Sequence[Any],
    params: Sequence[Any],
    x: Any,
    loss_cotangent: Any = None,
    track_live_bytes: bool = False,
    host_buffer: Optional[HostBuffer] = None,
    host_device=None,
    device=None,
    tracer: Optional[Tracer] = None,
) -> Tuple[Any, List[Any], Any]:
    """Run forward+backward per an offload-bearing ``schedule``.

    Same contract as ``core.executor.execute_schedule`` — returns
    ``(loss_output, param_grads, input_grad)`` plus, with
    ``track_live_bytes=True``, the empirical peak of the *device-side*
    saved-set in bytes.  Host-side bytes are accounted by ``host_buffer``
    (``host_buffer.peak_bytes`` after the run).

    ``tracer`` (opt-in) records one :class:`~repro.obs.trace.Span` per op —
    kind, op index, bytes produced/moved, wall time — fencing each op with
    ``jax.block_until_ready`` when ``tracer.sync`` so spans cover real
    device work; the untraced path is untouched.  Prefetch wall time (the
    schedule's synchronous stall) also lands in the
    ``offload.prefetch_stall_seconds`` metric.
    """
    L = schedule.length
    if host_buffer is None:
        host_buffer = HostBuffer()
    if host_device is None:
        host_device = default_host_device()
    if device is None and host_device is not None:
        device = jax.devices()[0]

    acts: Dict[int, Any] = {0: x}          # bare a^i values
    vjps: Dict[int, Any] = {}              # ā^l  (vjp closures)
    outs: Dict[int, Any] = {}              # stage outputs recorded by F_all
    deltas: Dict[int, Any] = {}
    grads: List[Any] = [None] * (L + 1)
    final_out = None
    peak_live = 0

    def get_act(i: int):
        if i in acts:
            return acts[i]
        if i in outs:  # a^i readable from ā^i (Table 1, second line)
            return outs[i]
        raise RuntimeError(f"a^{i} not available — invalid schedule")

    rec = tracer is not None and tracer.enabled
    for kind, l in schedule.ops:
        if rec:
            t0 = tracer.now()
            produced = None     # value fenced before the span closes
            moved: Optional[int] = None
        if kind == F_OFF:
            i = int(l)
            if i not in acts:
                raise RuntimeError(
                    f"Foff: a^{i} not live as a bare activation")
            host_copy = _to_host(acts[i], host_device)
            nbytes = _tree_bytes(host_copy)
            host_buffer.put(i, host_copy, nbytes=nbytes)
            if rec:
                produced, moved = host_copy, nbytes
        elif kind == PREFETCH:
            i = int(l)
            if i in acts:
                raise RuntimeError(f"Prefetch: a^{i} already on device")
            acts[i] = _to_device(host_buffer.pop(i), device, donate=True)
            if rec:
                produced = acts[i]
                moved = _tree_bytes(produced)
        elif kind in (F_NONE, F_CK, F_ALL):
            a_in = get_act(l - 1)
            if kind == F_ALL:
                out, vjp_fn = jax.vjp(stages[l - 1], params[l - 1], a_in)
                vjps[l] = vjp_fn
                outs[l] = out
                if l == L + 1:
                    final_out = out
            else:
                out = stages[l - 1](params[l - 1], a_in)
                acts[l] = out
                if l == L + 1:
                    final_out = out
            if kind == F_NONE:
                acts.pop(l - 1, None)
            if rec:
                produced = out
                moved = _tree_bytes(out)
        elif kind == BWD:
            if l == L + 1:
                out = outs[l]
                if loss_cotangent is not None:
                    delta = loss_cotangent
                else:
                    delta = jax.tree.map(lambda o: jnp.ones_like(o), out)
            else:
                delta = deltas.pop(l)
            dparams, da = vjps.pop(l)(delta)
            outs.pop(l, None)
            grads[l - 1] = dparams if grads[l - 1] is None else jax.tree.map(
                jnp.add, grads[l - 1], dparams)
            deltas[l - 1] = da
            acts.pop(l - 1, None)  # B^l consumes a^{l-1}
            if rec:
                produced = (dparams, da)
        else:
            raise ValueError(f"offload executor cannot run op kind {kind}")
        live = None
        if track_live_bytes:
            live = (_tree_bytes(acts) + _tree_bytes(vjps) + _tree_bytes(outs)
                    + _tree_bytes(deltas))
            peak_live = max(peak_live, live)
        if rec:
            tracer.fence(produced)
            t1 = tracer.now()
            tracer.record(kind, int(l), t0, t1, bytes=moved,
                          host_mem=(float(host_buffer.bytes_in_use)
                                    if kind in (F_OFF, PREFETCH) else None),
                          device_mem=(float(live) if live is not None
                                      else None))
            if kind == PREFETCH:
                # the prefetch is synchronous: its whole wall time is stall
                metrics.histogram(
                    "offload.prefetch_stall_seconds").observe(t1 - t0)

    if 0 not in deltas:
        raise RuntimeError("schedule did not produce δ^0")
    if track_live_bytes:
        return final_out, grads, deltas[0], peak_live
    return final_out, grads, deltas[0]
