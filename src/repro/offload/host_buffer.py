"""Host-RAM staging pool for offloaded activations.

The executor parks activation copies here between ``F_off`` and ``Prefetch``.
The pool models a *pinned* allocation: a fixed capacity is reserved up front
(pinned pages are what make async DMA possible), entries are accounted
byte-exactly, and an optional LRU policy reclaims the least-recently-touched
entries when an insert would overflow the reservation.

Checkpoint copies are precious — evicting one silently would force a
recompute the solver never planned — so eviction is opt-in: with
``evict=False`` (the executor's default) an overflowing ``put`` raises
instead.  The LRU machinery is still exercised for accounting, and the
serving path's KV-residency policies (:mod:`repro.runtime.kv_residency`)
stage cold prefix-KV blocks through the same pool with ``evict=True`` —
best-effort mode: a planned entry that gets evicted is detected at restore
time and raises rather than silently recomputing.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Callable, List, Optional

from ..obs import metrics as _metrics


@dataclasses.dataclass
class HostBufferStats:
    puts: int = 0
    gets: int = 0
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    evicted_bytes: int = 0
    peak_bytes: int = 0


class HostBuffer:
    """Keyed byte-accounted pool with optional LRU eviction.

    ``capacity_bytes=None`` means unbounded (accounting only).  ``on_evict``
    is called with ``(key, value)`` for every LRU victim.
    """

    def __init__(self, capacity_bytes: Optional[int] = None,
                 on_evict: Optional[Callable[[Any, Any], None]] = None):
        if capacity_bytes is not None and capacity_bytes < 0:
            raise ValueError("capacity_bytes must be >= 0")
        self.capacity_bytes = capacity_bytes
        self.on_evict = on_evict
        self._entries: "OrderedDict[Any, tuple]" = OrderedDict()  # key -> (value, nbytes)
        self._bytes = 0
        self.stats = HostBufferStats()

    # -- capacity ----------------------------------------------------------

    @property
    def bytes_in_use(self) -> int:
        return self._bytes

    @property
    def peak_bytes(self) -> int:
        return self.stats.peak_bytes

    def __contains__(self, key) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    # -- operations --------------------------------------------------------

    @staticmethod
    def _nbytes_of(value, nbytes: Optional[int]) -> int:
        if nbytes is not None:
            return int(nbytes)
        nb = getattr(value, "nbytes", None)
        if nb is None:
            raise ValueError("value has no .nbytes; pass nbytes explicitly")
        return int(nb)

    def put(self, key, value, nbytes: Optional[int] = None,
            evict: bool = False) -> List[Any]:
        """Insert (or replace) an entry; returns the keys evicted to fit.

        With ``evict=False`` an insert that would exceed the pinned capacity
        raises ``MemoryError`` — checkpoints must never vanish silently.
        """
        size = self._nbytes_of(value, nbytes)
        self.stats.puts += 1
        if key in self._entries:
            self._bytes -= self._entries.pop(key)[1]
        evicted: List[Any] = []
        if self.capacity_bytes is not None:
            if size > self.capacity_bytes:
                raise MemoryError(
                    f"host buffer: entry of {size} B exceeds pinned capacity "
                    f"{self.capacity_bytes} B")
            while self._bytes + size > self.capacity_bytes:
                if not evict:
                    raise MemoryError(
                        f"host buffer: {size} B put overflows pinned capacity "
                        f"{self.capacity_bytes} B ({self._bytes} B in use)")
                old_key, (old_val, old_size) = self._entries.popitem(last=False)
                self._bytes -= old_size
                self.stats.evictions += 1
                self.stats.evicted_bytes += old_size
                evicted.append(old_key)
                if self.on_evict is not None:
                    self.on_evict(old_key, old_val)
        self._entries[key] = (value, size)
        self._bytes += size
        self.stats.peak_bytes = max(self.stats.peak_bytes, self._bytes)
        if evicted:
            _metrics.counter("host_buffer.evictions").inc(len(evicted))
        self._publish()
        return evicted

    def get(self, key, default=None):
        """Fetch without removing; refreshes LRU recency."""
        self.stats.gets += 1
        if key not in self._entries:
            self.stats.misses += 1
            return default
        self.stats.hits += 1
        self._entries.move_to_end(key)
        return self._entries[key][0]

    def pop(self, key):
        """Fetch and release the entry's bytes (the Prefetch path)."""
        if key not in self._entries:
            raise KeyError(f"host buffer: no entry {key!r}")
        value, size = self._entries.pop(key)
        self._bytes -= size
        self._publish()
        return value

    def clear(self) -> None:
        self._entries.clear()
        self._bytes = 0
        self._publish()

    def _publish(self) -> None:
        """Mirror pin-pool occupancy into the process metrics registry (the
        gauge's ``max`` is the cross-buffer occupancy high-water mark)."""
        _metrics.gauge("host_buffer.bytes_in_use").set(self._bytes)
