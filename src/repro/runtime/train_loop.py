"""Production training loop: rotor-planned remat, checkpoint/restart,
straggler watchdog, deterministic data resume, optional int8 gradient
compression on the DP axes.

This is the same driver for a 1-chip CPU run and a 512-chip pod run — only
the mesh differs; every sharding flows from the logical-axis rules.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt.manager import CheckpointManager
from ..core.rematerialize import count_checkpoint_scopes
from ..data.pipeline import SyntheticLMData
from ..distributed.fault_tolerance import StragglerWatchdog
from ..distributed.sharding import DEFAULT_RULES, axis_rules
from ..launch.steps import (batch_axes, make_train_step, opt_axes,
                            plan_training, shard_tree, sharding_of)
from ..models.lm import StagedLM
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update
from ..optim.schedules import linear_warmup_cosine


def _make_offload_step(model, opt_cfg: AdamWConfig, schedule, lr_fn,
                       tracer=None):
    """Eager train step for a three-tier (host-offload) schedule: gradients
    come from the op-faithful offload executor — ``jax.device_put`` copies and
    all — and only the optimizer update is jitted.  This is the path where
    the solver's host tier is real, not a remat approximation.  ``tracer``
    (opt-in) records one span per schedule op every step."""
    from ..offload.executor import execute_offload_schedule
    from ..offload.host_buffer import HostBuffer

    stage_fns = model.stage_fns()

    @functools.partial(jax.jit, donate_argnums=(1, 2))
    def upd(grads, opt_state, params, lr):
        return adamw_update(opt_cfg, grads, opt_state, params, lr)

    def step_fn(params, opt_state, batch, step):
        sp = model.stage_params(params)
        loss, stage_grads, _ = execute_offload_schedule(
            schedule, stage_fns, sp, batch, host_buffer=HostBuffer(),
            tracer=tracer)
        grads = model.combine_stage_grads(stage_grads)
        lr = lr_fn(step) if lr_fn is not None else None
        new_p, new_o, metrics = upd(grads, opt_state, params, lr)
        metrics["loss"] = loss
        return new_p, new_o, metrics

    return step_fn


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    seed: int = 0
    lr: float = 3e-4
    warmup: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    async_ckpt: bool = True
    log_every: int = 10
    policy: Optional[str] = None        # remat policy override
    num_slots: Optional[int] = None     # DP discretization (None = plan default)
    solver_impl: Optional[str] = None   # DP kernels (dp_kernels.KNOWN_IMPLS)
    grad_accum: int = 1                 # microbatch accumulation factor
    straggler_threshold: float = 3.0
    data_host_count: int = 1
    data_host_index: int = 0
    trace_path: Optional[str] = None    # write a Perfetto trace.json here


def run_training(cfg, loop: TrainLoopConfig, mesh=None,
                 log_fn: Callable[[str], None] = print,
                 tracer=None) -> Dict[str, Any]:
    """Train a StagedLM; returns final metrics + state handles.

    ``tracer`` (a :class:`repro.obs.trace.Tracer`, opt-in) records per-op
    spans on the eager offload path and one fenced ``Step`` span per step on
    the jitted path; when the offload executor ran traced, the result dict
    gains a ``drift`` report comparing the plan's predicted makespan against
    the last (warmest) traced step.
    """
    from ..configs.shapes import ShapeSpec, input_specs
    from ..obs import metrics as obs_metrics

    if tracer is None and loop.trace_path:
        from ..obs.trace import Tracer
        tracer = Tracer(name="train")

    model = StagedLM(cfg)
    mesh = mesh or jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
    rules = DEFAULT_RULES
    opt_cfg = AdamWConfig(lr=loop.lr)
    lr_fn = linear_warmup_cosine(loop.lr, loop.warmup, loop.steps)

    shape = ShapeSpec("train", "train", loop.seq_len, loop.global_batch)
    with axis_rules(mesh, rules):
        batch_specs = input_specs(cfg, shape)
        # one planning entry point for every policy — the plan itself says
        # which executor it needs (no policy-string dispatch here)
        plan, chain = plan_training(model, batch_specs, mesh, rules,
                                    loop.policy, num_slots=loop.num_slots,
                                    impl=loop.solver_impl)
        offload_plan, tree = None, None
        if plan is not None and plan.uses_offload:
            if loop.grad_accum != 1:
                raise NotImplementedError(
                    "grad_accum > 1 with an offload schedule")
            if mesh.size > 1:
                # the eager executor commits prefetched activations to a
                # single device; mesh-sharded params/batch would mix
                # incompatible placements
                raise NotImplementedError(
                    "the optimal_offload eager path runs on a single "
                    "device; use a two-tier policy (rotor:...) on "
                    "multi-device meshes")
            offload_plan = plan
            log_fn(f"[offload] three-tier plan: "
                   f"{plan.schedule.count('Foff')} host offloads, "
                   f"predicted {plan.expected_time:.4f}s model "
                   f"time/step — eager executor engaged")
        elif plan is not None:
            tree = plan.tree
        if tree is not None:
            log_fn(f"[rotor] plan: {count_checkpoint_scopes(tree)} checkpoint "
                   f"scopes over {model.n_stages()} stages")
        from ..core import solver_cache
        st = solver_cache.stats()
        if st["hits"] or st["misses"]:
            log_fn(f"[plan] solver cache: {st['hits']} hits / "
                   f"{st['misses']} misses — identical relaunches skip the "
                   f"DP fill")
        if offload_plan is not None:
            step_fn = _make_offload_step(model, opt_cfg,
                                         offload_plan.schedule, lr_fn,
                                         tracer=tracer)
        else:
            step_fn = jax.jit(make_train_step(model, opt_cfg, tree, lr_fn,
                                              grad_accum=loop.grad_accum),
                              donate_argnums=(0, 1))

        params_spec = jax.eval_shape(model.init, jax.random.PRNGKey(loop.seed))
        p_shard = sharding_of(shard_tree(params_spec, model.param_axes(),
                                         mesh, rules))
        o_spec = jax.eval_shape(adamw_init, params_spec)
        o_shard = sharding_of(shard_tree(o_spec, opt_axes(model.param_axes()),
                                         mesh, rules))
        b_shard = sharding_of(shard_tree(batch_specs,
                                         batch_axes(cfg, "train"), mesh, rules))

        manager = (CheckpointManager(loop.ckpt_dir, keep=loop.ckpt_keep)
                   if loop.ckpt_dir else None)
        start_step = 0
        if manager is not None and manager.latest_step() is not None:
            target = {"params": params_spec, "opt": o_spec,
                      "step": jax.ShapeDtypeStruct((), jnp.int32)}
            shards = {"params": p_shard, "opt": o_shard, "step": None}
            s, state = manager.restore(target, shardings=shards)
            params, opt_state = state["params"], state["opt"]
            start_step = int(state["step"]) + 1
            log_fn(f"[ckpt] restored step {s}; resuming at {start_step}")
        else:
            params = jax.jit(model.init, out_shardings=p_shard)(
                jax.random.PRNGKey(loop.seed))
            opt_state = jax.jit(adamw_init, out_shardings=o_shard)(params)

        data = SyntheticLMData(cfg, loop.global_batch, loop.seq_len,
                               seed=loop.seed,
                               host_index=loop.data_host_index,
                               host_count=loop.data_host_count)
        data.start(from_step=start_step)
        watchdog = StragglerWatchdog(threshold=loop.straggler_threshold)
        losses = []
        t_begin = time.perf_counter()
        step = start_step
        try:
            for step in range(start_step, loop.steps):
                watchdog.step_begin()
                host_batch = data.next()
                batch = jax.tree.map(
                    lambda arr, shd: jax.device_put(arr, shd),
                    host_batch, b_shard)
                t_step = time.perf_counter()
                params, opt_state, metrics = step_fn(
                    params, opt_state, batch, jnp.asarray(step, jnp.int32))
                loss = float(metrics["loss"])  # blocks on the step's result
                step_s = time.perf_counter() - t_step
                obs_metrics.histogram("train.step_seconds").observe(step_s)
                obs_metrics.gauge("train.loss").set(loss)
                if (tracer is not None and tracer.enabled
                        and offload_plan is None):
                    # the offload executor already traced per-op spans; the
                    # jitted path gets one fenced span per whole step
                    t1 = tracer.now()
                    tracer.record("Step", step, t1 - step_s, t1)
                losses.append(loss)
                ev = watchdog.step_end(step)
                if ev is not None:
                    log_fn(f"[watchdog] straggler at step {ev.step}: "
                           f"{ev.duration:.2f}s vs median {ev.median:.2f}s")
                if watchdog.should_restart:
                    log_fn("[watchdog] persistent straggler — checkpointing "
                           "for restart")
                    if manager is not None:
                        manager.save(step, {"params": params, "opt": opt_state,
                                            "step": jnp.asarray(step, jnp.int32)},
                                     blocking=True)
                    break
                if step % loop.log_every == 0:
                    log_fn(f"step {step:5d} loss {loss:.4f} "
                           f"gnorm {float(metrics['grad_norm']):.3f}")
                if (manager is not None and loop.ckpt_every
                        and step and step % loop.ckpt_every == 0):
                    manager.save(step, {"params": params, "opt": opt_state,
                                        "step": jnp.asarray(step, jnp.int32)},
                                 blocking=not loop.async_ckpt)
        finally:
            data.stop()
            if manager is not None:
                manager.wait()
        wall = time.perf_counter() - t_begin
        if manager is not None:
            manager.save(step, {"params": params, "opt": opt_state,
                                "step": jnp.asarray(step, jnp.int32)},
                         blocking=True)
        tokens = loop.global_batch * loop.seq_len * max(len(losses), 1)
        result = {"losses": losses, "params": params, "opt_state": opt_state,
                  "last_step": step, "wall_s": wall,
                  "tokens_per_s": tokens / max(wall, 1e-9),
                  "straggler_events": len(watchdog.events)}
        if tracer is not None and tracer.spans:
            if loop.trace_path:
                tracer.save(loop.trace_path)
                log_fn(f"[obs] wrote {len(tracer.spans)} spans to "
                       f"{loop.trace_path}")
            if offload_plan is not None:
                # drift vs the last (warmest) step's per-op spans — earlier
                # steps carry one-time jit/transfer warm-up costs
                from ..obs.drift import compare
                from ..obs.trace import Tracer as _Tracer
                n_ops = len(offload_plan.schedule)
                last = _Tracer(name="train-last-step")
                last.spans.extend(tracer.spans[-n_ops:])
                report = compare(offload_plan, last)
                log_fn(f"[obs] {report.summary()}")
                result["drift"] = report
        return result
