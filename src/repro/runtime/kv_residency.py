"""KV-cache residency executors for the serve loop.

Two policies move cold prefix-KV blocks between device HBM and the pinned
host pool (:class:`repro.offload.host_buffer.HostBuffer`) around each decode
step:

- :class:`PlannedKV` executes a :func:`repro.plan.plan_serving` decision: the
  planner's staged layer set round-trips through the host pool every step —
  ``Prefetch`` ahead of the step, ``Foff`` back after it — and the stall
  accounting credits compute/transfer overlap the way the offload simulator
  does (only time beyond the step's own wall-clock stalls).
- :class:`LRUKV` is the naive baseline the planner must dominate: a
  capacity-bounded cache of KV blocks with true per-access LRU bookkeeping.
  Each layer's block is touched in order every step, so any capacity short
  of the full set degenerates into the classic cyclic-scan pathology — every
  access misses — which is exactly the behaviour an unplanned
  ``HostBuffer``-backed cache exhibits, and every miss stalls the step
  (nothing prefetches ahead of need).

Emulation note (mirrors :mod:`repro.offload.executor`): the jitted decode
step consumes the whole stacked cache, so blocks are *physically*
materialized for the step and re-staged after it; the byte/stall accounting
above models the per-layer pipelined residency a device runtime would see.
Transfer and stall totals come from the chain's
:class:`~repro.core.chain.HostTransferModel` — on CPU emulation the physical
copies are host↔host, so modeled time is authoritative, not wall-clock.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.chain import HostTransferModel
from ..obs import metrics as obs_metrics
from ..offload.host_buffer import HostBuffer


class _KVStager:
    """Shared mechanics: slice one layer's KV block out of the stacked
    per-chunk cache pytree, park it in the host pool, restore it later."""

    policy = "base"

    def __init__(self, model, layout, link: Optional[HostTransferModel] = None,
                 buffer: Optional[HostBuffer] = None, tracer=None):
        self.model = model
        self.layout = layout
        self.link = link or HostTransferModel.pcie_gen3()
        self.buffer = buffer if buffer is not None else HostBuffer(None)
        self.tracer = tracer
        self._slices = model.cfg.layer_slices
        self.offload_bytes = 0.0
        self.prefetch_bytes = 0.0
        self.stall_s = 0.0

    # -- physical block movement ------------------------------------------

    def _store(self, cache: Dict, j: int) -> Dict:
        """Copy layer ``j``'s KV block to the host pool and zero the device
        slice (the emulation's stand-in for freeing HBM)."""
        ci, off = self._slices[j]
        block = jax.tree.map(lambda x: np.asarray(x[off]),
                             cache["chunks"][ci])
        self.buffer.put(("kv", j), block,
                        nbytes=self.layout.block_bytes[j], evict=True)
        chunks = list(cache["chunks"])
        chunks[ci] = jax.tree.map(lambda x: x.at[off].set(0), chunks[ci])
        return {**cache, "chunks": chunks}

    def _load(self, cache: Dict, j: int) -> Dict:
        """Restore layer ``j``'s KV block from the host pool."""
        block = self.buffer.get(("kv", j))
        if block is None:
            raise RuntimeError(
                f"host pool no longer holds the KV block for layer {j} — "
                f"the pinned capacity evicted a planned entry; size the "
                f"HostBuffer to hold every host-resident layer")
        ci, off = self._slices[j]
        chunks = list(cache["chunks"])
        chunks[ci] = jax.tree.map(
            lambda x, v: x.at[off].set(jnp.asarray(v, x.dtype)),
            chunks[ci], block)
        return {**cache, "chunks": chunks}

    # -- accounting --------------------------------------------------------

    def _count(self, direction: str, j: int, stall: bool) -> float:
        b = self.layout.block_bytes[j]
        if direction == "offload":
            self.offload_bytes += b
            t = self.link.offload_time(b)
        else:
            self.prefetch_bytes += b
            t = self.link.prefetch_time(b)
        obs_metrics.counter("serve.kv_transfer_bytes").inc(b)
        if stall:
            self.stall_s += t
        if self.tracer is not None and getattr(self.tracer, "enabled", True):
            now = self.tracer.now()
            op = "Foff" if direction == "offload" else "Prefetch"
            self.tracer.record(op, j + 1, now, now + t, bytes=b)
        return t

    def result_stats(self) -> Dict[str, Any]:
        obs_metrics.histogram("serve.kv_stall_seconds").observe(self.stall_s)
        return {
            "kv_policy": self.policy,
            "kv_offload_bytes": self.offload_bytes,
            "kv_prefetch_bytes": self.prefetch_bytes,
            "kv_transfer_bytes": self.offload_bytes + self.prefetch_bytes,
            "kv_stall_s": self.stall_s,
        }


class PlannedKV(_KVStager):
    """Execute a planned residency set: the layers in ``host_layers`` live in
    host RAM between steps, prefetched ahead of each step and offloaded back
    behind it.  Transfers overlap the step's compute; only the excess beyond
    the measured step wall-clock is booked as stall."""

    policy = "planned"

    def __init__(self, model, layout, host_layers: List[int],
                 link: Optional[HostTransferModel] = None,
                 buffer: Optional[HostBuffer] = None, tracer=None):
        super().__init__(model, layout, link=link, buffer=buffer,
                         tracer=tracer)
        self.host_layers = sorted(host_layers)

    def stage_initial(self, cache: Dict) -> Dict:
        """Move the planned set to host right after prefill (off the decode
        critical path — no stall booked)."""
        for j in self.host_layers:
            cache = self._store(cache, j)
            self._count("offload", j, stall=False)
        return cache

    def begin_step(self, cache: Dict) -> Dict:
        """Prefetch the planned set back for the upcoming step; the transfer
        time is reconciled against the step's wall in :meth:`end_step`."""
        for j in self.host_layers:
            cache = self._load(cache, j)
            self._count("prefetch", j, stall=False)
        return cache

    def end_step(self, cache: Dict, step_wall_s: float = 0.0) -> Dict:
        """Offload the planned set again after the step.  The round-trip
        (this offload + the next prefetch) overlaps the *next* step's
        compute; time beyond ``step_wall_s`` is booked as stall."""
        t = 0.0
        for j in self.host_layers:
            cache = self._store(cache, j)
            t += self._count("offload", j, stall=False)
            t += self.link.prefetch_time(self.layout.block_bytes[j])
        self.stall_s += max(0.0, t - step_wall_s)
        return cache

    def result_stats(self) -> Dict[str, Any]:
        out = super().result_stats()
        out["kv_host_layers"] = list(self.host_layers)
        return out


class LRUKV(_KVStager):
    """Naive baseline: device HBM holds at most ``budget_bytes`` of KV
    blocks, managed by true per-access LRU.  Bookkeeping simulates the
    per-layer access sequence of each decode step (misses stall — the naive
    cache only fetches on demand); physically, the stacked cache is restored
    wholesale for the jitted step and re-staged to the bookkeeping's resident
    set afterwards (see the module docstring)."""

    policy = "lru"

    def __init__(self, model, layout, budget_bytes: float,
                 link: Optional[HostTransferModel] = None,
                 buffer: Optional[HostBuffer] = None, tracer=None):
        super().__init__(model, layout, link=link, buffer=buffer,
                         tracer=tracer)
        self.budget_bytes = float(budget_bytes)
        # recency-ordered resident set: first = least recently used
        self._resident: List[int] = []
        self.hits = 0
        self.misses = 0

    def _resident_bytes(self) -> float:
        return float(sum(self.layout.block_bytes[j] for j in self._resident))

    def _evict_to_fit(self, incoming: float, stall: bool) -> List[int]:
        """Evict least-recently-used blocks until ``incoming`` fits (always
        keeping at least the incoming block itself admissible)."""
        out = []
        while (self._resident
               and self._resident_bytes() + incoming > self.budget_bytes):
            k = self._resident.pop(0)
            out.append(k)
            self._count("offload", k, stall=stall)
        return out

    def stage_initial(self, cache: Dict) -> Dict:
        """After prefill everything is on device; evict coldest-first (layer
        0 was filled first) down to the budget.  Off the critical path — no
        stall booked."""
        self._resident = list(range(len(self.layout.block_bytes)))
        victims = self._evict_to_fit(0.0, stall=False)
        for j in victims:
            cache = self._store(cache, j)
        return cache

    def begin_step(self, cache: Dict) -> Dict:
        """Bookkeep one decode step's layer-order accesses (miss → demand
        fetch, stalling; evictions write back, stalling), then physically
        restore whatever the step needs."""
        host_before = [j for j in range(len(self.layout.block_bytes))
                       if j not in self._resident]
        for j in range(len(self.layout.block_bytes)):
            if j in self._resident:
                self.hits += 1
                self._resident.remove(j)
                self._resident.append(j)    # refresh recency
                continue
            self.misses += 1
            self._evict_to_fit(self.layout.block_bytes[j], stall=True)
            self._count("prefetch", j, stall=True)
            self._resident.append(j)
        # physically rebuild the full stacked cache for the jitted step
        for j in host_before:
            cache = self._load(cache, j)
        return cache

    def end_step(self, cache: Dict, step_wall_s: float = 0.0) -> Dict:
        """Re-stage the blocks the bookkeeping says ended up evicted."""
        for j in range(len(self.layout.block_bytes)):
            if j not in self._resident:
                cache = self._store(cache, j)
        return cache

    def result_stats(self) -> Dict[str, Any]:
        out = super().result_stats()
        out["kv_lru_hits"] = self.hits
        out["kv_lru_misses"] = self.misses
        out["kv_budget_bytes"] = self.budget_bytes
        return out
