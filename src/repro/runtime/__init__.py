"""Runtime loops and services.

Lazy exports (PEP 562): the train/serve loops drag in jax, but
:mod:`repro.runtime.plan_service` is importable on accelerator-free hosts —
``from repro.runtime import PlanService`` must not pay (or fail) the jax
import.
"""

_EXPORTS = {
    "TrainLoopConfig": ("train_loop", "TrainLoopConfig"),
    "run_training": ("train_loop", "run_training"),
    "ServeLoopConfig": ("serve_loop", "ServeLoopConfig"),
    "run_serving": ("serve_loop", "run_serving"),
    "PlannedKV": ("kv_residency", "PlannedKV"),
    "LRUKV": ("kv_residency", "LRUKV"),
    "PlanService": ("plan_service", "PlanService"),
    "TenantQuota": ("plan_service", "TenantQuota"),
    "QuotaExceededError": ("plan_service", "QuotaExceededError"),
    "DEFAULT_TENANT": ("plan_service", "DEFAULT_TENANT"),
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        module, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    import importlib
    value = getattr(importlib.import_module(f".{module}", __name__), attr)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
