from .train_loop import TrainLoopConfig, run_training
from .serve_loop import ServeLoopConfig, run_serving
