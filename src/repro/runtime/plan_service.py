"""Multi-tenant plan service: one process answering plan requests for a
fleet (ISSUE/ROADMAP item 2 — "plan once, bind anywhere").

:class:`PlanService` fronts a :class:`repro.store.PlanStore` with the
operational pieces a shared planning endpoint needs:

- a **request queue** drained by worker threads — callers get a
  :class:`concurrent.futures.Future` immediately and solves proceed in the
  background (``plan()`` is the blocking convenience wrapper);
- **per-tenant namespaces**: tenants address disjoint key prefixes
  (``plans/<tenant>/…``), so one tenant's plans and quota pressure are
  invisible to another's;
- **per-tenant quotas** (:class:`TenantQuota`): ``max_inflight`` bounds
  queued-plus-running requests (excess submissions raise
  :class:`QuotaExceededError` instead of queueing without bound) and
  ``max_plans`` bounds stored plans (oldest admitted-by-this-service entry
  evicted first);
- **single-flight dedup**: concurrent requests for the same
  chain × request × code content key share one solve — later submitters
  receive the same Future;
- a **verification gate**: every plan crossing the service boundary goes
  through :meth:`repro.plan.MemoryPlan.verify` — on the way in via
  :meth:`PlanStore.put` (an invalid plan is never admitted) and on the way
  out via :meth:`PlanStore.get` in strict mode (a tampered stored entry is
  quarantined, counted, and transparently re-solved; it never reaches
  ``bind``/``execute``).

Every outcome ticks the :mod:`repro.obs` registry:
``plan_service.hits`` / ``misses`` / ``solves`` / ``deduped`` /
``verify_rejects`` / ``evictions`` / ``quota_rejections``.

The module is importable on accelerator-free hosts (no jax anywhere in its
import closure) — a plan service can run on a CPU-only coordinator node.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from collections import deque
from concurrent.futures import Future
from typing import Any, Deque, Dict, List, Optional

from ..obs import metrics as _metrics
from ..store.config import default_store
from ..store.objects import ObjectStore
from ..store.plans import PlanStore

DEFAULT_TENANT = "default"


class QuotaExceededError(RuntimeError):
    """A tenant submitted more concurrent requests than its quota allows."""


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Per-tenant resource bounds.

    ``max_inflight`` — queued + running requests at any moment (further
    submissions raise); ``max_plans`` — plans this service keeps stored for
    the tenant (oldest evicted on overflow).
    """

    max_inflight: int = 8
    max_plans: int = 64


class PlanService:
    """Queue-fed, quota-bounded, verification-gated planning endpoint."""

    def __init__(self, store: Optional[ObjectStore] = None, *,
                 workers: int = 2,
                 default_quota: TenantQuota = TenantQuota(),
                 quotas: Optional[Dict[str, TenantQuota]] = None):
        if store is None:
            store = default_store(required=True)
        self.plans = PlanStore(store)
        self.default_quota = default_quota
        self.quotas = dict(quotas or {})
        self._workers_wanted = max(1, workers)
        self._queue: "queue.Queue" = queue.Queue()
        self._threads: List[threading.Thread] = []
        self._lock = threading.Lock()
        self._inflight: Dict[str, Future] = {}
        self._inflight_tenant: Dict[str, str] = {}
        self._admitted: Dict[str, Deque[str]] = {}
        self._closed = False

    # -- lifecycle ---------------------------------------------------------

    def _ensure_workers(self) -> None:
        while len(self._threads) < self._workers_wanted:
            t = threading.Thread(target=self._worker, daemon=True,
                                 name=f"plan-service-{len(self._threads)}")
            t.start()
            self._threads.append(t)

    def close(self) -> None:
        """Drain the queue and stop the workers (idempotent)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            threads = list(self._threads)
        for _ in threads:
            self._queue.put(None)
        for t in threads:
            t.join()

    def __enter__(self) -> "PlanService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- quota accounting --------------------------------------------------

    def quota_for(self, tenant: str) -> TenantQuota:
        return self.quotas.get(tenant, self.default_quota)

    def _tenant_inflight(self, tenant: str) -> int:
        return sum(1 for t in self._inflight_tenant.values() if t == tenant)

    def _enforce_plan_quota(self, tenant: str) -> None:
        """Evict this tenant's oldest service-admitted plans beyond
        ``max_plans`` (storage the service never wrote is left alone)."""
        quota = self.quota_for(tenant)
        with self._lock:
            admitted = self._admitted.setdefault(tenant, deque())
            evict = []
            while len(admitted) > max(1, quota.max_plans):
                evict.append(admitted.popleft())
        for key in evict:
            if self.plans.delete(key):
                _metrics.counter("plan_service.evictions").inc()

    # -- the request path --------------------------------------------------

    def submit(self, chain, request, *,
               tenant: str = DEFAULT_TENANT) -> "Future":
        """Enqueue one plan request; returns a Future resolving to the
        verified :class:`~repro.plan.MemoryPlan` (or raising the solve's
        error, e.g. :class:`~repro.plan.InfeasiblePlanError`).

        Requests for a content key already queued or running are deduped
        onto the existing Future, regardless of tenant quota pressure.
        """
        key = self.plans.key_for(chain, request, tenant=tenant)
        with self._lock:
            if self._closed:
                raise RuntimeError("PlanService is closed")
            existing = self._inflight.get(key)
            if existing is not None:
                _metrics.counter("plan_service.deduped").inc()
                return existing
            quota = self.quota_for(tenant)
            if self._tenant_inflight(tenant) >= max(1, quota.max_inflight):
                _metrics.counter("plan_service.quota_rejections").inc()
                raise QuotaExceededError(
                    f"tenant {tenant!r} already has "
                    f"{quota.max_inflight} requests in flight")
            fut: Future = Future()
            self._inflight[key] = fut
            self._inflight_tenant[key] = tenant
            self._ensure_workers()
        self._queue.put((key, chain, request, tenant, fut))
        return fut

    def plan(self, chain, request, *, tenant: str = DEFAULT_TENANT) -> Any:
        """Blocking convenience wrapper around :meth:`submit`."""
        return self.submit(chain, request, tenant=tenant).result()

    # -- workers -----------------------------------------------------------

    def _worker(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                return
            key, chain, request, tenant, fut = item
            try:
                fut.set_result(self._resolve(key, chain, request, tenant))
            except BaseException as e:  # propagate to the submitter
                fut.set_exception(e)
            finally:
                with self._lock:
                    self._inflight.pop(key, None)
                    self._inflight_tenant.pop(key, None)

    def _resolve(self, key: str, chain, request, tenant: str) -> Any:
        from ..check import PlanVerificationError
        from ..store.keys import PlanKey

        try:
            plan = self.plans.get_key(
                key, expect=PlanKey.for_plan(chain, request), strict=True)
        except PlanVerificationError:
            # tampered / semantically invalid stored entry: PlanStore has
            # already quarantined it; the service answers with a fresh solve
            _metrics.counter("plan_service.verify_rejects").inc()
            plan = None
        if plan is not None:
            _metrics.counter("plan_service.hits").inc()
            return plan
        _metrics.counter("plan_service.misses").inc()
        plan = self._solve(chain, request)
        _metrics.counter("plan_service.solves").inc()
        stored_key = self.plans.put(plan, chain=chain, request=request,
                                    tenant=tenant)
        with self._lock:
            self._admitted.setdefault(tenant, deque()).append(stored_key)
        self._enforce_plan_quota(tenant)
        return plan

    @staticmethod
    def _solve(chain, request) -> Any:
        from ..plan import build_plan
        return build_plan(request, chain)
