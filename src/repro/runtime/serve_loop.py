"""Batched serving loop: prefill a batch of prompts, then greedy-decode with
a jitted one-token step (continuous-batching-lite: finished sequences keep
decoding into padding; a real deployment would swap in new requests — the
slot bookkeeping below is where that plugs in)."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.lm import StagedLM


@dataclasses.dataclass
class ServeLoopConfig:
    max_new_tokens: int = 16
    max_len: int = 256
    greedy: bool = True
    eos_id: Optional[int] = None


def run_serving(cfg, params, prompts: np.ndarray, loop: ServeLoopConfig,
                model: Optional[StagedLM] = None) -> Dict[str, Any]:
    """prompts: (B, S0) int32 token batch. Returns generations + stats."""
    model = model or StagedLM(cfg)
    B, S0 = prompts.shape
    assert S0 + loop.max_new_tokens <= loop.max_len

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=loop.max_len))
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    t_prefill = time.perf_counter() - t0

    out_tokens: List[np.ndarray] = [np.asarray(next_tok)]
    done = np.zeros((B,), bool)
    t0 = time.perf_counter()
    for _ in range(loop.max_new_tokens - 1):
        logits, cache = decode(params, cache, next_tok[:, None])
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        toks = np.asarray(next_tok)
        if loop.eos_id is not None:
            done |= toks == loop.eos_id
            if done.all():
                out_tokens.append(toks)
                break
        out_tokens.append(toks)
    jax.block_until_ready(next_tok)
    t_decode = time.perf_counter() - t0
    gen = np.stack(out_tokens, axis=1)
    n_decoded = max(gen.shape[1] - 1, 1)
    return {
        "generations": gen,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tokens_per_s": B * n_decoded / max(t_decode, 1e-9),
    }
