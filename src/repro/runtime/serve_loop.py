"""Batched serving loop: prefill a batch of prompts, then greedy-decode with
a jitted one-token step (continuous-batching-lite: finished sequences keep
decoding into padding; a real deployment would swap in new requests — the
slot bookkeeping below is where that plugs in)."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.lm import StagedLM
from ..obs import metrics as obs_metrics


@dataclasses.dataclass
class ServeLoopConfig:
    max_new_tokens: int = 16
    max_len: int = 256
    greedy: bool = True
    eos_id: Optional[int] = None


def _kv_bytes(cache) -> int:
    """Total bytes resident in the KV cache pytree."""
    return int(sum(np.prod(leaf.shape) * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(cache)
                   if hasattr(leaf, "shape")))


def run_serving(cfg, params, prompts: np.ndarray, loop: ServeLoopConfig,
                model: Optional[StagedLM] = None,
                tracer=None) -> Dict[str, Any]:
    """prompts: (B, S0) int32 token batch. Returns generations + stats.

    ``tracer`` (a :class:`repro.obs.trace.Tracer`, opt-in) records one
    ``Decode`` span per emitted token plus a ``Step`` span for the prefill;
    each span carries the KV-cache residency in its ``bytes`` field.  The
    same residency is exported as the ``serve.kv_bytes`` gauge.
    """
    model = model or StagedLM(cfg)
    B, S0 = prompts.shape
    assert S0 + loop.max_new_tokens <= loop.max_len
    rec = tracer is not None and getattr(tracer, "enabled", True)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=loop.max_len))
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    t_prefill = time.perf_counter() - t0
    kv_bytes = _kv_bytes(cache)
    obs_metrics.gauge("serve.kv_bytes").set(float(kv_bytes))
    obs_metrics.histogram("serve.prefill_seconds").observe(t_prefill)
    if rec:
        t1 = tracer.now()
        tracer.record("Step", 0, t1 - t_prefill, t1, bytes=kv_bytes)

    out_tokens: List[np.ndarray] = [np.asarray(next_tok)]
    done = np.zeros((B,), bool)
    t0 = time.perf_counter()
    for tok_idx in range(loop.max_new_tokens - 1):
        td0 = tracer.now() if rec else 0.0
        logits, cache = decode(params, cache, next_tok[:, None])
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        toks = np.asarray(next_tok)
        if rec:
            tracer.record("Decode", tok_idx + 1, td0, tracer.now(),
                          bytes=kv_bytes)
        if loop.eos_id is not None:
            done |= toks == loop.eos_id
            if done.all():
                out_tokens.append(toks)
                break
        out_tokens.append(toks)
    jax.block_until_ready(next_tok)
    t_decode = time.perf_counter() - t0
    gen = np.stack(out_tokens, axis=1)
    n_decoded = max(gen.shape[1] - 1, 1)
    obs_metrics.counter("serve.decode_tokens").inc(B * n_decoded)
    return {
        "generations": gen,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tokens_per_s": B * n_decoded / max(t_decode, 1e-9),
        "kv_bytes": kv_bytes,
    }
