"""Batched serving loop: prefill a batch of prompts, then greedy-decode with
a jitted one-token step (continuous-batching-lite: finished sequences keep
decoding into padding; a real deployment would swap in new requests — the
slot bookkeeping below is where that plugs in).

KV-cache residency is pluggable: pass a :func:`repro.plan.plan_serving` plan
(``plan=``) to stage the planner's cold-layer set through the pinned host
pool around every step, or ``kv_policy="lru"`` with a byte budget for the
naive on-demand baseline the planner is benchmarked against
(:mod:`repro.runtime.kv_residency`).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.lm import StagedLM
from ..obs import metrics as obs_metrics


@dataclasses.dataclass
class ServeLoopConfig:
    max_new_tokens: int = 16
    max_len: int = 256
    greedy: bool = True
    eos_id: Optional[int] = None


def _serve_fns(model, max_len: int):
    """Jitted prefill/decode pair, memoized per (model instance, max_len) so
    repeated `run_serving` calls (benchmark sweeps) don't retrace."""
    memo = model.__dict__.setdefault("_serve_jit", {})
    fns = memo.get(max_len)
    if fns is None:
        prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
        decode = jax.jit(model.decode_step, donate_argnums=(1,))
        fns = memo[max_len] = (prefill, decode)
    return fns


def _make_residency(model, layout, tracer, *, plan, kv_policy, kv_budget,
                    host, host_buffer):
    """Resolve the KV-residency policy for this serving run (None = keep the
    whole cache in device memory)."""
    if plan is not None and kv_policy is not None:
        raise ValueError("pass either plan= or kv_policy=, not both")
    if plan is None and kv_policy is None:
        return None
    from ..offload.host_buffer import HostBuffer
    from .kv_residency import LRUKV, PlannedKV
    buffer = host_buffer if host_buffer is not None else HostBuffer(None)
    if plan is not None:
        from ..plan.serving import kv_residency_layers
        plan._verify_or_raise("refusing to serve an unverified kv plan")
        layers = kv_residency_layers(plan, budget_bytes=kv_budget)
        link = host or (plan.chain.host if plan.chain is not None else None)
        return PlannedKV(model, layout, layers, link=link, buffer=buffer,
                         tracer=tracer)
    if kv_policy != "lru":
        raise ValueError(f"unknown kv_policy {kv_policy!r}; expected 'lru' "
                         f"(or pass plan= for the planned policy)")
    if kv_budget is None:
        raise ValueError("kv_policy='lru' needs kv_budget= (device KV bytes)")
    return LRUKV(model, layout, kv_budget, link=host, buffer=buffer,
                 tracer=tracer)


def run_serving(cfg, params, prompts: np.ndarray, loop: ServeLoopConfig,
                model: Optional[StagedLM] = None, tracer=None, *,
                plan=None, kv_policy: Optional[str] = None,
                kv_budget: Optional[float] = None, host=None,
                host_buffer=None) -> Dict[str, Any]:
    """prompts: (B, S0) int32 token batch. Returns generations + stats.

    ``tracer`` (a :class:`repro.obs.trace.Tracer`, opt-in) records one
    ``Decode`` span per emitted token plus a ``Step`` span for the prefill;
    each span's ``bytes`` field carries the *logical* KV residency at that
    point — ``CacheLayout.logical_bytes(pos)``, i.e. what the cache holds,
    not the padded ``max_len`` allocation.  Gauges: ``serve.kv_bytes``
    (logical, tracks ``pos``) and ``serve.kv_bytes_allocated`` (the padded
    allocation, constant per run).  ``serve.decode_tokens`` counts only live
    tokens — sequences finished by ``eos_id`` stop contributing even while
    they keep decoding into padding.

    KV residency: ``plan=`` (a verified :func:`repro.plan.plan_serving`
    plan; ``kv_budget=`` optionally re-clamps to the requested budget when
    the plan fell back to min-memory) or ``kv_policy="lru"`` +
    ``kv_budget=``.  ``host`` overrides the
    :class:`~repro.core.chain.HostTransferModel`; ``host_buffer`` supplies
    the pinned pool (default: unbounded accounting-only pool).
    """
    model = model or StagedLM(cfg)
    B, S0 = prompts.shape
    if S0 + loop.max_new_tokens > loop.max_len:
        raise ValueError(
            f"prompt length {S0} + max_new_tokens {loop.max_new_tokens} "
            f"exceeds max_len {loop.max_len}; raise ServeLoopConfig.max_len")
    rec = tracer is not None and getattr(tracer, "enabled", True)
    layout = model.cache_layout(B, loop.max_len)
    residency = _make_residency(model, layout, tracer, plan=plan,
                                kv_policy=kv_policy, kv_budget=kv_budget,
                                host=host, host_buffer=host_buffer)

    prefill, decode = _serve_fns(model, loop.max_len)

    ts0 = tracer.now() if rec else 0.0
    t0 = time.perf_counter()
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    jax.block_until_ready(next_tok)
    t_prefill = time.perf_counter() - t0
    pos0 = int(cache["pos"])
    kv_bytes = layout.logical_bytes(pos0)
    obs_metrics.gauge("serve.kv_bytes").set(float(kv_bytes))
    obs_metrics.gauge("serve.kv_bytes_allocated").set(
        float(layout.allocated_bytes))
    obs_metrics.histogram("serve.prefill_seconds").observe(t_prefill)
    if rec:
        tracer.record("Step", 0, ts0, tracer.now(), bytes=kv_bytes)

    if residency is not None:
        cache = residency.stage_initial(cache)

    out_tokens: List[np.ndarray] = [np.asarray(next_tok)]
    done = np.zeros((B,), bool)
    if loop.eos_id is not None:
        done |= out_tokens[0] == loop.eos_id
    decode_tokens = 0
    t0 = time.perf_counter()
    for tok_idx in range(loop.max_new_tokens - 1):
        if residency is not None:
            cache = residency.begin_step(cache)
        td0 = tracer.now() if rec else 0.0
        ts = time.perf_counter()
        logits, cache = decode(params, cache, next_tok[:, None])
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        toks = np.asarray(next_tok)
        step_wall = time.perf_counter() - ts
        kv_bytes = layout.logical_bytes(pos0 + tok_idx + 1)
        obs_metrics.gauge("serve.kv_bytes").set(float(kv_bytes))
        if rec:
            tracer.record("Decode", tok_idx + 1, td0, tracer.now(),
                          bytes=kv_bytes)
        decode_tokens += int((~done).sum())
        if loop.eos_id is not None:
            done |= toks == loop.eos_id
        out_tokens.append(toks)
        finished = loop.eos_id is not None and bool(done.all())
        last = finished or tok_idx == loop.max_new_tokens - 2
        if residency is not None and not last:
            # no step follows the last one — nothing to stage back for
            cache = residency.end_step(cache, step_wall)
        if finished:
            break
    jax.block_until_ready(next_tok)
    t_decode = time.perf_counter() - t0
    gen = np.stack(out_tokens, axis=1)
    obs_metrics.counter("serve.decode_tokens").inc(decode_tokens)
    out = {
        "generations": gen,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tokens": decode_tokens,
        "decode_tokens_per_s": decode_tokens / max(t_decode, 1e-9),
        "kv_bytes": kv_bytes,
        "kv_bytes_allocated": layout.allocated_bytes,
    }
    if residency is not None:
        out.update(residency.result_stats())
    return out
