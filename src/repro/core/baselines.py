"""Baseline checkpointing strategies the paper compares against (§5.3).

- ``store_all``      — the **PyTorch** strategy: autograd default, keep every
                       residual (``Schedule.store_all``).
- ``periodic``       — the **sequential** strategy (PyTorch
                       ``checkpoint_sequential`` [1], idea of Chen et al. [6]):
                       split the chain into ``k`` segments, store each segment
                       input on the forward pass, replay each segment with
                       ``F_all`` before its backward.  The last segment is not
                       replayed (computed with ``F_all`` directly), matching
                       the paper: "Each forward computation is thus performed
                       twice, except those of the last segment."
- ``chen_sqrt``      — ``periodic`` with ``k = ceil(sqrt(L))`` (the classic
                       sublinear-memory heuristic).
- ``revolve``        — optimal AD-model strategy adapted to heterogeneous
                       chains: checkpoints are restricted to plain activations
                       ``a`` and every backward is preceded by ``F_all``; we
                       obtain it from the same DP with the ``F_all``-first
                       branch disabled (``solve_optimal(allow_fall=False)``).
                       This is the strategy of paper §5.3 / [14] Appendix C
                       (in fact a slightly *stronger* variant: optimized
                       directly in the true cost model rather than converted
                       post-hoc, so it can only make the comparator better).
"""

from __future__ import annotations

import math
from typing import List

import numpy as np

from .chain import Chain
from .schedule import BWD, F_ALL, F_CK, F_NONE, Schedule
from .solver import Solution, solve_optimal


def periodic(chain: Chain, num_segments: int) -> Schedule:
    """PyTorch ``checkpoint_sequential`` with ``num_segments`` segments.

    Stages 1..L are split into segments; the loss stage L+1 is appended to the
    last segment (torch's tool checkpoints the user-provided sequential module;
    the loss is computed outside it, with grad).
    """
    L = chain.length
    k = max(1, min(num_segments, L))
    bounds = np.linspace(0, L, k + 1).astype(int)  # segment i = stages (b[i], b[i+1]]
    segments: List[List[int]] = [
        list(range(bounds[i] + 1, bounds[i + 1] + 1)) for i in range(k)
    ]
    segments[-1].append(L + 1)  # loss stage rides with the last segment

    ops = []
    # forward phase: checkpoint each segment input, stream inside; the last
    # segment runs with F_all (it is backpropagated immediately, no replay).
    for seg in segments[:-1]:
        ops.append((F_CK, seg[0]))
        ops.extend((F_NONE, l) for l in seg[1:])
    ops.extend((F_ALL, l) for l in segments[-1])
    # backward phase
    ops.extend((BWD, l) for l in reversed(segments[-1]))
    for seg in reversed(segments[:-1]):
        ops.extend((F_ALL, l) for l in seg)
        ops.extend((BWD, l) for l in reversed(seg))
    return Schedule(L, ops)


def chen_sqrt(chain: Chain) -> Schedule:
    return periodic(chain, int(math.ceil(math.sqrt(chain.length))))


def revolve(chain: Chain, mem_limit: float, num_slots: int = 500) -> Solution:
    return solve_optimal(chain, mem_limit, num_slots, allow_fall=False)


def best_periodic(chain: Chain, mem_limit: float) -> tuple:
    """Best feasible segment count for ``periodic`` under ``mem_limit`` —
    the paper sweeps 2..2*sqrt(L) segments and keeps the best (§5.3)."""
    from .schedule import simulate

    L = chain.length
    best = None
    hi = max(2, int(2 * math.sqrt(L)) + 1)
    for k in range(1, min(L, hi) + 1):
        sched = periodic(chain, k)
        res = simulate(chain, sched, mem_limit)
        if res.valid and (best is None or res.time < best[1].time):
            best = (k, res, sched)
    return best  # None if no segment count fits
