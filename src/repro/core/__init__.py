"""Core of the reproduction: the paper's optimal heterogeneous-chain
checkpointing (rotor) — cost model, DP solver, baselines, simulator, and the
two execution paths (nested-remat compiler and faithful eager executor)."""

from .chain import Chain, DiscreteChain, HostTransferModel
from .schedule import (Schedule, SimResult, assert_valid, simulate,
                       uses_offload)
from .solver import (AllNode, CkNode, Leaf, Solution, Tree, solve_optimal,
                     tree_to_schedule)
from .baselines import best_periodic, chen_sqrt, periodic, revolve
# Execution-side re-exports are lazy (PEP 562), for two reasons:
# - policies.py imports repro.plan, which imports straight back into
#   repro.core — importing it eagerly here made `import repro.plan` crash
#   with a circular-import error whenever it was the process's *first*
#   repro import (exactly the README quickstart).
# - rematerialize/executor/planner are the jax boundary; importing them
#   eagerly made `import repro.core` require jax, breaking plan-serving
#   hosts with no accelerator stack (guarded by the jax-blocked subprocess
#   test in tests/test_check_lint.py and the `jax-import` lint rule).
# Every name still resolves via __getattr__ below.
_POLICY_EXPORTS = ("PolicyPlan", "make_policy_plan", "make_policy_tree",
                   "parse_budget", "policy_to_request", "resolve_policy")
_JAX_EXPORTS = {
    "build_remat_fn": "rematerialize",
    "count_checkpoint_scopes": "rematerialize",
    "full_remat_tree": "rematerialize",
    "periodic_tree": "rematerialize",
    "sequential_tree": "rematerialize",
    "tree_stage_span": "rematerialize",
    "execute_schedule": "executor",
    "reference_grads": "executor",
    "measure_host_bandwidth": "planner",
    "profile_stages_analytic": "planner",
    "profile_stages_measured": "planner",
    "residual_bytes": "planner",
}


def __getattr__(name):
    if name in _POLICY_EXPORTS:
        from . import policies
        return getattr(policies, name)
    if name in _JAX_EXPORTS:
        import importlib
        mod = importlib.import_module("." + _JAX_EXPORTS[name], __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "Chain", "DiscreteChain", "HostTransferModel", "Schedule", "SimResult",
    "simulate", "uses_offload", "assert_valid", "solve_optimal",
    "tree_to_schedule", "Solution", "Tree", "Leaf", "AllNode", "CkNode",
    "periodic", "chen_sqrt", "revolve", "best_periodic", "build_remat_fn",
    "sequential_tree", "full_remat_tree", "periodic_tree", "tree_stage_span",
    "count_checkpoint_scopes", "execute_schedule", "reference_grads",
    "measure_host_bandwidth", "profile_stages_analytic",
    "profile_stages_measured", "residual_bytes", "PolicyPlan",
    "make_policy_plan", "make_policy_tree", "parse_budget",
    "policy_to_request", "resolve_policy",
]
