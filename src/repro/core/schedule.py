"""Schedule IR + memory simulator implementing the paper's Table 1 semantics.

An operation is a ``(kind, l)`` pair with ``l`` in *paper numbering* (stages
1..L+1, where L+1 is the loss stage):

- ``("Fnone", l)`` — :math:`F_\\varnothing^l`: forward without saving; consumes
  ``a^{l-1}`` (if live as a bare activation), produces ``a^l``.
- ``("Fck", l)``   — :math:`F_{ck}^l`: forward, checkpointing the *input*
  ``a^{l-1}``; produces ``a^l``, keeps ``a^{l-1}``.
- ``("Fall", l)``  — :math:`F_{all}^l`: forward, recording the full residual
  set; produces ``ā^l``, keeps the input.
- ``("B", l)``     — backward; consumes ``{δ^l, ā^l, a^{l-1}}`` and produces
  ``δ^{l-1}`` (if the input is available as ``ā^{l-1}``, it is kept — Table 1,
  second line).
- ``("Free", item)`` — explicit drop (never emitted by the solver; used by the
  brute-force enumerator to explore *non-persistent* schedules, §4.1).

Three-tier extension (the ``repro.offload`` subsystem; requires
``chain.host``):

- ``("Foff", i)``     — :math:`F_{off}^i`: launch an asynchronous device→host
  copy of the *bare* activation ``a^i``.  Takes no compute time; the copy
  lands at ``t + offload_time(w_{a^i})`` on an uncontended DMA link, so it
  overlaps any amount of subsequent compute.  The device copy is untouched
  (it is consumed later by ``F_∅``/``B`` as usual); host memory is charged
  from launch.
- ``("Prefetch", i)`` — synchronous host→device copy of ``a^i``: waits for
  the offload to land (``t = max(t, offload_done)``) then pays
  ``prefetch_time(w_{a^i})``; re-creates device item ``("a", i)`` and drops
  the host copy.

Live memory items are tuples ``("a", i)``, ``("abar", i)``, ``("delta", i)``;
host copies are tracked separately and reported as ``host_peak_mem``.
``ā^i`` *includes* ``a^i`` (paper §3.1), so any op that needs ``a^{i}`` may read
it from a live ``ā^{i}`` without consuming it.

Peak-memory accounting matches the paper's :math:`m_\\varnothing`/:math:`m_{all}`
formulas: during a forward, memory = live + (new output) + overhead; during a
backward, memory = live + overhead (the output ``δ^{l-1}`` reuses the space
freed by the consumed inputs — this is what makes the formulas of Theorem 1
exact for this simulator).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

from .chain import Chain

Item = Tuple[str, int]
Op = Tuple[str, object]

F_NONE, F_CK, F_ALL, BWD, FREE = "Fnone", "Fck", "Fall", "B", "Free"
F_OFF, PREFETCH = "Foff", "Prefetch"
_FORWARD_KINDS = (F_NONE, F_CK, F_ALL)
_OFFLOAD_KINDS = (F_OFF, PREFETCH)


def uses_offload(schedule: "Schedule") -> bool:
    """True if the schedule contains any host-tier (Foff/Prefetch) ops."""
    return any(k in _OFFLOAD_KINDS for k, _ in schedule.ops)


@dataclasses.dataclass
class Schedule:
    """An ordered list of operations for a chain of length L (stages 1..L+1)."""

    length: int  # L (number of real stages; loss stage is L+1)
    ops: List[Op]

    # -- canned strategies (baselines live in baselines.py; these two are the
    #    trivial ones used everywhere) --------------------------------------

    @staticmethod
    def store_all(length: int) -> "Schedule":
        """The default autograd strategy: save everything, then backprop."""
        ops: List[Op] = [(F_ALL, l) for l in range(1, length + 2)]
        ops += [(BWD, l) for l in range(length + 1, 0, -1)]
        return Schedule(length, ops)

    def count(self, kind: str) -> int:
        return sum(1 for k, _ in self.ops if k == kind)

    def forward_counts(self) -> dict:
        """How many times each stage's forward is executed (recompute factor)."""
        c: dict = {}
        for k, l in self.ops:
            if k in _FORWARD_KINDS:
                c[l] = c.get(l, 0) + 1
        return c

    def __iter__(self):
        return iter(self.ops)

    def __len__(self):
        return len(self.ops)


@dataclasses.dataclass
class SimResult:
    valid: bool
    time: float
    peak_mem: float
    error: str = ""
    # memory occupied after the final op (should be just δ^0)
    final_mem: float = 0.0
    # peak bytes parked on the host tier (0 for two-tier schedules)
    host_peak_mem: float = 0.0
    # time spent stalled waiting on host transfers (prefetch wait + copy)
    transfer_stall: float = 0.0
    # structured failure info (mirrors repro.check.violations.VIOLATION_KINDS):
    # the violation kind, the 0-based op index it fired at (-1 for
    # whole-schedule errors), and a short residency summary of the live set
    error_kind: str = ""
    error_index: int = -1
    error_state: str = ""


def _size(chain: Chain, item: Item) -> float:
    kind, i = item
    if kind == "a":
        if i == chain.length + 1:
            return 0.0  # the loss value is a scalar
        return float(chain.wa[i])
    if kind == "abar":
        return float(chain.wabar[i - 1])  # ā^i stored at array index i-1
    if kind == "delta":
        if i == chain.length + 1:
            return 0.0  # δ^{L+1} = ∂L/∂L, a scalar
        return float(chain.wdelta[i])
    raise ValueError(f"unknown item {item}")


def _residency(live: dict, host_copies: set) -> str:
    """Compact lattice state: ``dev a{0,3} ā{5} δ{6} | host{2}`` — same
    format as ``repro.check.schedule_verifier.residency_summary``."""
    parts = []
    for kind, tag in (("a", "a"), ("abar", "ā"), ("delta", "δ")):
        idxs = sorted(i for (k, i) in live if k == kind)
        if idxs:
            parts.append(tag + "{" + ",".join(map(str, idxs)) + "}")
    dev = "dev " + " ".join(parts) if parts else "dev empty"
    if host_copies:
        dev += " | host{" + ",".join(map(str, sorted(host_copies))) + "}"
    return dev


def simulate(chain: Chain, schedule: Schedule, mem_limit: float | None = None,
             track_checkpoint_persistence: bool = False,
             host_mem_limit: float | None = None,
             trace: List[dict] | None = None) -> SimResult:
    """Execute ``schedule`` on the cost model; returns validity, makespan, peak.

    If ``mem_limit`` is given, the schedule is invalid if any during-op memory
    exceeds it.  With ``track_checkpoint_persistence``, additionally marks the
    schedule invalid-as-persistent if a checkpointed value is dropped before
    its backward use (used to classify brute-force schedules).

    Offload schedules (``Foff``/``Prefetch`` ops) additionally need
    ``chain.host``; device and host peaks are tracked separately, and
    ``host_mem_limit`` bounds the host tier the same way ``mem_limit`` bounds
    the device.

    ``trace`` (optional list) collects one record per executed op —
    ``{"op", "arg", "t_start", "t_end", "device_mem", "host_mem"}`` with the
    memory values *after* the op commits — the per-op timeline surfaced by
    ``repro.plan.MemoryPlan.timeline()``.
    """
    L = chain.length
    live: dict = {("a", 0): True, ("delta", L + 1): True}
    # map item -> bool "was explicitly checkpointed"
    ckpt: set = {("a", 0)}
    mem = _size(chain, ("a", 0))
    peak = mem
    t = 0.0
    persistent = True
    # host tier: which a^i have a host copy, when their offload DMA lands
    host_copies: set = set()
    off_done: dict = {}
    host_mem = 0.0
    host_peak = 0.0
    stall = 0.0

    def has_input_act(i: int) -> Tuple[bool, Item | None]:
        """Is a^i readable? Returns (ok, the live item that provides it)."""
        if ("a", i) in live:
            return True, ("a", i)
        if i >= 1 and ("abar", i) in live:
            return True, ("abar", i)
        return False, None

    def _rec(kind, arg, t0, t1):
        if trace is not None:
            trace.append({"op": kind, "arg": arg, "t_start": t0, "t_end": t1,
                          "device_mem": mem, "host_mem": host_mem})

    def fail(kind_: str, idx_: int, msg: str, **kw) -> SimResult:
        state = _residency(live, host_copies)
        err = msg if idx_ < 0 else f"{msg} at op[{idx_}] [{state}]"
        return SimResult(False, t, peak, err, error_kind=kind_,
                         error_index=idx_, error_state=state, **kw)

    for idx, op in enumerate(schedule.ops):
        kind, arg = op
        t_op = t
        if kind == FREE:
            item = arg  # type: ignore[assignment]
            if item not in live:
                return fail("free-not-live", idx, f"Free of non-live {item}")
            if item in ckpt:
                persistent = False
            mem -= _size(chain, item)
            del live[item]
            _rec(kind, item, t_op, t)
            continue

        if kind in _OFFLOAD_KINDS:
            i = int(arg)  # activation index, 0..L
            if chain.host is None or not chain.host.enabled:
                return fail("no-host-tier", idx,
                            f"{kind} a^{i}: chain has no host tier")
            if not (0 <= i <= L):
                return fail("bad-stage", idx, f"{kind}: bad activation {i}")
            w = float(chain.wa[i])
            if kind == F_OFF:
                if ("a", i) not in live:
                    return fail("offload-not-bare", idx,
                                f"Foff: a^{i} not live as a bare activation")
                if i in host_copies:
                    return fail("double-offload", idx,
                                f"Foff: a^{i} already offloaded")
                # async launch: zero compute time, lands later; host memory is
                # charged from launch.  The device copy stays (it is consumed
                # by the following F_∅/B); the checkpoint obligation moves to
                # the host copy.
                off_done[i] = t + chain.host.offload_time(w)
                host_copies.add(i)
                host_mem += w
                host_peak = max(host_peak, host_mem)
                if host_mem_limit is not None and host_mem > host_mem_limit + 1e-9:
                    return fail("host-budget", idx,
                                f"Foff: host mem {host_mem} > limit "
                                f"{host_mem_limit}", host_peak_mem=host_peak)
                ckpt.discard(("a", i))
            else:  # PREFETCH
                if i not in host_copies:
                    return fail("prefetch-no-copy", idx,
                                f"Prefetch: a^{i} has no host copy")
                if ("a", i) in live:
                    return fail("prefetch-resident", idx,
                                f"Prefetch: a^{i} already on device")
                during = mem + w
                peak = max(peak, during)
                if mem_limit is not None and during > mem_limit + 1e-9:
                    return fail("device-budget", idx,
                                f"Prefetch: mem {during} > limit "
                                f"{mem_limit}", host_peak_mem=host_peak)
                t0 = t
                t = max(t, off_done.get(i, t)) + chain.host.prefetch_time(w)
                stall += t - t0
                live[("a", i)] = True
                mem += w
                ckpt.add(("a", i))
                host_copies.discard(i)
                host_mem -= w
            _rec(kind, i, t_op, t)
            continue

        l = int(arg)  # stage index, 1..L+1
        if kind in _FORWARD_KINDS:
            if not (1 <= l <= L + 1):
                return fail("bad-stage", idx, f"bad stage {l}")
            ok, src = has_input_act(l - 1)
            if not ok:
                return fail("missing-input", idx,
                            f"{kind}^{l}: a^{l-1} not live")
            out: Item = ("abar", l) if kind == F_ALL else ("a", l)
            if kind != F_ALL and l == L + 1:
                # the loss output is a scalar; modelled as a^{L+1} of size 0,
                # but Fnone/Fck of the loss stage are pointless — allow anyway.
                pass
            new_bytes = 0.0 if out in live else _size(chain, out)
            during = mem + new_bytes + float(chain.of[l - 1])
            peak = max(peak, during)
            if mem_limit is not None and during > mem_limit + 1e-9:
                return fail("device-budget", idx,
                            f"{kind}^{l}: mem {during} > limit {mem_limit}")
            t += float(chain.uf[l - 1])
            # commit: maybe consume input, add output
            if kind == F_NONE and src == ("a", l - 1):
                if src in ckpt:
                    persistent = False
                mem -= _size(chain, src)
                del live[src]
            if out not in live:
                live[out] = True
                mem += new_bytes
            if kind in (F_CK, F_ALL) and ("a", l - 1) in live:
                # the retained bare input is now a stored value awaiting its
                # backward use — dropping it later is a persistency violation
                ckpt.add(("a", l - 1))
            if kind == F_ALL:
                ckpt.add(out)
        elif kind == BWD:
            if not (1 <= l <= L + 1):
                return fail("bad-stage", idx, f"bad stage {l}")
            need = [(("delta", l), "missing-grad"),
                    (("abar", l), "missing-residual")]
            for item, vkind in need:
                if item not in live:
                    return fail(vkind, idx, f"B^{l}: {item} not live")
            ok, src = has_input_act(l - 1)
            if not ok:
                return fail("missing-input", idx, f"B^{l}: a^{l-1} not live")
            during = mem + float(chain.ob[l - 1])
            peak = max(peak, during)
            if mem_limit is not None and during > mem_limit + 1e-9:
                return fail("device-budget", idx,
                            f"B^{l}: mem {during} > limit {mem_limit}")
            t += float(chain.ub[l - 1])
            # consume δ^l, ā^l, and a^{l-1} (unless provided by ā^{l-1})
            for item in (("delta", l), ("abar", l)):
                mem -= _size(chain, item)
                del live[item]
                ckpt.discard(item)
            if src == ("a", l - 1):
                mem -= _size(chain, src)
                del live[src]
                ckpt.discard(src)
            out = ("delta", l - 1)
            if out not in live:
                live[out] = True
                mem += _size(chain, out)
        else:
            return fail("bad-op", idx, f"unknown op kind {kind}")
        _rec(kind, l, t_op, t)

    if ("delta", 0) not in live:
        return fail("no-output", -1, "schedule did not produce δ^0")
    if track_checkpoint_persistence and not persistent:
        return fail("non-persistent", -1, "non-persistent", final_mem=mem,
                    host_peak_mem=host_peak, transfer_stall=stall)
    return SimResult(True, t, peak, final_mem=mem, host_peak_mem=host_peak,
                     transfer_stall=stall)


class ScheduleViolationError(AssertionError):
    """``assert_valid`` failure carrying the structured
    :class:`repro.check.violations.Violation` the simulator hit — the same
    type the static verifier reports, so dynamic and static checks are
    interchangeable oracles."""

    def __init__(self, violation):
        self.violation = violation
        # violation.message already carries op position + residency summary
        super().__init__(
            f"invalid schedule [{violation.kind}]: {violation.message}")


def assert_valid(chain: Chain, schedule: Schedule,
                 mem_limit: float | None = None) -> SimResult:
    """Simulate and raise :class:`ScheduleViolationError` (an
    ``AssertionError``) on any validity failure.  This is the thin dynamic
    cross-check of the static pass in ``repro.check.schedule_verifier``."""
    res = simulate(chain, schedule, mem_limit)
    if not res.valid:
        from ..check.violations import Violation  # lazy: no import cycle
        op = (schedule.ops[res.error_index]
              if 0 <= res.error_index < len(schedule.ops) else None)
        raise ScheduleViolationError(Violation(
            kind=res.error_kind or "bad-op", message=res.error,
            op_index=res.error_index, op=op, state=res.error_state))
    return res
