"""Exhaustive optimal-schedule search on tiny chains (test oracle).

Explores the full schedule space of the paper's Table-1 operation model with a
Dijkstra search over states ``(live-set, next-backward, persistent-flag)``.
Supports non-persistent schedules via value drops (``Free``), which is what
the §4.1 counter-example needs.

Only usable for small L (state space is exponential), which is exactly its
role: an oracle to validate the DP solver's optimality over *persistent*
schedules and the strict gap to *non-persistent* ones.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Dict, FrozenSet, Optional, Tuple

from .chain import Chain
from .schedule import BWD, F_ALL, F_CK, F_NONE, FREE, Schedule

Item = Tuple[str, int]
State = Tuple[FrozenSet[Item], int, bool]  # (live a/abar items, next_bwd, persistent)


def _sizes(chain: Chain):
    L = chain.length

    def size(item: Item) -> float:
        k, i = item
        if k == "a":
            return 0.0 if i == L + 1 else float(chain.wa[i])
        if k == "abar":
            return float(chain.wabar[i - 1])
        if k == "delta":
            return 0.0 if i == L + 1 else float(chain.wdelta[i])
        raise ValueError(item)

    return size


def optimal_time(chain: Chain, mem_limit: float,
                 persistent_only: bool = False,
                 return_schedule: bool = False):
    """Minimum makespan over ALL valid schedules within ``mem_limit``.

    With ``persistent_only=True``, restrict to memory-persistent schedules
    (checkpointed values never dropped before their backward use).
    Returns ``inf`` if infeasible; with ``return_schedule`` also returns the
    argmin ``Schedule`` (or None).
    """
    L = chain.length
    size = _sizes(chain)

    def mem_of(live: FrozenSet[Item], next_bwd: int) -> float:
        return sum(size(it) for it in live) + size(("delta", next_bwd))

    # ``ckpt`` membership is tracked implicitly: an ("a", i) item is a
    # checkpoint iff it was retained by an F_ck/F_all/initial-input event.
    # In this reduced state we conservatively treat every *stored* item as a
    # checkpoint: any live ("a", i) that gets consumed by F_none or dropped
    # makes the schedule non-persistent UNLESS it was just produced by the
    # immediately preceding forward (streaming).  To keep the state small we
    # instead annotate items: ("a", i, tag) with tag "ck" or "tmp".
    start_live: FrozenSet = frozenset({("a", 0)})
    start: State = (start_live, L + 1, True)

    # item encoding inside `live`: ("a", i) means *checkpointed* a^i;
    # ("t", i) means transient a^i (produced by F_none, droppable freely);
    # ("abar", i) is always a checkpoint.
    def a_live(live, i):
        return ("a", i) in live or ("t", i) in live or ("abar", i) in live

    def size2(item):
        if item[0] == "t":
            return size(("a", item[1]))
        return size(item)

    def mem2(live, next_bwd):
        return sum(size2(it) for it in live) + size(("delta", next_bwd))

    dist: Dict[State, float] = {start: 0.0}
    prev: Dict[State, Tuple[State, tuple]] = {}
    pq = [(0.0, 0, start)]
    counter = itertools.count(1)
    goal_time = float("inf")
    goal_state: Optional[State] = None

    while pq:
        d, _, state = heapq.heappop(pq)
        if d > dist.get(state, float("inf")):
            continue
        live, nb, pers = state
        if nb == 0:
            if d < goal_time:
                goal_time, goal_state = d, state
            break  # Dijkstra: first goal pop is optimal
        base_mem = mem2(live, nb)

        def push(nstate: State, cost: float, op: tuple):
            nd = d + cost
            if nd < dist.get(nstate, float("inf")):
                dist[nstate] = nd
                prev[nstate] = (state, op)
                heapq.heappush(pq, (nd, next(counter), nstate))

        # forwards
        for l in range(1, L + 2):
            if not a_live(live, l - 1):
                continue
            uf = float(chain.uf[l - 1])
            of = float(chain.of[l - 1])
            # F_none
            out_t = ("t", l)
            if out_t not in live and ("a", l) not in live:
                new_bytes = size(("a", l))
                if base_mem + new_bytes + of <= mem_limit + 1e-9:
                    nl = set(live)
                    npers = pers
                    if ("t", l - 1) in nl:
                        nl.discard(("t", l - 1))
                    elif ("a", l - 1) in nl:
                        nl.discard(("a", l - 1))
                        npers = False  # consumed a checkpoint
                    nl.add(out_t)
                    if not (persistent_only and not npers):
                        push((frozenset(nl), nb, npers), uf, (F_NONE, l))
            # F_ck (same compute; input becomes/stays a checkpoint)
            if ("t", l) not in live and ("a", l) not in live:
                new_bytes = size(("a", l))
                if base_mem + new_bytes + of <= mem_limit + 1e-9:
                    nl = set(live)
                    if ("t", l - 1) in nl:
                        nl.discard(("t", l - 1))
                        nl.add(("a", l - 1))
                    nl.add(("t", l))
                    push((frozenset(nl), nb, pers), uf, (F_CK, l))
            # F_all
            if ("abar", l) not in live:
                new_bytes = size(("abar", l))
                if base_mem + new_bytes + of <= mem_limit + 1e-9:
                    nl = set(live)
                    if ("t", l - 1) in nl:  # input retained -> checkpoint
                        nl.discard(("t", l - 1))
                        nl.add(("a", l - 1))
                    nl.add(("abar", l))
                    push((frozenset(nl), nb, pers), uf, (F_ALL, l))
        # backward of stage nb
        l = nb
        if ("abar", l) in live and a_live(live, l - 1):
            ob = float(chain.ob[l - 1])
            if base_mem + ob <= mem_limit + 1e-9:
                nl = set(live)
                nl.discard(("abar", l))
                # consume the bare a^{l-1} if live (matches simulator's
                # preference); if only ā^{l-1} provides it, keep ā^{l-1}.
                for tag in ("a", "t"):
                    if (tag, l - 1) in nl:
                        nl.discard((tag, l - 1))
                        break
                push((frozenset(nl), nb - 1, pers), float(chain.ub[l - 1]),
                     (BWD, l))
        # frees (only useful for non-persistent exploration)
        if not persistent_only:
            for it in live:
                nl = set(live)
                nl.discard(it)
                npers = pers if it[0] == "t" else False
                push((frozenset(nl), nb, npers), 0.0, (FREE, it))

    if goal_state is None:
        return (float("inf"), None) if return_schedule else float("inf")
    if not return_schedule:
        return goal_time
    ops = []
    st = goal_state
    while st in prev:
        st, op = prev[st]
        ops.append(op)
    ops.reverse()
    # map internal Free-item encoding back to simulator items
    fixed = []
    for k, arg in ops:
        if k == FREE and isinstance(arg, tuple) and arg[0] == "t":
            fixed.append((FREE, ("a", arg[1])))
        else:
            fixed.append((k, arg))
    return goal_time, Schedule(L, fixed)
