"""Config-facing remat policies: string → schedule tree / execution plan.

``make_policy_tree(policy, chain)`` accepts:

- ``"none"``          — store everything (autograd default / paper "PyTorch").
- ``"full"``          — remat every stage (minimum memory, max recompute).
- ``"periodic:K"``    — the paper's "sequential" comparator with K segments.
- ``"rotor:BUDGET"``  — the paper's optimal persistent schedule under BUDGET
                        bytes of activation memory (per device).  BUDGET
                        accepts ``1.5e9``, ``1.5G``, ``800M``, or ``x0.5``
                        (fraction of the store-all peak).
- ``"revolve:BUDGET"``— AD-model comparator (activations-only checkpoints).
- ``"optimal_offload:BUDGET[:BW]"`` — the three-tier schedule (device /
                        device-full-history / host copy) under BUDGET bytes
                        of *device* activation memory, with host link
                        bandwidth BW in bytes/s (``8G`` = 8e9; defaults to
                        ``chain.host`` when profiled, else the PCIe-3 x16
                        constant).  ``BW = 0`` falls back to the two-tier
                        optimal solver.

The returned tree feeds :func:`repro.core.rematerialize.build_remat_fn` —
which is why ``make_policy_tree`` refuses offload-bearing plans (XLA cannot
express host DMA from a remat tree): use :func:`make_policy_plan` and run the
plan's ``schedule`` through the eager offload executor instead.

All solver-backed policies (``rotor:*``, ``revolve:*``, ``optimal_offload:*``)
are memoized through :mod:`repro.core.solver_cache`: resolving the same
policy on the same profiled chain — a relaunch, or one point of a budget
sweep revisited — returns the cached ``Solution`` without filling DP tables.
``REPRO_SOLVER_CACHE=0`` disables this; ``REPRO_SOLVER_CACHE_DIR`` moves the
on-disk store.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

from .chain import Chain, HostTransferModel
from .rematerialize import full_remat_tree, periodic_tree, sequential_tree
from .schedule import Schedule, simulate
from .solver import Solution, Tree, solve_optimal

_UNITS = {"K": 1e3, "M": 1e6, "G": 1e9, "T": 1e12}


def _parse_size(spec: str) -> float:
    m = re.fullmatch(r"([\d.eE+-]+)([KMGT]?)", spec.strip())
    if not m:
        raise ValueError(f"cannot parse size {spec!r}")
    return float(m.group(1)) * _UNITS.get(m.group(2), 1.0)


def parse_budget(spec: str, chain: Optional[Chain]) -> float:
    spec = spec.strip()
    if spec.startswith("x"):
        if chain is None:
            raise ValueError("fractional budget needs a profiled chain")
        peak = simulate(chain, Schedule.store_all(chain.length)).peak_mem
        return float(spec[1:]) * peak
    return _parse_size(spec)


@dataclasses.dataclass
class PolicyPlan:
    """A resolved policy: the recursion tree (when the plan is expressible as
    nested remat) and the op schedule (always).  ``uses_offload`` marks plans
    that need the eager offload executor."""

    policy: str
    tree: Optional[Tree]
    schedule: Optional[Schedule]
    solution: Optional[Solution]
    chain: Optional[Chain]
    uses_offload: bool = False


def make_policy_plan(policy: str, chain: Optional[Chain],
                     length: Optional[int] = None,
                     num_slots: int = 500) -> PolicyPlan:
    """Resolve any policy string — including ``optimal_offload`` — into a
    :class:`PolicyPlan`."""
    if not policy.startswith("optimal_offload"):
        tree = make_policy_tree(policy, chain, length=length,
                                num_slots=num_slots)
        from .solver import tree_to_schedule
        L = chain.length if chain is not None else length
        sched = tree_to_schedule(tree, L)
        return PolicyPlan(policy, tree, sched, None, chain)

    if chain is None:
        raise ValueError(f"{policy!r} needs a profiled chain")
    parts = policy.split(":")
    if len(parts) < 2:
        raise ValueError(
            "optimal_offload policy needs a budget: 'optimal_offload:BUDGET"
            "[:BW]'")
    budget = parse_budget(parts[1], chain)
    host = chain.host
    if len(parts) >= 3:
        bw = _parse_size(parts[2])
        host = HostTransferModel(bandwidth_d2h=bw) if bw > 0 else None
    elif host is None:
        host = HostTransferModel.pcie_gen3()

    if host is None or not host.enabled:
        # zero host bandwidth: the third tier does not exist — two-tier DP
        sol = solve_optimal(chain, budget, num_slots=num_slots)
        if not sol.feasible:
            raise MemoryError(
                f"optimal_offload (bw=0 fallback): no feasible persistent "
                f"schedule within {budget:.3e} bytes")
        return PolicyPlan(policy, sol.tree, sol.schedule, sol, chain,
                          uses_offload=False)

    from ..offload.solver import solve_optimal_offload, tree_uses_offload
    hchain = chain.with_host(host)
    sol = solve_optimal_offload(hchain, budget, num_slots=num_slots)
    if not sol.feasible:
        raise MemoryError(
            f"optimal_offload: no feasible schedule within {budget:.3e} "
            f"bytes of device memory even with the host tier")
    return PolicyPlan(policy, sol.tree, sol.schedule, sol, hchain,
                      uses_offload=tree_uses_offload(sol.tree))


def make_policy_tree(policy: str, chain: Optional[Chain],
                     length: Optional[int] = None,
                     num_slots: int = 500) -> Tree:
    if chain is not None:
        length = chain.length
    if length is None:
        raise ValueError("need chain or length")
    if policy == "none":
        return sequential_tree(length)
    if policy == "full":
        return full_remat_tree(length)
    if policy.startswith("periodic:"):
        return periodic_tree(length, int(policy.split(":", 1)[1]))
    if policy.startswith(("rotor:", "revolve:")):
        if chain is None:
            raise ValueError(f"{policy!r} needs a profiled chain")
        kind, spec = policy.split(":", 1)
        budget = parse_budget(spec, chain)
        sol = solve_optimal(chain, budget, num_slots=num_slots,
                            allow_fall=(kind == "rotor"))
        if not sol.feasible:
            raise MemoryError(
                f"{kind}: no feasible persistent schedule within "
                f"{budget:.3e} bytes for this chain")
        return sol.tree
    if policy.startswith("optimal_offload"):
        plan = make_policy_plan(policy, chain, length=length,
                                num_slots=num_slots)
        if plan.uses_offload:
            raise ValueError(
                f"{policy!r} resolved to a host-offload plan, which nested "
                f"remat cannot express — use make_policy_plan() and run "
                f"plan.schedule through repro.offload.executor")
        return plan.tree
    raise ValueError(f"unknown remat policy {policy!r}")
