"""Config-facing remat policies: string → schedule tree.

``make_policy_tree(policy, chain)`` accepts:

- ``"none"``          — store everything (autograd default / paper "PyTorch").
- ``"full"``          — remat every stage (minimum memory, max recompute).
- ``"periodic:K"``    — the paper's "sequential" comparator with K segments.
- ``"rotor:BUDGET"``  — the paper's optimal persistent schedule under BUDGET
                        bytes of activation memory (per device).  BUDGET
                        accepts ``1.5e9``, ``1.5G``, ``800M``, or ``x0.5``
                        (fraction of the store-all peak).
- ``"revolve:BUDGET"``— AD-model comparator (activations-only checkpoints).

The returned tree feeds :func:`repro.core.rematerialize.build_remat_fn`.
"""

from __future__ import annotations

import re
from typing import Optional

from .chain import Chain
from .rematerialize import full_remat_tree, periodic_tree, sequential_tree
from .schedule import Schedule, simulate
from .solver import Tree, solve_optimal

_UNITS = {"K": 1e3, "M": 1e6, "G": 1e9, "T": 1e12}


def parse_budget(spec: str, chain: Optional[Chain]) -> float:
    spec = spec.strip()
    if spec.startswith("x"):
        if chain is None:
            raise ValueError("fractional budget needs a profiled chain")
        peak = simulate(chain, Schedule.store_all(chain.length)).peak_mem
        return float(spec[1:]) * peak
    m = re.fullmatch(r"([\d.eE+-]+)([KMGT]?)", spec)
    if not m:
        raise ValueError(f"cannot parse memory budget {spec!r}")
    return float(m.group(1)) * _UNITS.get(m.group(2), 1.0)


def make_policy_tree(policy: str, chain: Optional[Chain],
                     length: Optional[int] = None,
                     num_slots: int = 500) -> Tree:
    if chain is not None:
        length = chain.length
    if length is None:
        raise ValueError("need chain or length")
    if policy == "none":
        return sequential_tree(length)
    if policy == "full":
        return full_remat_tree(length)
    if policy.startswith("periodic:"):
        return periodic_tree(length, int(policy.split(":", 1)[1]))
    if policy.startswith(("rotor:", "revolve:")):
        if chain is None:
            raise ValueError(f"{policy!r} needs a profiled chain")
        kind, spec = policy.split(":", 1)
        budget = parse_budget(spec, chain)
        sol = solve_optimal(chain, budget, num_slots=num_slots,
                            allow_fall=(kind == "rotor"))
        if not sol.feasible:
            raise MemoryError(
                f"{kind}: no feasible persistent schedule within "
                f"{budget:.3e} bytes for this chain")
        return sol.tree
    raise ValueError(f"unknown remat policy {policy!r}")
