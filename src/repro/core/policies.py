"""Back-compat shim: remat policy *strings* → :mod:`repro.plan` requests.

The planning surface of this repo is :mod:`repro.plan` — typed
:class:`~repro.plan.PlanRequest` in, inspectable/serializable
:class:`~repro.plan.MemoryPlan` out.  This module keeps the historical
string grammar working: every string maps onto exactly one request
(:func:`repro.plan.compat.policy_to_request` — the migration table) and
resolves through the single path :func:`repro.plan.compat.resolve_policy`;
no policy-prefix dispatch exists outside :mod:`repro.plan`.

``make_policy_tree(policy, chain)`` accepts:

- ``"none"``          — store everything (autograd default / paper "PyTorch").
- ``"full"``          — remat every stage (minimum memory, max recompute).
- ``"periodic:K"``    — the paper's "sequential" comparator with K segments.
- ``"rotor:BUDGET"``  — the paper's optimal persistent schedule under BUDGET
                        bytes of activation memory (per device).  BUDGET
                        accepts ``1.5e9``, ``1.5G``, ``800M``, or ``x0.5``
                        (fraction of the store-all peak).
- ``"revolve:BUDGET"``— AD-model comparator (activations-only checkpoints).
- ``"optimal_offload:BUDGET[:BW]"`` — the three-tier schedule (device /
                        device-full-history / host copy) under BUDGET bytes
                        of *device* activation memory, with host link
                        bandwidth BW in bytes/s (``8G`` = 8e9; defaults to
                        ``chain.host`` when profiled, else the PCIe-3 x16
                        constant).  ``BW = 0`` falls back to the two-tier
                        optimal solver.

The returned tree feeds :func:`repro.core.rematerialize.build_remat_fn` —
which is why ``make_policy_tree`` refuses offload-bearing plans (XLA cannot
express host DMA from a remat tree): use :func:`make_policy_plan` (or
:func:`repro.plan.build_plan` directly) and run the plan through
``plan.bind(...)`` / the eager offload executor instead.

All solver-backed policies are memoized through
:mod:`repro.core.solver_cache` exactly as before — resolving the same policy
on the same profiled chain returns the cached ``Solution`` without filling
DP tables.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from ..plan import MemoryPlan, parse_size
from ..plan.compat import (DOCUMENTED_POLICIES, parse_budget,
                           policy_to_request, resolve_policy)
from .chain import Chain
from .schedule import Schedule
from .solver import Solution, Tree

__all__ = ["DOCUMENTED_POLICIES", "PolicyPlan", "make_policy_plan",
           "make_policy_tree", "parse_budget", "policy_to_request",
           "resolve_policy"]


def _parse_size(spec: str) -> float:
    """Absolute size with optional K/M/G/T suffix (strict; see
    :func:`repro.plan.parse_size`)."""
    return parse_size(spec)


@dataclasses.dataclass
class PolicyPlan:
    """A resolved policy (back-compat wrapper around :class:`MemoryPlan`):
    the recursion tree (when the plan is expressible as nested remat) and the
    op schedule (always).  ``uses_offload`` marks plans that need the eager
    offload executor; ``plan`` is the underlying planning artifact."""

    policy: str
    tree: Optional[Tree]
    schedule: Optional[Schedule]
    solution: Optional[Solution]
    chain: Optional[Chain]
    uses_offload: bool = False
    plan: Optional[MemoryPlan] = None


def make_policy_plan(policy: str, chain: Optional[Chain],
                     length: Optional[int] = None,
                     num_slots: Optional[int] = None,
                     impl: Optional[str] = None) -> PolicyPlan:
    """Resolve any policy string — including ``optimal_offload`` — into a
    :class:`PolicyPlan`."""
    plan = resolve_policy(policy, chain, length=length, num_slots=num_slots,
                          impl=impl)
    return PolicyPlan(policy, plan.tree, plan.schedule, plan.solution,
                      plan.chain, uses_offload=plan.uses_offload, plan=plan)


def make_policy_tree(policy: str, chain: Optional[Chain],
                     length: Optional[int] = None,
                     num_slots: Optional[int] = None,
                     impl: Optional[str] = None) -> Tree:
    """Resolve a policy string into a remat-expressible recursion tree
    (raises for plans that need the host tier — those cannot run under
    ``jax.checkpoint``)."""
    plan = resolve_policy(policy, chain, length=length, num_slots=num_slots,
                          impl=impl)
    if plan.uses_offload:
        raise ValueError(
            f"{policy!r} resolved to a host-offload plan, which nested "
            f"remat cannot express — use make_policy_plan() and run "
            f"plan.schedule through repro.offload.executor")
    return plan.tree
