"""Heterogeneous-chain cost model (paper §3).

A chain has L stages, numbered 1..L, plus a virtual loss stage L+1 (the paper's
``F^{L+1}/B^{L+1}``).  Stage ``l`` carries:

- ``uf[l]`` / ``ub[l]``  : forward / backward compute time,
- ``wa[l]``              : size of the stage *output* activation ``a^l``,
- ``wabar[l]``           : size of the full residual set ``ā^l`` (everything the
                           backward of stage l needs, *including* ``a^l`` but
                           excluding ``a^{l-1}``),
- ``wdelta[l]``          : size of the back-propagated gradient ``δ^l``
                           (in practice ``wdelta == wa``; kept separate for the
                           counter-example of §4.1 where δ sizes are 0),
- ``of[l]`` / ``ob[l]``  : transient memory overheads of the fwd / bwd op.

Arrays are indexed 0..L where index ``l`` refers to stage ``l+1`` of the paper
for compute costs; to keep the code close to the paper we store arrays of
length ``L+1`` with the convention below:

- ``uf[i]``, ``ub[i]``, ``wabar[i]``, ``of[i]``, ``ob[i]`` for ``i in 0..L``
  describe stage ``i+1`` in paper numbering (so ``i=L`` is the loss stage).
- ``wa[i]`` for ``i in 0..L`` is the size of activation ``a^i`` — ``wa[0]`` is
  the chain *input* ``a^0 = x`` and ``wa[i]`` the output of (paper) stage i.
  The output of the loss stage is a scalar and never checkpointed.
- ``wdelta[i]`` for ``i in 0..L`` is the size of ``δ^i`` (gradient w.r.t.
  ``a^i``); ``δ^{L+1}`` (gradient of the loss w.r.t. itself) is a scalar = 0.

All sizes are in abstract units (the solver discretizes to memory slots); the
planner produces them in bytes and converts.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class HostTransferModel:
    """Cost model of the device↔host link (the third storage tier).

    Transfers are modelled as asynchronous DMA copies on an uncontended link:
    a transfer launched at time ``t`` completes at ``t + latency + bytes/bw``
    regardless of what the compute stream does, so offloads *overlap* with
    compute and only stall the timeline when a dependent op (a ``Prefetch``)
    reaches the data before the copy has landed.

    Bandwidths are in (size units)/second — bytes/s when the chain is profiled
    in bytes, matching ``Chain.wa``.  ``bandwidth_h2d`` defaults to the
    device→host value (full-duplex symmetric link, e.g. PCIe).  A zero
    ``bandwidth_d2h`` disables the tier entirely (transfers take forever);
    solvers fall back to the two-tier model.
    """

    bandwidth_d2h: float                  # device → host, size-units / s
    bandwidth_h2d: float | None = None    # host → device (default: = d2h)
    latency: float = 0.0                  # fixed per-transfer cost, seconds

    def __post_init__(self):
        if self.bandwidth_d2h < 0 or (self.bandwidth_h2d or 0) < 0:
            raise ValueError("host bandwidth must be non-negative")
        if self.latency < 0:
            raise ValueError("host latency must be non-negative")

    @property
    def enabled(self) -> bool:
        return self.bandwidth_d2h > 0

    def offload_time(self, size: float) -> float:
        """Seconds for a device→host copy of ``size`` units (inf if disabled)."""
        if not self.enabled:
            return float("inf")
        return self.latency + float(size) / self.bandwidth_d2h

    def prefetch_time(self, size: float) -> float:
        """Seconds for a host→device copy of ``size`` units (inf if disabled)."""
        bw = self.bandwidth_h2d if self.bandwidth_h2d else self.bandwidth_d2h
        if not bw or bw <= 0:
            return float("inf")
        return self.latency + float(size) / bw

    @staticmethod
    def pcie_gen3() -> "HostTransferModel":
        """Effective PCIe 3.0 x16 pinned-memory throughput (~12 GB/s)."""
        return HostTransferModel(bandwidth_d2h=12e9)


@dataclasses.dataclass(frozen=True)
class Chain:
    """Cost description of a heterogeneous backprop chain of length L.

    ``length`` is the number of real stages L; internal arrays have L+1
    entries, the last describing the loss stage F^{L+1}/B^{L+1}.

    ``host`` (optional) prices the third storage tier — asynchronous
    activation offload to host RAM; ``None`` means the two-tier model.
    """

    uf: np.ndarray      # (L+1,) forward times, stage 1..L+1
    ub: np.ndarray      # (L+1,) backward times, stage 1..L+1
    wa: np.ndarray      # (L+1,) sizes of a^0 .. a^L
    wabar: np.ndarray   # (L+1,) sizes of ā^1 .. ā^{L+1}
    wdelta: np.ndarray  # (L+1,) sizes of δ^0 .. δ^L
    of: np.ndarray      # (L+1,) fwd memory overheads, stage 1..L+1
    ob: np.ndarray      # (L+1,) bwd memory overheads, stage 1..L+1
    host: "HostTransferModel | None" = None

    @property
    def length(self) -> int:
        return len(self.uf) - 1

    def __post_init__(self):
        n = len(self.uf)
        for name in ("ub", "wa", "wabar", "wdelta", "of", "ob"):
            arr = getattr(self, name)
            if len(arr) != n:
                raise ValueError(
                    f"chain field {name} has length {len(arr)}, expected {n}")
        for name in ("uf", "ub", "wa", "wabar", "wdelta", "of", "ob"):
            if np.any(np.asarray(getattr(self, name)) < 0):
                raise ValueError(f"chain field {name} has negative entries")

    # -- constructors ------------------------------------------------------

    @staticmethod
    def make(
        uf: Sequence[float],
        ub: Sequence[float],
        wa: Sequence[float],
        wabar: Sequence[float],
        wdelta: Sequence[float] | None = None,
        of: Sequence[float] | None = None,
        ob: Sequence[float] | None = None,
        host: "HostTransferModel | None" = None,
    ) -> "Chain":
        uf = np.asarray(uf, dtype=np.float64)
        n = len(uf)
        z = np.zeros(n, dtype=np.float64)

        def arr(x, default):
            return default.copy() if x is None else np.asarray(x, dtype=np.float64)

        wa_ = np.asarray(wa, dtype=np.float64)
        wdelta_ = arr(wdelta, wa_)
        return Chain(
            uf=uf,
            ub=np.asarray(ub, dtype=np.float64),
            wa=wa_,
            wabar=np.asarray(wabar, dtype=np.float64),
            wdelta=wdelta_,
            of=arr(of, z),
            ob=arr(ob, z),
            host=host,
        )

    @staticmethod
    def homogeneous(length: int, uf: float = 1.0, ub: float = 1.0,
                    wa: float = 1.0, wabar: float = 2.0) -> "Chain":
        """A homogeneous chain (the classic AD setting) with a free loss stage."""
        n = length + 1
        ufs = np.full(n, uf); ufs[-1] = 0.0
        ubs = np.full(n, ub); ubs[-1] = 0.0
        was = np.full(n, wa)
        wabars = np.full(n, wabar); wabars[-1] = 0.0
        return Chain.make(ufs, ubs, was, wabars)

    # -- utilities ---------------------------------------------------------

    def with_host(self, host: "HostTransferModel | None") -> "Chain":
        """A copy of this chain priced with the given host-transfer model."""
        return dataclasses.replace(self, host=host)

    def calibrate(self, uf: "Sequence[float] | None" = None,
                  ub: "Sequence[float] | None" = None,
                  blend: float = 1.0) -> "Chain":
        """A copy with *measured* per-stage compute times folded in.

        ``uf``/``ub`` are length-``L+1`` arrays of measured forward/backward
        seconds (same indexing as the chain's own arrays); ``NaN`` entries
        keep the modeled value — :func:`repro.obs.trace.measured_stage_times`
        produces exactly this shape from an execution trace.  ``blend``
        interpolates model → measurement (1.0 = trust the measurement
        fully); sizes and the host link are untouched, so a calibrated
        chain re-plans on the same memory model with grounded times.
        """
        if not 0.0 <= blend <= 1.0:
            raise ValueError("blend must be in [0, 1]")

        def fold(model: np.ndarray, measured) -> np.ndarray:
            if measured is None:
                return model
            meas = np.asarray(measured, dtype=np.float64)
            if meas.shape != model.shape:
                raise ValueError(
                    f"measured times have shape {meas.shape}, "
                    f"expected {model.shape}")
            if np.any(meas[~np.isnan(meas)] < 0):
                raise ValueError("measured times must be non-negative")
            out = model.copy()
            ok = ~np.isnan(meas)
            out[ok] = (1.0 - blend) * model[ok] + blend * meas[ok]
            return out

        return dataclasses.replace(self, uf=fold(np.asarray(self.uf), uf),
                                   ub=fold(np.asarray(self.ub), ub))

    def offload_times(self) -> np.ndarray:
        """Per-activation device→host copy time: entry ``i`` is ``a^i``."""
        if self.host is None:
            return np.full(len(self.wa), np.inf)
        return np.array([self.host.offload_time(w) for w in self.wa])

    def prefetch_times(self) -> np.ndarray:
        """Per-activation host→device copy time: entry ``i`` is ``a^i``."""
        if self.host is None:
            return np.full(len(self.wa), np.inf)
        return np.array([self.host.prefetch_time(w) for w in self.wa])

    def discretize(self, mem_limit: float, num_slots: int) -> "DiscreteChain":
        """Discretize memory sizes into ``num_slots`` slots of size
        ``mem_limit / num_slots`` each, rounding *up* (paper §5.2: at most a
        ``1 + 1/S`` overestimation)."""
        if mem_limit <= 0:
            raise ValueError("mem_limit must be positive")
        slot = mem_limit / num_slots

        def q(x: np.ndarray) -> np.ndarray:
            return np.ceil(np.asarray(x, dtype=np.float64) / slot - 1e-12).astype(np.int64)

        return DiscreteChain(
            chain=self,
            slot_size=slot,
            num_slots=num_slots,
            wa=q(self.wa),
            wabar=q(self.wabar),
            wdelta=q(self.wdelta),
            of=q(self.of),
            ob=q(self.ob),
        )

    def store_all_peak(self) -> float:
        """Peak memory of the default store-everything strategy (all F_all then
        all B), per the simulator. Useful as an upper bound for budgets."""
        from .schedule import Schedule, simulate  # local import, avoid cycle
        sched = Schedule.store_all(self.length)
        res = simulate(self, sched)
        return res.peak_mem


@dataclasses.dataclass(frozen=True)
class DiscreteChain:
    """A chain with memory sizes expressed in integer slots."""

    chain: Chain
    slot_size: float
    num_slots: int
    wa: np.ndarray
    wabar: np.ndarray
    wdelta: np.ndarray
    of: np.ndarray
    ob: np.ndarray

    @property
    def length(self) -> int:
        return self.chain.length

    @property
    def uf(self) -> np.ndarray:
        return self.chain.uf

    @property
    def ub(self) -> np.ndarray:
        return self.chain.ub
