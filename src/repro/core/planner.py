"""Parameter estimation (paper §5.1) — produce a ``Chain`` cost model for a
sequence of JAX stage functions.

Two modes, mirroring the two ways we run:

- **analytic** (dry-run / TPU-target): per-stage FLOPs from
  ``jit(fn).lower(...).compile().cost_analysis()`` divided by a peak FLOP/s
  constant; activation/residual *sizes* are exact, from ``jax.eval_shape`` of
  the stage and of its VJP (the VJP closure is a pytree whose leaves are the
  residual tensors — JAX's ``ā^l``).  Residual leaves that are shape/dtype-
  identical to parameter leaves are greedily excluded (the paper removes
  model/grad memory from the activation budget, §3.1).
- **measured** (CPU reproduction benchmarks): wall-clock each stage's forward
  and forward+backward, exactly like the paper's measurement tool.

Both return a :class:`repro.core.chain.Chain` (sizes in bytes, times in
seconds for measured / FLOP-derived seconds for analytic).
"""

from __future__ import annotations

import collections
import time
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .chain import Chain, HostTransferModel

# TPU v5e-ish defaults; overridable.
PEAK_FLOPS_BF16 = 197e12


def measure_host_bandwidth(sample_bytes: int = 1 << 26, repeats: int = 3,
                           latency: float = 1e-4) -> HostTransferModel:
    """Measure the effective device↔host copy bandwidth (paper-§5.1 style:
    wall-clock the actual operation).  Device→host is a forced ``np.asarray``
    materialization, host→device a ``jax.device_put`` — both are real copies
    on every backend, including CPU (where they time memcpy, the honest cost
    of the 'host tier' there)."""
    n = max(sample_bytes // 4, 1)
    dev = jnp.ones((n,), jnp.float32)
    jax.block_until_ready(dev)
    t0 = time.perf_counter()
    for _ in range(repeats):
        host = np.array(dev, copy=True)  # asarray may alias on CPU backends
    t_d2h = (time.perf_counter() - t0) / repeats
    t0 = time.perf_counter()
    for _ in range(repeats):
        back = jax.device_put(host)
        jax.block_until_ready(back)
    t_h2d = (time.perf_counter() - t0) / repeats
    nbytes = n * 4
    return HostTransferModel(
        bandwidth_d2h=nbytes / max(t_d2h, 1e-12),
        bandwidth_h2d=nbytes / max(t_h2d, 1e-12),
        latency=latency)


def _bytes_of(spec) -> int:
    return int(np.prod(spec.shape)) * np.dtype(spec.dtype).itemsize if spec.shape else np.dtype(spec.dtype).itemsize


def _pytree_bytes(tree) -> int:
    return sum(_bytes_of(l) for l in jax.tree.leaves(tree))


def residual_bytes(fn: Callable, p: Any, a: Any) -> int:
    """ω_ā for one stage: VJP-residual bytes minus param-aliased leaves."""
    _, vjp_spec = jax.eval_shape(lambda p_, a_: jax.vjp(fn, p_, a_), p, a)
    res = jax.tree.leaves(vjp_spec)
    param_shapes = collections.Counter(
        (tuple(l.shape), jnp.dtype(l.dtype).name) for l in jax.tree.leaves(
            jax.eval_shape(lambda q: q, p)))
    total = 0
    for leaf in res:
        key = (tuple(leaf.shape), jnp.dtype(leaf.dtype).name)
        if param_shapes[key] > 0:
            param_shapes[key] -= 1  # assume it aliases a live param buffer
            continue
        total += _bytes_of(leaf)
    return total


def _flops_of(fn: Callable, *args) -> float:
    from ..compat import cost_analysis_dict
    ca = cost_analysis_dict(jax.jit(fn).lower(*args).compile())
    return float(ca.get("flops", 0.0))


def profile_stages_analytic(
    stages: Sequence[Callable],
    params: Sequence[Any],
    x: Any,
    peak_flops: float = PEAK_FLOPS_BF16,
    activation_shard_factor: float = 1.0,
    flops_fwd: Optional[Sequence[float]] = None,
    flops_bwd: Optional[Sequence[float]] = None,
    host: Optional[HostTransferModel] = None,
) -> Chain:
    """Build the chain cost model without executing anything.

    ``activation_shard_factor`` divides all activation/residual sizes — pass
    the product of mesh-axis sizes over which activations are sharded so the
    DP sees *per-device* bytes.  ``flops_fwd/bwd`` skip the per-stage compiles
    when the caller already knows the FLOP counts (e.g. from config math).
    """
    n = len(stages)
    uf, ub, wa, wabar = [], [], [], []
    wa.append(_pytree_bytes(jax.eval_shape(lambda v: v, x)) / activation_shard_factor)
    a = x
    for i, (fn, p) in enumerate(zip(stages, params)):
        out_spec = jax.eval_shape(fn, p, a)
        if flops_fwd is not None:
            f_fwd = flops_fwd[i]
        else:
            f_fwd = _flops_of(fn, p, a)
        if flops_bwd is not None:
            f_bwd = flops_bwd[i]
        else:
            def fwd_bwd(p_, a_, ct):
                out, vjp = jax.vjp(fn, p_, a_)
                return vjp(ct)
            ct = jax.eval_shape(fn, p, a)
            ct = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), ct)
            f_bwd = max(_flops_of(fwd_bwd, p, a, ct) - f_fwd, f_fwd)
        uf.append(f_fwd / peak_flops)
        ub.append(f_bwd / peak_flops)
        wabar.append(residual_bytes(fn, p, a) / activation_shard_factor)
        if i < n - 1:
            wa.append(_pytree_bytes(out_spec) / activation_shard_factor)
        a = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), out_spec) \
            if flops_fwd is None else out_spec
    return Chain.make(uf=uf, ub=ub, wa=wa, wabar=wabar, host=host)


def profile_stages_measured(
    stages: Sequence[Callable],
    params: Sequence[Any],
    x: Any,
    repeats: int = 3,
    host: Optional[HostTransferModel] = None,
) -> Chain:
    """Wall-clock per-stage costs (the paper's §5.1 measurement phase)."""
    n = len(stages)
    uf, ub, wa, wabar = [], [], [], []
    wa.append(_pytree_bytes(jax.eval_shape(lambda v: v, x)))
    a = x
    for i, (fn, p) in enumerate(zip(stages, params)):
        jfn = jax.jit(fn)

        def fwd_bwd(p_, a_, ct):
            out, vjp = jax.vjp(fn, p_, a_)
            return vjp(ct)

        jfb = jax.jit(fwd_bwd)
        out = jfn(p, a)
        ct = jax.tree.map(jnp.ones_like, out)
        jax.block_until_ready(jfb(p, a, ct))  # warmup both

        t0 = time.perf_counter()
        for _ in range(repeats):
            out = jfn(p, a)
        jax.block_until_ready(out)
        t_fwd = (time.perf_counter() - t0) / repeats

        t0 = time.perf_counter()
        for _ in range(repeats):
            g = jfb(p, a, ct)
        jax.block_until_ready(g)
        t_fb = (time.perf_counter() - t0) / repeats

        uf.append(t_fwd)
        ub.append(max(t_fb - t_fwd, 0.25 * t_fwd))
        wabar.append(residual_bytes(fn, p, a))
        if i < n - 1:
            wa.append(_pytree_bytes(jax.eval_shape(lambda v: v, out)))
        a = out
    return Chain.make(uf=uf, ub=ub, wa=wa, wabar=wabar, host=host)
