"""Optimal persistent checkpointing DP — paper Theorem 1 / Algorithms 1 & 2.

``C[s, t, m]`` = optimal makespan to backprop the sub-chain ``[s, t]`` (paper
numbering, ``1 <= s <= t <= L+1``) with ``m`` memory slots, given that the
input ``a^{s-1}`` and the gradient ``δ^t`` are live, with ``a^{s-1}`` *not*
counted against ``m`` (``δ^t`` *is* counted — it appears in the
:math:`m_\\varnothing`/:math:`m_{all}` thresholds).

Four fill implementations share the recursion (``dp_kernels.KNOWN_IMPLS``):

- ``impl="banded"`` (default): the length-banded, split-batched float32
  kernels of :mod:`repro.core.dp_kernels` — all starts of a sub-chain length
  are processed together, one vectorized candidate plane per split, over
  pre-shifted companion tables; the cost tables are upper-triangular bands
  (~5.5× smaller than the seed layout), and branch choices are recomputed at
  the O(L) cells the reconstruction visits instead of being stored.
  ``expected_time`` is recomputed in float64 by the simulator, so the
  published makespan is exact.
- ``impl="pallas"``: the same band recursion with the split-batched min
  reduction on the per-band Pallas kernel of :mod:`repro.kernels.dp_fill` —
  jit on TPU, interpret-mode CPU fallback elsewhere; band-exact against
  ``"banded"`` (tested on f32-exact chains).  The band loop stays on the
  host: O(L) kernel dispatches per fill.
- ``impl="pallas_fused"``: the whole band recursion in ONE ``pallas_call``
  (same package) — companion tables are rebuilt in-kernel, output bands
  accumulate in device-resident buffers sized by the saturation-cap band
  width, and the host touches the tables exactly twice (upload base case,
  download result).  Also band-exact against ``"banded"``.
- ``impl="reference"``: the original per-cell float64 fill, retained as the
  slow-but-transparent comparator (kernel-equivalence tests and benchmarks
  diff the implementations).

All three share the saturated m-column pruning pass
(:func:`repro.core.dp_kernels.saturation_caps`): per-band column frontiers
are computed before any fill runs, each band is filled only up to its
frontier, and the saturated tail is broadcast — bit-identical tables for a
fraction of the work (``REPRO_DP_PRUNE=0`` disables).

Results are memoized through :mod:`repro.core.solver_cache` (in-memory LRU +
on-disk store keyed by a content hash of the discretized problem), so
repeated launches and budget sweeps skip the DP fill entirely.

Outputs:
- the optimal op ``Schedule`` (Algorithm 2),
- the equivalent recursion *tree* consumed by ``rematerialize.py`` to build a
  nested ``jax.checkpoint`` function,
- the predicted makespan, for validation against the simulator (they must
  agree exactly — tested).
"""

from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Tuple, Union

import numpy as np

from . import dp_kernels, solver_cache
from ..obs import metrics as _obs
from .chain import Chain
from .dp_kernels import (INFEASIBLE, _m_all, _m_none, _shift,  # noqa: F401
                         _views)
from .schedule import BWD, F_ALL, F_CK, F_NONE, Schedule, simulate


def _resolve_impl(impl: Optional[str]) -> str:
    impl = impl or os.environ.get("REPRO_DP_IMPL", "banded")
    if impl not in dp_kernels.KNOWN_IMPLS:
        raise ValueError(f"unknown DP impl {impl!r}; "
                         f"expected one of {dp_kernels.KNOWN_IMPLS}")
    return impl


# ---------------------------------------------------------------------------
# Recursion tree (consumed by the nested-remat compiler)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Leaf:
    """Stage ``s`` executed as ``F_all^s`` immediately followed by ``B^s``."""
    s: int


@dataclasses.dataclass
class AllNode:
    """``F_all^s`` first: stage ``s`` residuals are recorded, rest recurses."""
    s: int
    rest: "Tree"


@dataclasses.dataclass
class CkNode:
    """``F_ck^s`` first: segment ``[s, sp-1]`` streamed with ``F_∅`` (its input
    ``a^{s-1}`` checkpointed), then ``[sp, t]`` solved, then ``[s, sp-1]``
    re-solved recursively."""
    s: int
    sp: int
    right: "Tree"   # sub-chain [sp, t]
    left: "Tree"    # sub-chain [s, sp-1], executed after `right`'s backward


Tree = Union[Leaf, AllNode, CkNode]


@dataclasses.dataclass
class Solution:
    feasible: bool
    expected_time: float
    schedule: Optional[Schedule]
    tree: Optional[Tree]
    mem_limit: float
    num_slots: int
    slots_used: int
    # DP diagnostics
    table_bytes: int = 0


# ---------------------------------------------------------------------------
# Reference DP tables (the seed implementation, kept as the slow comparator)
# ---------------------------------------------------------------------------

class _Tables:
    """Raw DP tables; index convention: C[s, t, m] with 1-based s,t."""

    def __init__(self, L: int, S: int):
        self.L, self.S = L, S
        shape = (L + 2, L + 2, S + 1)
        self.C = np.full(shape, INFEASIBLE, dtype=np.float64)
        # choice: 0 = infeasible, 1 = Ck (split stored in `split`), 2 = All
        self.choice = np.zeros(shape, dtype=np.int8)
        self.split = np.zeros(shape, dtype=np.int16)

    @property
    def nbytes(self) -> int:
        return self.C.nbytes + self.choice.nbytes + self.split.nbytes


def _fill_tables(dchain, tables: _Tables, allow_fall: bool = True,
                 prune: Optional[bool] = None) -> None:
    """Bottom-up DP fill.  ``allow_fall=False`` disables the C2 (``F_all``)
    branch for sub-chains of length > 1 — the revolve comparator.  Saturated
    m-columns are pruned per band (the shared
    :func:`repro.core.dp_kernels.saturation_caps` pass): only columns up to
    the band's frontier are computed and the frontier column is broadcast
    across the rest — bit-identical values, ``REPRO_DP_PRUNE=0`` disables."""
    v = _views(dchain)
    L, S = tables.L, tables.S
    C, choice, split = tables.C, tables.choice, tables.split
    ms = np.arange(S + 1)
    caps = (dp_kernels.saturation_caps(v, S, allow_fall)
            if dp_kernels._resolve_prune(prune) else None)

    # base cases: C[s, s, m]
    for s in range(1, L + 2):
        feas = ms >= _m_all(v, s, s)
        C[s, s, feas] = v["UF"][s] + v["UB"][s]
        choice[s, s, feas] = 2

    # bottom-up by sub-chain length
    for d in range(1, L + 1):
        W = dp_kernels.band_width(caps, d, S)
        msW = ms[:W]
        for s in range(1, L + 2 - d):
            t = s + d

            def bcast():
                if W <= S:
                    C[s, t, W:] = C[s, t, W - 1]
                    choice[s, t, W:] = choice[s, t, W - 1]
                    split[s, t, W:] = split[s, t, W - 1]

            # --- C1: start with F_ck^s, split at s' ----------------------
            sps = np.arange(s + 1, t + 1)
            # candidate[k, m] for split sps[k]
            cand = np.empty((len(sps), W), dtype=np.float64)
            for k, sp in enumerate(sps):
                fwd = v["CUM_UF"][sp - 1] - v["CUM_UF"][s - 1]
                cand[k] = (fwd
                           + _shift(C[sp, t, :W], int(v["WA"][sp - 1]))
                           + C[s, sp - 1, :W])
            best_k = np.argmin(cand, axis=0)
            c1 = cand[best_k, msW]
            c1[msW < _m_none(v, s, t)] = INFEASIBLE
            if not allow_fall:
                C[s, t, :W] = c1
                ch = np.zeros(W, dtype=np.int8)
                ch[np.isfinite(c1)] = 1
                choice[s, t, :W] = ch
                split[s, t, :W] = np.where(ch == 1, sps[best_k],
                                           0).astype(np.int16)
                bcast()
                continue
            # --- C2: start with F_all^s ---------------------------------
            c2 = (v["UF"][s] + _shift(C[s + 1, t, :W], int(v["WABAR"][s]))
                  + v["UB"][s])
            c2[msW < _m_all(v, s, t)] = INFEASIBLE
            # --- combine -------------------------------------------------
            use_all = c2 < c1  # ties -> Ck (arbitrary, both optimal)
            C[s, t, :W] = np.where(use_all, c2, c1)
            ch = np.zeros(W, dtype=np.int8)
            ch[np.isfinite(c1)] = 1
            ch[use_all & np.isfinite(c2)] = 2
            ch[~np.isfinite(C[s, t, :W])] = 0
            choice[s, t, :W] = ch
            split[s, t, :W] = np.where(ch == 1, sps[best_k], 0).astype(np.int16)
            bcast()


# ---------------------------------------------------------------------------
# Reconstruction (Algorithm 2) — both as op sequence and as recursion tree
# ---------------------------------------------------------------------------

def _rebuild(v: dict, tables: _Tables, s: int, t: int, m: int
             ) -> Tuple[List, Tree]:
    """Reference-path reconstruction (``v`` is computed once by the caller
    and threaded through — the per-node ``_views`` rebuild was O(L) each)."""
    ch = tables.choice[s, t, m]
    if ch == 0:
        raise ValueError(f"infeasible sub-problem ({s},{t},{m})")
    if s == t:
        return [(F_ALL, s), (BWD, s)], Leaf(s)
    if ch == 2:
        ops_rest, tree_rest = _rebuild(
            v, tables, s + 1, t, m - int(v["WABAR"][s]))
        return ([(F_ALL, s)] + ops_rest + [(BWD, s)], AllNode(s, tree_rest))
    sp = int(tables.split[s, t, m])
    ops = [(F_CK, s)] + [(F_NONE, j) for j in range(s + 1, sp)]
    ops_right, tree_right = _rebuild(
        v, tables, sp, t, m - int(v["WA"][sp - 1]))
    ops_left, tree_left = _rebuild(v, tables, s, sp - 1, m)
    return ops + ops_right + ops_left, CkNode(s, sp, tree_right, tree_left)


def _rebuild_banded(v: dict, tab: "dp_kernels.BandedTable", s: int, t: int,
                    m: int, allow_fall: bool) -> Tuple[List, Tree]:
    """Banded-path reconstruction: branch choices are recomputed per visited
    cell (the banded fill stores costs only)."""
    ch, sp = dp_kernels.choose_two_tier(v, tab, s, t, m, allow_fall)
    if ch == 0:
        raise ValueError(f"infeasible sub-problem ({s},{t},{m})")
    if s == t:
        return [(F_ALL, s), (BWD, s)], Leaf(s)
    if ch == 2:
        ops_rest, tree_rest = _rebuild_banded(
            v, tab, s + 1, t, m - int(v["WABAR"][s]), allow_fall)
        return ([(F_ALL, s)] + ops_rest + [(BWD, s)], AllNode(s, tree_rest))
    ops = [(F_CK, s)] + [(F_NONE, j) for j in range(s + 1, sp)]
    ops_right, tree_right = _rebuild_banded(
        v, tab, sp, t, m - int(v["WA"][sp - 1]), allow_fall)
    ops_left, tree_left = _rebuild_banded(v, tab, s, sp - 1, m, allow_fall)
    return ops + ops_right + ops_left, CkNode(s, sp, tree_right, tree_left)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def _finish(chain: Chain, mem_limit: float, num_slots: int,
            m_use: int, table_bytes: int, rebuild_fn) -> Solution:
    """Rebuild at ``m_use`` and publish the float64 simulator makespan."""
    ops, tree = rebuild_fn(m_use)
    sched = Schedule(chain.length, ops)
    expected = float(simulate(chain, sched).time)
    return Solution(True, expected, sched, tree, mem_limit, num_slots, m_use,
                    table_bytes)


def solve_optimal(chain: Chain, mem_limit: float, num_slots: int = 500,
                  allow_fall: bool = True, impl: Optional[str] = None,
                  cache: bool = True) -> Solution:
    """Optimal persistent schedule for ``chain`` under ``mem_limit`` memory.

    ``allow_fall=False`` disables the ``C2`` branch for sub-chains of length
    > 1, which restricts checkpoints to plain activations ``a`` — this is the
    **revolve** comparator of the paper (§5.3, third strategy), i.e. the best
    persistent strategy in the Automatic Differentiation model, converted to a
    valid schedule by running ``F_all`` right before each backward.

    ``impl`` picks the fill kernels (``"banded"`` default, ``"pallas"`` /
    ``"pallas_fused"`` for the per-band / single-dispatch Pallas kernels,
    ``"reference"`` for the seed float64 path; env ``REPRO_DP_IMPL``
    overrides the default).  ``cache=False`` bypasses the solver cache
    (used by benchmarks).
    """
    impl = _resolve_impl(impl)
    dchain = chain.discretize(mem_limit, num_slots)

    def solve() -> Solution:
        L, S = dchain.length, num_slots
        m_top = S - int(dchain.wa[0])  # Alg. 1: budget excludes the input a^0
        v = _views(dchain)
        if impl == "reference":
            tables = _Tables(L, S)
            with _obs.histogram("dp_fill.reference.seconds").time():
                _fill_tables(dchain, tables, allow_fall=allow_fall)
            if m_top < 0 or not np.isfinite(tables.C[1, L + 1, m_top]):
                return Solution(False, INFEASIBLE, None, None, mem_limit,
                                num_slots, max(m_top, 0), tables.nbytes)
            ops, tree = _rebuild(v, tables, 1, L + 1, m_top)
            return Solution(True, float(tables.C[1, L + 1, m_top]),
                            Schedule(L, ops), tree, mem_limit, num_slots,
                            m_top, tables.nbytes)
        tab = dp_kernels.fill_tables(dchain, S, impl=impl,
                                     allow_fall=allow_fall, v=v)
        if m_top < 0 or not np.isfinite(tab.row(1, L + 1)[m_top]):
            return Solution(False, INFEASIBLE, None, None, mem_limit,
                            num_slots, max(m_top, 0), tab.nbytes)
        return _finish(chain, mem_limit, num_slots, m_top, tab.nbytes,
                       lambda m: _rebuild_banded(v, tab, 1, L + 1, m,
                                                 allow_fall))

    return solver_cache.memoize_solve("solve_optimal", impl, chain, dchain,
                                      num_slots, allow_fall, cache, solve)


def solve_min_memory(chain: Chain, num_slots: int = 500,
                     allow_fall: bool = True, impl: Optional[str] = None,
                     cache: bool = True) -> Solution:
    """Smallest-memory feasible persistent schedule: run the DP with the
    store-all peak as the limit, then rebuild at the smallest feasible slot
    count.  Used as the planner's fallback when the requested budget is
    infeasible (reports the actual budget it needed)."""
    impl = _resolve_impl(impl)
    peak = simulate(chain, Schedule.store_all(chain.length)).peak_mem
    dchain = chain.discretize(peak, num_slots)

    def solve() -> Solution:
        L, S = dchain.length, num_slots
        w0 = int(dchain.wa[0])
        v = _views(dchain)
        if impl == "reference":
            tables = _Tables(L, S)
            with _obs.histogram("dp_fill.reference.seconds").time():
                _fill_tables(dchain, tables, allow_fall=allow_fall)
            top = tables.C[1, L + 1]
            table_bytes = tables.nbytes
            rebuild_fn = lambda m: _rebuild(v, tables, 1, L + 1, m)  # noqa: E731
        else:
            tab = dp_kernels.fill_tables(dchain, S, impl=impl,
                                         allow_fall=allow_fall, v=v)
            top = tab.row(1, L + 1)
            table_bytes = tab.nbytes
            rebuild_fn = lambda m: _rebuild_banded(v, tab, 1, L + 1, m,  # noqa: E731
                                                   allow_fall)
        feasible = np.where(np.isfinite(top))[0]
        if len(feasible) == 0:
            return Solution(False, INFEASIBLE, None, None, peak, num_slots,
                            0, table_bytes)
        m_min = int(feasible[0])
        budget = (m_min + w0) * dchain.slot_size  # physical mem incl. a^0
        if impl == "reference":
            ops, tree = rebuild_fn(m_min)
            return Solution(True, float(top[m_min]), Schedule(L, ops), tree,
                            budget, num_slots, m_min, table_bytes)
        return _finish(chain, budget, num_slots, m_min, table_bytes,
                       rebuild_fn)

    return solver_cache.memoize_solve("solve_min_memory", impl, chain,
                                      dchain, num_slots, allow_fall, cache,
                                      solve)


def tree_to_schedule(tree: Tree, length: int) -> Schedule:
    """Flatten a recursion tree back into the canonical op sequence."""
    ops: List = []

    def rec(node: Tree):
        if isinstance(node, Leaf):
            ops.extend([(F_ALL, node.s), (BWD, node.s)])
        elif isinstance(node, AllNode):
            ops.append((F_ALL, node.s))
            rec(node.rest)
            ops.append((BWD, node.s))
        else:
            # right spans [sp, t]; left spans [s, sp-1]
            ops.append((F_CK, node.s))
            ops.extend((F_NONE, j) for j in range(node.s + 1, node.sp))
            rec(node.right)
            rec(node.left)

    rec(tree)
    return Schedule(length, ops)
