"""Optimal persistent checkpointing DP — paper Theorem 1 / Algorithms 1 & 2.

``C[s, t, m]`` = optimal makespan to backprop the sub-chain ``[s, t]`` (paper
numbering, ``1 <= s <= t <= L+1``) with ``m`` memory slots, given that the
input ``a^{s-1}`` and the gradient ``δ^t`` are live, with ``a^{s-1}`` *not*
counted against ``m`` (``δ^t`` *is* counted — it appears in the
:math:`m_\\varnothing`/:math:`m_{all}` thresholds).

The recursion is computed bottom-up by sub-chain length, vectorized over the
memory axis with numpy (the paper ships a C implementation for the same
reason: a naive Python triple loop is ~1e11 ops for L=339, S=500).

Outputs:
- the optimal op ``Schedule`` (Algorithm 2),
- the equivalent recursion *tree* consumed by ``rematerialize.py`` to build a
  nested ``jax.checkpoint`` function,
- the predicted makespan, for validation against the simulator (they must
  agree exactly — tested).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

import numpy as np

from .chain import Chain
from .schedule import BWD, F_ALL, F_CK, F_NONE, Schedule, simulate

INFEASIBLE = np.inf


# ---------------------------------------------------------------------------
# Recursion tree (consumed by the nested-remat compiler)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Leaf:
    """Stage ``s`` executed as ``F_all^s`` immediately followed by ``B^s``."""
    s: int


@dataclasses.dataclass
class AllNode:
    """``F_all^s`` first: stage ``s`` residuals are recorded, rest recurses."""
    s: int
    rest: "Tree"


@dataclasses.dataclass
class CkNode:
    """``F_ck^s`` first: segment ``[s, sp-1]`` streamed with ``F_∅`` (its input
    ``a^{s-1}`` checkpointed), then ``[sp, t]`` solved, then ``[s, sp-1]``
    re-solved recursively."""
    s: int
    sp: int
    right: "Tree"   # sub-chain [sp, t]
    left: "Tree"    # sub-chain [s, sp-1], executed after `right`'s backward


Tree = Union[Leaf, AllNode, CkNode]


@dataclasses.dataclass
class Solution:
    feasible: bool
    expected_time: float
    schedule: Optional[Schedule]
    tree: Optional[Tree]
    mem_limit: float
    num_slots: int
    slots_used: int
    # DP diagnostics
    table_bytes: int = 0


# ---------------------------------------------------------------------------
# DP tables
# ---------------------------------------------------------------------------

class _Tables:
    """Raw DP tables; index convention: C[s, t, m] with 1-based s,t."""

    def __init__(self, L: int, S: int):
        self.L, self.S = L, S
        shape = (L + 2, L + 2, S + 1)
        self.C = np.full(shape, INFEASIBLE, dtype=np.float64)
        # choice: 0 = infeasible, 1 = Ck (split stored in `split`), 2 = All
        self.choice = np.zeros(shape, dtype=np.int8)
        self.split = np.zeros(shape, dtype=np.int16)

    @property
    def nbytes(self) -> int:
        return self.C.nbytes + self.choice.nbytes + self.split.nbytes


def _views(dchain) -> dict:
    """1-based views aligned with paper notation (see chain.py docstring)."""
    L = dchain.length
    uf = np.concatenate([[0.0], dchain.uf])          # UF[l], l=1..L+1
    ub = np.concatenate([[0.0], dchain.ub])
    wabar = np.concatenate([[0], dchain.wabar])      # WABAR[l]
    of = np.concatenate([[0], dchain.of])
    ob = np.concatenate([[0], dchain.ob])
    wa = np.asarray(dchain.wa)                       # WA[i], i=0..L
    wd = np.concatenate([dchain.wdelta, [0]])        # WD[i], i=0..L+1 (δ^{L+1}=0)
    cum_uf = np.cumsum(uf)                           # cum_uf[l] = Σ_{k<=l} UF[k]
    return dict(L=L, UF=uf, UB=ub, WA=wa, WABAR=wabar, OF=of, OB=ob, WD=wd,
                CUM_UF=cum_uf)


def _shift(vec: np.ndarray, w: int) -> np.ndarray:
    """shifted[m] = vec[m - w]: positive ``w`` is a memory *reduction*
    (entries below ``w`` become inf), negative ``w`` a memory *gain* (used by
    the offload DP when a checkpoint's device slots are reclaimed; lookups
    beyond the table clamp to the last column — ``vec`` is non-increasing in
    ``m`` and budgets above the total slot count are physically meaningless).
    """
    if w == 0:
        return vec
    out = np.full_like(vec, INFEASIBLE)
    if w > 0:
        if w < len(vec):
            out[w:] = vec[: len(vec) - w]
        return out
    k = -w
    if k < len(vec):
        out[: len(vec) - k] = vec[k:]
        out[len(vec) - k:] = vec[-1]
    else:
        out[:] = vec[-1]
    return out


def _m_all(v: dict, s: int, t: int) -> int:
    return int(max(v["WD"][t] + v["WABAR"][s] + v["OF"][s],
                   v["WD"][s] + v["WABAR"][s] + v["OB"][s]))


def _m_none(v: dict, s: int, t: int) -> int:
    best = v["WD"][t] + v["WA"][s] + v["OF"][s]
    js = np.arange(s + 1, t)
    if len(js):
        best = max(best, (v["WD"][t] + v["WA"][js - 1] + v["WA"][js]
                          + v["OF"][js]).max())
    return int(best)


def _fill_tables(dchain, tables: _Tables, allow_fall: bool = True) -> None:
    """Bottom-up DP fill.  ``allow_fall=False`` disables the C2 (``F_all``)
    branch for sub-chains of length > 1 — the revolve comparator."""
    v = _views(dchain)
    L, S = tables.L, tables.S
    C, choice, split = tables.C, tables.choice, tables.split
    ms = np.arange(S + 1)

    # base cases: C[s, s, m]
    for s in range(1, L + 2):
        feas = ms >= _m_all(v, s, s)
        C[s, s, feas] = v["UF"][s] + v["UB"][s]
        choice[s, s, feas] = 2

    # bottom-up by sub-chain length
    for d in range(1, L + 1):
        for s in range(1, L + 2 - d):
            t = s + d
            # --- C1: start with F_ck^s, split at s' ----------------------
            sps = np.arange(s + 1, t + 1)
            # candidate[k, m] for split sps[k]
            cand = np.empty((len(sps), S + 1), dtype=np.float64)
            for k, sp in enumerate(sps):
                fwd = v["CUM_UF"][sp - 1] - v["CUM_UF"][s - 1]
                cand[k] = (fwd
                           + _shift(C[sp, t], int(v["WA"][sp - 1]))
                           + C[s, sp - 1])
            best_k = np.argmin(cand, axis=0)
            c1 = cand[best_k, ms]
            c1[ms < _m_none(v, s, t)] = INFEASIBLE
            if not allow_fall:
                C[s, t] = c1
                ch = np.zeros(S + 1, dtype=np.int8)
                ch[np.isfinite(c1)] = 1
                choice[s, t] = ch
                split[s, t] = np.where(ch == 1, sps[best_k], 0).astype(np.int16)
                continue
            # --- C2: start with F_all^s ---------------------------------
            c2 = v["UF"][s] + _shift(C[s + 1, t], int(v["WABAR"][s])) + v["UB"][s]
            c2[ms < _m_all(v, s, t)] = INFEASIBLE
            # --- combine -------------------------------------------------
            use_all = c2 < c1  # ties -> Ck (arbitrary, both optimal)
            C[s, t] = np.where(use_all, c2, c1)
            ch = np.zeros(S + 1, dtype=np.int8)
            ch[np.isfinite(c1)] = 1
            ch[use_all & np.isfinite(c2)] = 2
            ch[~np.isfinite(C[s, t])] = 0
            choice[s, t] = ch
            split[s, t] = np.where(ch == 1, sps[best_k], 0).astype(np.int16)


# ---------------------------------------------------------------------------
# Reconstruction (Algorithm 2) — both as op sequence and as recursion tree
# ---------------------------------------------------------------------------

def _rebuild(dchain, tables: _Tables, s: int, t: int, m: int
             ) -> Tuple[List, Tree]:
    v = _views(dchain)
    ch = tables.choice[s, t, m]
    if ch == 0:
        raise ValueError(f"infeasible sub-problem ({s},{t},{m})")
    if s == t:
        return [(F_ALL, s), (BWD, s)], Leaf(s)
    if ch == 2:
        ops_rest, tree_rest = _rebuild(
            dchain, tables, s + 1, t, m - int(v["WABAR"][s]))
        return ([(F_ALL, s)] + ops_rest + [(BWD, s)], AllNode(s, tree_rest))
    sp = int(tables.split[s, t, m])
    ops = [(F_CK, s)] + [(F_NONE, j) for j in range(s + 1, sp)]
    ops_right, tree_right = _rebuild(
        dchain, tables, sp, t, m - int(v["WA"][sp - 1]))
    ops_left, tree_left = _rebuild(dchain, tables, s, sp - 1, m)
    return ops + ops_right + ops_left, CkNode(s, sp, tree_right, tree_left)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def solve_optimal(chain: Chain, mem_limit: float, num_slots: int = 500,
                  allow_fall: bool = True) -> Solution:
    """Optimal persistent schedule for ``chain`` under ``mem_limit`` memory.

    ``allow_fall=False`` disables the ``C2`` branch for sub-chains of length
    > 1, which restricts checkpoints to plain activations ``a`` — this is the
    **revolve** comparator of the paper (§5.3, third strategy), i.e. the best
    persistent strategy in the Automatic Differentiation model, converted to a
    valid schedule by running ``F_all`` right before each backward.
    """
    dchain = chain.discretize(mem_limit, num_slots)
    L, S = dchain.length, num_slots
    tables = _Tables(L, S)
    _fill_tables(dchain, tables, allow_fall=allow_fall)

    # Algorithm 1: top-level budget excludes the chain input a^0
    m_top = S - int(dchain.wa[0])
    if m_top < 0 or not np.isfinite(tables.C[1, L + 1, m_top]):
        return Solution(False, INFEASIBLE, None, None, mem_limit, num_slots,
                        max(m_top, 0), tables.nbytes)
    ops, tree = _rebuild(dchain, tables, 1, L + 1, m_top)
    sched = Schedule(L, ops)
    return Solution(True, float(tables.C[1, L + 1, m_top]), sched, tree,
                    mem_limit, num_slots, m_top, tables.nbytes)


def solve_min_memory(chain: Chain, num_slots: int = 500,
                     allow_fall: bool = True) -> Solution:
    """Smallest-memory feasible persistent schedule: run the DP with the
    store-all peak as the limit, then rebuild at the smallest feasible slot
    count.  Used as the planner's fallback when the requested budget is
    infeasible (reports the actual budget it needed)."""
    peak = simulate(chain, Schedule.store_all(chain.length)).peak_mem
    dchain = chain.discretize(peak, num_slots)
    L, S = dchain.length, num_slots
    tables = _Tables(L, S)
    _fill_tables(dchain, tables, allow_fall=allow_fall)
    w0 = int(dchain.wa[0])
    feasible = np.where(np.isfinite(tables.C[1, L + 1]))[0]
    if len(feasible) == 0:
        return Solution(False, INFEASIBLE, None, None, peak, num_slots, 0,
                        tables.nbytes)
    m_min = int(feasible[0])
    ops, tree = _rebuild(dchain, tables, 1, L + 1, m_min)
    budget = (m_min + w0) * dchain.slot_size  # physical memory incl. a^0
    return Solution(True, float(tables.C[1, L + 1, m_min]), Schedule(L, ops),
                    tree, budget, num_slots, m_min, tables.nbytes)


def tree_to_schedule(tree: Tree, length: int) -> Schedule:
    """Flatten a recursion tree back into the canonical op sequence."""
    ops: List = []

    def rec(node: Tree):
        if isinstance(node, Leaf):
            ops.extend([(F_ALL, node.s), (BWD, node.s)])
        elif isinstance(node, AllNode):
            ops.append((F_ALL, node.s))
            rec(node.rest)
            ops.append((BWD, node.s))
        else:
            # right spans [sp, t]; left spans [s, sp-1]
            ops.append((F_CK, node.s))
            ops.extend((F_NONE, j) for j in range(node.s + 1, node.sp))
            rec(node.right)
            rec(node.left)

    rec(tree)
    return Schedule(length, ops)
