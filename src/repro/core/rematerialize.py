"""Compile a persistent-schedule recursion tree into a nested-``jax.checkpoint``
function — the production execution path (§2 of DESIGN.md).

Correspondence (exact, per-node):

- ``Leaf(s)`` / ``AllNode(s)``  →  stage ``s`` applied *plain*: when the
  enclosing scope is (re)executed, XLA records stage ``s``'s residuals — this
  is ``F_all^s`` (+ its later ``B^s``).
- ``CkNode(s, sp, right, left)``  →  ``right_fn ∘ jax.checkpoint(left_fn)``:
  the forward of ``jax.checkpoint`` runs ``left_fn`` (stages ``s..sp-1``)
  saving only its input ``a^{s-1}`` — this is ``F_ck^s`` followed by ``F_∅``;
  on the backward, ``left_fn`` is replayed and *its* internal checkpoint
  structure applies — exactly the OptRec recursion on ``[s, sp-1]``.

The builder returns ``f(params, x)`` where ``params`` is a per-stage sequence;
``jax.grad(f)`` then executes the paper's schedule structurally under XLA.
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax

from .solver import AllNode, CkNode, Leaf, Tree

StageFn = Callable  # (stage_params, activation) -> activation


def build_remat_fn(tree: Tree, stages: Sequence[StageFn],
                   checkpoint_policy=None) -> Callable:
    """Return ``f(params, x)`` executing the chain per the schedule tree.

    ``stages[l-1]`` is the callable for paper-stage ``l`` (1-based). ``params``
    passed to ``f`` must be indexable the same way.  ``checkpoint_policy``
    (optional ``jax.checkpoint_policies.*``) applies to every ``F_ck`` scope —
    the paper's model corresponds to the default (save nothing but inputs).
    """

    def rec(node: Tree) -> Callable:
        if isinstance(node, Leaf):
            s = node.s
            return lambda params, x: stages[s - 1](params[s - 1], x)
        if isinstance(node, AllNode):
            s = node.s
            rest = rec(node.rest)
            return lambda params, x: rest(params, stages[s - 1](params[s - 1], x))
        if isinstance(node, CkNode):
            left = rec(node.left)    # stages [s, sp-1]
            right = rec(node.right)  # stages [sp, t]
            kwargs = {}
            if checkpoint_policy is not None:
                kwargs["policy"] = checkpoint_policy
            left_ck = jax.checkpoint(left, **kwargs)
            return lambda params, x: right(params, left_ck(params, x))
        raise TypeError(f"unknown tree node {node!r}")

    return rec(tree)


def sequential_tree(length: int) -> Tree:
    """Store-all tree: every stage plain (AllNode chain) — autograd default."""
    node: Tree = Leaf(length + 1)
    for s in range(length, 0, -1):
        node = AllNode(s, node)
    return node


def full_remat_tree(length: int) -> Tree:
    """``F_ck`` every stage: remat everything (max recompute, min memory)."""

    def make(s: int, t: int) -> Tree:
        if s == t:
            return Leaf(s)
        # checkpoint a^{s-1}, stream just stage s, recurse on the rest
        return CkNode(s, s + 1, make(s + 1, t), Leaf(s))

    return make(1, length + 1)


def periodic_tree(length: int, num_segments: int) -> Tree:
    """The `sequential` baseline (torch checkpoint_sequential) as a tree:
    each non-final segment is a CkNode whose left child is a plain sub-chain."""
    import numpy as np

    L = length
    k = max(1, min(num_segments, L))
    bounds = np.linspace(0, L, k + 1).astype(int)
    segments = [(int(bounds[i]) + 1, int(bounds[i + 1])) for i in range(k)]
    # last segment includes the loss stage
    segments[-1] = (segments[-1][0], L + 1)

    def plain(a: int, b: int) -> Tree:
        node: Tree = Leaf(b)
        for s in range(b - 1, a - 1, -1):
            node = AllNode(s, node)
        return node

    def rec(i: int) -> Tree:
        a, b = segments[i]
        if i == len(segments) - 1:
            return plain(a, b)
        return CkNode(a, b + 1, rec(i + 1), plain(a, b))

    return rec(0)


def tree_stage_span(tree: Tree) -> tuple:
    """(first, last) stage covered by a tree (sanity checking)."""
    if isinstance(tree, Leaf):
        return tree.s, tree.s
    if isinstance(tree, AllNode):
        _, last = tree_stage_span(tree.rest)
        return tree.s, last
    a, _ = tree_stage_span(tree.left)
    _, b = tree_stage_span(tree.right)
    return a, b


def count_checkpoint_scopes(tree: Tree) -> int:
    if isinstance(tree, Leaf):
        return 0
    if isinstance(tree, AllNode):
        return count_checkpoint_scopes(tree.rest)
    return 1 + count_checkpoint_scopes(tree.left) + count_checkpoint_scopes(tree.right)
