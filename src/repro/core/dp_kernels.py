"""Banded, split-batched DP kernels for the checkpointing solvers.

The seed implementation (``core/solver.py::_fill_tables`` and
``offload/solver.py::_fill_tables_offload``) walks every sub-chain ``(s, t)``
in a Python double loop and, per cell, builds a ``(num_splits, S+1)``
candidate matrix with one ``_shift`` allocation per split — ~``L^3/6`` tiny
numpy calls at paper scale (L=339 / S=500), which is why plan-time dominated
every launch.  This module restructures the same recursion around *length
bands*:

- tables are stored upper-triangular only (``1 <= s <= t <= L+1``), one
  contiguous block per sub-chain length ``d = t - s``, in **float32** — no
  ``choice``/``split`` tables at all (branch decisions are recomputed at the
  O(L) cells the reconstruction actually visits, see :func:`choose_two_tier`);
- for each length ``d`` the candidate planes of **all** starts ``s`` are
  evaluated split-by-split into a running minimum.  Two companion tables,
  built once per cell with contiguous copies, collapse the C1 candidate to a
  *single add per split*:  ``R[s',t][m] = C[s',t][m - WA[s'-1]] + CUM[s'-1]``
  (the per-split memory shift pre-applied, with a ``+inf`` sentinel column
  absorbing out-of-budget reads) and ``Lm[s,t][m] = C[s,t][m] - CUM[s-1]`` —
  the forward-stream cost ``CUM[sp-1] - CUM[s-1]`` telescopes away;
- the offload C3 plane folds its stall into a max
  (``X + max(T_off - X, 0) = max(X, T_off)``) and reads the same ``R`` at a
  parent-side column offset, so it too needs no gather;
- all per-band scratch planes are preallocated once and re-sliced across
  lengths, and big bands fan the split loop out over a small thread pool
  (exact: min-accumulation does not round).

Memory: the seed kept ``(L+2)^2 (S+1)`` cells ×11 B (two-tier: float64 cost +
int8 choice + int16 split; ×2 tables for offload) — ~640 MB / ~1.3 GB at
paper scale.  The band layout keeps ``(L+1)(L+2)/2`` cells × 4 B — a ~5.5×
shrink (``Solution.table_bytes`` reports it).

Exactness: costs are float32, but every quantity the tier-1 test chains
produce (integer stage costs, dyadic transfer times) is exactly representable
in float32 below 2^24, so the banded DP is bit-equal to the float64 reference
there; ``solve_optimal`` recomputes ``expected_time`` of the reconstructed
schedule in float64 via the simulator, so the published makespan is exact
regardless of the table dtype.

The fills all share a *saturated m-column pruning* pass
(:func:`saturation_caps`): ``C[s, t, m]`` is constant in ``m`` beyond a
per-band frontier (once every threshold is passed and every child read lands
in the child's own constant region, more memory cannot change any candidate),
and the frontier is computable from the thresholds and shift widths alone —
before any fill runs.  Each band is therefore filled only up to its frontier
column and the last computed column is broadcast across the rest; the result
is bit-identical to the unpruned fill (tested), but small-length bands — the
ones with the most rows — shrink to a few dozen columns.  ``REPRO_DP_PRUNE=0``
disables pruning globally (every fill also takes an explicit ``prune=``).

Four implementations share this recursion end to end (``KNOWN_IMPLS``):
``"banded"`` (this module's numpy kernels), ``"reference"`` (the seed
per-cell float64 fill in the solvers), ``"pallas"`` (the per-band Pallas
kernel of :mod:`repro.kernels.dp_fill` — host-driven band loop, one launch
per length), and ``"pallas_fused"`` (the same package's device-resident fill:
ONE ``pallas_call`` runs the whole recursion with in-kernel companion
rebuild, buffers sized by the :func:`saturation_caps` band-width bound).
The Pallas impls are dispatched lazily by :func:`fill_tables` /
:func:`fill_tables_offload` so the numpy core never imports jax.
"""

from __future__ import annotations

import concurrent.futures
import os
from typing import Optional, Tuple

import numpy as np
from numpy.lib.stride_tricks import sliding_window_view

from ..obs import metrics as _obs

INFEASIBLE = np.inf
COST_DTYPE = np.float32
_F32 = np.float32
_INF32 = np.float32(np.inf)

#: The DP fill implementations every solver entry point accepts.
KNOWN_IMPLS = ("banded", "reference", "pallas", "pallas_fused")


def _resolve_prune(prune: Optional[bool]) -> bool:
    """Saturated m-column pruning default: on, unless ``REPRO_DP_PRUNE``
    says otherwise (``0``/``false``/``off``)."""
    if prune is not None:
        return bool(prune)
    return os.environ.get("REPRO_DP_PRUNE", "1").lower() not in (
        "0", "false", "off")

# The split loop parallelizes exactly (each split's candidate plane is
# independent; min-accumulation is order-free — IEEE min does not round), so
# big bands are fanned out over a small thread pool: numpy ufuncs release the
# GIL on these contiguous float32 planes.  ``REPRO_DP_THREADS=1`` forces the
# serial path.
_pool: Optional[concurrent.futures.ThreadPoolExecutor] = None
_pool_size = 0
# thread only bands whose total candidate volume amortizes the dispatch
_PAR_MIN_ELEMS = 1 << 21


def _n_workers(default_parallel: bool = True) -> int:
    env = os.environ.get("REPRO_DP_THREADS")
    if env:
        try:
            return max(1, int(env))
        except ValueError:
            pass
    if not default_parallel:
        return 1
    return max(1, min(4, os.cpu_count() or 1))


def _executor(n: int) -> concurrent.futures.ThreadPoolExecutor:
    global _pool, _pool_size
    if _pool is None or _pool_size < n:
        _pool = concurrent.futures.ThreadPoolExecutor(max_workers=n)
        _pool_size = n
    return _pool


# ---------------------------------------------------------------------------
# 1-based views of a DiscreteChain (shared by fills, chooses, and rebuilds)
# ---------------------------------------------------------------------------

def _views(dchain) -> dict:
    """1-based views aligned with paper notation (see chain.py docstring)."""
    L = dchain.length
    uf = np.concatenate([[0.0], dchain.uf])          # UF[l], l=1..L+1
    ub = np.concatenate([[0.0], dchain.ub])
    wabar = np.concatenate([[0], dchain.wabar])      # WABAR[l]
    of = np.concatenate([[0], dchain.of])
    ob = np.concatenate([[0], dchain.ob])
    wa = np.asarray(dchain.wa)                       # WA[i], i=0..L
    wd = np.concatenate([dchain.wdelta, [0]])        # WD[i], i=0..L+1 (δ^{L+1}=0)
    cum_uf = np.cumsum(uf)                           # cum_uf[l] = Σ_{k<=l} UF[k]
    return dict(L=L, UF=uf, UB=ub, WA=wa, WABAR=wabar, OF=of, OB=ob, WD=wd,
                CUM_UF=cum_uf)


def _shift(vec: np.ndarray, w: int) -> np.ndarray:
    """shifted[m] = vec[m - w]: positive ``w`` is a memory *reduction*
    (entries below ``w`` become inf), negative ``w`` a memory *gain* (used by
    the offload DP when a checkpoint's device slots are reclaimed; lookups
    beyond the table clamp to the last column — ``vec`` is non-increasing in
    ``m`` and budgets above the total slot count are physically meaningless).
    """
    if w == 0:
        return vec
    out = np.full_like(vec, INFEASIBLE)
    if w > 0:
        if w < len(vec):
            out[w:] = vec[: len(vec) - w]
        return out
    k = -w
    if k < len(vec):
        out[: len(vec) - k] = vec[k:]
        out[len(vec) - k:] = vec[-1]
    else:
        out[:] = vec[-1]
    return out


def _m_all(v: dict, s: int, t: int) -> int:
    return int(max(v["WD"][t] + v["WABAR"][s] + v["OF"][s],
                   v["WD"][s] + v["WABAR"][s] + v["OB"][s]))


def _m_none(v: dict, s: int, t: int) -> int:
    best = v["WD"][t] + v["WA"][s] + v["OF"][s]
    js = np.arange(s + 1, t)
    if len(js):
        best = max(best, (v["WD"][t] + v["WA"][js - 1] + v["WA"][js]
                          + v["OF"][js]).max())
    return int(best)


def _h_vector(v: dict) -> np.ndarray:
    """H[j] = WA[j-1] + WA[j] + OF[j] (the F_∅-stream liveness of a^{j-1},
    a^j plus the forward overhead), j = 1..L — windows of it give m_∅."""
    L = v["L"]
    WA = np.asarray(v["WA"], dtype=np.int64)
    H = np.zeros(L + 1, dtype=np.int64)
    if L >= 1:
        H[1:] = WA[:-1] + WA[1:] + np.asarray(v["OF"][1:L + 1], dtype=np.int64)
    return H


def _band_thresholds(v: dict, H: np.ndarray, d: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """(m_all, m_none) for every start ``s = 1..L+1-d`` at length ``d``."""
    L = v["L"]
    ns = L + 1 - d
    sv = np.arange(1, ns + 1)
    tv = sv + d
    WD, OF, OB = v["WD"], v["OF"], v["OB"]
    WA = np.asarray(v["WA"], dtype=np.int64)
    WB = np.asarray(v["WABAR"], dtype=np.int64)
    ma = np.maximum(WD[tv] + WB[sv] + OF[sv].astype(np.int64),
                    WD[sv] + WB[sv] + OB[sv].astype(np.int64))
    base = WA[sv] + OF[sv].astype(np.int64)
    if d >= 2:
        wmax = sliding_window_view(H[2:L + 1], d - 1)[:ns].max(axis=1)
        mn = WD[tv] + np.maximum(base, wmax)
    else:
        mn = WD[tv] + base
    return ma, mn


def saturation_caps(v: dict, S: int, allow_fall: bool = True) -> np.ndarray:
    """Per-band saturated-column frontier, computable *before any fill runs*.

    ``caps[d]`` is a column index ``c <= S`` such that every cell of band
    ``d`` is constant in ``m`` on ``[c, S]``.  Induction: a base-case cell is
    ``+inf`` below its ``m_all`` threshold and constant above it; a band-``d``
    cell at ``m >= caps[d]`` has every threshold passed (``caps[d]`` majorizes
    the band's ``m_∅``/``m_all``) and every candidate read lands at column
    ``m - w >= caps[d-1]`` (``caps[d] >= caps[d-1] + wshift`` with ``wshift``
    the largest in-table memory shift) — i.e. in the child's own constant
    region — so no candidate, and hence no min, can change with ``m``.  The
    offload C3 memory-*gain* reads land at columns ``> m``, which the same
    argument covers.  Shifts beyond ``S+1`` read the ``+inf`` sentinel at
    every ``m`` and are constant trivially, so ``wshift`` clips there.

    The fills use the caps to compute each band only on ``[0, caps[d]]`` and
    broadcast column ``caps[d]`` across the rest — bit-identical to the
    unpruned fill, but the small-length bands (the ones with the most rows)
    shrink to a few dozen columns.
    """
    L = v["L"]
    H = _h_vector(v)
    WA = np.asarray(v["WA"], dtype=np.int64)
    WB = np.asarray(v["WABAR"], dtype=np.int64)
    wshift = int(np.minimum(WA, S + 1).max(initial=0))
    if allow_fall:
        wshift = max(wshift, int(np.minimum(WB[1:], S + 1).max(initial=0)))
    caps = np.empty(L + 1, dtype=np.int64)
    sv = np.arange(1, L + 2)
    ma0 = (v["WD"][sv] + WB[sv]
           + np.maximum(v["OF"][sv], v["OB"][sv]).astype(np.int64))
    caps[0] = min(S, max(0, int(ma0.max())))
    for d in range(1, L + 1):
        ma, mn = _band_thresholds(v, H, d)
        t = int(mn.max())
        if allow_fall:
            t = max(t, int(ma.max()))
        caps[d] = min(S, max(t, int(caps[d - 1]) + wshift))
    return caps


def band_width(caps: Optional[np.ndarray], d: int, S: int) -> int:
    """Number of columns band ``d`` must actually compute (``S+1`` unpruned)."""
    if caps is None:
        return S + 1
    return min(S + 1, int(caps[d]) + 1)


# ---------------------------------------------------------------------------
# Band storage
# ---------------------------------------------------------------------------

class BandedTable:
    """Upper-triangular cost table ``C[s, t, m]`` (``1 <= s <= t <= L+1``,
    ``0 <= m <= S``), stored as one contiguous float32 block per sub-chain
    length ``d = t - s``.

    Storage column 0 is a hidden ``+inf`` sentinel: gather indices are the
    memory index **plus one**, clipped to ``[0, S+1]``, so an out-of-budget
    shift reads infeasibility directly and the fill needs no masking pass.
    ``row(s, t)`` returns the m-indexed view (sentinel excluded).
    """

    def __init__(self, L: int, S: int):
        self.L, self.S = L, S
        sizes = np.array([L + 1 - d for d in range(L + 1)], dtype=np.int64)
        self.off = np.concatenate([[0], np.cumsum(sizes)])  # off[d] band start
        self.data = np.full((int(self.off[-1]), S + 2), INFEASIBLE,
                            dtype=COST_DTYPE)

    def band(self, d: int) -> np.ndarray:
        """Rows for all sub-chains of length ``d`` (s = 1..L+1-d), incl. the
        sentinel column."""
        return self.data[self.off[d]:self.off[d + 1]]

    def row(self, s: int, t: int) -> np.ndarray:
        """``C[s, t, :]`` — the (S+1,) cost vector over memory slots."""
        return self.data[self.off[t - s] + (s - 1), 1:]

    @property
    def nbytes(self) -> int:
        return self.data.nbytes


class _Scratch:
    """Preallocated per-fill scratch: a handful of ``(L+1, S+1)``-sized
    planes re-sliced across band lengths and split offsets.  The fills
    accumulate a running minimum over splits instead of materializing the
    full ``(num_s, num_splits, S+1)`` candidate tensor, so the working set
    per numpy op stays cache-resident."""

    def __init__(self, L: int, S: int, planes: int, iplanes: int = 2):
        ncols = S + 1
        self.f32 = [np.empty((L + 1) * ncols, dtype=COST_DTYPE)
                    for _ in range(planes)]
        self.i32 = [np.empty((L + 1) * ncols, dtype=np.int32)
                    for _ in range(iplanes)]

    def plane(self, k: int, ns: int, ncols: int) -> np.ndarray:
        return self.f32[k][:ns * ncols].reshape(ns, ncols)

    def iplane(self, k: int, ns: int, ncols: int) -> np.ndarray:
        return self.i32[k][:ns * ncols].reshape(ns, ncols)


class _FillCtx:
    """Everything a band fill needs that is independent of the band length."""

    def __init__(self, v: dict, L: int, S: int):
        self.v, self.L, self.S = v, L, S
        self.S1, self.S2 = S + 1, S + 2
        ms = np.arange(S + 1)
        self.ms = ms
        WA = np.asarray(v["WA"], dtype=np.int64)        # (L+1,) a^0..a^L
        WB = np.asarray(v["WABAR"], dtype=np.int64)     # (L+2,) 1-based
        self.WA, self.WB = WA, WB
        # storage-column gather indices (sentinel layout: column = m - w + 1,
        # clipped to [0, S+1]; 0 reads +inf, S+1 reads m = S)
        self.idx_wb = np.clip(ms[None, :] - WB[:, None] + 1,
                              0, S + 1).astype(np.int32)
        # raw (unclipped) m - WA[p], for the offload branch whose shift also
        # depends on the group input; clamped low so int32 cannot overflow
        # after adding WA[s-1] back (values below -2^30 are equally infeasible)
        self.raw_wa = np.clip(ms[None, :] - WA[:, None],
                              -(1 << 30), S).astype(np.int32)
        # flat-storage row strides: is2[i] = i * (S+2)
        self.is2 = (np.arange(L + 1, dtype=np.int64) * self.S2
                    ).astype(np.int32)
        # Activation sizes come quantized into few distinct slot counts, so
        # per-row shifted reads are done as one contiguous block copy per
        # distinct WA value.  groups[w] lists the p's (= band row indices of
        # the cells whose *input* is a^p) with min(WA[p], S+1) == w.
        wvals = np.minimum(WA, S + 1)
        self.groups = [(int(w), np.nonzero(wvals == w)[0])
                       for w in np.unique(wvals)]
        self.wcap = int(wvals.max(initial=0))
        # True when no activation exceeds the whole budget — the precondition
        # for the slice-based (gather-free) C3 plane
        self.wa_uncapped = bool(WA.max(initial=0) <= S + 1)
        self.UF32 = v["UF"].astype(COST_DTYPE)
        self.UB32 = v["UB"].astype(COST_DTYPE)
        self.CUM = v["CUM_UF"]
        # CUM32[i] = float32 cumulative forward time up to stage i.  The fill
        # bakes it into the companion tables (see fill_two_tier) so the C1
        # candidate is a single add per split: the forward-stream cost
        # fwd = CUM[sp-1] - CUM[s-1] telescopes into
        # (C_right + CUM[sp-1]) + (C_left - CUM[s-1]).
        self.CUM32 = v["CUM_UF"].astype(COST_DTYPE)
        OF, OB, WD = v["OF"], v["OB"], v["WD"]
        self.OF, self.OB, self.WD = OF, OB, WD
        self.H = _h_vector(v)

    def thresholds(self, d: int) -> Tuple[np.ndarray, np.ndarray]:
        """(m_all, m_none) for every start ``s = 1..L+1-d`` at length d."""
        return _band_thresholds(self.v, self.H, d)

    def base_case(self, tab: BandedTable) -> None:
        """``C[s, s, m] = u_f^s + u_b^s`` wherever ``m >= m_all(s, s)``."""
        L = self.L
        sv = np.arange(1, L + 2)
        ma = (self.WD[sv] + self.WB[sv]
              + np.maximum(self.OF[sv], self.OB[sv]).astype(np.int64))
        vals = (self.v["UF"][sv] + self.v["UB"][sv]).astype(COST_DTYPE)
        band0 = tab.band(0)[:, 1:]
        band0[:] = np.where(self.ms[None, :] >= ma[:, None],
                            vals[:, None], _INF32)


def _build_r_band(ctx: _FillCtx, R: np.ndarray, tab: BandedTable, d: int,
                  clamp_tail: bool) -> None:
    """Publish band ``d`` of the pre-shifted right-child companion table:
    ``R[s', t][m'] = C[s', t][m' - WA[s'-1]] + CUM32[s'-1]`` (``+inf`` below
    the shift, and — when ``clamp_tail`` — clamped to ``C[·][S]`` above it,
    the offload DP's memory-gain semantics).  Built once per cell with one
    contiguous copy per distinct WA value; every parent's right-child read
    then becomes a plain block slice instead of a gather."""
    ns = ctx.L + 1 - d
    width = R.shape[1]
    S1 = ctx.S1
    Rband = R[tab.off[d]:tab.off[d] + ns]
    Cband = tab.band(d)
    for w, ps in ctx.groups:
        rows = ps[:np.searchsorted(ps, ns)]
        if len(rows) == 0:
            continue
        cum = ctx.CUM32[rows][:, None]
        ncopy = min(S1, width - w)
        if ncopy > 0:
            Rband[rows, w:w + ncopy] = Cband[rows, 1:1 + ncopy] + cum
        if clamp_tail and width - (w + S1) > 0:
            Rband[rows, w + S1:] = Cband[rows, S1:S1 + 1] + cum


def _build_lm_band(ctx: _FillCtx, Lm: np.ndarray, tab: BandedTable, d: int
                   ) -> None:
    """Publish band ``d`` of the left-child companion table:
    ``Lm[s, t][m] = C[s, t][m] - CUM32[s-1]``."""
    ns = ctx.L + 1 - d
    np.subtract(tab.band(d)[:, 1:], ctx.CUM32[:ns, None],
                out=Lm[tab.off[d]:tab.off[d] + ns])


def _fall_plane(ctx: _FillCtx, tab: BandedTable, d: int, ns: int,
                ma: np.ndarray, out: np.ndarray) -> np.ndarray:
    """C2: ``u_f^s + C[s+1, t][m - wā^s] + u_b^s``, masked by m_all.  The
    plane is computed at whatever column width ``out`` has (the pruned band
    width — gather indices are column-aligned, so slicing is exact)."""
    S2 = ctx.S2
    W = out.shape[1]
    rows = ((tab.off[d - 1] + 1 + np.arange(ns, dtype=np.int64)) * S2
            ).astype(np.int32)
    fi = rows[:, None] + ctx.idx_wb[1:1 + ns, :W]
    np.take(tab.data.reshape(-1), fi, out=out)
    out += ctx.UF32[1:1 + ns, None]
    out += ctx.UB32[1:1 + ns, None]
    out[ctx.ms[None, :W] < ma[:, None]] = _INF32
    return out


# ---------------------------------------------------------------------------
# Two-tier fill
# ---------------------------------------------------------------------------

def fill_two_tier(dchain, S: int, allow_fall: bool = True,
                  v: Optional[dict] = None,
                  prune: Optional[bool] = None) -> BandedTable:
    """Banded bottom-up fill of the paper's Theorem-1 recursion: for each
    sub-chain length the C1 candidates of **all** starts are evaluated one
    split offset at a time — one add of two contiguous companion-table
    blocks (``R`` + ``Lm``) per split — into a running minimum.  With
    ``prune`` (default on, env ``REPRO_DP_PRUNE``), each band computes only
    its unsaturated columns (:func:`saturation_caps`) and broadcasts the
    saturated tail."""
    if v is None:
        v = _views(dchain)
    L = dchain.length
    ctx = _FillCtx(v, L, S)
    tab = BandedTable(L, S)
    ctx.base_case(tab)
    caps = saturation_caps(v, S, allow_fall) if _resolve_prune(prune) else None
    nw = _n_workers()
    scratch = _Scratch(L, S, planes=2 * nw + 1, iplanes=0)
    S1 = ctx.S1
    off = tab.off
    # pre-shifted companions (fill scratch, freed with this frame): the C1
    # candidate for split sp collapses to one add —
    #   (C[sp,t][m - WA[sp-1]] + CUM[sp-1]) + (C[s,sp-1][m] - CUM[s-1])
    # = fwd-stream cost + shifted right child + left child.
    R = np.full((int(off[-1]), S1), INFEASIBLE, dtype=COST_DTYPE)
    Lm = np.empty((int(off[-1]), S1), dtype=COST_DTYPE)
    _build_r_band(ctx, R, tab, 0, clamp_tail=False)
    _build_lm_band(ctx, Lm, tab, 0)
    for d in range(1, L + 1):
        ns = L + 1 - d
        W = band_width(caps, d, S)
        ma, mn = ctx.thresholds(d)
        resfull = tab.band(d)[:, 1:]        # starts at +inf; min-accumulated
        res = resfull[:, :W]

        def run(jlo: int, jhi: int, acc: np.ndarray, tmp: np.ndarray):
            for j in range(jlo, jhi):       # split sp = s + 1 + j
                base = int(off[d - 1 - j]) + 1 + j
                np.add(R[base:base + ns, :W], Lm[off[j]:off[j] + ns, :W],
                       out=tmp)
                np.minimum(acc, tmp, out=acc)

        if nw > 1 and d >= 2 * nw and ns * d * W >= _PAR_MIN_ELEMS:
            bounds = np.linspace(0, d, nw + 1).astype(int)
            futs, accs = [], []
            ex = _executor(nw)
            for k in range(nw):
                if bounds[k] == bounds[k + 1]:
                    continue
                acc = scratch.plane(2 * k, ns, W)
                acc[:] = _INF32
                accs.append(acc)
                futs.append(ex.submit(run, int(bounds[k]), int(bounds[k + 1]),
                                      acc, scratch.plane(2 * k + 1, ns, W)))
            for f in futs:
                f.result()
            for acc in accs:
                np.minimum(res, acc, out=res)
        else:
            run(0, d, res, scratch.plane(0, ns, W))
        res[ctx.ms[None, :W] < mn[:, None]] = _INF32
        if allow_fall:
            c2 = scratch.plane(2 * nw, ns, W)
            _fall_plane(ctx, tab, d, ns, ma, c2)
            np.minimum(res, c2, out=res)
        if W <= S:
            resfull[:, W:] = resfull[:, W - 1:W]   # saturated tail
        _build_r_band(ctx, R, tab, d, clamp_tail=False)
        _build_lm_band(ctx, Lm, tab, d)
    return tab


# ---------------------------------------------------------------------------
# Offload (three-tier) fill — the C3 branch is one more candidate plane
# ---------------------------------------------------------------------------

def fill_offload(dchain, S: int, allow_fall: bool = True,
                 v: Optional[dict] = None, prune: Optional[bool] = None
                 ) -> Tuple[BandedTable, BandedTable]:
    """Banded fill of the offload-aware DP: returns ``(Cb, Ce)`` — input bare
    (all three branches) vs input embedded in an ``ā`` (two-tier branches)."""
    if v is None:
        v = _views(dchain)
    L = dchain.length
    ctx = _FillCtx(v, L, S)
    tb, te = BandedTable(L, S), BandedTable(L, S)
    ctx.base_case(tb)
    ctx.base_case(te)
    caps = saturation_caps(v, S, allow_fall) if _resolve_prune(prune) else None
    host = dchain.chain.host
    host_on = host is not None and host.enabled
    tpre32 = dchain.chain.prefetch_times().astype(COST_DTYPE)
    # the offload fill streams ~4 companion tables per split; extra threads
    # thrash the shared cache on typical 2-core runners, so it defaults to
    # serial (REPRO_DP_THREADS opts in)
    nw = _n_workers(default_parallel=False)
    scratch = _Scratch(L, S, planes=5 * nw + 1, iplanes=nw)
    S1, S2 = ctx.S1, ctx.S2
    flat_b = tb.data.reshape(-1)
    offb, offe = tb.off, te.off
    # pre-shifted right-child companion of C_b (right children are always
    # bare) and left-child companions of both tables.  The C3 plane reads R
    # at a parent-side column offset WA[s-1], so R's width is padded by wcap
    # and the tail clamps to C[·][S] (the memory-gain semantics); that slice
    # trick needs every WA <= S+1, else C3 falls back to an explicit gather.
    slice_c3 = host_on and ctx.wa_uncapped
    ncells = int(offb[-1])
    R = np.full((ncells, S1 + (ctx.wcap if slice_c3 else 0)),
                INFEASIBLE, dtype=COST_DTYPE)
    Lmb = np.empty((ncells, S1), dtype=COST_DTYPE)
    Lme = np.empty((ncells, S1), dtype=COST_DTYPE)
    # C3 left-child companion with the prefetch charge pre-added:
    # Lmb3[s, t][m] = (C_b[s, t][m] - CUM32[s-1]) + T_pre(a^{s-1})
    Lmb3 = np.empty((ncells, S1), dtype=COST_DTYPE) if host_on else None
    _build_r_band(ctx, R, tb, 0, clamp_tail=slice_c3)
    _build_lm_band(ctx, Lmb, tb, 0)
    _build_lm_band(ctx, Lme, te, 0)
    # the C3 stall folds into a max:  X + max(T_off - X, 0) = max(X, T_off);
    # in the CUM-shifted domain the threshold is T_off(a^{s-1}) + CUM[s-1]
    toffP = (dchain.chain.offload_times()
             + np.asarray(v["CUM_UF"][:L + 1])).astype(COST_DTYPE)

    def build_lmb3(d: int) -> None:
        ns_ = L + 1 - d
        lo = int(offb[d])
        np.add(Lmb[lo:lo + ns_], tpre32[:ns_, None], out=Lmb3[lo:lo + ns_])

    if host_on:
        build_lmb3(0)
    for d in range(1, L + 1):
        ns = L + 1 - d
        W = band_width(caps, d, S)
        ma, mn = ctx.thresholds(d)
        resb_full = tb.band(d)[:, 1:]
        rese_full = te.band(d)[:, 1:]
        resb = resb_full[:, :W]
        rese = rese_full[:, :W]
        if host_on:
            toffPcol = toffP[:ns, None]
            wacol = ctx.WA[:ns].astype(np.int32)[:, None]
            par_groups = [(w, ps[:np.searchsorted(ps, ns)])
                          for w, ps in ctx.groups]

        def run(jlo: int, jhi: int, accb, acce, acc3, tmp, tmp3, ifi):
            for j in range(jlo, jhi):       # split sp = s + 1 + j
                base = int(offb[d - 1 - j]) + 1 + j
                lo = int(offb[j])
                # C1 keeps the parent's input-state bit in the left child;
                # the right child is always bare (C_b)
                np.add(R[base:base + ns, :W], Lmb[lo:lo + ns, :W], out=tmp)
                np.minimum(accb, tmp, out=accb)
                np.add(R[base:base + ns, :W], Lme[lo:lo + ns, :W], out=tmp)
                np.minimum(acce, tmp, out=acce)
                if not host_on:
                    continue
                # C3 right segment: the group input's slots are reclaimed,
                # so the shift is WA[sp-1] - WA[s-1] — i.e. the R row read
                # at column offset w0 = WA[s-1], fused with the stall max
                if slice_c3:
                    Rblk = R[base:base + ns]
                    for w0, rows in par_groups:
                        if len(rows):
                            tmp3[rows] = np.maximum(
                                Rblk[rows, w0:w0 + W], toffP[rows][:, None])
                else:
                    np.add(ctx.raw_wa[1 + j:1 + j + ns, :W], wacol, out=ifi)
                    np.clip(ifi, -1, S, out=ifi)
                    ifi += 1
                    ifi += ctx.is2[:ns, None]
                    np.take(flat_b[base * S2:], ifi, out=tmp3)
                    tmp3 += ctx.CUM32[1 + j:1 + j + ns, None]
                    np.maximum(tmp3, toffPcol, out=tmp3)
                tmp3 += Lmb3[lo:lo + ns, :W]            # C3 left is bare
                np.minimum(acc3, tmp3, out=acc3)

        c3acc = None
        if nw > 1 and d >= 2 * nw and ns * d * W >= _PAR_MIN_ELEMS:
            bounds = np.linspace(0, d, nw + 1).astype(int)
            futs, accs = [], []
            ex = _executor(nw)
            for k in range(nw):
                if bounds[k] == bounds[k + 1]:
                    continue
                bufs = [scratch.plane(5 * k + i, ns, W) for i in range(5)]
                bufs[0][:] = _INF32
                bufs[1][:] = _INF32
                bufs[2][:] = _INF32
                accs.append(bufs[:3])
                futs.append(ex.submit(
                    run, int(bounds[k]), int(bounds[k + 1]), bufs[0], bufs[1],
                    bufs[2], bufs[3], bufs[4], scratch.iplane(k, ns, W)))
            for f in futs:
                f.result()
            if host_on:
                c3acc = accs[0][2]
            for i, acc in enumerate(accs):
                np.minimum(resb, acc[0], out=resb)
                np.minimum(rese, acc[1], out=rese)
                if host_on and i > 0:
                    np.minimum(c3acc, acc[2], out=c3acc)
        else:
            if host_on:
                c3acc = scratch.plane(2, ns, W)
                c3acc[:] = _INF32
            run(0, d, resb, rese, c3acc, scratch.plane(0, ns, W),
                scratch.plane(3, ns, W), scratch.iplane(0, ns, W))
        infeas = ctx.ms[None, :W] < mn[:, None]
        resb[infeas] = _INF32
        rese[infeas] = _INF32
        if allow_fall:
            c2 = scratch.plane(5 * nw, ns, W)
            _fall_plane(ctx, te, d, ns, ma, c2)         # C2 child is embedded
            np.minimum(resb, c2, out=resb)
            np.minimum(rese, c2, out=rese)
        if host_on:
            c3acc[infeas] = _INF32
            np.minimum(resb, c3acc, out=resb)
        if W <= S:
            resb_full[:, W:] = resb_full[:, W - 1:W]   # saturated tail
            rese_full[:, W:] = rese_full[:, W - 1:W]
        _build_r_band(ctx, R, tb, d, clamp_tail=slice_c3)
        _build_lm_band(ctx, Lmb, tb, d)
        _build_lm_band(ctx, Lme, te, d)
        if host_on:
            build_lmb3(d)
    return tb, te


# ---------------------------------------------------------------------------
# Impl dispatch — the seam every solver-side kernel goes through
# ---------------------------------------------------------------------------

def fill_tables(dchain, S: int, impl: str = "banded",
                allow_fall: bool = True, v: Optional[dict] = None,
                prune: Optional[bool] = None) -> BandedTable:
    """Two-tier band fill behind the ``impl`` seam: ``"banded"`` runs this
    module's numpy kernels; ``"pallas"`` dispatches (lazily, so the numpy
    core never imports jax) to :mod:`repro.kernels.dp_fill` — the per-band
    Pallas kernel, jit on TPU and interpret-mode on CPU; ``"pallas_fused"``
    runs the same package's device-resident fill (one ``pallas_call`` for
    the whole recursion).  All produce the same :class:`BandedTable` layout,
    so reconstruction is impl-agnostic.  (``"reference"`` keeps its own
    table format and stays in the solvers.)

    Fill wall time lands in the ``dp_fill.<impl>.seconds`` histogram of the
    process metrics registry (:mod:`repro.obs.metrics`)."""
    with _obs.histogram(f"dp_fill.{impl}.seconds").time():
        if impl == "pallas":
            from ..kernels.dp_fill import ops as _dp_fill_ops
            return _dp_fill_ops.fill_two_tier(
                dchain, S, allow_fall=allow_fall, v=v, prune=prune)
        if impl == "pallas_fused":
            from ..kernels.dp_fill import ops as _dp_fill_ops
            return _dp_fill_ops.fill_two_tier_fused(
                dchain, S, allow_fall=allow_fall, v=v, prune=prune)
        if impl != "banded":
            raise ValueError(f"fill_tables cannot run impl {impl!r}")
        return fill_two_tier(dchain, S, allow_fall=allow_fall, v=v,
                             prune=prune)


def fill_tables_offload(dchain, S: int, impl: str = "banded",
                        allow_fall: bool = True, v: Optional[dict] = None,
                        prune: Optional[bool] = None
                        ) -> Tuple[BandedTable, BandedTable]:
    """Offload (three-tier) band fill behind the same ``impl`` seam; wall
    time lands in the ``dp_fill.<impl>.offload_seconds`` histogram."""
    with _obs.histogram(f"dp_fill.{impl}.offload_seconds").time():
        if impl == "pallas":
            from ..kernels.dp_fill import ops as _dp_fill_ops
            return _dp_fill_ops.fill_offload(
                dchain, S, allow_fall=allow_fall, v=v, prune=prune)
        if impl == "pallas_fused":
            from ..kernels.dp_fill import ops as _dp_fill_ops
            return _dp_fill_ops.fill_offload_fused(
                dchain, S, allow_fall=allow_fall, v=v, prune=prune)
        if impl != "banded":
            raise ValueError(f"fill_tables_offload cannot run impl {impl!r}")
        return fill_offload(dchain, S, allow_fall=allow_fall, v=v,
                            prune=prune)


# ---------------------------------------------------------------------------
# Choice recomputation (used by the reconstructions instead of stored tables)
# ---------------------------------------------------------------------------

def _lookup(tab: BandedTable, s: int, t: int, m_shifted: int) -> np.float32:
    if m_shifted < 0:
        return _INF32
    return tab.row(s, t)[min(m_shifted, tab.S)]


def _c1_candidates(v: dict, right_tab: BandedTable, left_tab: BandedTable,
                   s: int, t: int, m: int) -> np.ndarray:
    """C1 candidate values for every split, in the exact float32 operation
    order the banded fill used: the forward-stream cost telescopes as
    ``(C_right[m - w] + CUM32[sp-1]) + (C_left[m] - CUM32[s-1])``."""
    sps = np.arange(s + 1, t + 1)
    n = len(sps)
    right = np.empty(n, dtype=COST_DTYPE)
    left = np.empty(n, dtype=COST_DTYPE)
    for k, sp in enumerate(sps):
        right[k] = _lookup(right_tab, sp, t, m - int(v["WA"][sp - 1]))
        left[k] = left_tab.row(s, sp - 1)[m]
    cum32 = v["CUM_UF"].astype(COST_DTYPE)
    return (right + cum32[sps - 1]) + (left - cum32[s - 1])


def _c2_value(v: dict, child_tab: BandedTable, s: int, t: int, m: int
              ) -> np.float32:
    if m < _m_all(v, s, t):
        return _INF32
    val = _lookup(child_tab, s + 1, t, m - int(v["WABAR"][s]))
    return (val + _F32(v["UF"][s])) + _F32(v["UB"][s])


def choose_two_tier(v: dict, tab: BandedTable, s: int, t: int, m: int,
                    allow_fall: bool = True) -> Tuple[int, int]:
    """Recompute the optimal branch at one cell: returns ``(choice, split)``
    with choice 0 = infeasible, 1 = Ck, 2 = All (seed tie-breaking: ties go
    to Ck).  Only the ~O(L) cells the reconstruction visits are recomputed —
    the banded fill stores costs only."""
    if s == t:
        return (2, 0) if np.isfinite(tab.row(s, s)[m]) else (0, 0)
    cand = _c1_candidates(v, tab, tab, s, t, m)
    if m < _m_none(v, s, t):
        cand[:] = _INF32
    k = int(np.argmin(cand))
    best = cand[k]
    choice, sp = (1, s + 1 + k) if np.isfinite(best) else (0, 0)
    if allow_fall:
        c2 = _c2_value(v, tab, s, t, m)
        if c2 < best or (not np.isfinite(best) and np.isfinite(c2)):
            choice, sp, best = 2, 0, c2
    if not np.isfinite(best):
        return 0, 0
    return choice, sp


def choose_offload(v: dict, tb: BandedTable, te: BandedTable,
                   toffP: np.ndarray, tpre32: np.ndarray,
                   s: int, t: int, m: int, bare: bool,
                   allow_fall: bool = True) -> Tuple[int, int]:
    """Branch decision for the offload DP at one cell: choice 0 = infeasible,
    1 = Ck, 2 = All, 3 = Offload (seed tie-breaking: Ck ≺ All ≺ Offload).
    ``toffP`` is the CUM-shifted offload-time vector the fill used
    (``T_off(a^i) + CUM[i]`` in float32)."""
    tab = tb if bare else te
    if s == t:
        return (2, 0) if np.isfinite(tab.row(s, s)[m]) else (0, 0)
    m_none = _m_none(v, s, t)
    cand = _c1_candidates(v, tb, tab, s, t, m)
    if m < m_none:
        cand[:] = _INF32
    k = int(np.argmin(cand))
    best = cand[k]
    choice, sp = (1, s + 1 + k) if np.isfinite(best) else (0, 0)
    if allow_fall:
        c2 = _c2_value(v, te, s, t, m)
        if c2 < best or (not np.isfinite(best) and np.isfinite(c2)):
            choice, sp, best = 2, 0, c2
    if bare and np.isfinite(toffP[s - 1]):
        sps = np.arange(s + 1, t + 1)
        n = len(sps)
        hidden = np.empty(n, dtype=COST_DTYPE)   # CUM-shifted hidden work
        left = np.empty(n, dtype=COST_DTYPE)
        w0 = int(v["WA"][s - 1])
        cum32 = v["CUM_UF"].astype(COST_DTYPE)
        for kk, spp in enumerate(sps):
            hidden[kk] = (_lookup(tb, spp, t, m - int(v["WA"][spp - 1]) + w0)
                          + cum32[spp - 1])
            left[kk] = tb.row(s, spp - 1)[m]
        # X + max(T_off - X, 0) = max(X, T_off), in the CUM-shifted domain;
        # the prefetch charge rides on the left-child companion (Lmb3)
        cand3 = (np.maximum(hidden, toffP[s - 1])
                 + ((left - cum32[s - 1]) + tpre32[s - 1]))
        if m < m_none:
            cand3[:] = _INF32
        k3 = int(np.argmin(cand3))
        if cand3[k3] < best or (not np.isfinite(best)
                                and np.isfinite(cand3[k3])):
            choice, sp, best = 3, s + 1 + k3, cand3[k3]
    if not np.isfinite(best):
        return 0, 0
    return choice, sp
