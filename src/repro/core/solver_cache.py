"""Persistent memoization for the DP solvers: in-memory LRU + on-disk store.

Every public solver entry point (``solve_optimal``, ``solve_min_memory``,
``solve_optimal_offload``, ``solve_min_device_memory``) keys its inputs by a
content hash of the *discretized* problem — the slot-rounded size arrays, the
continuous stage times, the host-link model, the budget/slot count, and the
branch flags — and memoizes the returned :class:`~repro.core.solver.Solution`.
Repeated launches with the same (model × shape × mesh × policy) and budget
sweeps that revisit a point therefore skip the table fill entirely; this is
what makes plan-time a non-cost for the train/serve launch paths.

Environment knobs:

- ``REPRO_SOLVER_CACHE=0`` (or ``off``/``false``/``no``) disables caching
  entirely (no reads, no writes).
- ``REPRO_SOLVER_CACHE_DIR=<dir>`` sets the on-disk store location; an empty
  value keeps the cache memory-only.  Default:
  ``$XDG_CACHE_HOME/repro/solver-cache`` (``~/.cache/...``).
- ``REPRO_SOLVER_CACHE_SIZE=<n>`` caps the in-memory LRU (default 128).
- ``REPRO_SOLVER_CACHE_DISK_SIZE=<n>`` caps the on-disk store (default 512
  entries; oldest evicted).

Keys include a content hash of the solver source modules, so editing solver
logic automatically invalidates stale on-disk entries.

Disk entries are pickles written atomically; a corrupted, truncated, or
version-skewed entry is treated as a miss (and deleted best-effort) — the
caller simply re-solves and overwrites it.
"""

from __future__ import annotations

import hashlib
import os
import pickle
import sys
import tempfile
import threading
from collections import OrderedDict
from pathlib import Path
from typing import Any, Optional

import numpy as np

from ..obs import metrics as _obs

_MAGIC = "repro-solver-cache"
_VERSION = 1
_FALSEY = {"0", "off", "false", "no"}

# modules whose source defines what a Solution means; their content hash is
# part of every cache key, so editing solver logic auto-invalidates stale
# on-disk entries instead of silently serving pre-fix Solutions
_FINGERPRINT_MODULES = ("repro.core.chain", "repro.core.schedule",
                        "repro.core.dp_kernels", "repro.core.solver",
                        "repro.offload.solver")
# the Pallas kernel package is fingerprinted too (its fills produce cached
# Solutions under impl="pallas"/"pallas_fused") — by file path relative to
# the repro package, NOT by import, so fingerprinting never drags jax into
# the numpy core (importing, or even find_spec-ing, a dp_fill submodule
# would execute the package __init__, which imports jax)
_FINGERPRINT_FILES = ("kernels/dp_fill/kernel.py", "kernels/dp_fill/ops.py")
_code_fingerprint: Optional[str] = None


def code_fingerprint() -> str:
    """Content hash of the solver implementation (computed once)."""
    global _code_fingerprint
    if _code_fingerprint is None:
        import importlib
        h = hashlib.sha256()
        for name in _FINGERPRINT_MODULES:
            try:
                mod = importlib.import_module(name)
                with open(mod.__file__, "rb") as f:
                    h.update(f.read())
            except Exception:
                h.update(name.encode())  # missing module: still deterministic
        pkg_root = Path(__file__).resolve().parent.parent  # src/repro/
        for rel in _FINGERPRINT_FILES:
            try:
                with open(pkg_root / rel, "rb") as f:
                    h.update(f.read())
            except Exception:
                h.update(rel.encode())  # missing file: still deterministic
        _code_fingerprint = h.hexdigest()
    return _code_fingerprint


def _hash_host(h, host) -> None:
    if host is None:
        h.update(b"nohost")
    else:
        h.update(np.array(
            [host.bandwidth_d2h,
             -1.0 if host.bandwidth_h2d is None else host.bandwidth_h2d,
             host.latency], dtype=np.float64).tobytes())


def chain_fingerprint(chain) -> str:
    """Content hash of a :class:`~repro.core.chain.Chain` — all continuous
    cost/size arrays plus the host-link model.  Shared by the solver cache
    and by :mod:`repro.plan` plan serialization, so a saved ``MemoryPlan``
    validates against exactly the chain it was solved for."""
    h = hashlib.sha256()
    h.update(b"repro-chain\0")
    for arr in (chain.uf, chain.ub, chain.wa, chain.wabar, chain.wdelta,
                chain.of, chain.ob):
        h.update(np.ascontiguousarray(arr, dtype=np.float64).tobytes())
        h.update(b"\0")
    _hash_host(h, chain.host)
    return h.hexdigest()


def _default_dir() -> Optional[Path]:
    env = os.environ.get("REPRO_SOLVER_CACHE_DIR")
    if env is not None:
        return Path(env) if env else None
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache")
    return Path(base) / "repro" / "solver-cache"


class SolverCache:
    """Thread-safe LRU of solver Solutions with an optional disk tier."""

    def __init__(self, capacity: Optional[int] = None,
                 directory: Optional[Path] = "auto",
                 enabled: Optional[bool] = None):
        if enabled is None:
            enabled = os.environ.get(
                "REPRO_SOLVER_CACHE", "1").strip().lower() not in _FALSEY
        if capacity is None:
            try:
                capacity = int(os.environ.get("REPRO_SOLVER_CACHE_SIZE", 128))
            except ValueError:
                capacity = 128
        self.enabled = enabled
        self.capacity = max(capacity, 1)
        try:
            self.disk_capacity = max(int(os.environ.get(
                "REPRO_SOLVER_CACHE_DISK_SIZE", 512)), 1)
        except ValueError:
            self.disk_capacity = 512
        self.directory = _default_dir() if directory == "auto" else (
            Path(directory) if directory else None)
        if not self.enabled:
            self.directory = None
        self._mem: "OrderedDict[str, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self._disk_failures = 0     # consecutive; disk tier pauses after 8
        self.stats = {"hits": 0, "misses": 0, "disk_hits": 0,
                      "disk_errors": 0, "puts": 0, "evictions": 0}

    def _bump(self, stat: str, n: int = 1) -> None:
        """Count in the instance stats AND the process metrics registry —
        a cache hit is no longer indistinguishable from a 0.2 ms solve."""
        self.stats[stat] += n
        _obs.counter(f"solver_cache.{stat}").inc(n)

    # -- keying ------------------------------------------------------------

    def key_for(self, kind: str, impl: str, chain, dchain,
                num_slots: int, allow_fall: bool) -> str:
        """Content hash of the discretized problem + solve flags."""
        h = hashlib.sha256()
        for part in (_MAGIC, str(_VERSION), code_fingerprint(), kind, impl,
                     str(num_slots), str(int(allow_fall))):
            h.update(part.encode())
            h.update(b"\0")
        h.update(np.float64(dchain.slot_size).tobytes())
        for arr in (dchain.wa, dchain.wabar, dchain.wdelta, dchain.of,
                    dchain.ob):
            h.update(np.ascontiguousarray(arr, dtype=np.int64).tobytes())
        for arr in (chain.uf, chain.ub, chain.wa):
            h.update(np.ascontiguousarray(arr, dtype=np.float64).tobytes())
        _hash_host(h, chain.host)
        return h.hexdigest()

    # -- lookup / store ----------------------------------------------------

    def _path(self, key: str) -> Optional[Path]:
        return self.directory / f"{key}.pkl" if self.directory else None

    def get(self, key: str) -> Optional[Any]:
        if not self.enabled:
            return None
        with self._lock:
            if key in self._mem:
                self._mem.move_to_end(key)
                self._bump("hits")
                return self._mem[key]
        value = self._disk_get(key)
        if value is not None:
            with self._lock:
                self._bump("hits")
                self._bump("disk_hits")
                self._mem_put(key, value)
            return value
        with self._lock:
            self._bump("misses")
        return None

    def put(self, key: str, value: Any) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._bump("puts")
            self._mem_put(key, value)
        self._disk_put(key, value)

    def _mem_put(self, key: str, value: Any) -> None:
        self._mem[key] = value
        self._mem.move_to_end(key)
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)
            self._bump("evictions")

    # -- disk tier ---------------------------------------------------------

    def _disk_get(self, key: str) -> Optional[Any]:
        path = self._path(key)
        if path is None or not path.is_file():
            return None
        try:
            with open(path, "rb") as f:
                payload = pickle.load(f)
            magic, version, stored_key, value = payload
            if magic != _MAGIC or version != _VERSION or stored_key != key:
                raise ValueError("cache entry header mismatch")
            return value
        except Exception:
            with self._lock:
                self._bump("disk_errors")
            try:
                path.unlink()
            except OSError:
                pass
            return None

    def _disk_put(self, key: str, value: Any) -> None:
        path = self._path(key)
        if path is None or self._disk_failures >= 8:
            return
        # recursion trees nest O(L) deep; pickling recurses through them
        limit = sys.getrecursionlimit()
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
            try:
                sys.setrecursionlimit(max(limit, 100_000))
                with os.fdopen(fd, "wb") as f:
                    pickle.dump((_MAGIC, _VERSION, key, value), f,
                                protocol=pickle.HIGHEST_PROTOCOL)
                os.replace(tmp, path)
            finally:
                sys.setrecursionlimit(limit)
                if os.path.exists(tmp):
                    try:
                        os.unlink(tmp)
                    except OSError:
                        pass
            self._disk_failures = 0
            self._disk_prune()
        except Exception:
            # best-effort tier: count the failure and keep trying (a burst of
            # consecutive failures pauses disk writes for this process)
            with self._lock:
                self._bump("disk_errors")
            self._disk_failures += 1

    def _disk_prune(self) -> None:
        """Bound the on-disk store: evict oldest entries beyond the cap."""
        try:
            entries = sorted(self.directory.glob("*.pkl"),
                             key=lambda p: p.stat().st_mtime)
            for p in entries[:max(len(entries) - self.disk_capacity, 0)]:
                p.unlink()
        except OSError:
            pass

    # -- maintenance -------------------------------------------------------

    def clear(self, memory_only: bool = False) -> None:
        with self._lock:
            self._mem.clear()
        if not memory_only and self.directory and self.directory.is_dir():
            for p in self.directory.glob("*.pkl"):
                try:
                    p.unlink()
                except OSError:
                    pass

    def reset_stats(self) -> None:
        with self._lock:
            for k in self.stats:
                self.stats[k] = 0


# ---------------------------------------------------------------------------
# process-wide default cache (rebuilt lazily so env changes take effect)
# ---------------------------------------------------------------------------

_default: Optional[SolverCache] = None
_default_lock = threading.Lock()


def get_cache() -> SolverCache:
    global _default
    with _default_lock:
        if _default is None:
            _default = SolverCache()
        return _default


def configure(**kwargs) -> SolverCache:
    """Replace the process-wide cache (kwargs as for :class:`SolverCache`)."""
    global _default
    with _default_lock:
        _default = SolverCache(**kwargs)
        return _default


def reset() -> None:
    """Drop the process-wide cache; the next use rebuilds it from the env."""
    global _default
    with _default_lock:
        _default = None


def stats() -> dict:
    return dict(get_cache().stats)


def memoize_solve(kind: str, impl: str, chain, dchain, num_slots: int,
                  allow_fall: bool, use_cache: bool, solve):
    """Shared lookup/store wrapper for the solver entry points: returns the
    cached Solution for this discretized problem, or runs ``solve()`` and
    stores its result.  ``use_cache=False`` bypasses the cache entirely
    (benchmarks time real fills)."""
    if not use_cache:
        return solve()
    sc = get_cache()
    if not sc.enabled:
        return solve()
    key = sc.key_for(kind, impl, chain, dchain, num_slots, allow_fall)
    hit = sc.get(key)
    if hit is not None:
        return hit
    sol = solve()
    sc.put(key, sol)
    return sol
