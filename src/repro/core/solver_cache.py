"""Persistent memoization for the DP solvers: in-memory LRU + on-disk store.

Every public solver entry point (``solve_optimal``, ``solve_min_memory``,
``solve_optimal_offload``, ``solve_min_device_memory``) keys its inputs by a
content hash of the *discretized* problem — the slot-rounded size arrays, the
continuous stage times, the host-link model, the budget/slot count, and the
branch flags — and memoizes the returned :class:`~repro.core.solver.Solution`.
Repeated launches with the same (model × shape × mesh × policy) and budget
sweeps that revisit a point therefore skip the table fill entirely; this is
what makes plan-time a non-cost for the train/serve launch paths.

The persistent tier is a :class:`repro.store` directory backend: entries
are tamper-evident :mod:`repro.store.codec` envelopes written atomically; a
corrupted, truncated, or version-skewed entry is quarantined and treated as
a miss — the caller simply re-solves and overwrites it.  This class is the
back-compat shim over that store: its constructor/env surface is unchanged
from the pre-store releases while all bytes flow through the one Backend
API.

Environment knobs (see :mod:`repro.store.config`):

- ``REPRO_STORE=<uri>`` — the store location (``file://<dir>``,
  ``shared://<dir>``, ``memory://``); ``off`` disables caching entirely.
  Default: ``file://$XDG_CACHE_HOME/repro/solver-cache``.
- ``REPRO_STORE_MEM_ENTRIES=<n>`` caps the in-memory LRU (default 128);
  ``REPRO_STORE_MAX_ENTRIES=<n>`` the on-disk store (default 512 entries,
  oldest evicted).
- Deprecated (mapped onto the above with a ``DeprecationWarning``):
  ``REPRO_SOLVER_CACHE=0`` → ``REPRO_STORE=off``;
  ``REPRO_SOLVER_CACHE_DIR=<dir>`` → ``REPRO_STORE=file://<dir>`` (empty
  value → memory-only); ``REPRO_SOLVER_CACHE_SIZE`` →
  ``REPRO_STORE_MEM_ENTRIES``; ``REPRO_SOLVER_CACHE_DISK_SIZE`` →
  ``REPRO_STORE_MAX_ENTRIES``.

Keys include a content hash of the solver source modules, so editing solver
logic automatically invalidates stale on-disk entries.
"""

from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from pathlib import Path
from typing import Any, Optional

import numpy as np

from ..obs import metrics as _obs
from ..store.backend import LocalDirectoryBackend, StoreError
from ..store.codec import CorruptEntryError, decode, encode

_MAGIC = "repro-solver-cache"
_VERSION = 1
#: Envelope kind tag for cached solver Solutions (autotune winners are
#: stored through the same cache with kind="autotune").
SOLUTION_KIND = "solution"

# modules whose source defines what a Solution means; their content hash is
# part of every cache key, so editing solver logic auto-invalidates stale
# on-disk entries instead of silently serving pre-fix Solutions
_FINGERPRINT_MODULES = ("repro.core.chain", "repro.core.schedule",
                        "repro.core.dp_kernels", "repro.core.solver",
                        "repro.offload.solver")
# the Pallas kernel package is fingerprinted too (its fills produce cached
# Solutions under impl="pallas"/"pallas_fused") — by file path relative to
# the repro package, NOT by import, so fingerprinting never drags jax into
# the numpy core (importing, or even find_spec-ing, a dp_fill submodule
# would execute the package __init__, which imports jax)
_FINGERPRINT_FILES = ("kernels/dp_fill/kernel.py", "kernels/dp_fill/ops.py")
_code_fingerprint: Optional[str] = None


def code_fingerprint() -> str:
    """Content hash of the solver implementation (computed once)."""
    global _code_fingerprint
    if _code_fingerprint is None:
        import importlib
        h = hashlib.sha256()
        for name in _FINGERPRINT_MODULES:
            try:
                mod = importlib.import_module(name)
                with open(mod.__file__, "rb") as f:
                    h.update(f.read())
            except Exception:
                h.update(name.encode())  # missing module: still deterministic
        pkg_root = Path(__file__).resolve().parent.parent  # src/repro/
        for rel in _FINGERPRINT_FILES:
            try:
                with open(pkg_root / rel, "rb") as f:
                    h.update(f.read())
            except Exception:
                h.update(rel.encode())  # missing file: still deterministic
        _code_fingerprint = h.hexdigest()
    return _code_fingerprint


def _hash_host(h, host) -> None:
    if host is None:
        h.update(b"nohost")
    else:
        h.update(np.array(
            [host.bandwidth_d2h,
             -1.0 if host.bandwidth_h2d is None else host.bandwidth_h2d,
             host.latency], dtype=np.float64).tobytes())


def chain_fingerprint(chain) -> str:
    """Content hash of a :class:`~repro.core.chain.Chain` — all continuous
    cost/size arrays plus the host-link model.  Shared by the solver cache
    and by :mod:`repro.plan` plan serialization, so a saved ``MemoryPlan``
    validates against exactly the chain it was solved for."""
    h = hashlib.sha256()
    h.update(b"repro-chain\0")
    for arr in (chain.uf, chain.ub, chain.wa, chain.wabar, chain.wdelta,
                chain.of, chain.ob):
        h.update(np.ascontiguousarray(arr, dtype=np.float64).tobytes())
        h.update(b"\0")
    _hash_host(h, chain.host)
    return h.hexdigest()


class SolverCache:
    """Thread-safe LRU of solver Solutions with an optional persistent tier
    (a :class:`repro.store` directory backend — the back-compat shim over
    the typed store API)."""

    def __init__(self, capacity: Optional[int] = None,
                 directory: Optional[Path] = "auto",
                 enabled: Optional[bool] = None):
        from ..store.config import resolve_settings
        settings = resolve_settings()
        if enabled is None:
            enabled = settings.enabled
        if capacity is None:
            capacity = settings.mem_entries
        self.enabled = enabled
        self.capacity = max(capacity, 1)
        self.disk_capacity = settings.max_entries
        if directory == "auto":
            backend = settings.make_backend() if self.enabled else None
            # a memory:// default store adds nothing over the LRU tier
            if backend is not None and not hasattr(backend, "path"):
                backend = None
        elif directory and self.enabled:
            backend = LocalDirectoryBackend(
                Path(directory), max_entries=self.disk_capacity)
        else:
            backend = None
        self._backend = backend
        self.directory = Path(backend.path) if backend is not None else None
        self._mem: "OrderedDict[str, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self._disk_failures = 0     # consecutive; disk tier pauses after 8
        self.stats = {"hits": 0, "misses": 0, "disk_hits": 0,
                      "disk_errors": 0, "puts": 0, "evictions": 0}

    def _bump(self, stat: str, n: int = 1) -> None:
        """Count in the instance stats AND the process metrics registry —
        a cache hit is no longer indistinguishable from a 0.2 ms solve."""
        self.stats[stat] += n
        _obs.counter(f"solver_cache.{stat}").inc(n)

    # -- keying ------------------------------------------------------------

    def key_for(self, kind: str, impl: str, chain, dchain,
                num_slots: int, allow_fall: bool) -> str:
        """Content hash of the discretized problem + solve flags."""
        h = hashlib.sha256()
        for part in (_MAGIC, str(_VERSION), code_fingerprint(), kind, impl,
                     str(num_slots), str(int(allow_fall))):
            h.update(part.encode())
            h.update(b"\0")
        h.update(np.float64(dchain.slot_size).tobytes())
        for arr in (dchain.wa, dchain.wabar, dchain.wdelta, dchain.of,
                    dchain.ob):
            h.update(np.ascontiguousarray(arr, dtype=np.int64).tobytes())
        for arr in (chain.uf, chain.ub, chain.wa):
            h.update(np.ascontiguousarray(arr, dtype=np.float64).tobytes())
        _hash_host(h, chain.host)
        return h.hexdigest()

    # -- lookup / store ----------------------------------------------------

    def get(self, key: str) -> Optional[Any]:
        if not self.enabled:
            return None
        with self._lock:
            if key in self._mem:
                self._mem.move_to_end(key)
                self._bump("hits")
                return self._mem[key]
        value = self._disk_get(key)
        if value is not None:
            with self._lock:
                self._bump("hits")
                self._bump("disk_hits")
                self._mem_put(key, value)
            return value
        with self._lock:
            self._bump("misses")
        return None

    def put(self, key: str, value: Any, kind: str = SOLUTION_KIND) -> None:
        """Store a value under its content key.  ``kind`` tags the codec
        envelope (``"solution"`` for DP Solutions, ``"autotune"`` for
        dp_fill winner entries); lookups are kind-agnostic — the key is a
        content hash, so kinds can never collide."""
        if not self.enabled:
            return
        with self._lock:
            self._bump("puts")
            self._mem_put(key, value)
        self._disk_put(key, value, kind)

    def _mem_put(self, key: str, value: Any) -> None:
        self._mem[key] = value
        self._mem.move_to_end(key)
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)
            self._bump("evictions")

    # -- persistent tier (store backend) -----------------------------------

    def _disk_get(self, key: str) -> Optional[Any]:
        if self._backend is None:
            return None
        data = self._backend.get(key)
        if data is None:
            return None
        try:
            _, _, value = decode(data, key=key)
            return value
        except CorruptEntryError:
            # tampered / truncated / version-skewed / foreign-format entry:
            # quarantine it and fall back to a fresh solve
            with self._lock:
                self._bump("disk_errors")
            self._backend.quarantine(key)
            return None

    def _disk_put(self, key: str, value: Any,
                  kind: str = SOLUTION_KIND) -> None:
        if self._backend is None or self._disk_failures >= 8:
            return
        try:
            self._backend.put(key, encode(kind, key, value))
            self._disk_failures = 0
        except (StoreError, OSError):
            # best-effort tier: count the failure and keep trying (a burst of
            # consecutive failures pauses disk writes for this process)
            with self._lock:
                self._bump("disk_errors")
            self._disk_failures += 1

    # -- maintenance -------------------------------------------------------

    def clear(self, memory_only: bool = False) -> None:
        with self._lock:
            self._mem.clear()
        if not memory_only and self._backend is not None:
            self._backend.clear()

    def reset_stats(self) -> None:
        with self._lock:
            for k in self.stats:
                self.stats[k] = 0


# ---------------------------------------------------------------------------
# process-wide default cache (rebuilt lazily so env changes take effect)
# ---------------------------------------------------------------------------

_default: Optional[SolverCache] = None
_default_lock = threading.Lock()


def get_cache() -> SolverCache:
    global _default
    with _default_lock:
        if _default is None:
            _default = SolverCache()
        return _default


def configure(**kwargs) -> SolverCache:
    """Replace the process-wide cache (kwargs as for :class:`SolverCache`)."""
    global _default
    with _default_lock:
        _default = SolverCache(**kwargs)
        return _default


def reset() -> None:
    """Drop the process-wide cache; the next use rebuilds it from the env."""
    global _default
    with _default_lock:
        _default = None


def stats() -> dict:
    return dict(get_cache().stats)


def memoize_solve(kind: str, impl: str, chain, dchain, num_slots: int,
                  allow_fall: bool, use_cache: bool, solve):
    """Shared lookup/store wrapper for the solver entry points: returns the
    cached Solution for this discretized problem, or runs ``solve()`` and
    stores its result.  ``use_cache=False`` bypasses the cache entirely
    (benchmarks time real fills)."""
    if not use_cache:
        return solve()
    sc = get_cache()
    if not sc.enabled:
        return solve()
    key = sc.key_for(kind, impl, chain, dchain, num_slots, allow_fall)
    hit = sc.get(key)
    if hit is not None:
        return hit
    sol = solve()
    sc.put(key, sol)
    return sol
