"""Paper-faithful eager executor: runs the op *sequence* literally.

This is the JAX analogue of the paper's PyTorch tool (§5): it walks the
schedule op by op, maintaining an explicit saved-set:

- ``F_all^l``  → ``jax.vjp(stage_l, params_l, a)``; the returned vjp closure
  *is* ``ā^l`` (its pytree leaves are the residual tensors).
- ``F_ck^l``   → plain forward; the input stays in the saved-set.
- ``F_∅^l``    → plain forward; the input is dropped.
- ``B^l``      → call the stored vjp with ``δ^l``; accumulate parameter
  cotangents; the result is ``δ^{l-1}``.

Used to (a) validate that rotor computes *exactly the same gradients* as plain
autograd (the paper's "same results" guarantee, §1), and (b) run the eager
CPU reproduction benchmarks where real per-op wall-clock matters.  The
production path is ``rematerialize.build_remat_fn`` (nested remat under jit).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .schedule import BWD, F_ALL, F_CK, F_NONE, Schedule


def _tree_bytes(tree: Any) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        nb = getattr(leaf, "nbytes", None)
        if nb is not None:
            total += int(nb)
    return total


def execute_schedule(
    schedule: Schedule,
    stages: Sequence[Callable],
    params: Sequence[Any],
    x: Any,
    loss_cotangent: Any = None,
    track_live_bytes: bool = False,
) -> Tuple[Any, List[Any], Any]:
    """Run forward+backward per ``schedule``.

    Returns ``(loss_output, param_grads, input_grad)``. ``stages[l-1]`` maps
    paper stage ``l``; the last stage must produce the loss (a scalar) unless
    ``loss_cotangent`` is supplied.

    With ``track_live_bytes=True`` additionally returns a 4th element: the
    **empirical** peak of the executor's saved-set in bytes (activations,
    vjp residuals and pending gradients it holds references to after each
    op) — real array memory, the paper's memory claim measured rather than
    modeled.  The vjp closures' pytree leaves *are* the residual tensors
    (``ā``), so this observes exactly what the Table-1 model accounts.
    """
    L = schedule.length
    acts: Dict[int, Any] = {0: x}          # bare a^i values
    vjps: Dict[int, Any] = {}              # ā^l  (vjp closures)
    outs: Dict[int, Any] = {}              # stage outputs recorded by F_all
    deltas: Dict[int, Any] = {}
    grads: List[Any] = [None] * (L + 1)
    final_out = None
    peak_live = 0

    def get_act(i: int):
        if i in acts:
            return acts[i]
        if i in outs:  # a^i readable from ā^i (Table 1, second line)
            return outs[i]
        raise RuntimeError(f"a^{i} not available — invalid schedule")

    for kind, l in schedule.ops:
        if kind in (F_NONE, F_CK, F_ALL):
            a_in = get_act(l - 1)
            if kind == F_ALL:
                out, vjp_fn = jax.vjp(stages[l - 1], params[l - 1], a_in)
                vjps[l] = vjp_fn
                outs[l] = out
                if l == L + 1:
                    final_out = out
            else:
                out = stages[l - 1](params[l - 1], a_in)
                acts[l] = out
                if l == L + 1:
                    final_out = out
            if kind == F_NONE:
                acts.pop(l - 1, None)
        elif kind == BWD:
            if l == L + 1:
                out = outs[l]
                if loss_cotangent is not None:
                    delta = loss_cotangent
                else:
                    delta = jax.tree.map(lambda o: jnp.ones_like(o), out)
            else:
                delta = deltas.pop(l)
            dparams, da = vjps.pop(l)(delta)
            outs.pop(l, None)
            grads[l - 1] = dparams if grads[l - 1] is None else jax.tree.map(
                jnp.add, grads[l - 1], dparams)
            deltas[l - 1] = da
            acts.pop(l - 1, None)  # B^l consumes a^{l-1}
        else:
            raise ValueError(f"executor cannot run op kind {kind}")
        if track_live_bytes:
            live = (_tree_bytes(acts) + _tree_bytes(vjps) + _tree_bytes(outs)
                    + _tree_bytes(deltas))
            peak_live = max(peak_live, live)

    if 0 not in deltas:
        raise RuntimeError("schedule did not produce δ^0")
    if track_live_bytes:
        return final_out, grads, deltas[0], peak_live
    return final_out, grads, deltas[0]


def reference_grads(stages: Sequence[Callable], params: Sequence[Any], x: Any
                    ) -> Tuple[Any, List[Any], Any]:
    """Plain autograd over the composed chain — the correctness oracle."""

    def composed(params, x):
        for fn, p in zip(stages, params):
            x = fn(p, x)
        return x

    out, vjp_fn = jax.vjp(composed, list(params), x)
    dparams, dx = vjp_fn(jax.tree.map(lambda o: jnp.ones_like(o), out))
    return out, list(dparams), dx
