"""Paper-faithful eager executor: runs the op *sequence* literally.

This is the JAX analogue of the paper's PyTorch tool (§5): it walks the
schedule op by op, maintaining an explicit saved-set:

- ``F_all^l``  → ``jax.vjp(stage_l, params_l, a)``; the returned vjp closure
  *is* ``ā^l`` (its pytree leaves are the residual tensors).
- ``F_ck^l``   → plain forward; the input stays in the saved-set.
- ``F_∅^l``    → plain forward; the input is dropped.
- ``B^l``      → call the stored vjp with ``δ^l``; accumulate parameter
  cotangents; the result is ``δ^{l-1}``.

Used to (a) validate that rotor computes *exactly the same gradients* as plain
autograd (the paper's "same results" guarantee, §1), and (b) run the eager
CPU reproduction benchmarks where real per-op wall-clock matters.  The
production path is ``rematerialize.build_remat_fn`` (nested remat under jit).
"""

from __future__ import annotations

from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .schedule import Schedule


def execute_schedule(
    schedule: Schedule,
    stages: Sequence[Callable],
    params: Sequence[Any],
    x: Any,
    loss_cotangent: Any = None,
    track_live_bytes: bool = False,
    tracer=None,
) -> Tuple[Any, List[Any], Any]:
    """Run forward+backward per ``schedule``.

    Returns ``(loss_output, param_grads, input_grad)``. ``stages[l-1]`` maps
    paper stage ``l``; the last stage must produce the loss (a scalar) unless
    ``loss_cotangent`` is supplied.

    ``tracer`` (a :class:`repro.obs.trace.Tracer`, opt-in) records one span
    per executed op — the measured timeline that
    :func:`repro.obs.drift.compare` holds against the plan's predicted one.

    With ``track_live_bytes=True`` additionally returns a 4th element: the
    **empirical** peak of the executor's saved-set in bytes (activations,
    vjp residuals and pending gradients it holds references to after each
    op) — real array memory, the paper's memory claim measured rather than
    modeled.  The vjp closures' pytree leaves *are* the residual tensors
    (``ā``), so this observes exactly what the Table-1 model accounts.

    The op walker itself lives in ``repro.offload.executor`` — a strict
    superset of the Table-1 op set (it adds ``Foff``/``Prefetch``); this
    wrapper keeps the classic two-tier entry point and contract.
    """
    from ..offload.executor import execute_offload_schedule
    return execute_offload_schedule(
        schedule, stages, params, x, loss_cotangent=loss_cotangent,
        track_live_bytes=track_live_bytes, tracer=tracer)


def reference_grads(stages: Sequence[Callable], params: Sequence[Any], x: Any
                    ) -> Tuple[Any, List[Any], Any]:
    """Plain autograd over the composed chain — the correctness oracle."""

    def composed(params, x):
        for fn, p in zip(stages, params):
            x = fn(p, x)
        return x

    out, vjp_fn = jax.vjp(composed, list(params), x)
    dparams, dx = vjp_fn(jax.tree.map(lambda o: jnp.ones_like(o), out))
    return out, list(dparams), dx
