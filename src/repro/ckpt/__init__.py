from .manager import CheckpointManager, restore_to_sharding
