"""Fault-tolerant checkpoint manager (npz-sharded, manifest-driven).

Properties required at 1000-node scale and implemented here:

- **atomic**: writes go to ``step_N.tmp/`` and are ``rename``d only after the
  manifest (with per-leaf checksums) is fsynced — a crash mid-write never
  corrupts the latest checkpoint;
- **async**: ``save(..., blocking=False)`` snapshots to host memory and
  writes on a background thread so the train loop keeps stepping;
- **keep-k** retention with newest-first restore fallback: if the newest
  checkpoint fails its checksum (torn write on a failed node), restore walks
  back to the previous one;
- **elastic**: arrays are stored unsharded (per-leaf files); restore takes a
  *target* sharding tree and ``device_put``s each leaf — so a checkpoint
  written on mesh A restores onto mesh B with different device counts
  (tested 8 hosts → 4 hosts in tests/test_ckpt.py).

The embedded :class:`~repro.plan.MemoryPlan` (``save(..., plan=...)`` /
:meth:`CheckpointManager.restore_plan`) persists through the
:mod:`repro.store.codec` tamper-evident envelope — the same integrity
story as every other plan crossing a process boundary: verified before it
lands, verified again (and staleness-diagnosed against the restoring
chain) on the way out.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_PLAN_FILE = "memory.plan"


def _flatten(tree: Any) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, np.asarray(leaf)))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- write --------------------------------------------------------------

    def save(self, step: int, state: Any, blocking: bool = True,
             plan: Any = None) -> None:
        """Write one checkpoint.  ``plan`` (an optional
        :class:`~repro.plan.MemoryPlan`) is embedded in the step directory
        under the same atomic rename — the plan that trained a step travels
        with its weights and is statically verified both on the way in
        (``MemoryPlan.save``) and on the way out (:meth:`restore_plan`)."""
        self.wait()  # one async save in flight at a time
        # snapshot to host memory synchronously (cheap vs device compute)
        leaves = _flatten(state)
        structure = jax.tree_util.tree_structure(state)
        if blocking:
            self._write(step, leaves, structure, plan)
        else:
            self._thread = threading.Thread(
                target=self._write_guard,
                args=(step, leaves, structure, plan),
                daemon=True)
            self._thread.start()

    def _write_guard(self, step, leaves, structure, plan=None):
        try:
            self._write(step, leaves, structure, plan)
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _write(self, step: int, leaves, structure, plan=None) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "treedef": str(structure), "leaves": {}}
        for key, arr in leaves:
            fname = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        if plan is not None:
            # MemoryPlan.save verifies the schedule before anything lands
            plan.save(os.path.join(tmp, _PLAN_FILE))
            manifest["plan"] = _PLAN_FILE
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _verify_and_load(self, step: int) -> Optional[Dict[str, np.ndarray]]:
        path = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            leaves = {}
            for key, meta in manifest["leaves"].items():
                arr = np.load(os.path.join(path, meta["file"]))
                if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != meta["crc32"]:
                    return None  # torn write
                leaves[key] = arr
            return leaves
        except (OSError, ValueError, KeyError):
            return None

    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[int, Any]:
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs).  Walks back through retained checkpoints until
        one passes checksum verification.  ``shardings``: matching pytree of
        (Named)Shardings for elastic placement onto the current mesh."""
        candidates = ([step] if step is not None
                      else list(reversed(self.all_steps())))
        for s in candidates:
            leaves = self._verify_and_load(s)
            if leaves is None:
                continue
            flat = jax.tree_util.tree_flatten_with_path(target)
            paths, treedef = flat[0], flat[1]
            shard_leaves = (jax.tree.leaves(shardings,
                                            is_leaf=lambda x: x is None)
                            if shardings is not None else [None] * len(paths))
            out = []
            for (path, leaf), shd in zip(paths, shard_leaves):
                key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                               for p in path)
                if key not in leaves:
                    raise KeyError(f"checkpoint step {s} missing leaf {key}")
                arr = leaves[key].astype(np.dtype(leaf.dtype))
                if shd is not None:
                    out.append(jax.device_put(arr, shd))
                else:
                    out.append(jax.numpy.asarray(arr))
            return s, jax.tree_util.tree_unflatten(treedef, out)
        raise FileNotFoundError(
            f"no valid checkpoint found in {self.dir} (tried {candidates})")

    def restore_plan(self, step: Optional[int] = None, chain: Any = None):
        """The :class:`~repro.plan.MemoryPlan` embedded at ``step`` (default:
        newest step that has one), or ``None`` if no retained checkpoint
        carries a plan.  The plan is statically re-verified on load and,
        with ``chain`` given, validated against the chain's content hash —
        so a resumed run cannot silently train under a stale or corrupted
        schedule."""
        from ..plan.plan import MemoryPlan

        candidates = ([step] if step is not None
                      else list(reversed(self.all_steps())))
        for s in candidates:
            path = os.path.join(self.dir, f"step_{s:08d}", _PLAN_FILE)
            if os.path.exists(path):
                return MemoryPlan.load(path, chain)
        return None


def restore_to_sharding(manager: CheckpointManager, target: Any,
                        shardings: Any, step: Optional[int] = None):
    return manager.restore(target, step=step, shardings=shardings)
