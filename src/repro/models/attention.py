"""Attention variants: GQA/MQA (+RoPE, optional QKV bias), DeepSeek-style MLA,
prefix-LM masking, and KV-cache decode paths for all of them.

Masking is *spec-driven* (causal / prefix / sliding-window / valid-length) —
the (S×S) mask tensor is never materialized; block masks are built from iotas
inside each q-block.  For sequences beyond ``direct_attend_max`` the scores
are computed in a q-block ``lax.scan`` whose body is ``jax.checkpoint``-ed, so
peak memory is O(block × S) and the backward rematerializes per block (the
same trade the Pallas flash kernel makes on real TPU; this path is what the
dry-run lowers since Pallas cannot target the CPU backend)."""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .common import apply_rope, dense_apply, dense_init

Params = Dict[str, Any]

NEG = -1e30
# direct (single-einsum) path below this q·kv size product, chunked above
DIRECT_ATTEND_MAX = 2048


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    causal: bool = True
    prefix_len: int = 0                  # first N kv positions bidirectional
    window: Optional[int] = None         # sliding window width
    kv_len: Optional[int] = None         # true kv length (padding cutoff)

    def block(self, q_pos: jax.Array, k_pos: jax.Array) -> jax.Array:
        """Boolean mask for broadcastable position index arrays."""
        m = jnp.ones(jnp.broadcast_shapes(q_pos.shape, k_pos.shape), bool)
        if self.causal:
            c = k_pos <= q_pos
            if self.prefix_len:
                c = c | (k_pos < self.prefix_len)
            m = m & c
        if self.window:
            m = m & (k_pos > q_pos - self.window)
        if self.kv_len is not None:
            m = m & (k_pos < self.kv_len)
        return m


def _block_scores_gqa(qblk, k, v, q0, spec: MaskSpec):
    """qblk: (B,bq,H,D); k/v: (B,S,K,D). Returns (B,bq,H,Dv)."""
    B, bq, H, D = qblk.shape
    S, K = k.shape[1], k.shape[2]
    g = H // K
    qg = qblk.reshape(B, bq, K, g, D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) / math.sqrt(D)
    q_pos = q0 + jnp.arange(bq)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = spec.block(q_pos, k_pos)                      # (bq, S)
    logits = jnp.where(mask[None, None, None], logits, NEG)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, bq, H, -1)


def _attend(q, k, v, spec: MaskSpec, q_offset: int = 0,
            block_q: int = 512, use_flash: bool = False) -> jax.Array:
    """q: (B,Sq,H,D); k/v: (B,Skv,K,D) grouped. Spec-masked attention."""
    B, Sq, H, D = q.shape
    if use_flash and spec.causal and not spec.prefix_len and not spec.window:
        from ..kernels.flash_attention import ops as flash_ops
        return flash_ops.flash_attention(q, k, v, causal=True)
    if Sq <= DIRECT_ATTEND_MAX:
        return _block_scores_gqa(q, k, v, q_offset, spec)
    block_q = min(block_q, Sq)
    pad = (-Sq) % block_q
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = q.shape[1] // block_q
    qb = q.reshape(B, nb, block_q, H, D).transpose(1, 0, 2, 3, 4)

    def body(_, inp):
        qblk, i = inp
        out = _block_scores_gqa(qblk, k, v, q_offset + i * block_q, spec)
        return None, out

    _, outs = jax.lax.scan(jax.checkpoint(body), None,
                           (qb, jnp.arange(nb) ))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nb * block_q, H, -1)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# GQA / MQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype) -> Params:
    d, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, (H, Dh), dtype, use_bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, (K, Dh), dtype, use_bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, (K, Dh), dtype, use_bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], H * Dh, d, dtype,
                         scale=1.0 / math.sqrt(H * Dh * max(cfg.num_layers, 1))),
    }


def gqa_param_axes(cfg) -> Params:
    qb = {"bias": ("heads", None)} if cfg.qkv_bias else {}
    kb = {"bias": ("kv", None)} if cfg.qkv_bias else {}
    return {
        "wq": {"kernel": ("embed", "heads", None), **qb},
        "wk": {"kernel": ("embed", "kv", None), **kb},
        "wv": {"kernel": ("embed", "kv", None), **kb},
        "wo": {"kernel": ("heads_merged", "embed")},
    }


def _gqa_qkv(p, cfg, x, positions):
    q = dense_apply(p["wq"], x)            # (B,S,H,Dh)
    k = dense_apply(p["wk"], x)            # (B,S,K,Dh)
    v = dense_apply(p["wv"], x)
    q = constrain(q, "act_batch", "act_seq", "act_heads", None)
    k = constrain(k, "act_batch", "act_seq", "act_kv", None)
    v = constrain(v, "act_batch", "act_seq", "act_kv", None)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.query_scale is not None:
        q = q * cfg.query_scale
    return q, k, v


def gqa_apply(p: Params, cfg, x: jax.Array, positions: jax.Array,
              spec: MaskSpec) -> jax.Array:
    B, S, d = x.shape
    q, k, v = _gqa_qkv(p, cfg, x, positions)
    out = _attend(q, k, v, spec, block_q=cfg.attn_block_q,
                  use_flash=cfg.use_flash_attention
                  and spec.causal and not spec.prefix_len and not spec.window)
    y = dense_apply(p["wo"], out.reshape(B, S, -1))
    return constrain(y, "act_batch", "act_seq", "act_embed")


def gqa_prefill(p: Params, cfg, x: jax.Array, positions: jax.Array,
                spec: MaskSpec) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, _ = x.shape
    q, k, v = _gqa_qkv(p, cfg, x, positions)
    out = _attend(q, k, v, spec, block_q=cfg.attn_block_q)
    y = dense_apply(p["wo"], out.reshape(B, S, -1))
    return y, {"k": k, "v": v}


def gqa_decode(p: Params, cfg, x: jax.Array, cache: Dict[str, jax.Array],
               pos: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. x: (B,1,d); cache k/v: (B,S_max,K,Dh); pos scalar."""
    B = x.shape[0]
    S_max = cache["k"].shape[1]
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    q, k_new, v_new = _gqa_qkv(p, cfg, x, positions)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
    k = constrain(k, "act_batch", "act_kv_seq", "act_kv", None)
    v = constrain(v, "act_batch", "act_kv_seq", "act_kv", None)
    spec = MaskSpec(causal=False, window=cfg.sliding_window,
                    kv_len=None)
    # decode mask: attend to positions <= pos (and window if configured)
    K = k.shape[2]
    H, D = q.shape[2], q.shape[3]
    g = H // K
    qg = q.reshape(B, 1, K, g, D)
    kc = k.astype(q.dtype)  # cache may store fp8; compute in model dtype
    vc = v.astype(q.dtype)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc,
                        preferred_element_type=jnp.float32) / math.sqrt(D)
    k_pos = jnp.arange(S_max)
    m = k_pos <= pos
    if cfg.sliding_window:
        m = m & (k_pos > pos - cfg.sliding_window)
    logits = jnp.where(m[None, None, None, None, :], logits, NEG)
    probs = jax.nn.softmax(logits, axis=-1).astype(vc.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, vc).reshape(B, 1, -1)
    y = dense_apply(p["wo"], out)
    y = constrain(y, "act_batch", None, "act_embed")
    return y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 Multi-head Latent Attention)
# ---------------------------------------------------------------------------
# Faithful structure for the -Lite variant: no query compression; KV
# compressed to a rank-`kv_lora` latent + a shared rotary key.  The decode
# cache stores only (c_kv, k_rope): 512+64 per token vs 2·H·Dh = 4096 —
# the paper-relevant point: ω_ā of MLA stages differs wildly from GQA.

def mla_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, (H, dn + dr), dtype),
        "wkv_a": dense_init(ks[1], d, r + dr, dtype),   # latent + shared k_rope
        "kv_norm": {"scale": jnp.ones((r,), dtype)},
        "wk_b": dense_init(ks[2], r, (H, dn), dtype),
        "wv_b": dense_init(ks[3], r, (H, dv), dtype),
        "wo": dense_init(ks[4], H * dv, d, dtype,
                         scale=1.0 / math.sqrt(H * dv * max(cfg.num_layers, 1))),
    }


def mla_param_axes(cfg) -> Params:
    return {
        "wq": {"kernel": ("embed", "heads", None)},
        "wkv_a": {"kernel": ("embed", "kv_lora")},
        "kv_norm": {"scale": (None,)},
        "wk_b": {"kernel": ("kv_lora", "heads", None)},
        "wv_b": {"kernel": ("kv_lora", "heads", None)},
        "wo": {"kernel": ("heads_merged", "embed")},
    }


def _mla_qkv(p: Params, cfg, x: jax.Array, positions: jax.Array):
    from .common import rms_norm
    dn = cfg.qk_nope_head_dim
    q = dense_apply(p["wq"], x)                              # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = dense_apply(p["wkv_a"], x)                          # (B,S,r+dr)
    c_kv, k_rope = kv[..., :cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    c_kv = rms_norm(p["kv_norm"], c_kv)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope  # k_rope: (B,S,1,dr)


def _mla_block(p, cfg, qn_blk, qr_blk, c_kv, k_rope, q0, spec: MaskSpec):
    """Latent-space attention for one q block (absorbed-W_kb trick)."""
    B, bq = qn_blk.shape[:2]
    S = c_kv.shape[1]
    q_lat = jnp.einsum("bqhd,rhd->bqhr", qn_blk,
                       p["wk_b"]["kernel"].astype(qn_blk.dtype))
    logits = (jnp.einsum("bqhr,bsr->bhqs", q_lat, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bsod->bhqs", qr_blk, k_rope,
                           preferred_element_type=jnp.float32))
    logits = logits / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    q_pos = q0 + jnp.arange(bq)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = spec.block(q_pos, k_pos)
    logits = jnp.where(mask[None, None], logits, NEG)
    probs = jax.nn.softmax(logits, axis=-1).astype(c_kv.dtype)
    ctx = jnp.einsum("bhqs,bsr->bqhr", probs, c_kv)          # latent context
    return jnp.einsum("bqhr,rhd->bqhd", ctx,
                      p["wv_b"]["kernel"].astype(ctx.dtype))


def _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope, spec: MaskSpec,
                block_q: int = 512):
    B, Sq = q_nope.shape[:2]
    if Sq <= DIRECT_ATTEND_MAX:
        out = _mla_block(p, cfg, q_nope, q_rope, c_kv, k_rope, 0, spec)
        return dense_apply(p["wo"], out.reshape(B, Sq, -1))
    block_q = min(block_q, Sq)
    pad = (-Sq) % block_q
    if pad:
        q_nope = jnp.pad(q_nope, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_rope = jnp.pad(q_rope, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = q_nope.shape[1] // block_q

    def split(t):
        return t.reshape(B, nb, block_q, *t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1))

    def body(_, inp):
        qn, qr, i = inp
        return None, _mla_block(p, cfg, qn, qr, c_kv, k_rope, i * block_q,
                                spec)

    _, outs = jax.lax.scan(jax.checkpoint(body), None,
                           (split(q_nope), split(q_rope), jnp.arange(nb)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nb * block_q, -1)
    return dense_apply(p["wo"], out[:, :Sq])


def mla_apply(p: Params, cfg, x: jax.Array, positions: jax.Array,
              spec: MaskSpec) -> jax.Array:
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    c_kv = constrain(c_kv, "act_batch", "act_seq", None)
    y = _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope, spec,
                    block_q=cfg.attn_block_q)
    return constrain(y, "act_batch", "act_seq", "act_embed")


def mla_prefill(p, cfg, x, positions, spec: MaskSpec):
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    y = _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope, spec)
    return y, {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(p, cfg, x, cache, pos):
    B = x.shape[0]
    S_max = cache["c_kv"].shape[1]
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    q_nope, q_rope, c_new, kr_new = _mla_qkv(p, cfg, x, positions)
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos, axis=1)
    c_kv = constrain(c_kv, "act_batch", "act_kv_seq", None)
    ckc = c_kv.astype(x.dtype)   # cache may store fp8
    krc = k_rope.astype(x.dtype)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope,
                       p["wk_b"]["kernel"].astype(q_nope.dtype))
    logits = (jnp.einsum("bqhr,bsr->bhqs", q_lat, ckc,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bsod->bhqs", q_rope, krc,
                           preferred_element_type=jnp.float32))
    logits = logits / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    m = jnp.arange(S_max) <= pos
    logits = jnp.where(m[None, None, None, :], logits, NEG)
    probs = jax.nn.softmax(logits, axis=-1).astype(ckc.dtype)
    ctx = jnp.einsum("bhqs,bsr->bqhr", probs, ckc)
    out = jnp.einsum("bqhr,rhd->bqhd", ctx,
                     p["wv_b"]["kernel"].astype(ctx.dtype))
    y = dense_apply(p["wo"], out.reshape(B, 1, -1))
    y = constrain(y, "act_batch", None, "act_embed")
    return y, {"c_kv": c_kv, "k_rope": k_rope}
