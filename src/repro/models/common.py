"""Shared layer primitives (pure-JAX, functional, init/apply pairs)."""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


Params = Dict[str, Any]


def truncated_normal_init(key, shape, dtype, scale: float):
    # 2-sigma truncated normal, fan-in scaled
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32
                                               ).astype(dtype)


def dense_init(key, in_dim: int, out_dims, dtype, use_bias: bool = False,
               scale: Optional[float] = None) -> Params:
    out_dims = (out_dims,) if isinstance(out_dims, int) else tuple(out_dims)
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    p: Params = {"kernel": truncated_normal_init(key, (in_dim,) + out_dims,
                                                 dtype, scale)}
    if use_bias:
        p["bias"] = jnp.zeros(out_dims, dtype)
    return p


def dense_apply(p: Params, x: jax.Array) -> jax.Array:
    """x: (..., in_dim) @ kernel: (in_dim, *out_dims) -> (..., *out_dims)."""
    k = p["kernel"]
    y = jax.lax.dot_general(
        x, k.astype(x.dtype),
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())))
    if "bias" in p:
        y = y + p["bias"].astype(y.dtype)
    return y


def rms_norm_init(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# -- rotary position embeddings ---------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
               ) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, dim: int, offset: int = 0) -> jax.Array:
    """MusicGen-style sinusoidal embeddings, (seq_len, dim), float32."""
    pos = jnp.arange(offset, offset + seq_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / dim))
    emb = jnp.zeros((seq_len, dim), jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(pos * div))
    emb = emb.at[:, 1::2].set(jnp.cos(pos * div))
    return emb


# -- losses -------------------------------------------------------------------

def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None,
                          z_loss: float = 0.0) -> jax.Array:
    """Token-mean xent; logits (B,S,V) any float dtype, labels (B,S) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - gold
    if z_loss:
        loss = loss + z_loss * lse ** 2
    if mask is not None:
        loss = loss * mask
        return loss.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss.mean()
