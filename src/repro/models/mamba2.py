"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in TPU-friendly
chunked form, plus the O(1)-per-token recurrent decode path.

The chunked SSD algorithm is the paper's "block decomposition": within-chunk
terms are dense matmuls (MXU-friendly — this is the TPU adaptation of the
CUDA kernel), across-chunk state is a short sequential scan over S/Q chunks.
A Pallas kernel for the within-chunk part lives in ``repro.kernels.ssd``; the
pure-jnp path below is its oracle and the default on CPU.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .common import dense_apply, dense_init, rms_norm

Params = Dict[str, Any]


def mamba2_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    H = d_inner // cfg.ssm_head_dim          # ssm heads
    G, N = cfg.ssm_groups, cfg.ssm_state
    conv_dim = d_inner + 2 * G * N
    ks = jax.random.split(key, 5)
    d_proj = 2 * d_inner + 2 * G * N + H     # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], d, d_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32)
                   * (1.0 / math.sqrt(cfg.ssm_conv))).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))),
        "norm": {"scale": jnp.ones((d_inner,), dtype)},
        "out_proj": dense_init(ks[3], d_inner, d, dtype,
                               scale=1.0 / math.sqrt(d_inner * max(cfg.num_layers, 1))),
    }


def mamba2_param_axes(cfg) -> Params:
    return {
        "in_proj": {"kernel": ("embed", "mlp")},
        "conv_w": ("conv", None),
        "conv_b": (None,),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm": {"scale": ("mlp",)},
        "out_proj": {"kernel": ("mlp", "embed")},
    }


def _split_proj(cfg, proj: jax.Array):
    d_inner = cfg.ssm_expand * cfg.d_model
    G, N = cfg.ssm_groups, cfg.ssm_state
    H = d_inner // cfg.ssm_head_dim
    z, xBC, dt = jnp.split(proj, [d_inner, d_inner + d_inner + 2 * G * N], axis=-1)
    return z, xBC, dt  # dt: (..., H)


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. xBC: (B,S,C), w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i][None, None, :].astype(xBC.dtype)
              for i in range(K))
    return jax.nn.silu(out + b.astype(xBC.dtype))


def _segsum(x: jax.Array) -> jax.Array:
    """segsum(x)[..., i, j] = sum_{j < k <= i} x[..., k] (−inf for j > i)."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                init_state: jax.Array | None = None,
                use_kernel: bool = False) -> Tuple[jax.Array, jax.Array]:
    """SSD scan.  x: (B,S,H,P), dt: (B,S,H) (post-softplus), A: (H,) (<0),
    Bm/Cm: (B,S,G,N).  Returns (y: (B,S,H,P), final_state: (B,H,P,N))."""
    if use_kernel:
        from ..kernels.ssd import ops as ssd_ops
        return ssd_ops.ssd_chunked(x, dt, A, Bm, Cm, chunk, init_state)
    B_, S, H, P = x.shape
    if S % chunk:  # pad time so chunks divide evenly (dt=0 is a no-op step)
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, st = ssd_chunked(x, dt, A, Bm, Cm, chunk, init_state)
        return y[:, :S], st
    G, N = Bm.shape[2], Bm.shape[3]
    nc = S // chunk
    rep = H // G

    xr = x.reshape(B_, nc, chunk, H, P)
    dtr = dt.reshape(B_, nc, chunk, H)
    Br = jnp.repeat(Bm.reshape(B_, nc, chunk, G, N), rep, axis=3)  # (B,nc,Q,H,N)
    Cr = jnp.repeat(Cm.reshape(B_, nc, chunk, G, N), rep, axis=3)

    dA = dtr * A[None, None, None, :]                    # (B,nc,Q,H) (negative)
    dA_cs = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum
    # 1) within-chunk (diagonal blocks): dense matmuls
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))       # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cr, Br,
                        preferred_element_type=jnp.float32)
    y_diag = jnp.einsum("bchqk,bchqk,bckh,bckhp->bcqhp",
                        scores, L.astype(jnp.float32),
                        dtr.astype(jnp.float32),
                        xr.astype(jnp.float32))
    # 2) chunk states: contribution of each chunk to its final state
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (B,nc,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqh,bcqhp->bchpn",
                        Br.astype(jnp.float32), decay_states.astype(jnp.float32),
                        dtr.astype(jnp.float32), xr.astype(jnp.float32))
    # 3) inter-chunk recurrence over nc chunks (sequential scan)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])            # (B,nc,H)
    if init_state is None:
        init_state = jnp.zeros((B_, H, P, N), jnp.float32)

    def step(carry, inp):
        st_in, dec, pos = carry, inp[0], inp[1]
        new = st_in * dec[:, :, None, None] + pos
        return new, st_in                                # emit state *entering* chunk

    final_state, prev_states = jax.lax.scan(
        step, init_state.astype(jnp.float32),
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # (B,nc,H,P,N)
    # 4) contribution of the incoming state to each position
    state_decay = jnp.exp(dA_cs)                          # (B,nc,Q,H)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                       Cr.astype(jnp.float32), prev_states,
                       state_decay.astype(jnp.float32))
    y = (y_diag + y_off).reshape(B_, S, H, P)
    return y.astype(x.dtype), final_state


def mamba2_apply(p: Params, cfg, x: jax.Array) -> jax.Array:
    """Full-sequence forward (training / prefill)."""
    B, S, d = x.shape
    d_inner = cfg.ssm_expand * d
    G, N = cfg.ssm_groups, cfg.ssm_state
    H = d_inner // cfg.ssm_head_dim
    proj = dense_apply(p["in_proj"], x)
    z, xBC, dt = _split_proj(cfg, proj)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + G * N], axis=-1)
    xs = constrain(xs.reshape(B, S, H, cfg.ssm_head_dim),
                   "act_batch", "act_seq", "act_ssm_heads", None)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk,
                       use_kernel=cfg.use_ssd_kernel)
    y = y + xs * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, d_inner)
    y = rms_norm(p["norm"], y * jax.nn.silu(z))
    out = dense_apply(p["out_proj"], y)
    return constrain(out, "act_batch", "act_seq", "act_embed")


def mamba2_prefill(p: Params, cfg, x: jax.Array
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence forward that also returns the recurrent decode cache."""
    B, S, d = x.shape
    d_inner = cfg.ssm_expand * d
    G, N = cfg.ssm_groups, cfg.ssm_state
    H = d_inner // cfg.ssm_head_dim
    K = cfg.ssm_conv
    proj = dense_apply(p["in_proj"], x)
    z, xBC_raw, dt = _split_proj(cfg, proj)
    # conv cache = last K-1 *raw* xBC inputs
    pad_raw = jnp.pad(xBC_raw, ((0, 0), (max(K - 1 - S, 0), 0), (0, 0)))
    conv_cache = pad_raw[:, -(K - 1):, :]
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + G * N], axis=-1)
    xs = xs.reshape(B, S, H, cfg.ssm_head_dim)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    y, final_state = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk,
                                 use_kernel=cfg.use_ssd_kernel)
    y = y + xs * p["D"][None, None, :, None].astype(y.dtype)
    y = rms_norm(p["norm"], y.reshape(B, S, d_inner) * jax.nn.silu(z))
    out = dense_apply(p["out_proj"], y)
    return out, {"conv": conv_cache, "ssm": final_state}


# -- decode -------------------------------------------------------------------

def mamba2_init_cache(cfg, batch: int, dtype) -> Dict[str, jax.Array]:
    d_inner = cfg.ssm_expand * cfg.d_model
    G, N = cfg.ssm_groups, cfg.ssm_state
    H = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * G * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, H, cfg.ssm_head_dim, N), jnp.float32),
    }


def mamba2_decode(p: Params, cfg, x: jax.Array, cache: Dict[str, jax.Array]
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token recurrent step. x: (B,1,d)."""
    B, _, d = x.shape
    d_inner = cfg.ssm_expand * d
    G, N = cfg.ssm_groups, cfg.ssm_state
    H = d_inner // cfg.ssm_head_dim
    proj = dense_apply(p["in_proj"], x)[:, 0]            # (B, d_proj)
    z, xBC, dt = _split_proj(cfg, proj)
    # conv over the window [cache, new]
    win = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)  # (B,K,C)
    w = p["conv_w"].astype(xBC.dtype)
    xBC = jax.nn.silu(jnp.einsum("bkc,kc->bc", win, w) + p["conv_b"].astype(xBC.dtype))
    new_conv = win[:, 1:]
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + G * N], axis=-1)
    xs = xs.reshape(B, H, cfg.ssm_head_dim)
    Bm = jnp.repeat(Bm.reshape(B, G, N), H // G, axis=1)  # (B,H,N)
    Cm = jnp.repeat(Cm.reshape(B, G, N), H // G, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A[None])                         # (B,H)
    st = cache["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xs.astype(jnp.float32), Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", st, Cm.astype(jnp.float32)).astype(x.dtype)
    y = y + xs * p["D"][None, :, None].astype(y.dtype)
    y = y.reshape(B, d_inner)
    y = rms_norm(p["norm"], y * jax.nn.silu(z))
    out = dense_apply(p["out_proj"], y)[:, None, :]       # (B,1,d)
    return constrain(out, "act_batch", None, "act_embed"), \
        {"conv": new_conv, "ssm": st}
