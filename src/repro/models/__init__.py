from .lm import ModelConfig, StagedLM
from .common import softmax_cross_entropy
