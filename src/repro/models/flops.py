"""Analytic per-stage FLOP counts — feeds the rotor planner (``u_f``/``u_b``)
without per-stage XLA compiles, and the §Roofline MODEL_FLOPS column.

Counting convention: multiply-add = 2 FLOPs; attention scores/values counted
at full (non-causal) cost, matching what XLA's ``cost_analysis`` reports for
the masked implementation.  Backward ≈ 2× forward (two matmul transposes per
forward matmul), loss stage ≈ fwd for the lse + 1× for the grad pass.
"""

from __future__ import annotations

from typing import List, Tuple


def _attn_flops(cfg, B: int, S: int, kv_len: int | None = None) -> float:
    kv = kv_len if kv_len is not None else S
    if cfg.attention_kind == "mla":
        d, H = cfg.d_model, cfg.n_heads
        dn, dr, dv, r = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                         cfg.v_head_dim, cfg.kv_lora_rank)
        proj = 2 * B * S * d * (H * (dn + dr) + r + dr + H * dv)
        absorb = 2 * B * S * H * dn * r + 2 * B * S * H * r * dv
        attn = 2 * B * S * kv * H * (r + dr) + 2 * B * S * kv * H * r
        return proj + absorb + attn
    d, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2 * B * S * d * (H * Dh + 2 * K * Dh) + 2 * B * S * H * Dh * d
    attn = 2 * B * S * kv * H * Dh * 2
    return proj + attn


def _mlp_flops(cfg, B: int, S: int, d_ff: int) -> float:
    mult = 3 if cfg.mlp_kind in ("swiglu", "geglu") else 2
    return 2 * B * S * cfg.d_model * d_ff * mult


def _moe_flops(cfg, B: int, S: int) -> float:
    T = B * S
    router = 2 * T * cfg.d_model * cfg.num_experts
    routed = 2 * (T * cfg.moe_top_k * cfg.moe_capacity_factor) * 3 \
        * cfg.d_model * cfg.moe_d_ff
    shared = 2 * T * 3 * cfg.d_model * (cfg.moe_d_ff * cfg.num_shared_experts)
    return router + routed + shared


def _mamba_flops(cfg, B: int, S: int) -> float:
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    G, N = cfg.ssm_groups, cfg.ssm_state
    H = d_inner // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    Q = cfg.ssm_chunk
    proj = 2 * B * S * d * (2 * d_inner + 2 * G * N + H) + 2 * B * S * d_inner * d
    conv = 2 * B * S * (d_inner + 2 * G * N) * cfg.ssm_conv
    # SSD: scores (Q×N)@(N×Q), y (Q×Q)@(Q×P), states (P×Q)@(Q×N), y_off (Q×N)@(N×P)
    nc = max(S // Q, 1)
    ssd = B * H * nc * (2 * Q * Q * N + 2 * Q * Q * P + 2 * Q * P * N * 2)
    return proj + conv + ssd


def _layer_flops(cfg, kind: str, B: int, S: int, kv_len=None) -> float:
    if kind == "dense":
        return _attn_flops(cfg, B, S, kv_len) + _mlp_flops(cfg, B, S, cfg.d_ff)
    if kind == "moe":
        return _attn_flops(cfg, B, S, kv_len) + _moe_flops(cfg, B, S)
    return _mamba_flops(cfg, B, S)


def per_layer_flops(cfg, B: int, S: int, kv_len: int | None = None
                    ) -> List[float]:
    """Forward FLOPs per *model layer* (length ``cfg.num_layers``).

    The Zamba2 shared-attention block is attributed to the period-start
    layers that invoke it.  ``kv_len`` prices attention against a KV prefix
    longer than ``S`` (the decode-step case: ``S=1``, ``kv_len=`` cache
    position) — this is what the KV-residency planner uses for per-layer
    ``u_f``/``u_b`` estimates (:mod:`repro.plan.serving`)."""
    out = [0.0] * cfg.num_layers
    for kind, start, length in cfg.chunks:
        per = _layer_flops(cfg, kind, B, S, kv_len)
        for j in range(start, start + length):
            out[j] += per
        if (cfg.hybrid_period and kind == "zamba"
                and start % cfg.hybrid_period == 0):
            out[start] += (_attn_flops(cfg, B, S, kv_len)
                           + _mlp_flops(cfg, B, S, cfg.d_ff))
    return out


def stage_flops(cfg, B: int, S: int) -> Tuple[List[float], List[float]]:
    """(fwd, bwd) FLOPs per rotor stage: [embed] + chunks + [head+loss]."""
    fwd: List[float] = [2 * B * S * cfg.d_model]  # lookup/scale — negligible
    for kind, start, length in cfg.chunks:
        f = length * _layer_flops(cfg, kind, B, S)
        if (cfg.hybrid_period and kind == "zamba"
                and start % cfg.hybrid_period == 0):
            f += _attn_flops(cfg, B, S) + _mlp_flops(cfg, B, S, cfg.d_ff)
        fwd.append(f)
    S_eff = S - cfg.prefix_len if cfg.modality == "vlm" else S
    fwd.append(2 * B * S_eff * cfg.d_model * cfg.vocab_size)
    # backward ≈ 2× fwd; +1× when inner per-layer remat replays the forward
    inner = 1.0 if cfg.scan_layer_remat in ("full", "save_moe") else 0.0
    bwd = [(2.0 + inner) * f for f in fwd[:-1]] + [2.0 * fwd[-1]]
    return fwd, bwd


def model_flops_per_step(cfg, B: int, S: int, train: bool = True) -> float:
    """MODEL_FLOPS = 6·N_active·D for §Roofline (2ND fwd + 4ND bwd)."""
    n = cfg.active_params()
    tokens = B * S
    return (6.0 if train else 2.0) * n * tokens
