"""Feed-forward variants: GELU MLP, SwiGLU, and capacity-based top-k MoE
(shared + routed experts, DeepSeek-V2/Moonlight style).

The MoE uses Mesh-TensorFlow-style dispatch/combine einsums so that under
GSPMD the expert dimension shards on the ``model`` axis and routing lowers to
all-to-alls — no per-token gather/scatter host logic.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..compat import shard_map_unchecked
from ..distributed.sharding import constrain
from .common import dense_apply, dense_init

Params = Dict[str, Any]


# -- dense MLPs ---------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype, kind: str = "swiglu",
             num_layers: int = 1) -> Params:
    ks = jax.random.split(key, 3)
    out_scale = 1.0 / math.sqrt(d_ff * max(num_layers, 1))
    if kind in ("swiglu", "geglu"):
        return {
            "wi_gate": dense_init(ks[0], d_model, d_ff, dtype),
            "wi_up": dense_init(ks[1], d_model, d_ff, dtype),
            "wo": dense_init(ks[2], d_ff, d_model, dtype, scale=out_scale),
        }
    return {  # plain gelu MLP (StarCoder2, MusicGen)
        "wi": dense_init(ks[0], d_model, d_ff, dtype),
        "wo": dense_init(ks[1], d_ff, d_model, dtype, scale=out_scale),
    }


def mlp_param_axes(kind: str = "swiglu") -> Params:
    if kind in ("swiglu", "geglu"):
        return {"wi_gate": {"kernel": ("embed", "mlp")},
                "wi_up": {"kernel": ("embed", "mlp")},
                "wo": {"kernel": ("mlp", "embed")}}
    return {"wi": {"kernel": ("embed", "mlp")},
            "wo": {"kernel": ("mlp", "embed")}}


def mlp_apply(p: Params, x: jax.Array, kind: str = "swiglu") -> jax.Array:
    if kind == "swiglu":
        h = jax.nn.silu(dense_apply(p["wi_gate"], x)) * dense_apply(p["wi_up"], x)
    elif kind == "geglu":
        h = jax.nn.gelu(dense_apply(p["wi_gate"], x), approximate=True) \
            * dense_apply(p["wi_up"], x)
    else:
        h = jax.nn.gelu(dense_apply(p["wi"], x), approximate=True)
    h = constrain(h, "act_batch", "act_seq", "act_mlp")
    y = dense_apply(p["wo"], h)
    return constrain(y, "act_batch", "act_seq", "act_embed")


# -- mixture of experts --------------------------------------------------------

def moe_init(key, cfg, dtype) -> Params:
    d, e_ff = cfg.d_model, cfg.moe_d_ff
    E = cfg.num_experts
    ks = jax.random.split(key, 5)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(e_ff * max(cfg.num_layers, 1))
    p: Params = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        # stacked experts: (E, d, e_ff) / (E, e_ff, d)
        "we_gate": {"kernel": _stack_init(ks[1], E, (d, e_ff), dtype, scale_in)},
        "we_up": {"kernel": _stack_init(ks[2], E, (d, e_ff), dtype, scale_in)},
        "we_down": {"kernel": _stack_init(ks[3], E, (e_ff, d), dtype, scale_out)},
    }
    if cfg.num_shared_experts:
        from .mlp import mlp_init as _mi
        p["shared"] = _mi(ks[4], d, e_ff * cfg.num_shared_experts, dtype,
                          "swiglu", cfg.num_layers)
    return p


def _stack_init(key, E, shape, dtype, scale):
    return scale * jax.random.truncated_normal(
        key, -2.0, 2.0, (E,) + shape, jnp.float32).astype(dtype)


def moe_param_axes(cfg) -> Params:
    # router replicated (tiny); expert stacks sharded on the expert (EP) axis
    # only — the shard_map EP path consumes them as local (E_loc, d, f) blocks
    p = {
        "router": {"kernel": (None, None)},
        "we_gate": {"kernel": ("experts", None, None)},
        "we_up": {"kernel": ("experts", None, None)},
        "we_down": {"kernel": ("experts", None, None)},
    }
    if cfg.num_shared_experts:
        from .mlp import mlp_param_axes
        p["shared"] = mlp_param_axes("swiglu")
    return p


def _route(p: Params, cfg, xt: jax.Array):
    """Top-k routing: returns (probs, gate_vals, expert_idx)."""
    E, k = cfg.num_experts, cfg.moe_top_k
    logits = dense_apply(p["router"], xt.astype(jnp.float32))   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (T, k)
    if cfg.moe_norm_topk:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
    return probs, gate_vals, expert_idx


def _local_dispatch(xt, eidx, E: int, cap: int):
    """Local (single-device) capacity dispatch: returns (buf (E,cap,d),
    slot (T·k,), keep (T·k,)).  Pure local scatter — used inside shard_map
    where the partitioner never sees it."""
    Tk = eidx.shape[0]
    onehot = jax.nn.one_hot(eidx, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos, eidx[:, None], axis=1)[:, 0]
    keep = pos < cap
    slot = jnp.where(keep, eidx * cap + pos, E * cap)
    return slot, keep


def moe_apply_ep(p: Params, cfg, x: jax.Array, mesh, dp_axes, ep_axis="model"
                 ) -> tuple:
    """Expert parallelism via shard_map: local capacity dispatch (plain XLA
    scatter on local rows — invisible to the partitioner), ``all_to_all``
    over the EP axis to exchange (device, expert) row blocks, local expert
    matmuls, reverse ``all_to_all``, local combine.  This is the paper's-era
    Switch/GShard schedule expressed with jax-native collectives — the GSPMD
    scatter formulation degenerates to all-gathering every update (measured
    88 s of collectives per step on deepseek-v2-lite, see EXPERIMENTS.md)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.moe_top_k
    M = mesh.shape[ep_axis]
    E_loc = E // M

    def local_fn(router, wg, wu, wd, x_loc):
        Bl, S_, d_ = x_loc.shape
        Tl = Bl * S_
        xt = x_loc.reshape(Tl, d_)
        probs, gate_vals, expert_idx = _route(
            {"router": {"kernel": router}}, cfg, xt)
        cap = max(4, int(math.ceil(Tl * k / E * cfg.moe_capacity_factor)))
        cap = -(-cap // 8) * 8
        eidx = expert_idx.reshape(Tl * k)
        slot, keep = _local_dispatch(xt, eidx, E, cap)
        token_idx = jnp.repeat(jnp.arange(Tl), k)
        buf = jnp.zeros((E * cap + 1, d_), x_loc.dtype)
        buf = buf.at[slot].set(xt[token_idx], mode="drop")
        # (E, cap, d) -> exchange expert blocks: each peer keeps E_loc experts
        send = buf[:E * cap].reshape(M, E_loc * cap, d_)
        recv = jax.lax.all_to_all(send, ep_axis, split_axis=0, concat_axis=0,
                                  tiled=False)
        # recv: (M, E_loc·cap, d) = rows from every source device
        xs = recv.reshape(M, E_loc, cap, d_).transpose(1, 0, 2, 3) \
            .reshape(E_loc, M * cap, d_)
        wg_, wu_, wd_ = (w.astype(x_loc.dtype) for w in (wg, wu, wd))
        h = jnp.einsum("ecd,edf->ecf", xs, wg_)
        u = jnp.einsum("ecd,edf->ecf", xs, wu_)
        ys = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, wd_)
        # reverse exchange: rows return to their source device
        back = ys.reshape(E_loc, M, cap, d_).transpose(1, 0, 2, 3) \
            .reshape(M, E_loc * cap, d_)
        ret = jax.lax.all_to_all(back, ep_axis, split_axis=0, concat_axis=0,
                                 tiled=False).reshape(E * cap, d_)
        picked = ret[jnp.minimum(slot, E * cap - 1)]
        picked = jnp.where(keep[:, None], picked, 0.0)
        y = (picked.reshape(Tl, k, d_)
             * gate_vals[..., None].astype(x_loc.dtype)).sum(axis=1)
        # load-balance aux (local estimate, mean over DP by symmetry)
        me = probs.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[eidx].add(1.0 / (Tl * k))
        aux = cfg.moe_aux_loss * E * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, dp_axes) if dp_axes else aux
        return y.reshape(Bl, S_, d_), aux

    P_ = jax.sharding.PartitionSpec
    fn = shard_map_unchecked(
        local_fn, mesh=mesh,
        in_specs=(P_(), P_(ep_axis), P_(ep_axis), P_(ep_axis),
                  P_(dp_axes if dp_axes else None)),
        out_specs=(P_(dp_axes if dp_axes else None), P_()))
    y, aux = fn(p["router"]["kernel"], p["we_gate"]["kernel"],
                p["we_up"]["kernel"], p["we_down"]["kernel"], x)
    # name the EP output so remat policies can pin it (save_moe: the backward
    # replay then skips the all-to-alls — collectives are the scarce resource)
    from jax.ad_checkpoint import checkpoint_name
    y = checkpoint_name(y, "moe_out")
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, "swiglu")
    return constrain(y, "act_batch", "act_seq", "act_embed"), aux


def moe_apply(p: Params, cfg, x: jax.Array) -> tuple:
    """Returns (y, aux_loss).  Dispatches to the shard_map EP path when a
    mesh with a divisible expert axis is active; otherwise runs the local
    scatter path (single device / smoke tests).

    Capacity-based top-k routing with scatter dispatch — O(T·k·d), vs the
    Mesh-TF einsum dispatch whose (T,E,C) one-hot costs O(T²·k·d) at
    training shapes."""
    from ..distributed.sharding import current_mesh, current_rules, shard_factor

    mesh = current_mesh()
    if mesh is not None and cfg.num_experts % mesh.shape.get("model", 1) == 0 \
            and mesh.shape.get("model", 1) > 1:
        rules = current_rules()
        dp_axes = tuple(a for a in rules.get("act_batch", ())
                        if a in mesh.shape and mesh.shape[a] > 1
                        and x.shape[0] % mesh.shape[a] == 0)
        return moe_apply_ep(p, cfg, x, mesh, dp_axes)

    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.moe_top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = dense_apply(p["router"], xt.astype(jnp.float32))   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (T, k)
    if cfg.moe_norm_topk:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

    dp = shard_factor("act_batch", shape=(B,)) or 1             # DP groups
    Tl = T // dp
    cap = max(4, int(math.ceil(Tl * k / E * cfg.moe_capacity_factor)))
    cap = -(-cap // 8) * 8  # lane-align the expert matmul rows

    eidx = expert_idx.reshape(T * k)
    # position of each (token, choice) in its (group, expert) queue
    onehot = jax.nn.one_hot(eidx, E, dtype=jnp.int32).reshape(dp, Tl * k, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot                   # per group
    pos = jnp.take_along_axis(
        pos.reshape(T * k, E), eidx[:, None], axis=1)[:, 0]     # (T·k,)
    keep = pos < cap
    slot = jnp.where(keep, eidx * cap + pos, E * cap)           # overflow bin
    group = jnp.arange(T * k) // (Tl * k)

    token_idx = jnp.repeat(jnp.arange(T), k)
    buf = jnp.zeros((dp, E * cap + 1, d), x.dtype)
    buf = buf.at[group, slot].set(xt[token_idx], mode="drop")
    xs = buf[:, :E * cap].reshape(dp, E, cap, d)
    xs = constrain(xs, "act_group", "act_experts", None, "act_embed")
    h = jnp.einsum("gecd,edf->gecf", xs,
                   p["we_gate"]["kernel"].astype(x.dtype))
    u = jnp.einsum("gecd,edf->gecf", xs,
                   p["we_up"]["kernel"].astype(x.dtype))
    h = jax.nn.silu(h) * u
    h = constrain(h, "act_group", "act_experts", None, "act_mlp_expert")
    ys = jnp.einsum("gecf,efd->gecd", h,
                    p["we_down"]["kernel"].astype(x.dtype))
    ys = constrain(ys, "act_group", "act_experts", None, "act_embed")

    rows = ys.reshape(dp, E * cap, d)
    picked = rows[group, jnp.minimum(slot, E * cap - 1)]        # (T·k, d)
    picked = jnp.where(keep[:, None], picked, 0.0)
    y = (picked.reshape(T, k, d)
         * gate_vals[..., None].astype(x.dtype)).sum(axis=1)    # (T, d)

    if "shared" in p:
        y = y + mlp_apply(p["shared"], xt, "swiglu")

    # load-balancing aux loss (Switch-style)
    me = probs.mean(axis=0)                                     # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[eidx].add(1.0 / (T * k))
    aux = cfg.moe_aux_loss * E * jnp.sum(me * ce)
    y = y.reshape(B, S, d)
    return constrain(y, "act_batch", "act_seq", "act_embed"), aux
