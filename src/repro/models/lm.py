"""Unified staged decoder-LM covering all assigned architecture families.

The model is organized as a **chain of stages** — [embed] + [layer-chunks] +
[head+loss] — which is exactly the structure the paper's checkpointing DP
consumes.  Each chunk is a ``lax.scan`` over its (stacked) layer parameters,
so compile size stays O(n_chunks) regardless of depth; rotor's remat tree is
applied *across* chunks (DESIGN.md §4).

Families are selected per-layer via ``layer_kinds``:
- ``dense``  — pre-norm attention (GQA/MQA/MLA per cfg) + MLP,
- ``moe``    — attention + shared/routed MoE,
- ``mamba``  — Mamba2 (SSD) mixer,
- ``zamba``  — Mamba2 layer; chunks aligned to ``hybrid_period`` also invoke
               the *shared* attention block (Zamba2) at chunk start.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from . import attention as attn
from . import mamba2 as m2
from . import mlp as mlp_mod
from .common import (dense_apply, dense_init, rms_norm, rms_norm_init,
                     sinusoidal_positions, softmax_cross_entropy,
                     truncated_normal_init)

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class CacheLayout:
    """Byte layout of a decode cache (see :meth:`StagedLM.cache_layout`).

    - ``block_bytes[j]`` — allocated bytes of model layer ``j``'s cache
      slice: its KV block padded to ``max_len`` (attention layers) or its
      recurrent state (SSM layers); the Zamba2 shared-attention KV is
      attributed evenly to the period-start layers that invoke it.
    - ``token_bytes`` — bytes logically appended per decoded token across
      all attention layers (the cache's logical growth rate).
    - ``static_bytes`` — position-independent bytes (SSM conv/ssm states,
      the ``pos`` scalar).
    - ``allocated_bytes`` — total preallocated bytes; equals
      ``static_bytes + token_bytes * max_len`` exactly.
    """

    block_bytes: Tuple[int, ...]
    token_bytes: int
    static_bytes: int
    allocated_bytes: int
    max_len: int

    def logical_bytes(self, pos: int) -> int:
        """Bytes logically resident with ``pos`` tokens in the cache."""
        return self.static_bytes + int(pos) * self.token_bytes


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    num_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: Optional[int] = None
    # attention
    attention_kind: str = "gqa"          # gqa | mla
    qkv_bias: bool = False
    use_rope: bool = True
    rope_theta: float = 10000.0
    query_scale: Optional[float] = None
    sliding_window: Optional[int] = None  # windowed attention (long-context)
    # mlp
    mlp_kind: str = "swiglu"             # swiglu | geglu | gelu
    # block pattern
    layer_kinds: Optional[Tuple[str, ...]] = None   # default: all "dense"
    # MoE
    num_experts: int = 0
    moe_top_k: int = 2
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    moe_aux_loss: float = 0.01
    moe_norm_topk: bool = True
    # MLA
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # SSM (Mamba2)
    ssm_expand: int = 2
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # hybrid (Zamba2)
    hybrid_period: int = 0               # shared attn block every N layers
    # modality
    modality: str = "text"               # text | audio_embed | vlm
    prefix_len: int = 0                  # VLM image-token prefix (bidirectional)
    embed_scale: bool = False            # Gemma: embeddings * sqrt(d)
    # numerics / execution
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    n_chunks: int = 8
    scan_layer_remat: str = "none"       # none | full  (inner per-layer remat)
    remat_policy: str = "none"           # none|full|periodic:K|rotor:B|revolve:B
    use_flash_attention: bool = False
    use_ssd_kernel: bool = False
    logits_chunk: int = 0                # token-chunked xent if > 0
    z_loss: float = 0.0
    attn_block_q: int = 512              # q-block size of chunked attention
    kv_cache_dtype: Any = None           # e.g. jnp.float8_e4m3fn (serving)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.layer_kinds is None:
            object.__setattr__(self, "layer_kinds",
                               ("dense",) * self.num_layers)
        assert len(self.layer_kinds) == self.num_layers

    @property
    def kind_runs(self) -> List[Tuple[str, int, int]]:
        """Contiguous (kind, start, length) runs of identical layer kinds."""
        runs = []
        start = 0
        for i in range(1, self.num_layers + 1):
            if i == self.num_layers or self.layer_kinds[i] != self.layer_kinds[start]:
                runs.append((self.layer_kinds[start], start, i - start))
                start = i
        return runs

    @property
    def layer_slices(self) -> List[Tuple[int, int]]:
        """Per global layer ``j``: ``(chunk index, offset)`` into the stacked
        per-chunk parameter / decode-cache pytrees."""
        out: List[Tuple[int, int]] = []
        for ci, (kind, start, length) in enumerate(self.chunks):
            out.extend((ci, off) for off in range(length))
        return out

    @property
    def chunks(self) -> List[Tuple[str, int, int]]:
        """(kind, start, length) chunks — the rotor chain's interior stages.

        Chunks never cross kind boundaries; for Zamba2 they align with
        ``hybrid_period`` so each chunk owns at most one shared-attn call."""
        runs = self.kind_runs
        total = self.num_layers
        out: List[Tuple[str, int, int]] = []
        budget = max(self.n_chunks, len(runs))
        for kind, start, length in runs:
            if kind == "zamba" and self.hybrid_period:
                per = self.hybrid_period
                n = max(1, length // per)
            else:
                n = max(1, round(budget * length / total))
            n = min(n, length)
            base, extra = divmod(length, n)
            pos = start
            for j in range(n):
                size = base + (1 if j < extra else 0)
                out.append((kind, pos, size))
                pos += size
        return out

    def active_params(self) -> int:
        """Approximate active (per-token) parameter count, for 6ND math."""
        return _param_count(self, active_only=True)

    def total_params(self) -> int:
        return _param_count(self, active_only=False)


def _attn_params(cfg) -> int:
    if cfg.attention_kind == "mla":
        d, H = cfg.d_model, cfg.n_heads
        qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        return (d * H * qd + d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
                + cfg.kv_lora_rank * H * (cfg.qk_nope_head_dim + cfg.v_head_dim)
                + H * cfg.v_head_dim * d)
    d, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return d * H * Dh + 2 * d * K * Dh + H * Dh * d


def _mlp_params(cfg, d_ff) -> int:
    mult = 3 if cfg.mlp_kind in ("swiglu", "geglu") else 2
    return mult * cfg.d_model * d_ff


def _mamba_params(cfg) -> int:
    d_inner = cfg.ssm_expand * cfg.d_model
    gn = cfg.ssm_groups * cfg.ssm_state
    d_proj = 2 * d_inner + 2 * gn + d_inner // cfg.ssm_head_dim
    return cfg.d_model * d_proj + d_inner * cfg.d_model

def _param_count(cfg, active_only: bool) -> int:
    total = 2 * cfg.vocab_size * cfg.d_model  # embed + head
    shared_attn = 0
    for kind in cfg.layer_kinds:
        if kind == "dense":
            total += _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff)
        elif kind == "moe":
            ek = cfg.moe_top_k if active_only else cfg.num_experts
            total += _attn_params(cfg)
            total += ek * 3 * cfg.d_model * cfg.moe_d_ff
            total += cfg.num_shared_experts * 3 * cfg.d_model * cfg.moe_d_ff
        elif kind in ("mamba", "zamba"):
            total += _mamba_params(cfg)
    if cfg.hybrid_period and "zamba" in cfg.layer_kinds:
        shared_attn = _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff)
        total += shared_attn  # shared params counted once ...
        if active_only:
            pass  # ... but applied every period; active == stored here
    return total


# ---------------------------------------------------------------------------
# per-layer blocks
# ---------------------------------------------------------------------------

def _block_init(key, cfg, kind: str) -> Params:
    dt = cfg.param_dtype
    ks = jax.random.split(key, 4)
    if kind == "dense":
        a_init = attn.mla_init if cfg.attention_kind == "mla" else attn.gqa_init
        return {"ln1": rms_norm_init(cfg.d_model, dt),
                "attn": a_init(ks[0], cfg, dt),
                "ln2": rms_norm_init(cfg.d_model, dt),
                "mlp": mlp_mod.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt,
                                        cfg.mlp_kind, cfg.num_layers)}
    if kind == "moe":
        a_init = attn.mla_init if cfg.attention_kind == "mla" else attn.gqa_init
        return {"ln1": rms_norm_init(cfg.d_model, dt),
                "attn": a_init(ks[0], cfg, dt),
                "ln2": rms_norm_init(cfg.d_model, dt),
                "moe": mlp_mod.moe_init(ks[1], cfg, dt)}
    if kind in ("mamba", "zamba"):
        return {"ln": rms_norm_init(cfg.d_model, dt),
                "mixer": m2.mamba2_init(ks[0], cfg, dt)}
    raise ValueError(kind)


def _block_axes(cfg, kind: str) -> Params:
    a_axes = (attn.mla_param_axes(cfg) if cfg.attention_kind == "mla"
              else attn.gqa_param_axes(cfg))
    if kind == "dense":
        return {"ln1": {"scale": (None,)}, "attn": a_axes,
                "ln2": {"scale": (None,)},
                "mlp": mlp_mod.mlp_param_axes(cfg.mlp_kind)}
    if kind == "moe":
        return {"ln1": {"scale": (None,)}, "attn": a_axes,
                "ln2": {"scale": (None,)},
                "moe": mlp_mod.moe_param_axes(cfg)}
    if kind in ("mamba", "zamba"):
        return {"ln": {"scale": (None,)}, "mixer": m2.mamba2_param_axes(cfg)}
    raise ValueError(kind)


def _positions(B: int, S: int, offset: int = 0) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S)) + offset


def _train_mask(cfg, S: int) -> attn.MaskSpec:
    return attn.MaskSpec(causal=True, prefix_len=cfg.prefix_len,
                         window=cfg.sliding_window)


def _apply_block(p: Params, h: jax.Array, cfg, kind: str, mask, positions
                 ) -> Tuple[jax.Array, jax.Array]:
    """Returns (h, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "moe"):
        a_apply = attn.mla_apply if cfg.attention_kind == "mla" else attn.gqa_apply
        h = h + a_apply(p["attn"], cfg, rms_norm(p["ln1"], h), positions, mask)
        if kind == "dense":
            h = h + mlp_mod.mlp_apply(p["mlp"], rms_norm(p["ln2"], h), cfg.mlp_kind)
        else:
            y, aux = mlp_mod.moe_apply(p["moe"], cfg, rms_norm(p["ln2"], h))
            h = h + y
    else:  # mamba / zamba
        h = h + m2.mamba2_apply(p["mixer"], cfg, rms_norm(p["ln"], h))
    return h, aux


def _shared_attn_block(p: Params, cfg, h, mask, positions) -> jax.Array:
    h = h + attn.gqa_apply(p["attn"], cfg, rms_norm(p["ln1"], h), positions, mask)
    h = h + mlp_mod.mlp_apply(p["mlp"], rms_norm(p["ln2"], h), cfg.mlp_kind)
    return h


# ---------------------------------------------------------------------------
# the staged model
# ---------------------------------------------------------------------------

class StagedLM:
    """init/apply bundle; stages line up with the rotor chain."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- parameters -------------------------------------------------------

    def init(self, key) -> Params:
        cfg = self.cfg
        dt = cfg.param_dtype
        keys = jax.random.split(key, len(cfg.chunks) + 4)
        params: Params = {}
        if cfg.modality in ("text", "vlm"):
            params["embed"] = {"table": truncated_normal_init(
                keys[0], (cfg.vocab_size, cfg.d_model), dt, 1.0)}
        else:
            params["embed"] = {}  # audio stub delivers embeddings directly
        chunks = []
        for i, (kind, start, length) in enumerate(cfg.chunks):
            lk = jax.random.split(keys[i + 1], length)
            stacked = jax.vmap(lambda k: _block_init(k, cfg, kind))(lk)
            chunks.append(stacked)
        params["chunks"] = chunks
        if cfg.hybrid_period and any(k == "zamba" for k in cfg.layer_kinds):
            sk = jax.random.split(keys[-3], 2)
            params["shared_attn"] = {
                "ln1": rms_norm_init(cfg.d_model, dt),
                "attn": attn.gqa_init(sk[0], cfg, dt),
                "ln2": rms_norm_init(cfg.d_model, dt),
                "mlp": mlp_mod.mlp_init(sk[1], cfg.d_model, cfg.d_ff, dt,
                                        cfg.mlp_kind, cfg.num_layers)}
        params["final_norm"] = rms_norm_init(cfg.d_model, dt)
        params["head"] = dense_init(keys[-1], cfg.d_model, cfg.vocab_size, dt)
        return params

    def param_axes(self) -> Params:
        cfg = self.cfg
        axes: Params = {}
        if cfg.modality in ("text", "vlm"):
            axes["embed"] = {"table": ("vocab", "embed")}
        else:
            axes["embed"] = {}
        chs = []
        for kind, start, length in cfg.chunks:
            block = _block_axes(cfg, kind)
            chs.append(jax.tree.map(lambda ax: ("stack",) + tuple(ax), block,
                                    is_leaf=lambda x: isinstance(x, tuple)))
        axes["chunks"] = chs
        if cfg.hybrid_period and any(k == "zamba" for k in cfg.layer_kinds):
            axes["shared_attn"] = {
                "ln1": {"scale": (None,)}, "attn": attn.gqa_param_axes(cfg),
                "ln2": {"scale": (None,)},
                "mlp": mlp_mod.mlp_param_axes(cfg.mlp_kind)}
        axes["final_norm"] = {"scale": (None,)}
        axes["head"] = {"kernel": ("embed", "vocab")}
        return axes

    # -- stage functions (the rotor chain) ---------------------------------

    def n_stages(self) -> int:
        return len(self.cfg.chunks) + 2

    def stage_params(self, params: Params) -> List[Any]:
        cfg = self.cfg
        shared = params.get("shared_attn")
        sp: List[Any] = [params["embed"]]
        for i, _ in enumerate(cfg.chunks):
            if shared is not None:
                sp.append({"chunk": params["chunks"][i], "shared": shared})
            else:
                sp.append({"chunk": params["chunks"][i]})
        sp.append({"final_norm": params["final_norm"], "head": params["head"]})
        return sp

    def combine_stage_grads(self, stage_grads: List[Any]) -> Params:
        """Inverse of stage_params: rebuild a params-shaped gradient tree
        (summing the shared-attn contributions across chunks)."""
        cfg = self.cfg
        out: Params = {"embed": stage_grads[0]}
        chunk_grads, shared_sum = [], None
        for g in stage_grads[1:-1]:
            chunk_grads.append(g["chunk"])
            if "shared" in g:
                shared_sum = g["shared"] if shared_sum is None else jax.tree.map(
                    jnp.add, shared_sum, g["shared"])
        out["chunks"] = chunk_grads
        if shared_sum is not None:
            out["shared_attn"] = shared_sum
        out["final_norm"] = stage_grads[-1]["final_norm"]
        out["head"] = stage_grads[-1]["head"]
        return out

    def _embed_stage(self, p: Params, batch: Dict[str, jax.Array]) -> Dict:
        cfg = self.cfg
        if cfg.modality == "text":
            h = p["table"][batch["tokens"]].astype(cfg.dtype)
        elif cfg.modality == "audio_embed":
            emb = batch["embeds"].astype(cfg.dtype)
            S = emb.shape[1]
            h = emb + sinusoidal_positions(S, cfg.d_model).astype(cfg.dtype)[None]
        else:  # vlm: [image prefix] + [text tokens]
            img = batch["image_embeds"].astype(cfg.dtype)
            txt = p["table"][batch["tokens"]].astype(cfg.dtype)
            h = jnp.concatenate([img, txt], axis=1)
        if cfg.embed_scale:
            h = h * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
        h = constrain(h, "act_batch", "act_seq", "act_embed")
        return {"h": h, "aux": jnp.zeros((), jnp.float32),
                "labels": batch["labels"], "mask": batch.get("loss_mask")}

    def _chunk_stage(self, chunk_idx: int, p: Params, a: Dict) -> Dict:
        cfg = self.cfg
        kind, start, length = cfg.chunks[chunk_idx]
        h, aux = a["h"], a["aux"]
        B, S = h.shape[:2]
        mask = _train_mask(cfg, S)
        positions = _positions(B, S)

        if ("shared" in p and cfg.hybrid_period
                and start % cfg.hybrid_period == 0):
            h = _shared_attn_block(p["shared"], cfg, h, mask, positions)

        fn = functools.partial(_apply_block, cfg=cfg, kind=kind,
                               mask=mask, positions=positions)
        if cfg.scan_layer_remat == "full":
            fn = jax.checkpoint(fn)
        elif cfg.scan_layer_remat == "save_moe":
            # per-layer remat that pins the EP output: the backward replays
            # local compute but never re-runs the MoE all-to-alls
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.save_only_these_names(
                    "moe_out"))

        def body(carry, lp):
            h, aux = carry
            h2, aux2 = fn(lp, h)
            return (h2, aux + aux2), None

        (h, aux), _ = jax.lax.scan(body, (h, aux), p["chunk"])
        h = constrain(h, "act_batch", "act_seq", "act_embed")
        return {"h": h, "aux": aux, "labels": a["labels"], "mask": a["mask"]}

    def _head_stage(self, p: Params, a: Dict) -> jax.Array:
        cfg = self.cfg
        h = rms_norm(p["final_norm"], a["h"])
        labels, mask = a["labels"], a["mask"]
        if cfg.modality == "vlm" and cfg.prefix_len:
            h = h[:, cfg.prefix_len:]
        if cfg.logits_chunk:
            from ..kernels.xent import ops as xent_ops
            loss = xent_ops.token_chunked_xent(h, p["head"]["kernel"], labels,
                                               mask, block=cfg.logits_chunk,
                                               z_loss=cfg.z_loss)
        else:
            logits = dense_apply(p["head"], h)
            logits = constrain(logits, "act_batch", "act_seq", "act_vocab")
            loss = softmax_cross_entropy(logits, labels, mask, cfg.z_loss)
        return loss + a["aux"]

    def stage_fns(self) -> List[Any]:
        fns: List[Any] = [lambda p, batch: self._embed_stage(p, batch)]
        for i in range(len(self.cfg.chunks)):
            fns.append(functools.partial(self._chunk_stage, i))
        fns.append(lambda p, a: self._head_stage(p, a))
        return fns

    # -- plain & rotor forward ---------------------------------------------

    def loss_fn(self, params: Params, batch: Dict, tree=None) -> jax.Array:
        """Full train loss; if ``tree`` (a rotor/remat schedule tree) is
        given, execute through the nested-checkpoint structure."""
        sp = self.stage_params(params)
        fns = self.stage_fns()
        if tree is None:
            a = batch
            for fn, p in zip(fns, sp):
                a = fn(p, a)
            return a
        from ..core.rematerialize import build_remat_fn
        f = build_remat_fn(tree, fns)
        return f(sp, batch)

    # -- logits forward (eval / serving prefill) ----------------------------

    def forward_logits(self, params: Params, batch: Dict) -> jax.Array:
        cfg = self.cfg
        a = self._embed_stage_nolabel(params["embed"], batch)
        sp = self.stage_params(params)
        for i in range(len(cfg.chunks)):
            a = self._chunk_stage(i, sp[i + 1], a)
        h = rms_norm(params["final_norm"], a["h"])
        return dense_apply(params["head"], h)

    def _embed_stage_nolabel(self, p, batch):
        b2 = dict(batch)
        B = (batch.get("tokens") if "tokens" in batch else batch["embeds"]).shape[0]
        b2.setdefault("labels", jnp.zeros((B, 1), jnp.int32))
        b2.setdefault("loss_mask", None)
        return self._embed_stage(p, b2)

    def prefill(self, params: Params, batch: Dict, max_len: Optional[int] = None
                ) -> Tuple[jax.Array, Dict]:
        """Process a full prompt; returns (last-position logits, decode cache)."""
        cfg = self.cfg
        a = self._embed_stage_nolabel(params["embed"], batch)
        h = a["h"]
        B, S = h.shape[:2]
        max_len = max_len or S
        mask = _train_mask(cfg, S)
        positions = _positions(B, S)

        def pad_kv(x):  # (B, S, ...) -> (B, max_len, ...), cache storage dtype
            x = x.astype(cache_dt)
            if max_len == S:
                return x
            pad = [(0, 0), (0, max_len - S)] + [(0, 0)] * (x.ndim - 2)
            return jnp.pad(x, pad)

        cache_dt = cfg.kv_cache_dtype or cfg.dtype
        cache: Dict = {"pos": jnp.asarray(S, jnp.int32), "chunks": []}
        shared_kvs = []
        for ci, (kind, start, length) in enumerate(cfg.chunks):
            pstack = params["chunks"][ci]
            if ("shared_attn" in params and cfg.hybrid_period
                    and kind == "zamba" and start % cfg.hybrid_period == 0):
                sp = params["shared_attn"]
                y, kv = attn.gqa_prefill(sp["attn"], cfg,
                                         rms_norm(sp["ln1"], h), positions, mask)
                h = h + y
                h = h + mlp_mod.mlp_apply(sp["mlp"], rms_norm(sp["ln2"], h),
                                          cfg.mlp_kind)
                shared_kvs.append(jax.tree.map(pad_kv, kv))

            def body(h, lp):
                if kind in ("dense", "moe"):
                    hn = rms_norm(lp["ln1"], h)
                    pf = attn.mla_prefill if cfg.attention_kind == "mla" else attn.gqa_prefill
                    y, kv = pf(lp["attn"], cfg, hn, positions, mask)
                    h = h + y
                    if kind == "dense":
                        h = h + mlp_mod.mlp_apply(lp["mlp"], rms_norm(lp["ln2"], h), cfg.mlp_kind)
                    else:
                        y2, _ = mlp_mod.moe_apply(lp["moe"], cfg, rms_norm(lp["ln2"], h))
                        h = h + y2
                    return h, jax.tree.map(pad_kv, kv)
                y, c = m2.mamba2_prefill(lp["mixer"], cfg, rms_norm(lp["ln"], h))
                return h + y, c

            h, cstack = jax.lax.scan(body, h, pstack)
            cache["chunks"].append(cstack)
        if shared_kvs:
            cache["shared"] = jax.tree.map(lambda *xs: jnp.stack(xs), *shared_kvs)
        h = rms_norm(params["final_norm"], h[:, -1:])
        logits = dense_apply(params["head"], h)
        return logits, cache

    # -- decode path --------------------------------------------------------

    def init_cache(self, batch: int, max_len: int) -> Dict:
        cfg = self.cfg
        cdt = cfg.kv_cache_dtype or cfg.dtype
        caches = []
        for kind, start, length in cfg.chunks:
            if kind in ("dense", "moe"):
                if cfg.attention_kind == "mla":
                    one = {"c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), cdt),
                           "k_rope": jnp.zeros((batch, max_len, 1, cfg.qk_rope_head_dim), cdt)}
                else:
                    one = {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), cdt),
                           "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), cdt)}
            else:
                one = m2.mamba2_init_cache(cfg, batch, cfg.dtype)
            caches.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (length,) + x.shape), one))
        out = {"chunks": caches, "pos": jnp.zeros((), jnp.int32)}
        if cfg.hybrid_period and any(k == "zamba" for k in cfg.layer_kinds):
            n_inv = sum(1 for kind, start, _ in cfg.chunks
                        if kind == "zamba" and start % cfg.hybrid_period == 0)
            out["shared"] = {
                "k": jnp.zeros((n_inv, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
                "v": jnp.zeros((n_inv, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cfg.dtype)}
        return out

    def cache_layout(self, batch: int, max_len: int) -> "CacheLayout":
        """Byte layout of the decode cache, sized by ``jax.eval_shape`` over
        :meth:`init_cache` at the configured ``kv_cache_dtype`` (nothing is
        allocated).  This is the measurement base for the serve loop's KV
        telemetry and the sizing base for the KV-residency planner
        (:mod:`repro.plan.serving`)."""
        cfg = self.cfg
        spec = jax.eval_shape(lambda: self.init_cache(batch, max_len))

        def nbytes(tree) -> int:
            return int(sum(math.prod(leaf.shape) * leaf.dtype.itemsize
                           for leaf in jax.tree.leaves(tree)))

        blocks = [0] * cfg.num_layers
        token_bytes = 0
        static_bytes = nbytes(spec["pos"])
        for ci, (kind, start, length) in enumerate(cfg.chunks):
            chunk_bytes = nbytes(spec["chunks"][ci])
            per_layer = chunk_bytes // length
            for j in range(start, start + length):
                blocks[j] += per_layer
            if kind in ("dense", "moe"):
                token_bytes += chunk_bytes // max_len
            else:
                static_bytes += chunk_bytes  # recurrent state: no seq axis
        if "shared" in spec:
            shared_bytes = nbytes(spec["shared"])
            starts = [start for kind, start, _ in cfg.chunks
                      if kind == "zamba" and start % cfg.hybrid_period == 0]
            for s in starts:
                blocks[s] += shared_bytes // len(starts)
            token_bytes += shared_bytes // max_len
        return CacheLayout(block_bytes=tuple(blocks),
                           token_bytes=token_bytes,
                           static_bytes=static_bytes,
                           allocated_bytes=nbytes(spec),
                           max_len=max_len)

    def cache_axes(self) -> Dict:
        """Logical sharding axes for the decode cache (mirrors init_cache)."""
        cfg = self.cfg
        caches = []
        for kind, start, length in cfg.chunks:
            if kind in ("dense", "moe"):
                if cfg.attention_kind == "mla":
                    one = {"c_kv": ("act_batch", "act_kv_seq", None),
                           "k_rope": ("act_batch", "act_kv_seq", None, None)}
                else:
                    one = {"k": ("act_batch", "act_kv_seq", "act_kv", None),
                           "v": ("act_batch", "act_kv_seq", "act_kv", None)}
            else:
                one = {"conv": ("act_batch", None, "act_mlp"),
                       "ssm": ("act_batch", "act_ssm_heads", None, None)}
            caches.append(jax.tree.map(lambda ax: ("stack",) + tuple(ax), one,
                                       is_leaf=lambda x: isinstance(x, tuple)))
        out = {"chunks": caches, "pos": ()}
        if cfg.hybrid_period and any(k == "zamba" for k in cfg.layer_kinds):
            out["shared"] = {
                "k": ("stack", "act_batch", "act_kv_seq", "act_kv", None),
                "v": ("stack", "act_batch", "act_kv_seq", "act_kv", None)}
        return out

    def decode_step(self, params: Params, cache: Dict, tokens: jax.Array
                    ) -> Tuple[jax.Array, Dict]:
        """One greedy decode step. tokens: (B, 1) int32 (or embeds (B,1,d) for
        audio).  Returns (logits (B, 1, V), new cache)."""
        cfg = self.cfg
        pos = cache["pos"]
        B = tokens.shape[0]
        if cfg.modality == "audio_embed":
            # caller passes an embedding frame; add the sinusoidal positional
            # code for the (dynamic) current position — matches prefill
            h = tokens.astype(cfg.dtype)
            div = jnp.exp(jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32)
                          * (-math.log(10000.0) / cfg.d_model))
            ang = pos.astype(jnp.float32) * div
            row = jnp.zeros((cfg.d_model,), jnp.float32)
            row = row.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))
            h = h + row.astype(cfg.dtype)[None, None, :]
        else:
            h = params["embed"]["table"][tokens].astype(cfg.dtype)
        if cfg.embed_scale:
            h = h * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
        h = constrain(h, "act_batch", None, "act_embed")
        new_cache: Dict = {"pos": pos + 1, "chunks": []}
        shared_i = 0
        for ci, (kind, start, length) in enumerate(cfg.chunks):
            pstack = params["chunks"][ci]
            cstack = cache["chunks"][ci]
            if ("shared_attn" in params and cfg.hybrid_period
                    and kind == "zamba" and start % cfg.hybrid_period == 0):
                sc = {"k": cache["shared"]["k"][shared_i],
                      "v": cache["shared"]["v"][shared_i]}
                sp = params["shared_attn"]
                y, sc2 = attn.gqa_decode(sp["attn"], cfg,
                                         rms_norm(sp["ln1"], h), sc, pos)
                h = h + y
                h = h + mlp_mod.mlp_apply(sp["mlp"], rms_norm(sp["ln2"], h),
                                          cfg.mlp_kind)
                if "shared" not in new_cache:
                    new_cache["shared"] = jax.tree.map(jnp.copy, cache["shared"])
                new_cache["shared"] = jax.tree.map(
                    lambda full, upd, i=shared_i: full.at[i].set(upd),
                    new_cache["shared"], sc2)
                shared_i += 1

            def body(h, scanned):
                lp, lc = scanned
                if kind in ("dense", "moe"):
                    hn = rms_norm(lp["ln1"], h)
                    dec = attn.mla_decode if cfg.attention_kind == "mla" else attn.gqa_decode
                    y, lc2 = dec(lp["attn"], cfg, hn, lc, pos)
                    h = h + y
                    if kind == "dense":
                        h = h + mlp_mod.mlp_apply(lp["mlp"], rms_norm(lp["ln2"], h), cfg.mlp_kind)
                    else:
                        y2, _ = mlp_mod.moe_apply(lp["moe"], cfg, rms_norm(lp["ln2"], h))
                        h = h + y2
                else:
                    y, lc2 = m2.mamba2_decode(lp["mixer"], cfg,
                                              rms_norm(lp["ln"], h), lc)
                    h = h + y
                return h, lc2

            h, cstack2 = jax.lax.scan(body, h, (pstack, cstack))
            new_cache["chunks"].append(cstack2)
        if "shared" in cache and "shared" not in new_cache:
            new_cache["shared"] = cache["shared"]
        h = rms_norm(params["final_norm"], h)
        logits = dense_apply(params["head"], h)
        return logits, new_cache
