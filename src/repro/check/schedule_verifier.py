"""Static schedule verifier: an abstract interpreter over ``Schedule`` ops.

Walks the op stream once, tracking a liveness-and-residency lattice per
activation — absent / bare (``a^i``) / full-history (``ā^i``) / gradient
(``δ^i``) on the device tier, plus a host-copy set for the offload protocol —
and symbolic device/host memory accumulators.  It proves, without executing
or timing anything, that:

- every forward/backward op has its required inputs live (``ā^i`` includes
  ``a^i``, paper §3.1);
- nothing is used after an explicit ``Free``;
- the offload protocol is respected: ``Foff`` only on a live *bare*
  activation with no existing host copy, ``Prefetch`` only for an activation
  with a host copy that is not already device-resident;
- symbolic device/host peaks never exceed the plan's budgets (same
  accounting as the simulator: forward charges ``mem + new + of``, backward
  charges ``mem + ob``);
- the schedule ends with ``δ^0`` live, and (optionally) no checkpointed
  value is dropped before its backward use (persistence, §4.1).

Unlike :func:`repro.core.schedule.simulate` — which executes the cost model,
accumulates time, and stops at the first error — this pass is purely
structural, collects *all* violations (with local state repair so one fault
does not cascade), and returns a structured
:class:`~repro.check.violations.VerificationReport`.

The accounting deliberately mirrors the simulator op for op, in the same
order and with the same ``1e-9`` budget epsilon, so the two are
interchangeable oracles: for any schedule, ``simulate(...).valid`` iff
``verify_schedule(...).ok``, and the first violation kind matches the
simulator's ``error_kind`` (asserted by the mutation suite in
``tests/test_check_verifier.py``).

:func:`verify_slot_discipline` is the second, discretized pass: it re-walks
the schedule with sizes quantized to the solver's memory slots
(``chain.discretize(budget, S)``) and proves the integer-slot usage never
exceeds ``S``.  This is only sound for ``strategy="optimal"`` plans — the
min-memory solvers discretize against the store-all peak and report a
*derived* byte budget, so re-quantizing at ``budget/S`` would be a different
lattice than the one the DP solved over.
"""

from __future__ import annotations

from typing import Optional, Tuple

from .violations import VerificationReport, Violation

# Op vocabulary, duplicated from repro.core.schedule (kept in sync by
# tests/test_check_verifier.py) so this module stays importable without
# numpy/jax for plan files verified on a host with no solver stack.
F_NONE, F_CK, F_ALL, BWD, FREE = "Fnone", "Fck", "Fall", "B", "Free"
F_OFF, PREFETCH = "Foff", "Prefetch"
_FORWARD_KINDS = (F_NONE, F_CK, F_ALL)
_OFFLOAD_KINDS = (F_OFF, PREFETCH)

_EPS = 1e-9  # budget comparison epsilon — must match simulate()


class _Model:
    """Size/overhead oracle for one verification pass.

    Wraps either a :class:`~repro.core.chain.Chain` (byte-exact pass) or a
    :class:`~repro.core.chain.DiscreteChain` (slot pass); ``None`` sizes
    everything at 0 so structural rules still run for bare-length plans.
    """

    def __init__(self, sized, host_enabled: Optional[bool]):
        self._sized = sized
        self.host_enabled = host_enabled  # None = unknown (skip the rule)

    def size(self, item: Tuple[str, int]) -> float:
        if self._sized is None:
            return 0.0
        kind, i = item
        c = self._sized
        L = c.length
        if kind == "a":
            return 0.0 if i == L + 1 else float(c.wa[i])
        if kind == "abar":
            return float(c.wabar[i - 1])  # ā^i stored at array index i-1
        if kind == "delta":
            return 0.0 if i == L + 1 else float(c.wdelta[i])
        raise ValueError(f"unknown item {item}")

    def of(self, l: int) -> float:
        return 0.0 if self._sized is None else float(self._sized.of[l - 1])

    def ob(self, l: int) -> float:
        return 0.0 if self._sized is None else float(self._sized.ob[l - 1])


def residency_summary(live, host_copies) -> str:
    """Compact lattice state: ``dev a{0,3} ā{5} δ{6} | host{2}``."""
    parts = []
    for kind, tag in (("a", "a"), ("abar", "ā"), ("delta", "δ")):
        idxs = sorted(i for (k, i) in live if k == kind)
        if idxs:
            parts.append(tag + "{" + ",".join(map(str, idxs)) + "}")
    dev = "dev " + " ".join(parts) if parts else "dev empty"
    if host_copies:
        dev += " | host{" + ",".join(map(str, sorted(host_copies))) + "}"
    return dev


def _walk(
    schedule,
    model: _Model,
    device_budget: Optional[float],
    host_budget: Optional[float],
    check_persistent: bool,
    budget_kind: str,
    host_budget_kind: str,
    max_violations: int,
) -> VerificationReport:
    """One lattice walk.  Mirrors ``simulate()`` check-for-check (same order,
    same epsilon) but repairs state after each violation and keeps going."""
    L = schedule.length
    report = VerificationReport()
    live: dict = {("a", 0): True, ("delta", L + 1): True}
    ckpt: set = {("a", 0)}
    mem = model.size(("a", 0))
    peak = mem
    persistent = True
    host_copies: set = set()
    host_mem = 0.0
    host_peak = 0.0

    def fail(kind: str, message: str, idx: int, op) -> None:
        if len(report.violations) >= max_violations:
            report.truncated = True
            return
        report.violations.append(
            Violation(
                kind=kind,
                message=message,
                op_index=idx,
                op=op,
                state=residency_summary(live, host_copies),
            )
        )

    for idx, op in enumerate(schedule.ops):
        kind, arg = op
        if kind == FREE:
            item = arg
            if item not in live:
                fail("free-not-live", f"Free of non-live {item}", idx, op)
                continue  # repair: skip the free
            if item in ckpt:
                persistent = False
            mem -= model.size(item)
            del live[item]
            continue

        if kind in _OFFLOAD_KINDS:
            i = int(arg)
            if model.host_enabled is False:
                fail(
                    "no-host-tier",
                    f"{kind} a^{i}: chain has no host tier",
                    idx,
                    op,
                )
                # repair: pretend the tier exists and keep walking
            if not (0 <= i <= L):
                fail("bad-stage", f"{kind}: bad activation {i}", idx, op)
                continue
            w = model.size(("a", i))
            if kind == F_OFF:
                if ("a", i) not in live:
                    fail(
                        "offload-not-bare",
                        f"Foff: a^{i} not live as a bare activation",
                        idx,
                        op,
                    )
                if i in host_copies:
                    fail(
                        "double-offload",
                        f"Foff: a^{i} already offloaded",
                        idx,
                        op,
                    )
                    continue  # repair: don't double-charge the host
                host_copies.add(i)
                host_mem += w
                host_peak = max(host_peak, host_mem)
                if host_budget is not None and host_mem > host_budget + _EPS:
                    fail(
                        host_budget_kind,
                        f"Foff: host mem {host_mem} > limit {host_budget}",
                        idx,
                        op,
                    )
                ckpt.discard(("a", i))
            else:  # PREFETCH
                if i not in host_copies:
                    fail(
                        "prefetch-no-copy",
                        f"Prefetch: a^{i} has no host copy",
                        idx,
                        op,
                    )
                if ("a", i) in live:
                    fail(
                        "prefetch-resident",
                        f"Prefetch: a^{i} already on device",
                        idx,
                        op,
                    )
                    if i in host_copies:  # repair: consume the host copy only
                        host_copies.discard(i)
                        host_mem -= w
                    continue
                during = mem + w
                peak = max(peak, during)
                if device_budget is not None and during > device_budget + _EPS:
                    fail(
                        budget_kind,
                        f"Prefetch: mem {during} > limit {device_budget}",
                        idx,
                        op,
                    )
                live[("a", i)] = True
                mem += w
                ckpt.add(("a", i))
                if i in host_copies:
                    host_copies.discard(i)
                    host_mem -= w
            continue

        l = int(arg)
        if kind in _FORWARD_KINDS:
            if not (1 <= l <= L + 1):
                fail("bad-stage", f"bad stage {l}", idx, op)
                continue
            have_input = ("a", l - 1) in live or (
                l - 1 >= 1 and ("abar", l - 1) in live
            )
            src = (
                ("a", l - 1)
                if ("a", l - 1) in live
                else ("abar", l - 1)
                if l - 1 >= 1 and ("abar", l - 1) in live
                else None
            )
            if not have_input:
                fail(
                    "missing-input",
                    f"{kind}^{l}: a^{l - 1} not live",
                    idx,
                    op,
                )
                # repair: run the forward anyway so later ops can be checked
            out = ("abar", l) if kind == F_ALL else ("a", l)
            new_bytes = 0.0 if out in live else model.size(out)
            during = mem + new_bytes + model.of(l)
            peak = max(peak, during)
            if device_budget is not None and during > device_budget + _EPS:
                fail(
                    budget_kind,
                    f"{kind}^{l}: mem {during} > limit {device_budget}",
                    idx,
                    op,
                )
            if kind == F_NONE and src == ("a", l - 1):
                if src in ckpt:
                    persistent = False
                mem -= model.size(src)
                del live[src]
            if out not in live:
                live[out] = True
                mem += new_bytes
            if kind in (F_CK, F_ALL) and ("a", l - 1) in live:
                ckpt.add(("a", l - 1))
            if kind == F_ALL:
                ckpt.add(out)
        elif kind == BWD:
            if not (1 <= l <= L + 1):
                fail("bad-stage", f"bad stage {l}", idx, op)
                continue
            for item, vkind in (
                (("delta", l), "missing-grad"),
                (("abar", l), "missing-residual"),
            ):
                if item not in live:
                    fail(vkind, f"B^{l}: {item} not live", idx, op)
            have_input = ("a", l - 1) in live or (
                l - 1 >= 1 and ("abar", l - 1) in live
            )
            src = ("a", l - 1) if ("a", l - 1) in live else None
            if not have_input:
                fail(
                    "missing-input",
                    f"B^{l}: a^{l - 1} not live",
                    idx,
                    op,
                )
            during = mem + model.ob(l)
            peak = max(peak, during)
            if device_budget is not None and during > device_budget + _EPS:
                fail(
                    budget_kind,
                    f"B^{l}: mem {during} > limit {device_budget}",
                    idx,
                    op,
                )
            for item in (("delta", l), ("abar", l)):
                if item in live:  # repair: consume only what exists
                    mem -= model.size(item)
                    del live[item]
                    ckpt.discard(item)
            if src == ("a", l - 1):
                mem -= model.size(src)
                del live[src]
                ckpt.discard(src)
            out = ("delta", l - 1)
            if out not in live:
                live[out] = True
                mem += model.size(out)
        else:
            fail("bad-op", f"unknown op kind {kind}", idx, op)

    if ("delta", 0) not in live:
        fail("no-output", "schedule did not produce δ^0", -1, None)
    if check_persistent and not persistent:
        fail("non-persistent", "non-persistent", -1, None)
    return report


def verify_schedule(
    schedule,
    chain=None,
    device_budget: Optional[float] = None,
    host_budget: Optional[float] = None,
    check_persistent: bool = False,
    max_violations: int = 64,
) -> VerificationReport:
    """Statically verify one schedule; returns a
    :class:`~repro.check.violations.VerificationReport` (never raises on
    invalid schedules — raising is the caller's policy, see
    ``MemoryPlan.verify``).

    ``chain=None`` runs the structural rules only (liveness, offload
    protocol, output) with all sizes 0 — the budget rules need a profiled
    chain to mean anything.
    """
    host_enabled: Optional[bool]
    if chain is None:
        host_enabled = None
    else:
        host_enabled = chain.host is not None and chain.host.enabled
    model = _Model(chain, host_enabled)
    rules = ["liveness", "offload-protocol", "output"]
    if chain is not None and device_budget is not None:
        rules.append("device-budget")
    if chain is not None and host_budget is not None:
        rules.append("host-budget")
    if check_persistent:
        rules.append("persistence")
    report = _walk(
        schedule,
        model,
        device_budget if chain is not None else None,
        host_budget if chain is not None else None,
        check_persistent,
        budget_kind="device-budget",
        host_budget_kind="host-budget",
        max_violations=max_violations,
    )
    report.rules = rules
    return report


def verify_slot_discipline(
    schedule,
    chain,
    budget: float,
    num_slots: int,
    max_violations: int = 64,
) -> VerificationReport:
    """Prove the schedule fits ``num_slots`` memory slots after quantizing
    sizes exactly the way the DP solver did (``chain.discretize``; paper
    §5.2).  Only sound for plans whose solver discretized against ``budget``
    itself — i.e. ``strategy="optimal"``."""
    dchain = chain.discretize(budget, num_slots)
    model = _Model(dchain, chain.host is not None and chain.host.enabled)
    report = _walk(
        schedule,
        model,
        device_budget=float(num_slots),
        host_budget=None,
        check_persistent=False,
        budget_kind="slot-discipline",
        host_budget_kind="slot-discipline",
        max_violations=max_violations,
    )
    # structural violations are already reported by the byte pass; keep only
    # the slot-granular budget findings from this one
    report.violations = [
        v for v in report.violations if v.kind == "slot-discipline"
    ]
    report.rules = ["slot-discipline"]
    return report
