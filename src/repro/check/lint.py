"""AST-based repo-invariant linter (``python -m repro.check``).

Enforces, as a CI gate, the invariants earlier PRs established ad-hoc:

- **jax-import** — the numpy-only modules (all of ``core/`` except the three
  executor-side modules, all of ``obs/``, all of ``check/``) must not import
  ``jax`` — or any known jax-importing repro module — at module level.  This
  is what keeps ``import repro.core`` / ``repro.obs.metrics`` working on
  plan-serving hosts with no accelerator stack (the lazy-import discipline
  PRs 4–6 relied on; the dynamic side of the same guard is the jax-blocked
  subprocess test in ``tests/test_check_lint.py``).
- **policy-parse** — legacy policy *strings* are parsed in exactly one
  place, ``plan/compat.py`` (the PR 3 invariant).  Any
  ``x.startswith("optimal..."/"periodic:"/...)`` on a policy prefix outside
  it is flagged.
- **metric-name** — literal metric names passed to
  ``metrics.counter/gauge/histogram/value`` must follow the dotted
  ``noun.verb`` registry convention (``solver_cache.hits``,
  ``train.step_seconds``); f-string names are checked with placeholders
  substituted.
- **pickle-confinement** — raw (de)serialization modules (``pickle`` et
  al.) may be imported only under ``store/``: every other module persists
  through the tamper-evident :mod:`repro.store.codec` envelope, so
  corruption handling and quarantine live in exactly one place.  Checked
  over the whole AST (function-local imports count — laziness does not
  make a pickle safe).

The linter is purely syntactic (no imports of the linted modules), so it
runs in any environment — including ones where importing the module under
inspection would fail, which is precisely the regression it guards against.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Iterable, List, Optional

# -- rule configuration ------------------------------------------------------

# Modules that must stay importable without jax.  Paths relative to the
# ``src/repro`` root, directory entries cover every .py directly inside.
NUMPY_ONLY_DIRS = ("core", "obs", "check", "store")
# core modules that *are* the jax boundary (execution side) — exempt.
JAX_BOUNDARY = {
    "core/executor.py",
    "core/planner.py",
    "core/rematerialize.py",
}
# Importing any of these at module level re-introduces jax transitively.
_JAX_ROOTS = ("jax", "jaxlib")
_JAX_REPRO_MODULES = (
    "repro.core.executor",
    "repro.core.planner",
    "repro.core.rematerialize",
    "repro.offload.executor",
    "repro.offload.host_buffer",
    "repro.ckpt",
    "repro.kernels",
)
_JAX_RELATIVE = ("executor", "planner", "rematerialize", "host_buffer")

# Policy-string prefixes whose parsing is confined to plan/compat.py.
POLICY_PREFIXES = (
    "optimal",
    "optimal_offload",
    "periodic:",
    "rotor:",
    "revolve:",
    "store_all",
    "full_remat",
    "min_memory",
)
POLICY_PARSE_ALLOWED = ("plan/compat.py",)

# Raw (de)serialization is confined to the store package — everything else
# reads/writes objects through the repro.store.codec envelope, so integrity
# checks and quarantine happen in exactly one place.
_PICKLE_MODULES = ("pickle", "cPickle", "dill", "marshal", "shelve")
PICKLE_ALLOWED_DIRS = ("store",)

# Dotted lowercase noun.verb convention for registry metric names.
METRIC_NAME_RE = re.compile(r"^[a-z0-9_]+(\.[a-z0-9_]+)+$")
_METRIC_FNS = {"counter", "gauge", "histogram", "value"}
_METRIC_RECEIVERS = {"metrics", "_obs", "obs"}


@dataclasses.dataclass(frozen=True)
class LintViolation:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


# -- helpers -----------------------------------------------------------------


def _module_level_imports(tree: ast.Module) -> Iterable[ast.stmt]:
    """Import statements at module scope, descending into plain module-level
    ``if``/``try`` blocks except ``if TYPE_CHECKING:`` (annotation-only)."""
    stack: List[ast.stmt] = list(tree.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            yield node
        elif isinstance(node, ast.If):
            test = ast.dump(node.test)
            if "TYPE_CHECKING" not in test:
                stack.extend(node.body)
                stack.extend(node.orelse)
        elif isinstance(node, ast.Try):
            stack.extend(node.body)
            for h in node.handlers:
                stack.extend(h.body)
            stack.extend(node.orelse)
            stack.extend(node.finalbody)


def _is_jax_module(name: str) -> bool:
    root = name.split(".")[0]
    if root in _JAX_ROOTS:
        return True
    return any(
        name == m or name.startswith(m + ".") for m in _JAX_REPRO_MODULES
    )


def _literal_str(node: ast.AST) -> Optional[str]:
    """The string a literal (or f-string with placeholders → ``"x"``)
    evaluates to, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.JoinedStr):
        parts = []
        for v in node.values:
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                parts.append(v.value)
            else:
                parts.append("x")
        return "".join(parts)
    return None


# -- rules -------------------------------------------------------------------


def _check_jax_imports(rel: str, tree: ast.Module) -> List[LintViolation]:
    parts = rel.split("/")
    in_scope = (
        len(parts) == 2
        and parts[0] in NUMPY_ONLY_DIRS
        and rel not in JAX_BOUNDARY
    )
    if not in_scope:
        return []
    out = []
    for node in _module_level_imports(tree):
        if isinstance(node, ast.Import):
            names = [a.name for a in node.names]
        else:  # ImportFrom
            if node.level:  # relative: resolve against the package
                pkg = ["repro"] + parts[:-1]
                base = ".".join(pkg[: len(pkg) - (node.level - 1)])
                mod = node.module or ""
                names = [
                    (base + "." + mod if mod else base)
                    + "."
                    + a.name.split(".")[0]
                    for a in node.names
                ]
                # also flag `from . import executor`-style by bare name
                names += [
                    a.name
                    for a in node.names
                    if a.name in _JAX_RELATIVE and not mod
                ]
                if mod:
                    names.append(base + "." + mod)
            else:
                names = [node.module or ""]
        for name in names:
            if _is_jax_module(name) or name.split(".")[-1] in _JAX_RELATIVE:
                out.append(
                    LintViolation(
                        rel,
                        node.lineno,
                        "jax-import",
                        f"module-level import of {name!r} in a numpy-only "
                        f"module (use a function-local import)",
                    )
                )
                break
    return out


def _check_policy_parse(rel: str, tree: ast.Module) -> List[LintViolation]:
    if rel in POLICY_PARSE_ALLOWED:
        return []
    out = []
    for node in ast.walk(tree):
        if not (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "startswith"
            and node.args
        ):
            continue
        args = node.args[0]
        literals = (
            [_literal_str(e) for e in args.elts]
            if isinstance(args, ast.Tuple)
            else [_literal_str(args)]
        )
        for lit in literals:
            if lit is not None and any(
                lit == p or lit.startswith(p) for p in POLICY_PREFIXES
            ):
                out.append(
                    LintViolation(
                        rel,
                        node.lineno,
                        "policy-parse",
                        f"policy-string parsing ({lit!r}) outside "
                        f"plan/compat.py — route through the compat shim",
                    )
                )
                break
    return out


def _check_metric_names(rel: str, tree: ast.Module) -> List[LintViolation]:
    # names imported straight from the metrics module count as receivers too
    imported: set = set()
    for node in _module_level_imports(tree):
        if isinstance(node, ast.ImportFrom) and (node.module or "").endswith(
            "metrics"
        ):
            imported |= {a.asname or a.name for a in node.names}
    out = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and node.args):
            continue
        fn = node.func
        is_metric_call = (
            isinstance(fn, ast.Attribute)
            and fn.attr in _METRIC_FNS
            and isinstance(fn.value, ast.Name)
            and fn.value.id in _METRIC_RECEIVERS
        ) or (
            isinstance(fn, ast.Name)
            and fn.id in _METRIC_FNS
            and fn.id in imported
        )
        if not is_metric_call:
            continue
        name = _literal_str(node.args[0])
        if name is not None and not METRIC_NAME_RE.match(name):
            out.append(
                LintViolation(
                    rel,
                    node.lineno,
                    "metric-name",
                    f"metric name {name!r} does not match the dotted "
                    f"noun.verb convention ({METRIC_NAME_RE.pattern})",
                )
            )
    return out


def _check_pickle_confinement(rel: str, tree: ast.Module) -> List[LintViolation]:
    if rel.split("/")[0] in PICKLE_ALLOWED_DIRS:
        return []
    out = []
    for node in ast.walk(tree):  # whole tree: function-local imports count
        names: List[str] = []
        if isinstance(node, ast.Import):
            names = [a.name for a in node.names]
        elif isinstance(node, ast.ImportFrom) and not node.level:
            names = [node.module or ""]
        for name in names:
            if name.split(".")[0] in _PICKLE_MODULES:
                out.append(
                    LintViolation(
                        rel,
                        node.lineno,
                        "pickle-confinement",
                        f"import of {name!r} outside store/ — all "
                        f"(de)serialization goes through the "
                        f"repro.store.codec envelope",
                    )
                )
                break
    return out


_RULES = (
    _check_jax_imports,
    _check_policy_parse,
    _check_metric_names,
    _check_pickle_confinement,
)


# -- drivers -----------------------------------------------------------------


def lint_file(path: str, root: str) -> List[LintViolation]:
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    with open(path, "r", encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [
            LintViolation(rel, e.lineno or 0, "syntax", f"cannot parse: {e}")
        ]
    out: List[LintViolation] = []
    for rule in _RULES:
        out.extend(rule(rel, tree))
    return out


def lint_paths(paths: Iterable[str], root: str) -> List[LintViolation]:
    out: List[LintViolation] = []
    for p in sorted(paths):
        out.extend(lint_file(p, root))
    return out


def repo_root() -> str:
    """The ``src/repro`` package root this module was loaded from."""
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint_repo(root: Optional[str] = None) -> List[LintViolation]:
    """Lint every ``.py`` under ``src/repro`` (the CI entry point)."""
    root = root or repo_root()
    files = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in filenames:
            if fn.endswith(".py"):
                files.append(os.path.join(dirpath, fn))
    return lint_paths(files, root)
