"""Static analyzer for the ``kernels/dp_fill`` Pallas kernels.

PR 5's fused fill ships with a *hand* proof that its revisited whole-array
output blocks are safe: every garbage row a padded static-height slice
writes "always belongs to later bands and is rewritten by its own band's
step before any read" (see ``_FusedOperands``).  This module machine-checks
that argument — and the per-band kernels' accumulator/grid discipline —
directly from the kernel *sources* (``ast``; the kernels are never imported,
so the analyzer runs without jax).

How: an abstract interpreter executes each kernel body over the real
sequential TPU grid order (last dimension innermost) for a matrix of small
concrete instantiations ``(L, BR, allow_fall, host_on)``.  Index arithmetic
(`pl.program_id`, ``off_ref[...]`` reads, ``pl.ds`` bounds) is evaluated
*concretely*; array values are abstracted to per-row validity lanes.  Rows
of carried (revisited output) buffers start invalid; reads AND their lanes
into everything derived from them; writes store the result lanes.  The
checks:

- **out-of-bounds** — every ``pl.ds`` slice and scalar index on every
  buffer stays inside the driver-contract shapes (``nrows = ncells + 2L +
  BR`` row pad, ``vec = 2L + BR + 2`` vectors, ``(L, rt·BR)`` threshold
  mats — mirrored from ``ops._FusedOperands``);
- **write-before-read domination / final validity** — after the full grid,
  every *real* table row (``[0, ncells)``) must carry valid lanes: a read
  of a garbage row only taints lanes that are later overwritten by their
  own band, or the proof fails;
- **clobber** — no write may turn an already-valid row invalid (a garbage
  write landing on a finalized row is exactly the race the pad-margin
  argument rules out);
- **grid discipline** (per-band kernels) — the output BlockSpec index maps,
  extracted from the drivers' ``pallas_call`` and evaluated over the grid,
  must be constant along the innermost (split) dimension — the revisited
  accumulator contract — and pairwise disjoint across row tiles
  (write-disjointness for non-revisited steps).

Known-sound / known-incomplete boundary: rows are tracked exactly;
*columns* are not (all gathers are within-row ``take_along_axis`` whose
clamp ladder is part of the trusted pattern), float semantics are trusted
(IEEE min/max), and the driver contract (shapes, band offsets, base-case
validity) is asserted against ``ops.py`` by ``tests/test_check_kernel_analyzer``
rather than derived.  Anything the interpreter cannot model is reported as
an ``unsupported`` issue — the gate fails closed.

Results are keyed by :func:`repro.core.solver_cache.code_fingerprint` (which
already hashes the kernel sources): ``python -m repro.check`` skips the
analysis when the fingerprint matches the last recorded pass.
"""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

ISSUE_KINDS = (
    "out-of-bounds",      # slice/index escapes the driver-contract shape
    "final-invalid",      # a real table row ends the grid with garbage lanes
    "clobber",            # a write turned an already-valid row invalid
    "grid-race",          # out BlockSpec not revisited/disjoint as required
    "read-only-write",    # kernel writes an input buffer
    "unsupported",        # construct outside the modeled subset (fail closed)
)


@dataclasses.dataclass(frozen=True)
class KernelIssue:
    kernel: str
    kind: str
    message: str
    case: str = ""

    def __post_init__(self):
        if self.kind not in ISSUE_KINDS:
            raise ValueError(f"unknown issue kind {self.kind!r}")

    def __str__(self) -> str:
        where = f" [{self.case}]" if self.case else ""
        return f"{self.kernel}: {self.kind}: {self.message}{where}"


class _Unsupported(Exception):
    pass


class _IssueStop(Exception):
    """Raised to abort a case after too many issues."""


# -- abstract values ---------------------------------------------------------

VALID = object()  # fully-valid array of unknown lane structure


class Lanes:
    """Per-row validity of an array value whose leading axis is rows."""

    __slots__ = ("mask",)

    def __init__(self, mask: Sequence[bool]):
        self.mask = list(mask)


class DS:
    """A ``pl.ds(start, size)`` slice with concrete bounds."""

    __slots__ = ("start", "size")

    def __init__(self, start: int, size: int):
        self.start = int(start)
        self.size = int(size)


class FuncVal:
    """A def/lambda closure interpreted on call."""

    __slots__ = ("node", "env")

    def __init__(self, node: ast.AST, env: Dict[str, Any]):
        self.node = node
        self.env = env


def _combine(*values: Any) -> Any:
    """Validity meet: any invalid lane in any row-shaped operand taints the
    corresponding output lane (row-aligned elementwise/broadcast ops)."""
    out: Any = VALID
    for v in values:
        if isinstance(v, Lanes):
            if out is VALID:
                out = Lanes(v.mask)
            elif isinstance(out, Lanes):
                if len(out.mask) != len(v.mask):
                    raise _Unsupported(
                        f"combining lanes of different heights "
                        f"({len(out.mask)} vs {len(v.mask)})"
                    )
                out = Lanes(
                    [a and b for a, b in zip(out.mask, v.mask)]
                )
    return out


# -- buffers -----------------------------------------------------------------


class Buf:
    """One kernel ref: concrete shape, optional per-row validity, optional
    concrete integer contents (the band-offset vector)."""

    def __init__(
        self,
        name: str,
        shape: Tuple[int, ...],
        *,
        readonly: bool,
        valid: Optional[List[bool]] = None,
        values: Optional[List[int]] = None,
        window: Optional[Tuple[int, int]] = None,
    ):
        self.name = name
        self.shape = shape
        self.readonly = readonly
        self.valid = valid  # None => always-valid input
        self.values = values
        self.window = window  # (lo, hi) rows bound at this grid step


# -- the interpreter ---------------------------------------------------------


class _Interp:
    def __init__(
        self,
        module_env: Dict[str, Any],
        functions: Dict[str, ast.FunctionDef],
        issues: List[KernelIssue],
        kernel_name: str,
        case: str,
        max_issues: int = 8,
    ):
        self.module_env = module_env
        self.functions = functions
        self.issues = issues
        self.kernel = kernel_name
        self.case = case
        self.pids: Tuple[int, ...] = ()
        self.max_issues = max_issues

    def issue(self, kind: str, message: str) -> None:
        self.issues.append(
            KernelIssue(self.kernel, kind, message, self.case)
        )
        if len(self.issues) >= self.max_issues:
            raise _IssueStop()

    # -- buffer access ----------------------------------------------------

    def _slice_1d(self, buf: Buf, idx: Any, ctx: str) -> Tuple[int, int]:
        """Resolve an index on the leading axis to concrete (lo, hi)."""
        n = buf.shape[0]
        if isinstance(idx, DS):
            lo, hi = idx.start, idx.start + idx.size
        elif isinstance(idx, (int, bool)):
            lo, hi = int(idx), int(idx) + 1
        else:
            raise _Unsupported(f"non-concrete index on {buf.name} ({ctx})")
        if lo < 0 or hi > n:
            self.issue(
                "out-of-bounds",
                f"{ctx} rows [{lo}, {hi}) escape {buf.name}"
                f"[0, {n})",
            )
            lo, hi = max(lo, 0), min(hi, n)
        return lo, hi

    def read_buf(self, buf: Buf, index: Any) -> Any:
        if buf.window is not None:  # pre-sliced block (per-band kernels)
            if buf.valid is None:
                return VALID
            lo, hi = buf.window
            return Lanes(buf.valid[lo:hi])
        if index is Ellipsis:
            if buf.valid is None:
                return VALID
            return Lanes(list(buf.valid))
        idx = index[0] if isinstance(index, tuple) else index
        if isinstance(idx, (int, bool)) and buf.values is not None:
            i = int(idx)
            if not (0 <= i < buf.shape[0]):
                self.issue(
                    "out-of-bounds",
                    f"scalar read {buf.name}[{i}] escapes "
                    f"[0, {buf.shape[0]})",
                )
                return 0
            return buf.values[i]
        if isinstance(index, tuple) and len(index) == 2:
            a, b = index
            if isinstance(a, DS) and isinstance(b, DS):  # (L, rt·BR) mats
                lo0, hi0 = self._slice_1d(buf, a, f"read {buf.name}")
                if b.start < 0 or b.start + b.size > buf.shape[1]:
                    self.issue(
                        "out-of-bounds",
                        f"read {buf.name} cols [{b.start}, "
                        f"{b.start + b.size}) escape [0, {buf.shape[1]})",
                    )
                return VALID if buf.valid is None else Lanes(
                    buf.valid[lo0:hi0]
                )
        lo, hi = self._slice_1d(buf, idx, f"read {buf.name}")
        if buf.valid is None:
            return VALID
        return Lanes(buf.valid[lo:hi])

    def write_buf(self, buf: Buf, index: Any, value: Any) -> None:
        if buf.readonly:
            self.issue(
                "read-only-write", f"write to input buffer {buf.name}"
            )
            return
        if buf.window is not None:
            lo, hi = buf.window
        elif index is Ellipsis:
            lo, hi = 0, buf.shape[0]
        else:
            idx = index[0] if isinstance(index, tuple) else index
            lo, hi = self._slice_1d(buf, idx, f"write {buf.name}")
        h = hi - lo
        if value is VALID or isinstance(value, (int, float, bool)):
            new = [True] * h
        elif isinstance(value, Lanes):
            if len(value.mask) != h:
                raise _Unsupported(
                    f"write of {len(value.mask)} lanes into {h} rows "
                    f"of {buf.name}"
                )
            new = list(value.mask)
        else:
            raise _Unsupported(
                f"write of unmodeled value into {buf.name}"
            )
        assert buf.valid is not None
        for k in range(h):
            if buf.valid[lo + k] and not new[k]:
                self.issue(
                    "clobber",
                    f"write invalidates finalized row {lo + k} of "
                    f"{buf.name}",
                )
        buf.valid[lo:hi] = new

    # -- expression evaluation --------------------------------------------

    def _dotted(self, node: ast.AST) -> Optional[str]:
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            parts.append(node.id)
            return ".".join(reversed(parts))
        return None

    def eval(self, node: ast.AST, env: Dict[str, Any]) -> Any:
        if isinstance(node, ast.Constant):
            return node.value
        if isinstance(node, ast.Name):
            if node.id in env:
                return env[node.id]
            if node.id in self.module_env:
                return self.module_env[node.id]
            if node.id in self.functions:
                return FuncVal(self.functions[node.id], {})
            raise _Unsupported(f"unknown name {node.id!r}")
        if isinstance(node, ast.Tuple):
            return tuple(self.eval(e, env) for e in node.elts)
        if isinstance(node, ast.Attribute):
            dotted = self._dotted(node)
            if dotted in ("jnp.inf", "np.inf"):
                return float("inf")
            return VALID  # jnp.float32, COST_DT-as-attr, dtypes, ...
        if isinstance(node, ast.UnaryOp):
            v = self.eval(node.operand, env)
            if isinstance(node.op, ast.USub) and isinstance(
                v, (int, float)
            ):
                return -v
            if isinstance(node.op, ast.Not) and isinstance(v, bool):
                return not v
            return _combine(v)
        if isinstance(node, ast.BinOp):
            a = self.eval(node.left, env)
            b = self.eval(node.right, env)
            if isinstance(a, (int, float)) and isinstance(b, (int, float)):
                return self._arith(node.op, a, b)
            return _combine(a, b)
        if isinstance(node, ast.BoolOp):
            vals = [self.eval(v, env) for v in node.values]
            if all(isinstance(v, bool) for v in vals):
                return (
                    all(vals)
                    if isinstance(node.op, ast.And)
                    else any(vals)
                )
            return _combine(*vals)
        if isinstance(node, ast.Compare):
            if len(node.ops) != 1:
                raise _Unsupported("chained comparison")
            a = self.eval(node.left, env)
            b = self.eval(node.comparators[0], env)
            if isinstance(a, (int, float)) and isinstance(b, (int, float)):
                return self._cmp(node.ops[0], a, b)
            return _combine(a, b)
        if isinstance(node, ast.Call):
            return self.eval_call(node, env)
        if isinstance(node, ast.Subscript):
            return self.eval_subscript(node, env)
        if isinstance(node, ast.IfExp):
            c = self.eval(node.test, env)
            if isinstance(c, bool):
                return self.eval(node.body if c else node.orelse, env)
            return _combine(
                self.eval(node.body, env), self.eval(node.orelse, env)
            )
        if isinstance(node, ast.Lambda):
            return FuncVal(node, dict(env))
        raise _Unsupported(f"expression {ast.dump(node)[:60]}")

    @staticmethod
    def _arith(op: ast.operator, a, b):
        if isinstance(op, ast.Add):
            return a + b
        if isinstance(op, ast.Sub):
            return a - b
        if isinstance(op, ast.Mult):
            return a * b
        if isinstance(op, ast.FloorDiv):
            return a // b
        if isinstance(op, ast.Mod):
            return a % b
        if isinstance(op, ast.LShift):
            return a << b
        if isinstance(op, ast.BitAnd):
            return a & b
        if isinstance(op, ast.BitOr):
            return a | b
        raise _Unsupported(f"arithmetic op {op}")

    @staticmethod
    def _cmp(op: ast.cmpop, a, b):
        if isinstance(op, ast.Eq):
            return a == b
        if isinstance(op, ast.NotEq):
            return a != b
        if isinstance(op, ast.Lt):
            return a < b
        if isinstance(op, ast.LtE):
            return a <= b
        if isinstance(op, ast.Gt):
            return a > b
        if isinstance(op, ast.GtE):
            return a >= b
        raise _Unsupported(f"comparison op {op}")

    def eval_subscript(self, node: ast.Subscript, env: Dict[str, Any]):
        base = self.eval(node.value, env)
        if isinstance(base, Buf):
            index = self._eval_index(node.slice, env)
            return self.read_buf(base, index)
        if isinstance(base, tuple):
            idx = self.eval(node.slice, env)
            if isinstance(idx, int):
                return base[idx]
            raise _Unsupported("non-constant tuple index")
        # value[:, None], value[0], ... — row structure is preserved for the
        # patterns the kernels use; treat as passthrough
        return _combine(base)

    def _eval_index(self, node: ast.AST, env: Dict[str, Any]) -> Any:
        """Evaluate a subscript index into Ellipsis / DS / int / tuple."""
        if isinstance(node, ast.Constant) and node.value is Ellipsis:
            return Ellipsis
        if isinstance(node, ast.Tuple):
            return tuple(self._eval_index(e, env) for e in node.elts)
        if isinstance(node, ast.Slice):
            if node.lower is None and node.upper is None:
                return slice(None)
            raise _Unsupported("bounded python slice on a ref")
        return self.eval(node, env)

    def eval_call(self, node: ast.Call, env: Dict[str, Any]) -> Any:
        dotted = self._dotted(node.func)
        args = [self.eval(a, env) for a in node.args]
        kwargs = {
            k.arg: self.eval(k.value, env)
            for k in node.keywords
            if k.arg is not None
        }
        if dotted == "pl.program_id":
            axis = args[0]
            if not isinstance(axis, int) or axis >= len(self.pids):
                raise _Unsupported(f"pl.program_id({axis!r})")
            return self.pids[axis]
        if dotted == "pl.ds":
            if not all(isinstance(a, (int, bool)) for a in args):
                raise _Unsupported("pl.ds with non-concrete bounds")
            return DS(args[0], args[1])
        if dotted == "pl.load":
            buf = args[0]
            if not isinstance(buf, Buf):
                raise _Unsupported("pl.load of a non-ref")
            return self.read_buf(buf, args[1])
        if dotted == "jax.lax.fori_loop":
            lo, hi, fn, carry = args
            if not (
                isinstance(lo, int)
                and isinstance(hi, int)
                and isinstance(fn, FuncVal)
            ):
                raise _Unsupported("non-concrete fori_loop")
            for j in range(lo, hi):
                carry = self.call_func(fn, [j, carry])
            return carry
        if dotted in ("jax.lax.broadcasted_iota",):
            return VALID
        if dotted is not None and dotted.split(".")[-1] in self.functions:
            fn = self.functions[dotted.split(".")[-1]]
            return self.call_func(FuncVal(fn, {}), args)
        if isinstance(node.func, ast.Name) and isinstance(
            env.get(node.func.id), FuncVal
        ):
            return self.call_func(env[node.func.id], args)
        if dotted is not None and (
            dotted.startswith("jnp.") or dotted.startswith("np.")
        ):
            # elementwise / broadcast / gather ops: validity-meet of array
            # args (take_along_axis is within-row, so row-aligned)
            return _combine(*args, *kwargs.values())
        if dotted is not None and dotted.split(".")[0] in ("COST_DT",):
            return VALID
        # casting calls like jnp.float32(x) are caught above; a module
        # constant used as a cast (COST_DT(x)) would land here
        base = self.eval(node.func, env) if dotted is None else None
        if base is VALID or base is None and dotted is not None:
            return _combine(*args)
        raise _Unsupported(f"call to {dotted or ast.dump(node.func)[:40]}")

    def call_func(self, fv: FuncVal, args: List[Any]) -> Any:
        node = fv.node
        if isinstance(node, ast.Lambda):
            params = [a.arg for a in node.args.args]
            env = dict(fv.env)
            env.update(zip(params, args))
            # defaults (the _n=nd idiom) for unsupplied trailing params
            defaults = node.args.defaults
            if defaults:
                names = params[len(params) - len(defaults):]
                for name, d in zip(names, defaults):
                    if name not in env or len(args) < len(params):
                        env.setdefault(name, self.eval(d, fv.env))
            return self.eval(node.body, env)
        params = [a.arg for a in node.args.args]
        if len(args) != len(params):
            raise _Unsupported(
                f"call arity mismatch for {node.name}"
            )
        env = dict(fv.env)
        env.update(zip(params, args))
        return self.exec_body(node.body, env)

    # -- statements --------------------------------------------------------

    def exec_body(self, body: Sequence[ast.stmt], env: Dict[str, Any]):
        for stmt in body:
            if isinstance(stmt, ast.Return):
                if stmt.value is None:
                    return None
                return self.eval(stmt.value, env)
            self.exec_stmt(stmt, env)
        return None

    def exec_stmt(self, stmt: ast.stmt, env: Dict[str, Any]) -> None:
        if isinstance(stmt, ast.Expr):
            if isinstance(stmt.value, ast.Constant):
                return  # docstring
            self.eval(stmt.value, env)
            return
        if isinstance(stmt, ast.Assign):
            value = self.eval(stmt.value, env)
            for target in stmt.targets:
                self.assign(target, value, env)
            return
        if isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self.assign(stmt.target, self.eval(stmt.value, env), env)
            return
        if isinstance(stmt, ast.If):
            test = self.eval(stmt.test, env)
            if not isinstance(test, bool):
                raise _Unsupported("data-dependent python `if` in kernel")
            self.exec_many(stmt.body if test else stmt.orelse, env)
            return
        if isinstance(stmt, ast.FunctionDef):
            guard = None
            for dec in stmt.decorator_list:
                dotted = (
                    self._dotted(dec.func)
                    if isinstance(dec, ast.Call)
                    else None
                )
                if dotted == "pl.when":
                    guard = self.eval(dec.args[0], env)
                else:
                    raise _Unsupported(
                        f"decorator on {stmt.name} is not pl.when"
                    )
            if stmt.decorator_list:
                if not isinstance(guard, bool):
                    raise _Unsupported(
                        f"pl.when({stmt.name}) guard is not concrete"
                    )
                if guard:
                    self.exec_many(stmt.body, dict(env))
            else:
                env[stmt.name] = FuncVal(stmt, dict(env))
            return
        raise _Unsupported(f"statement {type(stmt).__name__}")

    def exec_many(self, body: Sequence[ast.stmt], env: Dict[str, Any]):
        for stmt in body:
            self.exec_stmt(stmt, env)

    def assign(self, target: ast.AST, value: Any, env: Dict[str, Any]):
        if isinstance(target, ast.Name):
            env[target.id] = value
            return
        if isinstance(target, ast.Tuple):
            if not isinstance(value, tuple) or len(value) != len(
                target.elts
            ):
                raise _Unsupported("tuple-unpack arity mismatch")
            for t, v in zip(target.elts, value):
                self.assign(t, v, env)
            return
        if isinstance(target, ast.Subscript):
            base = self.eval(target.value, env)
            if not isinstance(base, Buf):
                raise _Unsupported("subscript-assign to a non-ref")
            index = self._eval_index(target.slice, env)
            self.write_buf(base, index, value)
            return
        raise _Unsupported(f"assign target {type(target).__name__}")


# -- module loading ----------------------------------------------------------


def _load_module(path: str) -> Tuple[Dict[str, Any], Dict[str, ast.FunctionDef]]:
    """Parse a kernel source file: module-level functions + evaluable
    integer/float constants (e.g. ``_INT_CLAMP = 1 << 30``)."""
    with open(path, "r", encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    functions: Dict[str, ast.FunctionDef] = {}
    consts: Dict[str, Any] = {}
    for node in tree.body:
        if isinstance(node, ast.FunctionDef):
            functions[node.name] = node
        elif isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name):
                try:
                    consts[tgt.id] = ast.literal_eval(node.value)
                except (ValueError, TypeError, SyntaxError):
                    try:
                        consts[tgt.id] = _const_fold(node.value)
                    except _Unsupported:
                        consts[tgt.id] = VALID
    return consts, functions


def _const_fold(node: ast.AST) -> Any:
    if isinstance(node, ast.Constant):
        return node.value
    if isinstance(node, ast.BinOp):
        a, b = _const_fold(node.left), _const_fold(node.right)
        if isinstance(a, (int, float)) and isinstance(b, (int, float)):
            return _Interp._arith(node.op, a, b)
    raise _Unsupported("non-constant module assignment")


# -- the fused-kernel harness ------------------------------------------------

# parameter-name → role convention shared by the shipped kernels and the
# test fixtures (names are the contract; unknown names fail closed)
_FUSED_TABLE_INPUTS = ("t0", "t0b", "t0e")
_FUSED_VEC_INPUTS = ("wa", "wb", "cum", "uf", "ub", "toff", "tpre")
_FUSED_MAT_INPUTS = ("mn", "ma")
_FUSED_TABLES = ("t", "tb", "te")  # carried outputs checked for validity
_FUSED_SCRATCH = ("r", "lm", "lmb", "lme", "lmb3")  # carried, unchecked


@dataclasses.dataclass(frozen=True)
class FusedCase:
    L: int
    BR: int
    allow_fall: bool = True
    host_on: bool = False

    def describe(self) -> str:
        return (
            f"L={self.L} BR={self.BR} allow_fall={self.allow_fall}"
            + (f" host_on={self.host_on}" if self.host_on else "")
        )


DEFAULT_FUSED_CASES: Tuple[FusedCase, ...] = tuple(
    FusedCase(L, BR, af)
    for L in (1, 2, 3, 5)
    for BR in (1, 2, 3)
    for af in (False, True)
    if BR <= max(L, 1)
)


def _fused_contract(case: FusedCase) -> Dict[str, Any]:
    """Shapes and concrete offsets, mirrored from ``ops._FusedOperands``."""
    L, BR = case.L, case.BR
    sizes = [L + 1 - d for d in range(L + 1)]
    off = [0]
    for s in sizes:
        off.append(off[-1] + s)
    ncells = off[-1]
    nrows = ncells + 2 * L + BR
    vec = 2 * L + BR + 2
    rt = -(-max(L, 1) // BR)
    return {
        "off": off,
        "ncells": ncells,
        "nrows": nrows,
        "vec": vec,
        "rt": rt,
        "W": 4,  # columns are untracked; any width >= 2 works
    }


def _make_fused_bufs(
    kernel: ast.FunctionDef, case: FusedCase, contract: Dict[str, Any]
) -> Tuple[Dict[str, Buf], List[Buf]]:
    L = case.L
    nrows, vec, rt = contract["nrows"], contract["vec"], contract["rt"]
    W = contract["W"]
    bufs: Dict[str, Buf] = {}
    tables: List[Buf] = []
    base_valid = [i < L + 1 for i in range(nrows)]  # band 0 is real
    for p in kernel.args.args:
        name = p.arg
        if not name.endswith("_ref"):
            raise _Unsupported(f"positional param {name!r} is not a ref")
        short = name[:-4]
        if short in _FUSED_TABLE_INPUTS:
            bufs[name] = Buf(
                name, (nrows, W), readonly=True, valid=list(base_valid)
            )
        elif short == "off":
            bufs[name] = Buf(
                name,
                (len(contract["off"]),),
                readonly=True,
                values=list(contract["off"]),
            )
        elif short in _FUSED_VEC_INPUTS:
            bufs[name] = Buf(name, (vec,), readonly=True)
        elif short in _FUSED_MAT_INPUTS:
            bufs[name] = Buf(
                name, (max(L, 1), rt * case.BR), readonly=True
            )
        elif short in _FUSED_TABLES:
            b = Buf(
                name, (nrows, W), readonly=False, valid=[False] * nrows
            )
            bufs[name] = b
            tables.append(b)
        elif short in _FUSED_SCRATCH:
            bufs[name] = Buf(
                name, (nrows, W), readonly=False, valid=[False] * nrows
            )
        else:
            raise _Unsupported(
                f"parameter {name!r} outside the dp_fill name contract"
            )
    return bufs, tables


def analyze_fused_kernel(
    path: str,
    kernel_name: str,
    cases: Sequence[FusedCase] = DEFAULT_FUSED_CASES,
    offload: bool = False,
) -> List[KernelIssue]:
    """Run the lattice interpreter over one fused kernel for every case;
    returns all issues (empty = machine-checked safe on the case matrix)."""
    consts, functions = _load_module(path)
    if kernel_name not in functions:
        return [
            KernelIssue(
                kernel_name, "unsupported", f"kernel not found in {path}"
            )
        ]
    kernel = functions[kernel_name]
    issues: List[KernelIssue] = []
    all_cases = list(cases)
    if offload:
        all_cases = [
            dataclasses.replace(c, host_on=h)
            for c in cases
            for h in (False, True)
        ]
    for case in all_cases:
        contract = _fused_contract(case)
        interp = _Interp(
            dict(consts), functions, issues, kernel_name, case.describe()
        )
        try:
            bufs, tables = _make_fused_bufs(kernel, case, contract)
            env: Dict[str, Any] = dict(bufs)
            for kw in kernel.args.kwonlyargs:
                name = kw.arg
                env[name] = {
                    "L": case.L,
                    "W": contract["W"],
                    "BR": case.BR,
                    "allow_fall": case.allow_fall,
                    "host_on": case.host_on,
                }.get(name)
                if env[name] is None:
                    raise _Unsupported(f"unknown kw-only param {name!r}")
            rt = contract["rt"]
            before = len(issues)
            for pd in range(case.L):  # band dim, outer
                for pi in range(rt):  # row tiles, innermost (sequential)
                    interp.pids = (pd, pi)
                    interp.exec_many(kernel.body, dict(env))
            for tb in tables:
                assert tb.valid is not None
                bad = [
                    r
                    for r in range(contract["ncells"])
                    if not tb.valid[r]
                ]
                if bad:
                    interp.issue(
                        "final-invalid",
                        f"{len(bad)} real row(s) of {tb.name} end the "
                        f"grid with garbage lanes (first: {bad[:4]})",
                    )
            del before
        except _Unsupported as e:
            issues.append(
                KernelIssue(
                    kernel_name,
                    "unsupported",
                    str(e),
                    case.describe(),
                )
            )
        except _IssueStop:
            pass
    return issues


# -- the per-band harness ----------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BandCase:
    nt: int  # row tiles
    d: int   # splits (innermost grid dim)
    BR: int = 2

    def describe(self) -> str:
        return f"nt={self.nt} d={self.d} BR={self.BR}"


DEFAULT_BAND_CASES: Tuple[BandCase, ...] = (
    BandCase(1, 1),
    BandCase(2, 2),
    BandCase(3, 3),
    BandCase(2, 4),
)


def _extract_pallas_call(
    wrapper: ast.FunctionDef,
) -> Tuple[ast.Call, Dict[str, ast.expr]]:
    assigns: Dict[str, ast.expr] = {}
    found: Optional[ast.Call] = None
    for node in ast.walk(wrapper):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name):
                assigns[tgt.id] = node.value
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Attribute) and fn.attr == "pallas_call":
                found = node
            elif isinstance(fn, ast.Call):
                inner = fn.func
                if (
                    isinstance(inner, ast.Attribute)
                    and inner.attr == "pallas_call"
                ):
                    found = fn
    if found is None:
        raise _Unsupported("no pallas_call in wrapper")
    return found, assigns


def _resolve_specs(
    node: ast.expr, assigns: Dict[str, ast.expr]
) -> List[ast.Call]:
    """Resolve an ``out_specs`` expression to a list of BlockSpec calls."""
    seen = 0
    while isinstance(node, ast.Name) and node.id in assigns and seen < 5:
        node = assigns[node.id]
        seen += 1
    if isinstance(node, ast.List):
        out: List[ast.Call] = []
        for e in node.elts:
            out.extend(_resolve_specs(e, assigns))
        return out
    if isinstance(node, ast.Call):
        fn = node.func
        if isinstance(fn, ast.Attribute) and fn.attr == "BlockSpec":
            return [node]
    raise _Unsupported("out_specs is not a (list of) literal BlockSpec")


def analyze_band_kernel(
    path: str,
    wrapper_name: str,
    kernel_name: str,
    cases: Sequence[BandCase] = DEFAULT_BAND_CASES,
) -> List[KernelIssue]:
    """Check a per-band kernel + its driver's BlockSpecs: output index maps
    constant along the innermost (split) dim and row-disjoint across tiles,
    and the init/accumulate guard discipline actually initializes every
    output row before it is read (via the validity lattice)."""
    consts, functions = _load_module(path)
    issues: List[KernelIssue] = []
    if kernel_name not in functions or wrapper_name not in functions:
        return [
            KernelIssue(
                kernel_name,
                "unsupported",
                f"kernel/wrapper not found in {path}",
            )
        ]
    kernel = functions[kernel_name]
    for case in cases:
        interp = _Interp(
            dict(consts), functions, issues, kernel_name, case.describe()
        )
        try:
            call, assigns = _extract_pallas_call(functions[wrapper_name])
            out_specs_kw = next(
                (k.value for k in call.keywords if k.arg == "out_specs"),
                None,
            )
            if out_specs_kw is None:
                raise _Unsupported("pallas_call has no out_specs kwarg")
            specs = _resolve_specs(out_specs_kw, assigns)
            # evaluate each out index_map over the whole grid
            maps: List[List[List[int]]] = []  # [spec][i][origin-row]
            lam_env = {
                "block_rows": case.BR,
                "w": 4,
                "d": case.d,
                "ns_pad": case.nt * case.BR,
            }
            for spec in specs:
                if len(spec.args) < 2:
                    raise _Unsupported("BlockSpec without index_map")
                lam = spec.args[1]
                origins: List[List[int]] = []
                for i in range(case.nt):
                    row: List[int] = []
                    for j in range(case.d):
                        fv = FuncVal(lam, dict(lam_env))
                        got = interp.call_func(fv, [i, j])
                        if not (
                            isinstance(got, tuple)
                            and isinstance(got[0], int)
                        ):
                            raise _Unsupported(
                                "index_map origin is not concrete"
                            )
                        row.append(got[0])
                    origins.append(row)
                maps.append(origins)
            for si, origins in enumerate(maps):
                for i, row in enumerate(origins):
                    if any(o != row[0] for o in row):
                        interp.issue(
                            "grid-race",
                            f"out spec {si}: block origin varies along "
                            f"the innermost (split) dim at tile {i} — "
                            f"the accumulator is not revisited",
                        )
                firsts = [row[0] for row in origins]
                if len(set(firsts)) != len(firsts):
                    interp.issue(
                        "grid-race",
                        f"out spec {si}: row tiles alias "
                        f"(origins {firsts}) — writes are not disjoint",
                    )
            # lattice pass over the kernel body on the same grid
            nrows = case.nt * case.BR
            outs: List[Buf] = []
            bufs: Dict[str, Buf] = {}
            n_out = len(specs)
            params = [a.arg for a in kernel.args.args]
            for name in params[: len(params) - n_out]:
                bufs[name] = Buf(name, (nrows,), readonly=True)
            for k, name in enumerate(params[len(params) - n_out:]):
                b = Buf(
                    name,
                    (nrows,),
                    readonly=False,
                    valid=[False] * nrows,
                )
                bufs[name] = b
                outs.append(b)
            for i in range(case.nt):
                for j in range(case.d):
                    interp.pids = (i, j)
                    for k, b in enumerate(outs):
                        o = maps[k][i][j] * case.BR
                        b.window = (o, o + case.BR)
                    for name in params[: len(params) - n_out]:
                        bufs[name].window = (0, case.BR)
                    interp.exec_many(kernel.body, dict(bufs))
            for b in outs:
                assert b.valid is not None
                bad = [r for r in range(nrows) if not b.valid[r]]
                if bad:
                    interp.issue(
                        "final-invalid",
                        f"{len(bad)} row(s) of {b.name} never receive a "
                        f"valid write (first: {bad[:4]}) — the j==0 "
                        f"init is missing or reads the accumulator",
                    )
        except _Unsupported as e:
            issues.append(
                KernelIssue(
                    kernel_name, "unsupported", str(e), case.describe()
                )
            )
        except _IssueStop:
            pass
    return issues


# -- public entry points -----------------------------------------------------


def dp_fill_kernel_path() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.join(
        os.path.dirname(here), "kernels", "dp_fill", "kernel.py"
    )


def analyze_dp_fill(path: Optional[str] = None) -> List[KernelIssue]:
    """Analyze all four shipped dp_fill kernels (the CI gate)."""
    path = path or dp_fill_kernel_path()
    issues: List[KernelIssue] = []
    issues += analyze_band_kernel(
        path, "band_min_two_tier", "_band_min_kernel"
    )
    issues += analyze_band_kernel(
        path, "band_min_offload", "_band_min_offload_kernel"
    )
    issues += analyze_fused_kernel(path, "_fused_two_tier_kernel")
    issues += analyze_fused_kernel(
        path, "_fused_offload_kernel", offload=True
    )
    return issues


def cache_key() -> str:
    """Fingerprint of the solver + kernel sources — analysis results are
    valid exactly as long as this matches
    :func:`repro.core.solver_cache.code_fingerprint`."""
    from ..core.solver_cache import code_fingerprint

    return code_fingerprint()
