"""``python -m repro.check`` — the static-checks CI gate.

Runs the repo-invariant linter over every module under ``src/repro`` and the
kernel analyzer over the shipped ``kernels/dp_fill`` Pallas kernels; exits
non-zero on any finding.  Pure AST work: no jax, no kernel execution, safe
in any environment.

The kernel analysis is cached on
:func:`repro.core.solver_cache.code_fingerprint` (which hashes the solver +
kernel sources): an unchanged tree skips straight to "cached ok".  Pass
``--force`` to re-analyze regardless, ``--no-cache`` to skip reading and
writing the stamp (CI uses ``--force`` so the gate never trusts a stamp).
"""

from __future__ import annotations

import argparse
import os
import sys

from .kernel_analyzer import analyze_dp_fill
from .lint import lint_repo


def _stamp_path() -> str:
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache")
    return os.path.join(base, "repro", "kernel-analysis.ok")


def _fingerprint() -> str:
    from ..core.solver_cache import code_fingerprint

    return code_fingerprint()


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="repro static checks: repo lint + Pallas kernel analysis",
    )
    parser.add_argument("--force", action="store_true",
                        help="re-run the kernel analysis even if the code "
                             "fingerprint matches the cached pass")
    parser.add_argument("--no-cache", action="store_true",
                        help="neither read nor write the analysis stamp")
    parser.add_argument("--skip-kernels", action="store_true",
                        help="run only the repo linter")
    args = parser.parse_args(argv)

    failed = False

    lint = lint_repo()
    if lint:
        failed = True
        print(f"lint: {len(lint)} violation(s)")
        for v in lint:
            print(f"  {v}")
    else:
        print("lint: ok")

    if not args.skip_kernels:
        fp = _fingerprint()
        stamp = _stamp_path()
        cached = False
        if not args.force and not args.no_cache:
            try:
                with open(stamp, "r", encoding="utf-8") as f:
                    cached = f.read().strip() == fp
            except OSError:
                cached = False
        if cached:
            print(f"kernel-analysis: cached ok ({fp[:12]})")
        else:
            issues = analyze_dp_fill()
            if issues:
                failed = True
                print(f"kernel-analysis: {len(issues)} issue(s)")
                for i in issues:
                    print(f"  {i}")
            else:
                print(f"kernel-analysis: ok ({fp[:12]})")
                if not args.no_cache:
                    try:
                        os.makedirs(os.path.dirname(stamp), exist_ok=True)
                        with open(stamp, "w", encoding="utf-8") as f:
                            f.write(fp + "\n")
                    except OSError:
                        pass
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
