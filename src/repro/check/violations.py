"""Structured violation reports shared by the static verifier and the
dynamic simulator.

Stdlib-only and dependency-free on purpose: ``repro.core.schedule`` imports
this lazily from ``assert_valid`` (so the dynamic cross-check raises the same
:class:`Violation` the static verifier reports) and nothing here may import
back into ``repro.core`` or ``repro.plan``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

# The closed set of violation kinds both the static verifier
# (check/schedule_verifier.py) and the simulator (core/schedule.py) emit.
# Tests key on these — add, never rename.
VIOLATION_KINDS = (
    "bad-stage",        # stage/activation index outside 1..L+1 (or 0..L)
    "bad-op",           # unknown op kind
    "missing-input",    # forward/backward needs a^{l-1}, neither a nor ā live
    "missing-grad",     # B^l needs δ^l
    "missing-residual", # B^l needs ā^l
    "free-not-live",    # Free of an item that is not live
    "no-host-tier",     # Foff/Prefetch on a chain without an enabled host tier
    "offload-not-bare", # Foff of a^i that is not live as a bare activation
    "double-offload",   # Foff of a^i that already has a host copy
    "prefetch-no-copy", # Prefetch of a^i with no (completed-or-launched) Foff
    "prefetch-resident",# Prefetch of a^i that is already on device
    "device-budget",    # during-op device memory exceeds the budget
    "host-budget",      # host-tier memory exceeds the host budget
    "slot-discipline",  # discretized (slot-granular) accounting exceeds S slots
    "no-output",        # schedule ends without δ^0 live
    "non-persistent",   # a checkpointed value was dropped before its B use
    "metadata-drift",   # plan's stored makespan/peaks disagree with the model
    "store-corrupt",    # stored plan failed the envelope/fingerprint check
)


@dataclasses.dataclass(frozen=True)
class Violation:
    """One rule failure, anchored to an op position and the lattice state.

    ``op_index`` is the 0-based position in ``schedule.ops`` (-1 for
    whole-schedule violations such as ``no-output``); ``state`` is a short
    human-readable residency summary (device items, host copies) at the
    moment the rule fired.
    """

    kind: str
    message: str
    op_index: int = -1
    op: Optional[Tuple[str, object]] = None
    state: str = ""

    def __post_init__(self):
        if self.kind not in VIOLATION_KINDS:
            raise ValueError(f"unknown violation kind {self.kind!r}")

    def __str__(self) -> str:
        where = f" at op[{self.op_index}]={self.op}" if self.op_index >= 0 else ""
        lattice = f" [{self.state}]" if self.state else ""
        return f"{self.kind}: {self.message}{where}{lattice}"


@dataclasses.dataclass
class VerificationReport:
    """The result of one static verification pass over a schedule.

    ``rules`` names the rule families that actually ran (budget rules are
    skipped when the plan has no profiled chain); ``truncated`` is set when
    violation collection stopped at the cap.
    """

    violations: List[Violation] = dataclasses.field(default_factory=list)
    rules: List[str] = dataclasses.field(default_factory=list)
    truncated: bool = False

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def first_kind(self) -> Optional[str]:
        return self.violations[0].kind if self.violations else None

    def merge(self, other: "VerificationReport") -> "VerificationReport":
        self.violations.extend(other.violations)
        for r in other.rules:
            if r not in self.rules:
                self.rules.append(r)
        self.truncated = self.truncated or other.truncated
        return self

    def summary(self) -> str:
        head = (f"{len(self.violations)} violation(s)"
                + (" (truncated)" if self.truncated else "")
                if self.violations else "ok")
        lines = [f"schedule verification: {head} "
                 f"(rules: {', '.join(self.rules) or 'none'})"]
        lines += [f"  - {v}" for v in self.violations]
        return "\n".join(lines)


class PlanVerificationError(ValueError):
    """A :class:`~repro.plan.MemoryPlan` failed static verification.

    Raised by ``MemoryPlan.save``/``load`` (always) and by
    ``bind``/``execute`` when ``REPRO_CHECK=1``.  Carries the full report.
    """

    def __init__(self, report: VerificationReport, context: str = ""):
        self.report = report
        prefix = f"{context}: " if context else ""
        super().__init__(prefix + report.summary())
