"""`repro.check` — static analysis for plans, kernels, and repo invariants.

The planning stack's value proposition is that a *computed* schedule is
provably valid; this package is where "provably" stops meaning "we ran it
once and it did not crash".  Three passes, all importable without jax:

- :mod:`repro.check.schedule_verifier` — an abstract interpreter over
  :class:`~repro.core.schedule.Schedule` op streams.  It tracks a
  liveness-and-residency lattice per activation (absent / bare /
  full-history / host-copy) and proves, without simulating, that every
  backward has its required state, nothing is used after free, the offload
  protocol is respected, slot discipline holds, and symbolic device/host
  peaks never exceed the plan's budget.  Surfaced as
  :meth:`repro.plan.MemoryPlan.verify` (enforced on ``save``/``load``,
  opt-in before ``bind``/``execute`` via ``REPRO_CHECK=1``).
- :mod:`repro.check.kernel_analyzer` — a static pass over the
  :mod:`repro.kernels.dp_fill` Pallas kernel *sources* (AST, never
  imported): write-disjointness across grid steps for non-revisited blocks,
  write-before-read domination for the fused fill's revisited output
  blocks, and in-bounds dynamic slices given the padded row heights — the
  machine-checked replacement for PR 5's hand proofs, re-run whenever
  :func:`repro.core.solver_cache.code_fingerprint` changes.
- :mod:`repro.check.lint` — an AST linter for the invariants previous PRs
  asserted ad-hoc: no module-level jax import in the numpy-only core/obs
  modules, no policy-string parsing outside ``plan/compat.py``, metric
  names in the dotted ``noun.verb`` registry convention.

``python -m repro.check`` runs the linter and the kernel analyzer as a CI
gate (the ``static-checks`` job).
"""

from .kernel_analyzer import KernelIssue, analyze_dp_fill
from .lint import LintViolation, lint_paths, lint_repo
from .schedule_verifier import verify_schedule, verify_slot_discipline
from .violations import (
    VIOLATION_KINDS,
    PlanVerificationError,
    VerificationReport,
    Violation,
)

__all__ = [
    "KernelIssue",
    "VIOLATION_KINDS",
    "LintViolation",
    "PlanVerificationError",
    "VerificationReport",
    "Violation",
    "analyze_dp_fill",
    "lint_paths",
    "lint_repo",
    "verify_schedule",
    "verify_slot_discipline",
]
