"""Policy-string compatibility: the one place the historical string grammar
(``"rotor:x0.6"``, ``"optimal_offload:8G:12G"``, …) is parsed.

Each documented policy maps onto exactly one typed
:class:`~repro.plan.PlanRequest` (:func:`policy_to_request` — the migration
table), and :func:`resolve_policy` is the single resolution path both
``make_policy_tree`` and ``make_policy_plan`` (in
:mod:`repro.core.policies`) go through.  No other module in the repo
dispatches on policy-string prefixes.
"""

from __future__ import annotations

from typing import Optional

from ..core.chain import Chain, HostTransferModel
from .api import build_plan
from .plan import MemoryPlan
from .request import Budget, PlanRequest, parse_size

#: Every documented policy form (exercised by the back-compat test suite).
DOCUMENTED_POLICIES = ("none", "full", "periodic:K", "rotor:BUDGET",
                       "revolve:BUDGET", "optimal_offload:BUDGET[:BW]")


def policy_to_request(policy: str, num_slots: Optional[int] = None,
                      impl: Optional[str] = None) -> PlanRequest:
    """The translation table: one policy string → one typed request.

    ``num_slots`` and ``impl`` ride along unchanged (policy strings never
    encoded them); ``impl`` accepts every ``dp_kernels.KNOWN_IMPLS`` value —
    ``"banded"``, ``"pallas"`` (the per-band Pallas kernel),
    ``"pallas_fused"`` (the single-dispatch device-resident fill), or
    ``"reference"`` — validated by :class:`PlanRequest`.

    =============================  ==========================================
    policy string                  PlanRequest equivalent
    =============================  ==========================================
    ``none``                       ``strategy="store_all"``
    ``full``                       ``strategy="full_remat"``
    ``periodic:K``                 ``strategy="periodic", segments=K``
    ``rotor:B``                    ``strategy="optimal", budget=parse(B)``
    ``rotor:auto``                 …, ``budget=Budget.auto(),
                                   on_infeasible="min_memory"``
    ``revolve:B``                  ``strategy="revolve", budget=parse(B)``
    ``optimal_offload:B[:BW]``     ``strategy="optimal",
                                   tiers=("device","host")``, ``host`` from BW
                                   (``BW=0`` → ``tiers=("device",)``)
    =============================  ==========================================
    """
    kw = dict(num_slots=num_slots, impl=impl)
    if policy == "none":
        return PlanRequest(strategy="store_all", **kw)
    if policy == "full":
        return PlanRequest(strategy="full_remat", **kw)
    if policy.startswith("periodic:"):
        spec = policy.split(":", 1)[1]
        try:
            k = int(spec)
        except ValueError:
            raise ValueError(f"periodic policy needs an integer segment "
                             f"count, got {spec!r}") from None
        return PlanRequest(strategy="periodic", segments=k, **kw)
    if policy.startswith(("rotor:", "revolve:")):
        kind, spec = policy.split(":", 1)
        budget = Budget.parse(spec)
        return PlanRequest(
            strategy="optimal" if kind == "rotor" else "revolve",
            budget=budget,
            on_infeasible="min_memory" if budget.kind == "auto" else "raise",
            **kw)
    if policy.startswith("optimal_offload"):
        parts = policy.split(":")
        if len(parts) < 2:
            raise ValueError(
                "optimal_offload policy needs a budget: 'optimal_offload:"
                "BUDGET[:BW]'")
        budget = Budget.parse(parts[1])
        tiers, host = ("device", "host"), None
        if len(parts) >= 3:
            bw = parse_size(parts[2])
            if bw > 0:
                host = HostTransferModel(bandwidth_d2h=bw)
            else:
                # zero host bandwidth: the third tier does not exist
                tiers = ("device",)
        return PlanRequest(strategy="optimal", budget=budget, tiers=tiers,
                           host=host, **kw)
    raise ValueError(f"unknown remat policy {policy!r}")


def resolve_policy(policy: str, chain: Optional[Chain],
                   length: Optional[int] = None,
                   num_slots: Optional[int] = None,
                   impl: Optional[str] = None,
                   auto_budget=None) -> MemoryPlan:
    """The single resolution path: policy string → :class:`MemoryPlan`.
    Both ``make_policy_plan`` and ``make_policy_tree`` go through here —
    there is no second offload-handling branch to drift."""
    request = policy_to_request(policy, num_slots=num_slots, impl=impl)
    if request.strategy in ("optimal", "revolve") and chain is None:
        raise ValueError(f"{policy!r} needs a profiled chain")
    return build_plan(request, chain, length=length, auto_budget=auto_budget,
                      policy=policy)


def parse_budget(spec: str, chain: Optional[Chain]) -> float:
    """Budget in bytes: absolute size, or ``x0.5`` as a fraction of the
    chain's store-all activation peak."""
    b = Budget.parse(spec)
    if b.kind == "auto":
        raise ValueError(
            "'auto' budgets resolve only through the launch path (they need "
            "the per-device HBM and parameter footprint); pass bytes or a "
            "fraction like 'x0.5'")
    return b.resolve(chain)
