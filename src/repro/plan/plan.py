"""The :class:`MemoryPlan` artifact: a solved, inspectable, serializable
memory plan with a uniform executor binding.

A plan carries the op :class:`~repro.core.schedule.Schedule` (always), the
recursion tree (always present; remat-expressible iff it contains no offload
node), the solver :class:`~repro.core.solver.Solution` (for solver-backed
strategies), and the predicted makespan / device & host peaks from the
float64 simulator.  It answers the three questions call sites used to answer
with ad-hoc ``startswith("optimal_offload")`` branching:

- *how do I run this?* — :meth:`MemoryPlan.bind` returns a :class:`BoundPlan`
  whose ``value_and_grad`` is the jitted nested-remat function when the plan
  is remat-expressible, and the eager offload executor when it is not
  (``bound.jittable`` tells you which); :meth:`MemoryPlan.execute` always
  runs the exact op sequence through the faithful eager executor.
- *what does it cost?* — :meth:`summary` (human), :meth:`stats` (JSON), and
  :meth:`timeline` (per-op start/end time + device/host memory).
- *can I reuse it?* — :meth:`save` / :meth:`load` round-trip the plan through
  a path or a store URI (``file://<path>``, ``store://<namespace>/<key>``
  into the process default :mod:`repro.store`); the
  :mod:`repro.store.codec` envelope embeds the chain / request / code
  fingerprints, and loading against a diverged chain raises
  :class:`StalePlanError` naming exactly which component moved.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..core.chain import Chain
from ..core.schedule import Schedule, simulate, uses_offload
from ..core.solver import Solution
from ..core.solver_cache import chain_fingerprint, code_fingerprint
from ..store.codec import CorruptEntryError, decode, encode
from .request import PlanRequest

_PLAN_VERSION = 2
_PLAN_KIND = "memory-plan"
#: Envelope key for path-backed saves (store-backed saves use the store
#: key, which is cross-checked against renames by the codec).
_PLAN_FILE_KEY = "plan"
_STORE_SCHEME = "store://"
_FILE_SCHEME = "file://"


class StalePlanError(ValueError):
    """A saved plan was loaded against a chain it was not solved for."""


class InfeasiblePlanError(MemoryError):
    """No feasible schedule exists for the request (budget too small)."""


@dataclasses.dataclass
class MemoryPlan:
    """A resolved memory plan for one chain.

    ``tree`` is the recursion tree (two-tier :class:`~repro.core.solver.Tree`
    nodes, plus :class:`~repro.offload.solver.OffNode` for host-tier plans);
    ``schedule`` is the equivalent flat op sequence.  ``expected_time`` /
    peaks are float64-simulator numbers (NaN when the plan was built from a
    bare length with no profiled chain).
    """

    request: PlanRequest
    schedule: Schedule
    tree: Optional[Any]
    solution: Optional[Solution]
    chain: Optional[Chain]
    chain_hash: Optional[str]
    budget_bytes: Optional[float]
    expected_time: float
    peak_device_mem: float
    peak_host_mem: float
    transfer_stall: float
    policy: Optional[str] = None    # originating policy string, via the shim

    # -- introspection -----------------------------------------------------

    @property
    def length(self) -> int:
        return self.schedule.length

    @property
    def uses_offload(self) -> bool:
        """True if the schedule needs the host tier (Foff/Prefetch ops)."""
        return uses_offload(self.schedule)

    @property
    def remat_expressible(self) -> bool:
        """True if the plan compiles to nested ``jax.checkpoint`` scopes
        (host DMA cannot be expressed from a remat tree)."""
        return self.tree is not None and not self.uses_offload

    def op_counts(self) -> dict:
        counts: dict = {}
        for k, _ in self.schedule.ops:
            counts[k] = counts.get(k, 0) + 1
        return counts

    def recompute_factor(self) -> float:
        """Mean number of forward executions per stage (1.0 = no recompute)."""
        fc = self.schedule.forward_counts()
        return sum(fc.values()) / max(len(fc), 1)

    def timeline(self) -> List[dict]:
        """Per-op records ``{"op", "arg", "t_start", "t_end", "device_mem",
        "host_mem"}`` from the float64 simulator (needs a profiled chain)."""
        if self.chain is None:
            raise ValueError("timeline() needs a plan built from a profiled "
                             "chain, not a bare length")
        rows: List[dict] = []
        res = simulate(self.chain, self.schedule, trace=rows)
        if not res.valid:
            raise AssertionError(f"plan schedule does not simulate: "
                                 f"{res.error}")
        return rows

    def stats(self) -> dict:
        """JSON-serializable description (recorded by dry-run artifacts)."""
        return {
            "strategy": self.request.strategy,
            "tiers": "+".join(self.request.tiers),
            "policy": self.policy,
            "num_slots": self.request.resolved_num_slots,
            "slots_used": (self.solution.slots_used
                           if self.solution is not None else None),
            "budget_bytes": self.budget_bytes,
            "expected_time_s": self.expected_time,
            "peak_device_mem": self.peak_device_mem,
            "peak_host_mem": self.peak_host_mem,
            "transfer_stall_s": self.transfer_stall,
            "ops": self.op_counts(),
            "recompute_factor": self.recompute_factor(),
            "uses_offload": self.uses_offload,
            "executor": ("eager-offload" if self.uses_offload
                         else "jit-nested-remat"),
            "chain_hash": self.chain_hash,
        }

    def summary(self) -> str:
        """Human-readable multi-line description of the plan."""
        c = self.op_counts()
        lines = [f"MemoryPlan[{self.request.describe()}]"
                 + (f" (policy {self.policy!r})" if self.policy else "")]
        if self.chain is not None:
            lines.append(f"  chain: L={self.length} stages, "
                         f"hash {self.chain_hash[:12]}")
        else:
            lines.append(f"  chain: L={self.length} stages (no profile)")
        if self.budget_bytes is not None:
            used = (f", {self.solution.slots_used}/"
                    f"{self.request.resolved_num_slots} slots used"
                    if self.solution is not None else "")
            lines.append(f"  budget: {self.budget_bytes:.3e} B{used}")
        if self.expected_time == self.expected_time:  # not NaN
            lines.append(f"  predicted: {self.expected_time:.4f} s/iter, "
                         f"device peak {self.peak_device_mem:.3e} B, "
                         f"host peak {self.peak_host_mem:.3e} B, "
                         f"transfer stall {self.transfer_stall:.4f} s")
        ops = " ".join(f"{k}:{c[k]}" for k in
                       ("Fall", "Fck", "Fnone", "B", "Foff", "Prefetch")
                       if k in c)
        lines.append(f"  ops: {len(self.schedule)} ({ops}), "
                     f"recompute x{self.recompute_factor():.2f}")
        lines.append(f"  executor: "
                     f"{'eager offload (host DMA)' if self.uses_offload else 'jitted nested remat'}")
        return "\n".join(lines)

    # -- static verification ----------------------------------------------

    def verify(self, max_violations: int = 64):
        """Statically verify the plan's schedule against the liveness /
        offload-protocol / budget rules (:mod:`repro.check`); returns a
        :class:`~repro.check.VerificationReport`.

        Runs without executing anything: the abstract interpreter in
        ``check.schedule_verifier`` proves every backward has its required
        state, nothing is used after free, the offload protocol is
        respected, and (when the plan carries a profiled chain and budget)
        the symbolic device peak stays within ``budget_bytes``.  For
        solver-backed two-tier plans the slot-discretized accounting is
        additionally re-checked against the solver's slot budget.

        ``save``/``load`` call this unconditionally and raise
        :class:`~repro.check.PlanVerificationError`; ``bind``/``execute``
        call it when ``REPRO_CHECK=1`` is set in the environment.
        """
        from ..check import verify_schedule, verify_slot_discipline
        report = verify_schedule(
            self.schedule, chain=self.chain,
            device_budget=self.budget_bytes,
            max_violations=max_violations)
        if (self.chain is not None and self.solution is not None
                and self.budget_bytes is not None
                and self.request.strategy == "optimal"
                and not self.uses_offload):
            # re-quantizing against the plan budget is only sound for the
            # budget-driven two-tier solver (min-memory/offload solvers
            # discretize against a different reference scale)
            report.merge(verify_slot_discipline(
                self.schedule, self.chain, self.budget_bytes,
                self.request.resolved_num_slots,
                max_violations=max_violations))
        if (report.ok and self.chain is not None
                and self.expected_time == self.expected_time):  # not NaN
            report.merge(self._verify_metadata())
        return report

    def _verify_metadata(self):
        """Cross-check the plan's stored makespan/peaks against the float64
        cost model: a corruption that leaves the schedule *valid* but
        changes its behavior (e.g. a duplicated forward — correct result,
        different cost) still fails verification, because the numbers the
        plan advertises no longer describe the schedule it carries."""
        from ..check import VerificationReport, Violation
        res = simulate(self.chain, self.schedule)
        report = VerificationReport(rules=["metadata"])

        def drift(name, stored, got):
            if abs(got - stored) > 1e-9 * max(1.0, abs(stored)):
                report.violations.append(Violation(
                    kind="metadata-drift",
                    message=f"stored {name} {stored!r} but the schedule "
                            f"simulates to {got!r}"))

        drift("expected_time", self.expected_time, res.time)
        drift("peak_device_mem", self.peak_device_mem, res.peak_mem)
        drift("peak_host_mem", self.peak_host_mem, res.host_peak_mem)
        return report

    def _verify_or_raise(self, context: str) -> None:
        report = self.verify()
        if not report.ok:
            from ..check import PlanVerificationError
            raise PlanVerificationError(report, context=context)

    # -- execution ---------------------------------------------------------

    def bind(self, stages: Sequence[Callable],
             checkpoint_policy=None, tracer=None) -> "BoundPlan":
        """Bind per-stage callables to this plan: the uniform executor
        dispatch.  ``stages[l-1]`` is paper-stage ``l``; the result's
        ``value_and_grad`` runs the jitted remat tree when the plan is
        remat-expressible and the eager offload executor otherwise.

        ``tracer`` (a :class:`repro.obs.trace.Tracer`, opt-in) switches the
        binding onto the op-faithful executor with per-op
        ``jax.block_until_ready`` fences, so every execution emits one span
        per schedule op — the measured timeline for
        :func:`repro.obs.drift.compare`.  The untraced jitted fast path is
        untouched; tracing trades its fusion for per-op visibility (the
        binding reports ``jittable == False`` while traced)."""
        if os.environ.get("REPRO_CHECK") == "1":
            self._verify_or_raise("refusing to bind an invalid plan")
        return BoundPlan(self, list(stages), checkpoint_policy, tracer=tracer)

    def execute(self, stages: Sequence[Callable], params: Sequence[Any],
                x: Any, **kwargs) -> Tuple[Any, List[Any], Any]:
        """Run the exact op sequence through the faithful eager executor
        (host copies included); returns ``(out, param_grads, input_grad)``.
        Pass ``tracer=`` (a :class:`repro.obs.trace.Tracer`) to record one
        span per executed op."""
        if os.environ.get("REPRO_CHECK") == "1":
            self._verify_or_raise("refusing to execute an invalid plan")
        from ..core.executor import execute_schedule
        return execute_schedule(self.schedule, stages, params, x, **kwargs)

    def drift(self, trace) -> "Any":
        """Plan-vs-actual drift report for a trace recorded while executing
        this plan (:func:`repro.obs.drift.compare`)."""
        from ..obs.drift import compare
        return compare(self, trace)

    # -- persistence -------------------------------------------------------

    def validate_chain(self, chain: Chain) -> None:
        """Raise :class:`StalePlanError` unless ``chain`` is content-identical
        to the chain this plan was solved for."""
        got = chain_fingerprint(chain)
        if self.chain_hash is None:
            raise StalePlanError(
                "plan carries no chain hash (built from a bare length); "
                "cannot validate it against a profiled chain")
        if got != self.chain_hash:
            raise StalePlanError(
                f"plan was solved for chain {self.chain_hash[:12]}… but the "
                f"given chain hashes to {got[:12]}… — re-plan (costs, sizes "
                f"or the host link changed)")

    def to_payload(self) -> Dict[str, Any]:
        """The serialized form: the plan plus its full content address
        (chain × request × code fingerprints), so any later load can name
        exactly which component diverged."""
        from ..store.keys import request_digest
        return {
            "version": _PLAN_VERSION,
            "chain_hash": self.chain_hash,
            "request": request_digest(self.request),
            "code": code_fingerprint(),
            "plan": self,
        }

    def save(self, target: str) -> None:
        """Serialize the plan to ``target`` — a filesystem path,
        ``file://<path>``, or ``store://<namespace>/<key>`` (written into
        the process default :mod:`repro.store`).  The codec envelope embeds
        the chain/request/code fingerprints so :meth:`load` can refuse a
        mismatched chain and say why.  The plan is statically verified
        first — a corrupted schedule never reaches disk
        (:class:`~repro.check.PlanVerificationError`)."""
        self._verify_or_raise(f"refusing to save invalid plan to {target!r}")
        if target.startswith(_STORE_SCHEME):
            from ..store.config import default_store
            key = target[len(_STORE_SCHEME):]
            store = default_store(required=True)
            store.backend.put(key, encode(_PLAN_KIND, key, self.to_payload()))
            return
        path = (target[len(_FILE_SCHEME):]
                if target.startswith(_FILE_SCHEME) else target)
        data = encode(_PLAN_KIND, _PLAN_FILE_KEY, self.to_payload())
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                try:
                    os.unlink(tmp)
                except OSError:
                    pass

    @staticmethod
    def load(target: str, chain: Optional[Chain] = None,
             request: Optional[PlanRequest] = None) -> "MemoryPlan":
        """Load a saved plan from a path or URI (as :meth:`save`).

        With ``chain`` given, the plan is validated against it — always
        pass the chain you are about to execute on.  Staleness is reported
        per fingerprint component: the :class:`StalePlanError` names
        whether the *chain* (costs/sizes/host link), the *code* (solver
        sources), or — when ``request`` is given — the *request* diverged.
        The deserialized schedule is statically re-verified (a truncated or
        hand-edited plan file fails with
        :class:`~repro.check.PlanVerificationError`, not a crash at
        execution time)."""
        if target.startswith(_STORE_SCHEME):
            from ..store.config import default_store
            key = target[len(_STORE_SCHEME):]
            store = default_store(required=True)
            data = store.backend.get(key)
            if data is None:
                raise FileNotFoundError(f"no stored plan at {target!r}")
            envelope_key = key
        else:
            path = (target[len(_FILE_SCHEME):]
                    if target.startswith(_FILE_SCHEME) else target)
            with open(path, "rb") as f:
                data = f.read()
            envelope_key = _PLAN_FILE_KEY
        try:
            _, _, payload = decode(data, kind=_PLAN_KIND, key=envelope_key)
        except CorruptEntryError as e:
            raise ValueError(
                f"{target!r} is not a saved MemoryPlan ({e})") from e
        if not isinstance(payload, dict) or not isinstance(
                payload.get("plan"), MemoryPlan):
            raise ValueError(f"{target!r} does not contain a MemoryPlan")
        if payload.get("version") != _PLAN_VERSION:
            raise ValueError(
                f"saved plan {target!r} has payload version "
                f"{payload.get('version')!r}, this build reads "
                f"{_PLAN_VERSION}")
        plan: MemoryPlan = payload["plan"]
        if chain is not None:
            plan._check_staleness(target, payload, chain, request)
        plan._verify_or_raise(f"loaded plan {target!r} fails verification")
        return plan

    def _check_staleness(self, target: str, payload: Dict[str, Any],
                         chain: Chain,
                         request: Optional[PlanRequest]) -> None:
        """Component-wise fingerprint comparison: which of chain / code /
        request moved since the plan was saved."""
        from ..store.keys import request_digest
        diverged: List[str] = []
        if self.chain_hash is None:
            raise StalePlanError(
                "plan carries no chain hash (built from a bare length); "
                "cannot validate it against a profiled chain")
        if chain_fingerprint(chain) != self.chain_hash:
            diverged.append(
                "chain (costs, sizes or the host link changed)")
        stored_code = payload.get("code")
        if stored_code is not None and stored_code != code_fingerprint():
            diverged.append(
                "code (the solver sources changed since this plan was "
                "solved)")
        if request is not None:
            stored_req = payload.get("request")
            if stored_req is not None and (
                    stored_req != request_digest(request)):
                diverged.append(
                    "request (strategy/budget/tiers/slots/impl differ)")
        if diverged:
            raise StalePlanError(
                f"plan {target!r} is stale — fingerprint diverged in: "
                + "; ".join(diverged) + " — re-plan")


class BoundPlan:
    """A plan bound to concrete stage callables — one call surface for both
    execution backends.

    - ``jittable`` — True when the plan compiles to nested ``jax.checkpoint``
      scopes; ``forward``/``value_and_grad`` are then pure jit-able functions.
    - ``forward(params, x)`` — the chain's forward value.
    - ``value_and_grad(params, x)`` — ``(out, param_grads, input_grad)``;
      the remat path differentiates the composed function, the offload path
      runs the op-faithful eager executor (``jax.device_put`` copies and all).
    """

    def __init__(self, plan: MemoryPlan, stages: Sequence[Callable],
                 checkpoint_policy=None, tracer=None):
        self.plan = plan
        self.stages = list(stages)
        self.tracer = tracer
        self.traced = tracer is not None and getattr(tracer, "enabled", True)
        self.jittable = plan.remat_expressible and not self.traced
        if self.jittable:
            from ..core.rematerialize import build_remat_fn
            self._fn = build_remat_fn(plan.tree, self.stages,
                                      checkpoint_policy=checkpoint_policy)
        else:
            self._fn = None

    def forward(self, params: Sequence[Any], x: Any) -> Any:
        if self.jittable:
            return self._fn(params, x)
        out, _, _ = self._run_eager(params, x)
        return out

    def value_and_grad(self, params: Sequence[Any], x: Any
                       ) -> Tuple[Any, List[Any], Any]:
        if self.jittable:
            import jax
            out, (gp, gx) = jax.value_and_grad(
                self._fn, argnums=(0, 1))(params, x)
            return out, list(gp), gx
        return self._run_eager(params, x)

    def _run_eager(self, params, x):
        from ..offload.executor import execute_offload_schedule
        from ..offload.host_buffer import HostBuffer
        return execute_offload_schedule(self.plan.schedule, self.stages,
                                        params, x, host_buffer=HostBuffer(),
                                        tracer=self.tracer)

    def __repr__(self):
        mode = ("traced-eager" if self.traced
                else "jit-remat" if self.jittable else "eager-offload")
        return f"BoundPlan({mode}, L={self.plan.length})"
