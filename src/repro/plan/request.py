"""Typed planning requests: what a caller wants from the memory planner.

This module is the replacement for the stringly-typed policy surface: instead
of ``"rotor:x0.6"`` parsed by regex at every call site, callers build a
:class:`PlanRequest` — a budget (bytes, fraction of the store-all peak, or
``auto``), the storage tiers to plan over, an optional host-link override,
the slot discretization, and the DP kernel implementation — and hand it to
:func:`repro.plan.build_plan`.  The old policy strings still work through the
:mod:`repro.core.policies` shim, which maps each string onto exactly one
``PlanRequest`` (see :func:`repro.core.policies.policy_to_request`).

Size / budget grammar (shared by the shim):

- ``"1.5G"``, ``"800M"``, ``"2e9"``, ``"123"``, ``"0"`` — absolute sizes,
  with optional K/M/G/T decimal suffix (:func:`parse_size`);
- ``"x0.5"`` — a fraction of the chain's store-all activation peak;
- ``"auto"`` — derive the budget from launch context (HBM minus sharded
  parameter/optimizer state; only resolvable where that context exists).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Callable, Optional, Tuple, Union

from ..core.chain import Chain, HostTransferModel
from ..core.dp_kernels import KNOWN_IMPLS

#: Default slot count for the DP discretization (paper §5.2: the makespan
#: overestimation is at most a ``1 + 1/S`` factor).  Every entry point that
#: accepts ``num_slots=None`` resolves it here — one place to configure.
DEFAULT_NUM_SLOTS = 500

_UNITS = {"K": 1e3, "M": 1e6, "G": 1e9, "T": 1e12}

# a strict decimal-or-scientific literal: "1", "1.5", ".5", "2e9", "1.5E-3"
_NUMBER = r"(?:[0-9]+(?:\.[0-9]*)?|\.[0-9]+)(?:[eE][+-]?[0-9]+)?"
_SIZE_RE = re.compile(rf"({_NUMBER})\s*([KMGT]?)")
_FRACTION_RE = re.compile(rf"x({_NUMBER})")


def parse_size(spec: str) -> float:
    """Parse an absolute size: a non-negative number with an optional K/M/G/T
    suffix (``"1.5G"`` → 1.5e9).  Rejects anything else — including the
    garbage the old ``[\\d.eE+-]+`` regex let through (``"1e"``, ``"--5G"``,
    ``"1..5"``) — with a message naming the accepted forms."""
    m = _SIZE_RE.fullmatch(spec.strip())
    if not m:
        raise ValueError(
            f"cannot parse size {spec!r}: expected a number with an optional "
            f"K/M/G/T suffix, e.g. '1.5G', '800M', '2e9', '123'")
    return float(m.group(1)) * _UNITS.get(m.group(2), 1.0)


@dataclasses.dataclass(frozen=True)
class Budget:
    """A memory budget: absolute bytes, a fraction of the store-all peak, or
    ``auto`` (derived from launch context by the caller)."""

    kind: str           # "bytes" | "fraction" | "auto"
    value: float = 0.0

    _KINDS = ("bytes", "fraction", "auto")

    def __post_init__(self):
        if self.kind not in self._KINDS:
            raise ValueError(f"unknown budget kind {self.kind!r}; "
                             f"expected one of {self._KINDS}")
        if self.kind != "auto" and (self.value < 0 or self.value != self.value):
            raise ValueError(f"budget value must be non-negative, "
                             f"got {self.value!r}")

    # -- constructors ------------------------------------------------------

    @staticmethod
    def bytes(n: float) -> "Budget":
        return Budget("bytes", float(n))

    @staticmethod
    def fraction(f: float) -> "Budget":
        """Fraction of the chain's store-all activation peak."""
        return Budget("fraction", float(f))

    @staticmethod
    def auto() -> "Budget":
        """Budget derived from launch context (HBM − sharded param/opt
        states); resolvable only where the caller supplies that context."""
        return Budget("auto")

    @staticmethod
    def parse(spec: str) -> "Budget":
        """Parse the documented budget grammar: ``1.5G`` / ``800M`` / ``2e9``
        / ``123`` / ``0`` (bytes), ``x0.5`` (fraction), ``auto``."""
        spec = spec.strip()
        if spec == "auto":
            return Budget.auto()
        if spec.startswith("x"):
            m = _FRACTION_RE.fullmatch(spec)
            if not m:
                raise ValueError(
                    f"cannot parse fractional budget {spec!r}: expected "
                    f"'x' followed by a number, e.g. 'x0.5'")
            return Budget.fraction(float(m.group(1)))
        return Budget.bytes(parse_size(spec))

    # -- resolution --------------------------------------------------------

    def resolve(self, chain: Optional[Chain] = None, *,
                store_all_peak: Optional[float] = None,
                auto_budget: Union[float, Callable[[], float], None] = None,
                ) -> float:
        """The budget in bytes.  Fractions need ``chain`` (or an explicit
        ``store_all_peak``); ``auto`` needs ``auto_budget`` — a float or a
        zero-arg callable supplied by the launch path."""
        if self.kind == "bytes":
            return self.value
        if self.kind == "fraction":
            if store_all_peak is None:
                if chain is None:
                    raise ValueError("fractional budget needs a profiled chain")
                store_all_peak = chain.store_all_peak()
            return self.value * store_all_peak
        if auto_budget is None:
            raise ValueError(
                "auto budget needs launch context (per-device HBM and the "
                "sharded parameter/optimizer footprint) — pass auto_budget=, "
                "or use an explicit bytes/fraction budget")
        return float(auto_budget() if callable(auto_budget) else auto_budget)

    def describe(self) -> str:
        if self.kind == "bytes":
            return f"{self.value:.3e} B"
        if self.kind == "fraction":
            return f"x{self.value:g} of store-all peak"
        return "auto"


#: Strategies backed by a DP solve (need a chain; ``optimal``/``revolve``
#: also need a budget).
SOLVER_STRATEGIES = ("optimal", "revolve", "min_memory")
#: Strategies that are pure schedule structure (no solve; a bare ``length``
#: suffices when no profiled chain is at hand).
STRUCTURAL_STRATEGIES = ("store_all", "full_remat", "periodic")


@dataclasses.dataclass(frozen=True)
class PlanRequest:
    """A typed memory-planning request — the single argument of
    :func:`repro.plan.build_plan`.

    Fields:

    - ``strategy`` — ``"optimal"`` (the paper's DP), ``"revolve"`` (the
      AD-model comparator: ``F_all``-first branch disabled), ``"min_memory"``
      (smallest feasible budget; ignores ``budget``), or the structural
      baselines ``"store_all"`` / ``"full_remat"`` / ``"periodic"``.
    - ``budget`` — a :class:`Budget`; required for ``optimal``/``revolve``.
    - ``segments`` — segment count for ``periodic``.
    - ``tiers`` — storage tiers to plan over: ``("device",)`` is the paper's
      two-tier model, ``("device", "host")`` adds asynchronous host-RAM
      offload, ``("device", "kv")`` is the serving scenario (per-layer
      decode KV blocks staged to host RAM — see :mod:`repro.plan.serving`).
      The tier combo selects the solver through :mod:`repro.plan.registry`.
    - ``host`` — optional :class:`HostTransferModel` override; when the host
      tier is requested and this is ``None``, the chain's profiled link is
      used, falling back to the PCIe-3 x16 constant.
    - ``num_slots`` — DP discretization (``None`` → :data:`DEFAULT_NUM_SLOTS`).
    - ``impl`` — DP kernel implementation (``"banded"``/``"pallas"``/
      ``"pallas_fused"``/``"reference"``, see
      ``repro.core.dp_kernels.KNOWN_IMPLS``; ``None`` → the solver default /
      ``REPRO_DP_IMPL``).  ``"pallas"`` runs the band fill on the per-band
      Pallas kernel, ``"pallas_fused"`` on the single-dispatch
      device-resident fill (both jit on TPU, interpret-mode CPU fallback).
    - ``on_infeasible`` — ``"raise"`` (default: :class:`repro.plan
      .InfeasiblePlanError`) or ``"min_memory"`` (fall back to the
      smallest-memory feasible schedule and report its true need).
    """

    strategy: str = "optimal"
    budget: Optional[Budget] = None
    segments: int = 0
    tiers: Tuple[str, ...] = ("device",)
    host: Optional[HostTransferModel] = None
    num_slots: Optional[int] = None
    impl: Optional[str] = None
    on_infeasible: str = "raise"

    def __post_init__(self):
        known = SOLVER_STRATEGIES + STRUCTURAL_STRATEGIES
        if self.strategy not in known:
            raise ValueError(f"unknown plan strategy {self.strategy!r}; "
                             f"expected one of {known}")
        if self.strategy == "periodic" and self.segments < 1:
            raise ValueError("periodic strategy needs segments >= 1")
        if isinstance(self.tiers, list):
            object.__setattr__(self, "tiers", tuple(self.tiers))
        if not self.tiers or self.tiers[0] != "device":
            raise ValueError(f"tiers must start with 'device', "
                             f"got {self.tiers!r}")
        if self.on_infeasible not in ("raise", "min_memory"):
            raise ValueError(
                f"on_infeasible must be 'raise' or 'min_memory', "
                f"got {self.on_infeasible!r}")
        if self.impl is not None and self.impl not in KNOWN_IMPLS:
            raise ValueError(f"unknown DP impl {self.impl!r}; "
                             f"expected one of {KNOWN_IMPLS}")
        if self.num_slots is not None and self.num_slots < 1:
            raise ValueError("num_slots must be >= 1")

    @property
    def resolved_num_slots(self) -> int:
        return DEFAULT_NUM_SLOTS if self.num_slots is None else self.num_slots

    @property
    def allow_fall(self) -> bool:
        """The DP's ``F_all``-first branch is what `revolve` disables."""
        return self.strategy != "revolve"

    def describe(self) -> str:
        bits = [self.strategy, "+".join(self.tiers)]
        if self.budget is not None and self.strategy in ("optimal", "revolve"):
            bits.append(self.budget.describe())
        if self.strategy == "periodic":
            bits.append(f"k={self.segments}")
        bits.append(f"slots={self.resolved_num_slots}")
        if self.impl:
            bits.append(f"impl={self.impl}")
        return " ".join(bits)
