"""`repro.plan` entry points: ``build_plan`` (request → plan) and ``sweep``
(the time-vs-budget frontier).

``build_plan`` is the single place a planning decision is made: it resolves
the budget, picks the solver from the tier registry, runs it (through the
persistent solver cache), applies the infeasibility policy, and wraps the
result into a :class:`~repro.plan.plan.MemoryPlan` with simulator-exact
predicted numbers.  Everything above it — the policy-string shim, the train
loop, launch, benchmarks — only ever handles requests and plans.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Union

from ..core.chain import Chain, HostTransferModel
from ..core.schedule import Schedule, simulate
from ..core.solver import Solution, tree_to_schedule
from ..core.solver_cache import chain_fingerprint
from .plan import InfeasiblePlanError, MemoryPlan
from .registry import solver_for
from .request import STRUCTURAL_STRATEGIES, Budget, PlanRequest


def _structural_tree(request: PlanRequest, length: int):
    from ..core.rematerialize import (full_remat_tree, periodic_tree,
                                      sequential_tree)
    if request.strategy == "store_all":
        return sequential_tree(length)
    if request.strategy == "full_remat":
        return full_remat_tree(length)
    return periodic_tree(length, request.segments)


def _resolve_host(request: PlanRequest, chain: Chain) -> Chain:
    """For host-backed tier requests (``"host"`` for training activations,
    ``"kv"`` for serving-time KV blocks), attach the link model: explicit
    override → the chain's profiled link → the PCIe-3 x16 constant."""
    if not {"host", "kv"} & set(request.tiers):
        return chain
    host = request.host or chain.host or HostTransferModel.pcie_gen3()
    return chain.with_host(host)


def _finalize(request: PlanRequest, chain: Optional[Chain], tree,
              schedule: Schedule, solution: Optional[Solution],
              budget_bytes: Optional[float], policy: Optional[str]
              ) -> MemoryPlan:
    nan = float("nan")
    expected, peak_dev, peak_host, stall = nan, nan, nan, nan
    chain_hash = None
    if chain is not None:
        res = simulate(chain, schedule)
        if not res.valid:
            raise AssertionError(
                f"planned schedule does not simulate: {res.error}")
        expected, peak_dev = res.time, res.peak_mem
        peak_host, stall = res.host_peak_mem, res.transfer_stall
        chain_hash = chain_fingerprint(chain)
    return MemoryPlan(request=request, schedule=schedule, tree=tree,
                      solution=solution, chain=chain, chain_hash=chain_hash,
                      budget_bytes=budget_bytes, expected_time=expected,
                      peak_device_mem=peak_dev, peak_host_mem=peak_host,
                      transfer_stall=stall, policy=policy)


def build_plan(request: PlanRequest, chain: Optional[Chain] = None, *,
               length: Optional[int] = None,
               auto_budget: Union[float, Callable[[], float], None] = None,
               policy: Optional[str] = None) -> MemoryPlan:
    """Resolve a :class:`PlanRequest` into a :class:`MemoryPlan`.

    Structural strategies (``store_all``/``full_remat``/``periodic``) accept
    a bare ``length`` when no profiled chain is at hand (the plan then has
    NaN predicted numbers).  Solver strategies need ``chain``; ``auto``
    budgets additionally need ``auto_budget`` (a float or zero-arg callable
    supplied by the launch path).  ``policy`` tags the plan with the
    originating policy string when resolved through the compat shim.

    Raises :class:`InfeasiblePlanError` when no feasible schedule exists and
    ``request.on_infeasible == "raise"``; with ``"min_memory"`` it falls back
    to the smallest-memory feasible schedule (reporting its true budget).
    """
    num_slots = request.resolved_num_slots

    if request.strategy in STRUCTURAL_STRATEGIES:
        if chain is not None:
            length = chain.length
        if length is None:
            raise ValueError("need chain or length")
        tree = _structural_tree(request, length)
        schedule = tree_to_schedule(tree, length)
        return _finalize(request, chain, tree, schedule, None, None, policy)

    if chain is None:
        raise ValueError(f"strategy {request.strategy!r} needs a profiled "
                         f"chain")
    entry = solver_for(request.tiers)
    hchain = _resolve_host(request, chain)

    if request.strategy == "min_memory":
        sol = entry.solve_min(hchain, num_slots=num_slots,
                              allow_fall=request.allow_fall,
                              impl=request.impl)
        if not sol.feasible:
            raise InfeasiblePlanError(
                f"no feasible persistent schedule exists for this chain at "
                f"any budget (tiers {'+'.join(request.tiers)})")
        return _finalize(request, hchain, sol.tree, sol.schedule, sol,
                         sol.mem_limit, policy)

    if request.budget is None:
        raise ValueError(f"strategy {request.strategy!r} needs a budget")
    budget = request.budget.resolve(chain, auto_budget=auto_budget)
    sol = entry.solve(hchain, budget, num_slots=num_slots,
                      allow_fall=request.allow_fall, impl=request.impl)
    if not sol.feasible:
        if request.on_infeasible == "min_memory":
            fallback = entry.solve_min(hchain, num_slots=num_slots,
                                       allow_fall=request.allow_fall,
                                       impl=request.impl)
            if fallback.feasible:
                print(f"[plan] budget {budget/2**30:.2f} GiB infeasible; "
                      f"min-memory schedule needs "
                      f"{fallback.mem_limit/2**30:.2f} GiB of activations",
                      flush=True)
                return _finalize(request, hchain, fallback.tree,
                                 fallback.schedule, fallback,
                                 fallback.mem_limit, policy)
        tiers = "+".join(request.tiers)
        raise InfeasiblePlanError(
            f"{request.strategy}: no feasible persistent schedule within "
            f"{budget:.3e} bytes for this chain (tiers {tiers})")
    return _finalize(request, hchain, sol.tree, sol.schedule, sol, budget,
                     policy)


def two_tier_fallback(plan: MemoryPlan, chain: Optional[Chain] = None
                      ) -> MemoryPlan:
    """Best remat-expressible approximation of an offload-bearing plan: the
    two-tier optimum at the same device budget, degrading to the min-memory
    schedule when that budget is two-tier-infeasible.  Used by the jitted
    launch path, where XLA cannot express host DMA."""
    if not plan.uses_offload:
        return plan
    chain = chain if chain is not None else plan.chain
    request = dataclasses.replace(
        plan.request, tiers=("device",), host=None,
        budget=Budget.bytes(plan.solution.mem_limit),
        on_infeasible="min_memory")
    return build_plan(request, chain, policy=plan.policy)


@dataclasses.dataclass
class SweepPoint:
    """One point of a time-vs-budget frontier: ``plan`` is None when the
    budget is infeasible for the requested strategy/tiers."""
    fraction: float
    budget_bytes: float
    plan: Optional[MemoryPlan]

    @property
    def feasible(self) -> bool:
        return self.plan is not None


def _default_frontier():
    """The warm-start frontier over the process default store (None when
    the store is disabled)."""
    from ..store.config import default_store
    from ..store.frontier import WarmStartFrontier
    store = default_store()
    return WarmStartFrontier(store) if store is not None else None


def sweep(chain: Chain, fractions: Sequence[float],
          request: Optional[PlanRequest] = None, *,
          store_all_peak: Optional[float] = None,
          frontier: Optional[Any] = None,
          use_frontier: bool = True) -> List[SweepPoint]:
    """The time-vs-budget frontier: build one plan per budget fraction of the
    store-all peak (infeasible points yield ``plan=None`` instead of
    raising).  ``request`` is the template — its ``budget`` is replaced per
    point; defaults to the two-tier optimal strategy.

    Points are answered through the warm-start frontier
    (:class:`repro.store.WarmStartFrontier` — ``frontier`` overrides the
    default-store one; ``use_frontier=False`` opts out): a budget already
    recorded, bracketed by equal-time recorded points, or at/below a
    recorded infeasible budget costs **zero** solves, so a sweep over a
    cached chain is O(1) solves rather than one per fraction.  Undecided
    points solve once and densify the stored frontier."""
    if request is None:
        request = PlanRequest(strategy="optimal")
    if store_all_peak is None:
        store_all_peak = chain.store_all_peak()
    if frontier is None and use_frontier:
        frontier = _default_frontier()

    def _solve(budget: float) -> Optional[MemoryPlan]:
        req = dataclasses.replace(request, budget=Budget.bytes(budget),
                                  on_infeasible="raise")
        try:
            return build_plan(req, chain)
        except InfeasiblePlanError:
            return None

    points: List[SweepPoint] = []
    for frac in fractions:
        budget = store_all_peak * frac
        if frontier is not None:
            answer = frontier.query(chain, request, budget, solve=_solve)
            plan = answer.plan
        else:
            plan = _solve(budget)
        points.append(SweepPoint(float(frac), budget, plan))
    return points


def min_memory_plan(chain: Chain, *, tiers: Sequence[str] = ("device",),
                    num_slots: Optional[int] = None,
                    impl: Optional[str] = None) -> MemoryPlan:
    """The smallest-feasible-budget plan for a tier combination (the memory
    floor; with the host tier it drops below the two-tier floor)."""
    request = PlanRequest(strategy="min_memory", tiers=tuple(tiers),
                          num_slots=num_slots, impl=impl)
    return build_plan(request, chain)
