"""`repro.plan` — the first-class memory-planning API.

The paper's promise is "give us a memory limit, we pick the optimal
schedule"; this package is that surface.  A typed :class:`PlanRequest`
(budget as bytes / fraction / auto, storage tiers, host link, slot
discretization, DP kernel impl) resolves through :func:`build_plan` into a
:class:`MemoryPlan` — the inspectable, serializable planning artifact that
carries the schedule, the recursion tree, the solver
:class:`~repro.core.solver.Solution`, simulator-exact predicted
makespan/peaks, and the right executor binding
(:meth:`MemoryPlan.bind` / :meth:`MemoryPlan.execute`).

- :func:`sweep` returns the time-vs-budget frontier benchmarks used to
  hand-roll; :func:`min_memory_plan` the feasibility floor per tier combo.
- :mod:`repro.plan.registry` maps storage-tier combinations to solver entry
  points — the extension hook every future tier/solver plugs into.
- Plans :meth:`~MemoryPlan.save` to disk and :meth:`~MemoryPlan.load` back,
  validated by the chain content hash shared with the solver cache
  (:class:`StalePlanError` on mismatch).

The old policy strings (``"rotor:x0.6"``, ``"optimal_offload:8G:12G"``, …)
remain available through the thin shim in :mod:`repro.core.policies`, which
maps each string onto exactly one ``PlanRequest``.
"""

from ..check import PlanVerificationError
from .api import (SweepPoint, build_plan, min_memory_plan, sweep,
                  two_tier_fallback)
from .compat import (DOCUMENTED_POLICIES, policy_to_request, resolve_policy)
from .plan import BoundPlan, InfeasiblePlanError, MemoryPlan, StalePlanError
from .registry import SolverEntry, available_solvers, register_solver, solver_for
from .request import (DEFAULT_NUM_SLOTS, Budget, PlanRequest, parse_size,
                      SOLVER_STRATEGIES, STRUCTURAL_STRATEGIES)
from .serving import kv_chain, kv_residency_layers, plan_serving

__all__ = [
    "Budget", "PlanRequest", "MemoryPlan", "BoundPlan", "SweepPoint",
    "SolverEntry", "InfeasiblePlanError", "StalePlanError",
    "PlanVerificationError",
    "build_plan", "sweep", "min_memory_plan", "two_tier_fallback",
    "register_solver", "solver_for", "available_solvers", "parse_size",
    "kv_chain", "plan_serving", "kv_residency_layers",
    "policy_to_request", "resolve_policy", "DOCUMENTED_POLICIES",
    "DEFAULT_NUM_SLOTS", "SOLVER_STRATEGIES", "STRUCTURAL_STRATEGIES",
]
