"""KV-cache residency planning for the decode path (ROADMAP item 3).

Serving has the same shape as the paper's problem: per-layer state under a
device-HBM budget, with a slower tier (host RAM over the serving link) as
the spill target.  This module maps the decode cache onto a heterogeneous
chain whose per-layer "activations" are KV blocks — sized by
:meth:`repro.models.lm.StagedLM.cache_layout` at the configured
``kv_cache_dtype`` — and solves it through the ``("device", "kv")`` tier of
:mod:`repro.plan.registry`, i.e. the existing three-tier offload DP with
:class:`~repro.core.chain.HostTransferModel` link pricing.

Chain mapping (paper indexing, chain length ``L = cfg.num_layers``):

- ``wa[i]`` (``i`` in 1..L) — allocated bytes of layer ``i``'s KV block;
  ``wa[0]`` is the decode-step input hidden state (negligible → 0),
- ``wabar[i]`` — the block again (the decode "backward" of stage ``i+1`` is
  the per-step attention read over that block),
- ``wdelta = 0`` — no gradients flow at serving time (the §4.1 degenerate
  case the chain model explicitly supports),
- ``uf[i]`` — the cost of *rebuilding* layer ``i``'s prefix KV.  The decode
  path cannot recompute a layer's KV from a neighbouring layer's KV (that
  needs the hidden states, which are not retained), so recompute is priced
  out by ``recompute_penalty`` — the DP then satisfies the budget with
  ``Foff``/``Prefetch`` staging and spends the link model deciding *which*
  blocks to stage,
- ``ub[i]`` — the per-decode-step cost of stage ``i``: analytic FLOPs
  (:func:`repro.models.flops.per_layer_flops`) plus the HBM read of the
  block.

Model-vs-execution notes (the honest gaps, asserted nowhere else): the DP's
timeline is a forward+backward sweep while decode is a steady-state loop, so
the executed policy (:mod:`repro.runtime.kv_residency`) consumes only the
plan's staging *set* — the ``Foff`` args — and re-stages it every step with
``Prefetch``-ahead restore.  Schedules may also lean on recompute branches
despite the penalty (e.g. the min-memory fallback), which serving cannot
execute, so :func:`kv_residency_layers` applies a deterministic clamp: grow
the staged set (largest blocks first) until the resident remainder plus one
transient block fits the budget, then drop staged blocks (smallest first)
that the budget never needed.  Device-residency accounting models the
per-layer pipelined restore (one transient block in flight), not the CPU
emulation's materialize-everything step.
"""

from __future__ import annotations

from typing import List, Optional, Union

import numpy as np

from ..core.chain import Chain, HostTransferModel
from .api import build_plan
from .plan import MemoryPlan
from .request import Budget, PlanRequest

#: Defaults for the analytic per-layer time estimates: a serving-class
#: accelerator's dense throughput and HBM read bandwidth.  They only have to
#: be *relatively* right — the DP compares staging against compute overlap,
#: and ``Chain.calibrate`` can fold in measured decode spans later.
DEFAULT_DEVICE_FLOPS = 50e12
DEFAULT_HBM_BANDWIDTH = 800e9

#: Multiplier pricing recompute branches out of the serving DP (prefix KV is
#: not reconstructible inside the decode loop — see the module docstring).
DEFAULT_RECOMPUTE_PENALTY = 1e3


def kv_chain(cfg, *, batch: int, prompt_len: int,
             max_len: Optional[int] = None,
             host: Optional[HostTransferModel] = None,
             device_flops: float = DEFAULT_DEVICE_FLOPS,
             hbm_bandwidth: float = DEFAULT_HBM_BANDWIDTH,
             recompute_penalty: float = DEFAULT_RECOMPUTE_PENALTY) -> Chain:
    """The decode cache as a heterogeneous chain: one stage per model layer,
    activation ``a^i`` = layer ``i``'s KV block (allocated bytes at
    ``max_len`` and the configured ``kv_cache_dtype``), priced with the
    serving host link (default: the PCIe-3 x16 constant)."""
    # lazy model imports keep `import repro.plan` jax-free (the plan-service
    # path runs without jax; see the store-smoke CI job)
    from ..models.flops import per_layer_flops
    from ..models.lm import StagedLM

    max_len = max_len or prompt_len
    layout = StagedLM(cfg).cache_layout(batch, max_len)
    blocks = [float(b) for b in layout.block_bytes]
    prefill_flops = per_layer_flops(cfg, batch, prompt_len)
    decode_flops = per_layer_flops(cfg, batch, 1, kv_len=prompt_len)
    uf = [recompute_penalty * f / device_flops for f in prefill_flops] + [0.0]
    ub = [f / device_flops + b / hbm_bandwidth
          for f, b in zip(decode_flops, blocks)] + [0.0]
    n = cfg.num_layers + 1
    return Chain.make(uf=uf, ub=ub,
                      wa=[0.0] + blocks,
                      wabar=blocks + [0.0],
                      wdelta=np.zeros(n),
                      host=host or HostTransferModel.pcie_gen3())


def plan_serving(cfg, budget: Union[Budget, str, float], *, batch: int,
                 prompt_len: int, max_len: Optional[int] = None,
                 host: Optional[HostTransferModel] = None,
                 num_slots: Optional[int] = None,
                 impl: Optional[str] = None,
                 on_infeasible: str = "min_memory",
                 recompute_penalty: float = DEFAULT_RECOMPUTE_PENALTY
                 ) -> MemoryPlan:
    """Plan KV-cache residency for the decode path: which layers' cold
    prefix KV lives in device HBM vs host RAM under ``budget`` bytes of
    device KV.

    ``budget`` accepts a :class:`Budget`, the budget grammar string
    (``"1.5G"`` / ``"x0.5"``), or plain bytes.  Returns a
    :class:`MemoryPlan` over the ``("device", "kv")`` tier;
    :func:`repro.runtime.serve_loop.run_serving` binds it via ``plan=`` —
    the staged layers round-trip through the pinned
    :class:`~repro.offload.host_buffer.HostBuffer` each step, restored
    ahead of the step per the plan's ``Prefetch`` discipline."""
    if isinstance(budget, Budget):
        b = budget
    elif isinstance(budget, str):
        b = Budget.parse(budget)
    else:
        b = Budget.bytes(float(budget))
    chain = kv_chain(cfg, batch=batch, prompt_len=prompt_len, max_len=max_len,
                     host=host, recompute_penalty=recompute_penalty)
    request = PlanRequest(strategy="optimal", budget=b,
                          tiers=("device", "kv"), host=chain.host,
                          num_slots=num_slots, impl=impl,
                          on_infeasible=on_infeasible)
    return build_plan(request, chain)


def kv_residency_layers(plan: MemoryPlan,
                        budget_bytes: Optional[float] = None) -> List[int]:
    """The 0-based model layers whose prefix KV the plan stages to host.

    Core selection: the schedule's ``Foff`` args (activation ``a^i`` ↔ layer
    ``i-1``).  The DP may also satisfy the budget through recompute branches
    the decode loop cannot execute, so a deterministic clamp enforces the
    budget on the *executable* policy: grow the staged set largest-block
    first until resident + one transient block fits, then drop staged
    blocks (smallest first) the budget never needed.  ``budget_bytes``
    overrides the plan's own budget (e.g. the requested budget when the plan
    fell back to min-memory)."""
    if plan.chain is None:
        raise ValueError("kv_residency_layers needs a plan built from a "
                         "profiled kv chain")
    blocks = np.asarray(plan.chain.wa[1:], dtype=float)
    staged = {arg - 1 for op, arg in plan.schedule.ops
              if op == "Foff" and arg >= 1}
    budget = plan.budget_bytes if budget_bytes is None else float(budget_bytes)
    if budget is None:
        return sorted(staged)

    def fits(st) -> bool:
        resident = blocks.sum() - sum(blocks[j] for j in st)
        transient = max((blocks[j] for j in st), default=0.0)
        return resident + transient <= budget

    for j in sorted(range(len(blocks)), key=lambda j: (-blocks[j], j)):
        if fits(staged):
            break
        staged.add(j)
    for j in sorted(staged, key=lambda j: (blocks[j], j)):
        if fits(staged - {j}):
            staged.discard(j)
    return sorted(staged)
