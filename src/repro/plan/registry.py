"""Registry mapping storage-tier combinations to solver entry points.

Every future tier or solver plugs in here: a :class:`SolverEntry` provides a
budgeted solve and a minimum-memory solve for one tier combination, keyed by
the canonical ``"+"``-joined tier tuple (``"device"``, ``"device+host"``).
:func:`repro.plan.build_plan` looks the entry up from
``PlanRequest.tiers`` — no call site ever dispatches on policy-string
prefixes again.

The built-in entries wrap the paper's two-tier DP
(:func:`repro.core.solver.solve_optimal` / ``solve_min_memory``) and the
three-tier offload DP (:func:`repro.offload.solver.solve_optimal_offload` /
``solve_min_device_memory``).  Imports are lazy so registering a tier never
forces its solver module (and its dependencies) to load.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence

# solve(chain, budget_bytes, *, num_slots, allow_fall, impl) -> Solution
SolveFn = Callable[..., "object"]
# solve_min(chain, *, num_slots, allow_fall, impl) -> Solution
SolveMinFn = Callable[..., "object"]


@dataclasses.dataclass(frozen=True)
class SolverEntry:
    key: str
    solve: SolveFn
    solve_min: SolveMinFn
    description: str = ""


_REGISTRY: Dict[str, SolverEntry] = {}


def tier_key(tiers: Sequence[str]) -> str:
    """Canonical registry key for a tier combination."""
    return "+".join(tiers)


def register_solver(key: str, solve: SolveFn, solve_min: SolveMinFn,
                    description: str = "", overwrite: bool = False
                    ) -> SolverEntry:
    """Register a solver for a tier combination (the extension point for new
    storage tiers / planning backends)."""
    if key in _REGISTRY and not overwrite:
        raise ValueError(f"solver for tiers {key!r} already registered; "
                         f"pass overwrite=True to replace it")
    entry = SolverEntry(key, solve, solve_min, description)
    _REGISTRY[key] = entry
    return entry


def solver_for(tiers: Sequence[str]) -> SolverEntry:
    key = tier_key(tiers)
    entry = _REGISTRY.get(key)
    if entry is None:
        raise ValueError(
            f"no solver registered for storage tiers {key!r}; known combos: "
            f"{sorted(_REGISTRY)} (see repro.plan.registry.register_solver)")
    return entry


def available_solvers() -> Dict[str, SolverEntry]:
    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# built-in entries
# ---------------------------------------------------------------------------

def _two_tier_solve(chain, budget: float, *, num_slots: int, allow_fall: bool,
                    impl: Optional[str]):
    from ..core.solver import solve_optimal
    return solve_optimal(chain, budget, num_slots=num_slots,
                         allow_fall=allow_fall, impl=impl)


def _two_tier_solve_min(chain, *, num_slots: int, allow_fall: bool,
                        impl: Optional[str]):
    from ..core.solver import solve_min_memory
    return solve_min_memory(chain, num_slots=num_slots,
                            allow_fall=allow_fall, impl=impl)


def _three_tier_solve(chain, budget: float, *, num_slots: int,
                      allow_fall: bool, impl: Optional[str]):
    from ..offload.solver import solve_optimal_offload
    return solve_optimal_offload(chain, budget, num_slots=num_slots,
                                 allow_fall=allow_fall, impl=impl)


def _three_tier_solve_min(chain, *, num_slots: int, allow_fall: bool,
                          impl: Optional[str]):
    from ..offload.solver import solve_min_device_memory
    return solve_min_device_memory(chain, num_slots=num_slots,
                                   allow_fall=allow_fall, impl=impl)


register_solver(
    "device", _two_tier_solve, _two_tier_solve_min,
    "paper two-tier DP (device activations + device full-history residuals)")
register_solver(
    "device+host", _three_tier_solve, _three_tier_solve_min,
    "three-tier DP with asynchronous host-RAM activation offload")
register_solver(
    "device+kv", _three_tier_solve, _three_tier_solve_min,
    "serving-path KV-cache residency: per-layer decode KV blocks as chain "
    "activations, cold prefix KV staged to host RAM over the serving link "
    "(reuses the three-tier offload DP; see repro.plan.serving)")
