"""Deterministic synthetic LM data pipeline.

Production posture: per-host sharded generation (each host materializes only
its slice of the global batch), deterministic per (seed, step) so that a
checkpoint-restart resumes the *exact* stream — a fault-tolerance requirement
(the restarted run must consume the same data as the lost one).  A background
thread prefetches ``prefetch`` batches ahead.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def make_batch_specs(cfg, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    if cfg.modality == "text":
        return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
                "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
                "loss_mask": jax.ShapeDtypeStruct((batch, seq), jnp.float32)}
    if cfg.modality == "audio_embed":
        return {"embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model), cfg.dtype),
                "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
                "loss_mask": jax.ShapeDtypeStruct((batch, seq), jnp.float32)}
    P = cfg.prefix_len
    return {"image_embeds": jax.ShapeDtypeStruct((batch, P, cfg.d_model), cfg.dtype),
            "tokens": jax.ShapeDtypeStruct((batch, seq - P), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq - P), jnp.int32),
            "loss_mask": jax.ShapeDtypeStruct((batch, seq - P), jnp.float32)}


class SyntheticLMData:
    """Markov-ish synthetic token stream (structured enough that loss drops)."""

    def __init__(self, cfg, global_batch: int, seq_len: int, seed: int = 0,
                 host_index: int = 0, host_count: int = 1, prefetch: int = 2):
        assert global_batch % host_count == 0
        self.cfg = cfg
        self.local_batch = global_batch // host_count
        self.seq = seq_len
        self.seed = seed
        self.host = host_index
        self._step = 0
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self.prefetch = prefetch

    # -- deterministic batch synthesis -------------------------------------

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.host, step]))
        B, S, V = self.local_batch, self.seq, cfg.vocab_size
        # tokens with local structure: next token = (tok*a + b) % V w/ noise
        a = rng.integers(2, 7)
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0] = rng.integers(0, V, B)
        noise = rng.random((B, S)) < 0.1
        rand = rng.integers(0, V, (B, S))
        for t in range(S):
            nxt = (toks[:, t] * a + 1) % V
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        mask = np.ones((B, S), np.float32)
        if cfg.modality == "text":
            return {"tokens": tokens, "labels": labels, "loss_mask": mask}
        if cfg.modality == "audio_embed":
            emb = rng.standard_normal((B, S, cfg.d_model)).astype(np.float32)
            return {"embeds": emb, "labels": labels, "loss_mask": mask}
        P = cfg.prefix_len
        img = rng.standard_normal((B, P, cfg.d_model)).astype(np.float32)
        return {"image_embeds": img, "tokens": tokens[:, :S - P],
                "labels": labels[:, :S - P],
                "loss_mask": mask[:, :S - P]}

    # -- iteration with prefetch -------------------------------------------

    def start(self, from_step: int = 0) -> None:
        self._step = from_step
        self._q = queue.Queue(maxsize=self.prefetch)
        self._stop = threading.Event()

        def worker():
            s = from_step
            while not self._stop.is_set():
                try:
                    self._q.put(self.batch_at(s), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def next(self) -> Dict[str, np.ndarray]:
        if self._q is None:
            b = self.batch_at(self._step)
            self._step += 1
            return b
        return self._q.get()

    def stop(self) -> None:
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=2.0)
            self._thread = None
            self._q = None
