"""Fault-tolerance runtime pieces: straggler watchdog, heartbeat registry,
and the elastic re-mesh plan.

On a real multi-pod deployment these hook into the cluster scheduler; here
they are fully implemented and unit-tested against a fake clock, and the
train loop wires them in:

- :class:`StragglerWatchdog` — tracks per-step durations; a step exceeding
  ``threshold × (rolling median)`` flags a straggler.  Policy: after
  ``max_flags`` consecutive flags the loop checkpoints and requests a
  restart-without-the-slow-host (the standard TPU-pod remediation — you
  cannot drop a single member of a synchronous mesh, you re-slice).
- :class:`HeartbeatRegistry` — liveness bookkeeping for hosts; ``dead()``
  after ``timeout`` seconds silent.
- :func:`elastic_plan` — given old/new host counts, returns the new mesh
  shape and whether the global batch stays achievable (grad-accumulation
  factor), used by ``launch.train`` on restart.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float


class StragglerWatchdog:
    def __init__(self, threshold: float = 2.0, window: int = 32,
                 max_flags: int = 3, clock: Callable[[], float] = time.monotonic):
        self.threshold = threshold
        self.window: deque = deque(maxlen=window)
        self.max_flags = max_flags
        self.clock = clock
        self._t0: Optional[float] = None
        self.consecutive_flags = 0
        self.events: List[StragglerEvent] = []

    def step_begin(self) -> None:
        self._t0 = self.clock()

    def step_end(self, step: int) -> Optional[StragglerEvent]:
        assert self._t0 is not None, "step_end without step_begin"
        dur = self.clock() - self._t0
        self._t0 = None
        med = self.median()
        self.window.append(dur)
        if med is not None and dur > self.threshold * med:
            self.consecutive_flags += 1
            ev = StragglerEvent(step, dur, med)
            self.events.append(ev)
            return ev
        self.consecutive_flags = 0
        return None

    def median(self) -> Optional[float]:
        if len(self.window) < 4:
            return None
        s = sorted(self.window)
        return s[len(s) // 2]

    @property
    def should_restart(self) -> bool:
        return self.consecutive_flags >= self.max_flags


class HeartbeatRegistry:
    def __init__(self, hosts: int, timeout: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self.clock = clock
        self.last: Dict[int, float] = {h: clock() for h in range(hosts)}

    def beat(self, host: int) -> None:
        self.last[host] = self.clock()

    def dead(self) -> List[int]:
        now = self.clock()
        return [h for h, t in self.last.items() if now - t > self.timeout]


def elastic_plan(n_chips: int, model_parallel: int,
                 global_batch: int) -> Tuple[Tuple[int, ...], Tuple[str, ...], int]:
    """Largest (data, model) mesh fitting ``n_chips`` after losing hosts.

    Returns (mesh_shape, axis_names, grad_accum_factor): model-parallel width
    is preserved (weights were sharded that way), the data axis shrinks to
    what remains, and gradient accumulation makes up the lost batch so the
    optimizer trajectory (global batch) is unchanged.
    """
    if n_chips < model_parallel:
        raise ValueError("fewer chips than the model-parallel width; "
                         "cannot restore this sharding")
    data = n_chips // model_parallel
    # keep the global batch: accumulate if the data axis shrank
    while global_batch % data:
        data -= 1  # data axis must divide the global batch
    accum = 1
    return (data, model_parallel), ("data", "model"), accum
