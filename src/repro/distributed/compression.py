"""Gradient compression for the data-parallel all-reduce: int8 quantization
with error feedback.

At 1000+ nodes the cross-pod gradient all-reduce travels DCN (not ICI) and
dominates step time for small per-chip batches; int8 quantization cuts those
bytes 2× vs bf16 (4× vs f32) while **error feedback** keeps training unbiased
in the limit: the residual each member's quantizer drops is added back into
its next step's gradient.

API: gradients enter *per-DP-member* (computed from each member's local
microbatch, e.g. under ``shard_map`` in ``runtime.train_loop``'s
``grad_compression`` mode); ``compressed_psum_mean`` runs **inside** that
shard_map context and performs: quantize(g + error) → integer ``psum`` over
the DP axes → dequantize, with the per-tensor scale ``pmax``-synchronized so
all members share one grid.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def ef_init(params: Any) -> Any:
    """Per-member error-feedback accumulators (same shapes as grads)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(x: jax.Array, axes: Tuple[str, ...]):
    """Symmetric per-tensor int8; scale synchronized across ``axes``."""
    scale = jnp.max(jnp.abs(x)) / 127.0
    if axes:
        scale = jax.lax.pmax(scale, axes)
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_mean(grads: Any, error: Any, axes: Tuple[str, ...],
                         n_members: int):
    """Inside shard_map: per-member (grads, error) → (mean grads, new error)."""

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = quantize_int8(x, axes)
        deq = q.astype(jnp.float32) * scale
        total = jax.lax.psum(deq, axes) if axes else deq
        new_e = x - deq  # residual the quantizer dropped, re-applied next step
        return (total / n_members).astype(g.dtype), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def compression_ratio(params: Any, from_dtype=jnp.bfloat16) -> float:
    """Collective-byte ratio int8 vs ``from_dtype`` (scales are negligible)."""
    return jnp.dtype(from_dtype).itemsize / jnp.dtype(jnp.int8).itemsize
