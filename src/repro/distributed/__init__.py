from .sharding import (AxisRules, DEFAULT_RULES, LONG_CONTEXT_RULES,
                       axis_rules, constrain, current_mesh, current_rules,
                       spec_for, sharding_for, shard_factor)
