"""Logical-axis sharding rules (MaxText-style) for the (pod, data, model) mesh.

Models annotate activations with *logical* axis names via :func:`constrain`
and parameter trees carry logical-axes metadata; a rules table maps logical →
physical mesh axes.  Resolution drops any mapping whose dimension does not
divide evenly across the mapped mesh axes (e.g. 36 heads on a 16-wide model
axis, MQA's single KV head, batch=1 for long-context decode), so every config
shards as aggressively as its shapes allow without manual case-work.

Conventions:
- parameter logical names: ``embed`` (FSDP axis), ``mlp``, ``heads``, ``kv``,
  ``vocab``, ``experts``, ``kv_lora``, ``stack`` (stacked-layer dim, never
  sharded), ``conv``, ``state``.
- activation logical names are prefixed ``act_``.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Optional[str]

# physical axes of the production mesh
POD, DATA, MODEL = "pod", "data", "model"

DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    # parameters
    "embed": (POD, DATA),        # FSDP: shard the d_model dim of weights
    "mlp": (MODEL,),
    "heads": (MODEL,),
    "kv": (MODEL,),
    "vocab": (MODEL,),
    "experts": (MODEL,),
    "mlp_expert": (MODEL,),   # dropped when `experts` already took the axis
    "kv_lora": (),
    "stack": (),
    "conv": (),
    "state": (),
    "ssm_heads": (MODEL,),
    "heads_merged": (MODEL,),  # fused (H·Dh) input dim of the output proj
    # activations
    "act_batch": (POD, DATA),
    "act_seq": (),
    "act_embed": (),
    "act_heads": (MODEL,),
    "act_kv": (MODEL,),
    "act_mlp": (MODEL,),
    "act_vocab": (MODEL,),
    "act_group": (POD, DATA),   # MoE dispatch-buffer DP-group dim
    "act_experts": (MODEL,),
    "act_mlp_expert": (MODEL,),
    "act_kv_seq": (),           # KV-cache sequence dim
    "act_ssm_heads": (MODEL,),
}

# Serving (prefill/decode): the KV cache dominates memory, and KV-head counts
# rarely divide the model axis — shard the cache *sequence* dim on the model
# axis instead (softmax partial-reductions become collectives, handled by
# GSPMD; this is ring-attention-style cache placement).
DECODE_RULES: Dict[str, Tuple[str, ...]] = dict(
    DEFAULT_RULES,
    act_kv_seq=(MODEL,),
)

# Long-context decode (batch too small to shard): context-parallel the KV/seq
# dims over the data axis as well.
LONG_CONTEXT_RULES: Dict[str, Tuple[str, ...]] = dict(
    DEFAULT_RULES,
    act_kv_seq=(DATA, MODEL),
    act_seq=(DATA,),
)


@dataclasses.dataclass
class AxisRules:
    mesh: Optional[Mesh]
    rules: Dict[str, Tuple[str, ...]]


_state = threading.local()


def _stack():
    if not hasattr(_state, "stack"):
        _state.stack = []
    return _state.stack


@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh], rules: Dict[str, Tuple[str, ...]] | None = None):
    """Activate a mesh + logical rules table for model-internal constraints."""
    _stack().append(AxisRules(mesh, dict(rules or DEFAULT_RULES)))
    try:
        yield
    finally:
        _stack().pop()


def current_mesh() -> Optional[Mesh]:
    st = _stack()
    return st[-1].mesh if st else None


def current_rules() -> Dict[str, Tuple[str, ...]]:
    st = _stack()
    return st[-1].rules if st else DEFAULT_RULES


def _axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    return math.prod(mesh.shape[a] for a in axes if a in mesh.shape)


def spec_for(logical_axes: Sequence[Logical], shape: Sequence[int],
             mesh: Optional[Mesh] = None,
             rules: Dict[str, Tuple[str, ...]] | None = None) -> P:
    """Resolve logical axes to a PartitionSpec, dropping non-divisible dims."""
    mesh = mesh or current_mesh()
    rules = rules or current_rules()
    if mesh is None:
        return P()
    out = []
    used: set = set()
    for dim, name in zip(shape, logical_axes):
        if name is None:
            out.append(None)
            continue
        phys = tuple(a for a in rules.get(name, ()) if a in mesh.shape
                     and a not in used)
        size = _axes_size(mesh, phys)
        if not phys or size <= 1 or dim % size != 0:
            out.append(None)
            continue
        used.update(phys)
        out.append(phys if len(phys) > 1 else phys[0])
    return P(*out)


def sharding_for(logical_axes: Sequence[Logical], shape: Sequence[int],
                 mesh: Optional[Mesh] = None,
                 rules: Dict[str, Tuple[str, ...]] | None = None
                 ) -> Optional[NamedSharding]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(logical_axes, shape, mesh, rules))


def constrain(x: jax.Array, *logical_axes: Logical) -> jax.Array:
    """Apply a logical sharding constraint to an activation (no-op w/o mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = spec_for(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_factor(*logical_axes: Logical, shape: Sequence[int] | None = None) -> int:
    """Total number of shards a tensor with these axes gets (for the rotor
    planner's per-device activation sizes)."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    rules = current_rules()
    total = 1
    used: set = set()
    for i, name in enumerate(logical_axes):
        if name is None:
            continue
        phys = tuple(a for a in rules.get(name, ()) if a in mesh.shape
                     and a not in used)
        size = _axes_size(mesh, phys)
        if size <= 1:
            continue
        if shape is not None and shape[i] % size != 0:
            continue
        used.update(phys)
        total *= size
    return total
