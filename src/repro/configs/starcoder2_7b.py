"""Config module for --arch starcoder2-7b (see archs.py for the spec)."""
from .archs import starcoder2_7b as config, smoke_config as _smoke

ARCH = "starcoder2-7b"


def smoke(**ov):
    return _smoke(ARCH, **ov)
