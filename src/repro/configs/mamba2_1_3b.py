"""Config module for --arch mamba2-1.3b (see archs.py for the spec)."""
from .archs import mamba2_13b as config, smoke_config as _smoke

ARCH = "mamba2-1.3b"


def smoke(**ov):
    return _smoke(ARCH, **ov)
