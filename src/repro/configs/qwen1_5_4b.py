"""Config module for --arch qwen1.5-4b (see archs.py for the spec)."""
from .archs import qwen15_4b as config, smoke_config as _smoke

ARCH = "qwen1.5-4b"


def smoke(**ov):
    return _smoke(ARCH, **ov)
