"""The four assigned input-shape sets and their applicability rules."""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# long_500k needs sub-quadratic sequence mixing: only the SSM and hybrid archs
# run it (see DESIGN.md §Arch-applicability); pure full-attention archs skip.
LONG_CONTEXT_ARCHS = ("mamba2-1.3b", "zamba2-2.7b")


def applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def cells(archs) -> list:
    """All assigned (arch × shape) dry-run cells."""
    out = []
    for a in archs:
        for s in SHAPES:
            if applicable(a, s):
                out.append((a, s))
    return out


def input_specs(cfg, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this shape cell
    (weak-type-correct, shardable, no device allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = cfg.dtype

    def sds(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind == "train":
        if cfg.modality == "text":
            return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32),
                    "loss_mask": sds((B, S), jnp.float32)}
        if cfg.modality == "audio_embed":
            return {"embeds": sds((B, S, cfg.d_model), f),
                    "labels": sds((B, S), i32),
                    "loss_mask": sds((B, S), jnp.float32)}
        P = cfg.prefix_len
        return {"image_embeds": sds((B, P, cfg.d_model), f),
                "tokens": sds((B, S - P), i32),
                "labels": sds((B, S - P), i32),
                "loss_mask": sds((B, S - P), jnp.float32)}
    if shape.kind == "prefill":
        if cfg.modality == "text":
            return {"tokens": sds((B, S), i32)}
        if cfg.modality == "audio_embed":
            return {"embeds": sds((B, S, cfg.d_model), f)}
        P = cfg.prefix_len
        return {"image_embeds": sds((B, P, cfg.d_model), f),
                "tokens": sds((B, S - P), i32)}
    # decode: one new token against a cache of length S (cache specs are
    # produced separately via eval_shape of init_cache)
    if cfg.modality == "audio_embed":
        return {"tokens": sds((B, 1, cfg.d_model), f)}
    return {"tokens": sds((B, 1), i32)}
