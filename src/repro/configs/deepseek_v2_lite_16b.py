"""Config module for --arch deepseek-v2-lite-16b (see archs.py for the spec)."""
from .archs import deepseek_v2_lite as config, smoke_config as _smoke

ARCH = "deepseek-v2-lite-16b"


def smoke(**ov):
    return _smoke(ARCH, **ov)
