from .archs import ARCHS, get_config, smoke_config
from .shapes import SHAPES, ShapeSpec, applicable, cells, input_specs
