"""Config module for --arch musicgen-medium (see archs.py for the spec)."""
from .archs import musicgen_medium as config, smoke_config as _smoke

ARCH = "musicgen-medium"


def smoke(**ov):
    return _smoke(ARCH, **ov)
