"""Config module for --arch codeqwen1.5-7b (see archs.py for the spec)."""
from .archs import codeqwen15_7b as config, smoke_config as _smoke

ARCH = "codeqwen1.5-7b"


def smoke(**ov):
    return _smoke(ARCH, **ov)
