"""Config module for --arch paligemma-3b (see archs.py for the spec)."""
from .archs import paligemma_3b as config, smoke_config as _smoke

ARCH = "paligemma-3b"


def smoke(**ov):
    return _smoke(ARCH, **ov)
