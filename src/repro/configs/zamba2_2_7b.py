"""Config module for --arch zamba2-2.7b (see archs.py for the spec)."""
from .archs import zamba2_27b as config, smoke_config as _smoke

ARCH = "zamba2-2.7b"


def smoke(**ov):
    return _smoke(ARCH, **ov)
