"""The ten assigned architectures, exactly as specified in the assignment
sheet (``[source; tier]`` comments preserved), plus smoke-reduction helper.

Each arch also has its own module ``src/repro/configs/<id>.py`` re-exporting
``config()`` for ``--arch <id>`` selection.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp

from ..models.lm import ModelConfig

_COMMON = dict(dtype=jnp.bfloat16, param_dtype=jnp.bfloat16,
               scan_layer_remat="full", logits_chunk=4096)


def codeqwen15_7b(**ov) -> ModelConfig:
    # [dense] qwen1.5-arch [hf:Qwen/CodeQwen1.5-7B; hf] — QKV bias, SwiGLU
    return ModelConfig(name="codeqwen1.5-7b", num_layers=32, d_model=4096,
                       n_heads=32, n_kv_heads=32, d_ff=13440,
                       vocab_size=92416, qkv_bias=True, mlp_kind="swiglu",
                       rope_theta=1e6, n_chunks=8, **{**_COMMON, **ov})


def qwen15_4b(**ov) -> ModelConfig:
    # [dense] QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]
    return ModelConfig(name="qwen1.5-4b", num_layers=40, d_model=2560,
                       n_heads=20, n_kv_heads=20, d_ff=6912,
                       vocab_size=151936, qkv_bias=True, mlp_kind="swiglu",
                       rope_theta=5e6, n_chunks=10, **{**_COMMON, **ov})


def starcoder2_7b(**ov) -> ModelConfig:
    # [dense] GQA, RoPE [arXiv:2402.19173; hf] — GELU MLP, biases
    return ModelConfig(name="starcoder2-7b", num_layers=32, d_model=4608,
                       n_heads=36, n_kv_heads=4, d_ff=18432,
                       vocab_size=49152, qkv_bias=True, mlp_kind="gelu",
                       rope_theta=1e5, n_chunks=8, **{**_COMMON, **ov})


def qwen15_110b(**ov) -> ModelConfig:
    # [dense] QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]
    return ModelConfig(name="qwen1.5-110b", num_layers=80, d_model=8192,
                       n_heads=64, n_kv_heads=8, d_ff=49152,
                       vocab_size=152064, qkv_bias=True, mlp_kind="swiglu",
                       rope_theta=1e6, n_chunks=10, **{**_COMMON, **ov})


def musicgen_medium(**ov) -> ModelConfig:
    # [audio] decoder-only over EnCodec tokens [arXiv:2306.05284; hf]
    # frontend (EnCodec) is a stub: input_specs() provides frame embeddings.
    return ModelConfig(name="musicgen-medium", num_layers=48, d_model=1536,
                       n_heads=24, n_kv_heads=24, d_ff=6144, vocab_size=2048,
                       use_rope=False, mlp_kind="gelu",
                       modality="audio_embed", n_chunks=8,
                       **{**_COMMON, **ov})


def paligemma_3b(**ov) -> ModelConfig:
    # [vlm] SigLIP + gemma [arXiv:2407.07726; hf] — MQA, GeGLU, 256-patch
    # bidirectional prefix; SigLIP frontend is a stub (patch embeddings in).
    return ModelConfig(name="paligemma-3b", num_layers=18, d_model=2048,
                       n_heads=8, n_kv_heads=1, d_ff=16384,
                       vocab_size=257216, head_dim=256, mlp_kind="geglu",
                       modality="vlm", prefix_len=256, embed_scale=True,
                       rope_theta=10000.0, n_chunks=6, **{**_COMMON, **ov})


def deepseek_v2_lite(**ov) -> ModelConfig:
    # [moe] MLA kv_lora=512, shared+routed top-6 [arXiv:2405.04434; hf]
    # (assignment sheet: "MoE 64e top-6"; the "160 routed" note belongs to
    #  full V2 — we follow the primary 64e spec, 2 shared experts.)
    return ModelConfig(name="deepseek-v2-lite-16b", num_layers=27,
                       d_model=2048, n_heads=16, n_kv_heads=16,
                       d_ff=10944,  # first (dense) layer FFN
                       vocab_size=102400, attention_kind="mla",
                       kv_lora_rank=512, qk_nope_head_dim=128,
                       qk_rope_head_dim=64, v_head_dim=128,
                       layer_kinds=("dense",) + ("moe",) * 26,
                       num_experts=64, moe_top_k=6, moe_d_ff=1408,
                       num_shared_experts=2, n_chunks=10,
                       **{**_COMMON, **ov})


def moonshot_16b_a3b(**ov) -> ModelConfig:
    # [moe] kimi/moonlight 64e top-6 [hf:moonshotai/Moonlight-16B-A3B; hf]
    # assignment sheet pins GQA kv=16 (not MLA) — we follow the sheet.
    return ModelConfig(name="moonshot-v1-16b-a3b", num_layers=48,
                       d_model=2048, n_heads=16, n_kv_heads=16,
                       d_ff=11264,  # first (dense) layer FFN
                       vocab_size=163840,
                       layer_kinds=("dense",) + ("moe",) * 47,
                       num_experts=64, moe_top_k=6, moe_d_ff=1408,
                       num_shared_experts=2, n_chunks=12,
                       **{**_COMMON, **ov})


def mamba2_13b(**ov) -> ModelConfig:
    # [ssm] SSD (state-space duality) [arXiv:2405.21060; unverified]
    return ModelConfig(name="mamba2-1.3b", num_layers=48, d_model=2048,
                       n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=50280,
                       head_dim=64,
                       layer_kinds=("mamba",) * 48, ssm_state=128,
                       ssm_expand=2, ssm_head_dim=64, ssm_groups=1,
                       ssm_conv=4, ssm_chunk=256, n_chunks=12,
                       **{**_COMMON, **ov})


def zamba2_27b(**ov) -> ModelConfig:
    # [hybrid] Mamba2 + shared attn blocks [arXiv:2411.15242; hf]
    return ModelConfig(name="zamba2-2.7b", num_layers=54, d_model=2560,
                       n_heads=32, n_kv_heads=32, d_ff=10240,
                       vocab_size=32000,
                       layer_kinds=("zamba",) * 54, hybrid_period=6,
                       ssm_state=64, ssm_expand=2, ssm_head_dim=64,
                       ssm_groups=1, ssm_conv=4, ssm_chunk=256,
                       n_chunks=9, **{**_COMMON, **ov})


ARCHS: Dict[str, callable] = {
    "codeqwen1.5-7b": codeqwen15_7b,
    "qwen1.5-4b": qwen15_4b,
    "starcoder2-7b": starcoder2_7b,
    "qwen1.5-110b": qwen15_110b,
    "musicgen-medium": musicgen_medium,
    "paligemma-3b": paligemma_3b,
    "deepseek-v2-lite-16b": deepseek_v2_lite,
    "moonshot-v1-16b-a3b": moonshot_16b_a3b,
    "mamba2-1.3b": mamba2_13b,
    "zamba2-2.7b": zamba2_27b,
}


def get_config(arch: str, **overrides) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(ARCHS)}")
    cfg = ARCHS[arch]()
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def smoke_config(arch: str, **overrides) -> ModelConfig:
    """Reduced same-family config: small widths/depths, tiny vocab/experts —
    runs a real forward/train step on CPU in the per-arch smoke tests."""
    full = get_config(arch)
    kinds = full.layer_kinds
    # keep the structural pattern but shrink depth to 4 (or 2 periods)
    if full.hybrid_period:
        depth, period = 4, 2
        kinds = ("zamba",) * depth
    else:
        depth, period = 4, 0
        kinds = tuple(kinds[:1]) + tuple(kinds[-1] for _ in range(depth - 1))
    n_kv = max(1, (full.n_kv_heads * 4) // max(full.n_heads, 1)) or 1
    red = dict(
        num_layers=depth, layer_kinds=kinds,
        d_model=64, n_heads=4, n_kv_heads=min(4, max(n_kv, 1)),
        head_dim=16, d_ff=128, vocab_size=256,
        num_experts=8 if full.num_experts else 0, moe_top_k=2, moe_d_ff=32,
        num_shared_experts=min(full.num_shared_experts, 1),
        kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
        v_head_dim=16,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8, ssm_expand=2,
        hybrid_period=period, prefix_len=4 if full.modality == "vlm" else 0,
        n_chunks=3, dtype=jnp.float32, param_dtype=jnp.float32,
        scan_layer_remat="none", logits_chunk=0,
    )
    red.update(overrides)
    return dataclasses.replace(full, **red)
