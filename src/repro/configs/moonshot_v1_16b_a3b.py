"""Config module for --arch moonshot-v1-16b-a3b (see archs.py for the spec)."""
from .archs import moonshot_16b_a3b as config, smoke_config as _smoke

ARCH = "moonshot-v1-16b-a3b"


def smoke(**ov):
    return _smoke(ARCH, **ov)
