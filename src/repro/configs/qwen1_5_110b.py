"""Config module for --arch qwen1.5-110b (see archs.py for the spec)."""
from .archs import qwen15_110b as config, smoke_config as _smoke

ARCH = "qwen1.5-110b"


def smoke(**ov):
    return _smoke(ARCH, **ov)
