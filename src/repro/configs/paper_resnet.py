"""The paper's own workload family: a heterogeneous convolutional chain
(ResNet-style — the paper evaluates ResNet/DenseNet/Inception, §5.3).

Not part of the assigned LM pool; used by the reproduction benchmarks
(`benchmarks/bench_tradeoff.py`, `examples/tradeoff_curves.py`) where the
four strategies (store-all / sequential / revolve / optimal) are compared
exactly as in the paper's Figures 3–13, with measured per-stage costs.
"""

from benchmarks.chains import resnet_ish_chain as chain  # noqa: F401

ARCH = "paper-resnet"


def config(num_blocks: int = 8, image: int = 32, batch: int = 8, **kw):
    """Returns (stages, params, x) — a rotor chain, not an LM config."""
    return chain(num_blocks=num_blocks, image=image, batch=batch, **kw)
