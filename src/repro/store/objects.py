"""Typed object access over a byte :class:`~repro.store.backend.Backend`.

:class:`ObjectStore` is the layer every caller actually uses: it runs the
:mod:`repro.store.codec` envelope on the way in and out, quarantines
corrupted entries on first contact (so a bad byte range on a shared
directory is served exactly once, to exactly one process, as a miss), and
mirrors every outcome into the :mod:`repro.obs` metrics registry
(``store.hits`` / ``store.misses`` / ``store.puts`` /
``store.corrupt_quarantined`` / ``store.errors``).
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional

from repro.obs import metrics as _metrics

from .backend import Backend, StoreError
from .codec import CorruptEntryError, decode, encode


class ObjectStore:
    """Envelope-checked, metrics-instrumented object store."""

    def __init__(self, backend: Backend, *, name: str = "store"):
        self.backend = backend
        self.name = name
        self._lock = threading.Lock()
        self._stats: Dict[str, int] = {
            "hits": 0,
            "misses": 0,
            "puts": 0,
            "corrupt": 0,
            "errors": 0,
        }

    def _bump(self, stat: str, metric: str) -> None:
        with self._lock:
            self._stats[stat] += 1
        _metrics.counter(f"{self.name}.{metric}").inc()

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._stats)

    def reset_stats(self) -> None:
        with self._lock:
            for k in self._stats:
                self._stats[k] = 0

    def get(self, key: str, *, kind: Optional[str] = None) -> Optional[Any]:
        """The stored object, or None on miss.  A corrupted / tampered /
        wrong-kind entry is quarantined and reported as a miss."""
        try:
            data = self.backend.get(key)
        except StoreError:
            self._bump("errors", "errors")
            return None
        if data is None:
            self._bump("misses", "misses")
            return None
        try:
            _, _, obj = decode(data, kind=kind, key=key)
        except CorruptEntryError:
            self.backend.quarantine(key)
            self._bump("corrupt", "corrupt_quarantined")
            self._bump("misses", "misses")
            return None
        self._bump("hits", "hits")
        return obj

    def put(self, key: str, obj: Any, *, kind: str = "object") -> bool:
        """Store an object; False (and a ``store.errors`` tick) when the
        backend cannot take the write."""
        data = encode(kind, key, obj)
        try:
            self.backend.put(key, data)
        except StoreError:
            self._bump("errors", "errors")
            return False
        self._bump("puts", "puts")
        return True

    def delete(self, key: str) -> bool:
        return self.backend.delete(key)

    def keys(self, prefix: str = "") -> List[str]:
        return self.backend.keys(prefix)

    def clear(self, prefix: str = "") -> None:
        self.backend.clear(prefix)

    def uri(self) -> str:
        return self.backend.uri()
