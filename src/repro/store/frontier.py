"""Warm-start frontier: persisted ``sweep()`` results that answer *any*
budget query with at most one refinement solve.

The paper's DP returns, for one chain, the optimal makespan as a function
of the memory budget — a non-increasing step function ``t*(B)``.  A sweep
samples that frontier at a handful of budgets; this module persists those
samples (keyed chain × request-template × code, like every store entry)
and exploits two exact monotonicity facts to answer later queries without
re-running the fill:

- **feasibility is monotone**: if budget ``b`` is infeasible, every
  ``B <= b`` is infeasible — recorded infeasible points answer all queries
  at or below them with zero solves;
- **makespan is non-increasing and bracketable**: for a queried ``B``
  between recorded feasible budgets ``b_lo <= B <= b_hi`` with *equal*
  optimal times, ``t*(B)`` is pinched to that same value, and the
  ``b_lo`` plan (peak ``<= b_lo <= B``) achieves it — so the stored plan
  *is* the optimum at ``B``, returned with zero solves ("interpolation").

Any query the two facts do not decide costs exactly one refinement solve,
whose result is folded back into the stored frontier — the frontier only
ever gets denser.  Plans served from the frontier are statically verified
(:meth:`repro.plan.MemoryPlan.verify`) before they are handed out; an
entry that fails is quarantined and the query falls back to a fresh solve.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from repro.obs import metrics as _metrics

from .keys import FRONTIER_NAMESPACE, PlanKey, request_digest
from .objects import ObjectStore

_ENTRY_VERSION = 1
_KIND = "frontier"

#: Relative tolerance for "same budget" / "same optimal time".  Budgets and
#: DP makespans are float64 arithmetic on identical inputs, so true
#: revisits compare exactly; the epsilon only absorbs benign re-resolution
#: noise (e.g. ``peak * frac`` computed in a different order).
_REL_TOL = 1e-12


def _close(a: float, b: float) -> bool:
    return abs(a - b) <= _REL_TOL * max(abs(a), abs(b), 1.0)


def template_digest(request) -> str:
    """The request digest with the budget blanked — all sweep points of one
    template share it, whatever their per-point budget."""
    return request_digest(
        dataclasses.replace(request, budget=None, on_infeasible="raise")
    )


@dataclasses.dataclass
class FrontierAnswer:
    """One answered budget query: the plan (None = provably infeasible),
    how many refinement solves it cost, and how it was decided
    (``exact`` / ``interpolated`` / ``infeasible`` / ``solved``)."""

    plan: Optional[Any]
    solves: int
    source: str

    @property
    def feasible(self) -> bool:
        return self.plan is not None


class WarmStartFrontier:
    """Persisted time-vs-budget frontier over an :class:`ObjectStore`."""

    def __init__(self, store: ObjectStore,
                 namespace: str = FRONTIER_NAMESPACE):
        self.store = store
        self.namespace = namespace

    # -- storage -----------------------------------------------------------

    def _key(self, chain, request) -> str:
        pk = PlanKey.for_plan(chain, request)
        return dataclasses.replace(
            pk, request=template_digest(request)
        ).key(self.namespace)

    def _load(self, key: str) -> List[Dict[str, Any]]:
        entry = self.store.get(key, kind=_KIND)
        if (
            not isinstance(entry, dict)
            or entry.get("version") != _ENTRY_VERSION
            or not isinstance(entry.get("points"), list)
        ):
            return []
        return entry["points"]

    def _save(self, key: str, points: List[Dict[str, Any]]) -> None:
        points.sort(key=lambda p: p["budget_bytes"])
        self.store.put(
            key, {"version": _ENTRY_VERSION, "points": points}, kind=_KIND
        )

    def points(self, chain, request) -> List[Dict[str, Any]]:
        """The recorded ``{"budget_bytes", "feasible", "expected_time",
        "plan"}`` points for this chain × request template (sorted)."""
        return self._load(self._key(chain, request))

    def _merge(self, points: List[Dict[str, Any]], budget: float,
               plan: Optional[Any]) -> List[Dict[str, Any]]:
        kept = [p for p in points if not _close(p["budget_bytes"], budget)]
        kept.append({
            "budget_bytes": float(budget),
            "feasible": plan is not None,
            "expected_time": None if plan is None else plan.expected_time,
            "plan": plan,
        })
        return kept

    def record(self, chain, request, sweep_points) -> str:
        """Fold a sweep's points (objects with ``budget_bytes`` / ``plan``,
        e.g. :class:`repro.plan.SweepPoint`) into the stored frontier;
        returns the store key."""
        key = self._key(chain, request)
        points = self._load(key)
        for sp in sweep_points:
            points = self._merge(points, sp.budget_bytes, sp.plan)
        self._save(key, points)
        return key

    def record_point(self, chain, request, budget_bytes: float,
                     plan: Optional[Any]) -> str:
        key = self._key(chain, request)
        self._save(key, self._merge(self._load(key), budget_bytes, plan))
        return key

    # -- queries -----------------------------------------------------------

    def _serve(self, point: Dict[str, Any], key: str) -> Optional[Any]:
        """A stored plan, verified before crossing back into the caller;
        None (after quarantining the entry) when verification fails."""
        plan = point.get("plan")
        if plan is None:
            return None
        report = plan.verify()
        if not report.ok:
            self.store.backend.quarantine(key)
            _metrics.counter("frontier.verify_rejects").inc()
            return None
        return plan

    def query(self, chain, request, budget_bytes: float, *,
              solve: Optional[Callable[[float], Optional[Any]]] = None,
              ) -> FrontierAnswer:
        """Answer one budget query from the stored frontier.

        Decides from recorded points when the monotonicity facts allow it
        (zero solves); otherwise runs ``solve(budget_bytes)`` — which must
        return a plan or None for infeasible — exactly once and records the
        result.  With ``solve=None`` an undecidable query returns
        ``FrontierAnswer(None, 0, "unknown")``.
        """
        budget = float(budget_bytes)
        key = self._key(chain, request)
        points = self._load(key)

        exact = next(
            (p for p in points if _close(p["budget_bytes"], budget)), None
        )
        if exact is not None:
            if not exact["feasible"]:
                _metrics.counter("frontier.hits").inc()
                return FrontierAnswer(None, 0, "exact")
            plan = self._serve(exact, key)
            if plan is not None:
                _metrics.counter("frontier.hits").inc()
                return FrontierAnswer(plan, 0, "exact")
            points = []  # quarantined: below logic must not reuse it

        infeasible_above = [
            p["budget_bytes"] for p in points
            if not p["feasible"] and p["budget_bytes"] >= budget
        ]
        if infeasible_above:
            _metrics.counter("frontier.hits").inc()
            return FrontierAnswer(None, 0, "infeasible")

        feas = [p for p in points if p["feasible"]]
        lower = [p for p in feas if p["budget_bytes"] <= budget]
        upper = [p for p in feas if p["budget_bytes"] >= budget]
        if lower and upper:
            lo = max(lower, key=lambda p: p["budget_bytes"])
            hi = min(upper, key=lambda p: p["budget_bytes"])
            if _close(lo["expected_time"], hi["expected_time"]):
                plan = self._serve(lo, key)
                if plan is not None:
                    _metrics.counter("frontier.interpolations").inc()
                    return FrontierAnswer(plan, 0, "interpolated")

        if solve is None:
            _metrics.counter("frontier.misses").inc()
            return FrontierAnswer(None, 0, "unknown")
        plan = solve(budget)
        _metrics.counter("frontier.solves").inc()
        self.record_point(chain, request, budget, plan)
        return FrontierAnswer(plan, 1, "solved")
