"""Process-wide store configuration: env resolution and default instances.

One environment variable drives everything:

- ``REPRO_STORE=<uri>`` — the default store location (``memory://``,
  ``file://<dir>``, ``shared://<dir>``); ``off``/``0`` disables persistent
  storage entirely.  Unset → ``file://$XDG_CACHE_HOME/repro/solver-cache``
  (the directory the old solver cache already used, so upgrades keep their
  cache location).
- ``REPRO_STORE_MEM_ENTRIES`` / ``REPRO_STORE_MAX_ENTRIES`` — in-memory
  LRU and on-backend entry caps.

The pre-store env vars (``REPRO_SOLVER_CACHE``, ``REPRO_SOLVER_CACHE_DIR``,
``REPRO_SOLVER_CACHE_SIZE``, ``REPRO_SOLVER_CACHE_DISK_SIZE``) are still
honored when ``REPRO_STORE*`` is unset — mapped onto the equivalent store
settings with a :class:`DeprecationWarning` naming the replacement (the
README carries the full migration table).
"""

from __future__ import annotations

import dataclasses
import os
import threading
import warnings
from pathlib import Path
from typing import Optional

from .backend import Backend, MemoryBackend, StoreError, from_uri
from .objects import ObjectStore

FALSEY = {"0", "off", "false", "no"}

_DEPRECATIONS = {
    "REPRO_SOLVER_CACHE": "REPRO_STORE=off",
    "REPRO_SOLVER_CACHE_DIR": "REPRO_STORE=file://<dir>",
    "REPRO_SOLVER_CACHE_SIZE": "REPRO_STORE_MEM_ENTRIES",
    "REPRO_SOLVER_CACHE_DISK_SIZE": "REPRO_STORE_MAX_ENTRIES",
}


def _warn_legacy(var: str) -> None:
    warnings.warn(
        f"{var} is deprecated; use {_DEPRECATIONS[var]} (store URIs: "
        f"memory://, file://<dir>, shared://<dir>)",
        DeprecationWarning, stacklevel=3)


def _int_env(var: str, default: int, *, legacy: Optional[str] = None) -> int:
    raw = os.environ.get(var)
    if raw is None and legacy is not None:
        raw = os.environ.get(legacy)
        if raw is not None:
            _warn_legacy(legacy)
    if raw is None:
        return default
    try:
        return int(raw)
    except ValueError:
        return default


def default_cache_dir() -> Path:
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache")
    return Path(base) / "repro" / "solver-cache"


@dataclasses.dataclass(frozen=True)
class StoreSettings:
    """Resolved store configuration (env → concrete values)."""

    enabled: bool
    uri: Optional[str]          # None when disabled or memory-only
    directory: Optional[Path]   # backing directory for file:///shared://
    mem_entries: int
    max_entries: int

    def make_backend(self) -> Optional[Backend]:
        if not self.enabled:
            return None
        if self.uri is None:
            return MemoryBackend(capacity=self.mem_entries)
        backend = from_uri(self.uri)
        if hasattr(backend, "max_entries"):
            backend.max_entries = self.max_entries
        return backend


def resolve_settings() -> StoreSettings:
    """Resolve the store env surface (new vars first, legacy fallback)."""
    mem_entries = max(_int_env("REPRO_STORE_MEM_ENTRIES", 128,
                               legacy="REPRO_SOLVER_CACHE_SIZE"), 1)
    max_entries = max(_int_env("REPRO_STORE_MAX_ENTRIES", 512,
                               legacy="REPRO_SOLVER_CACHE_DISK_SIZE"), 1)

    uri = os.environ.get("REPRO_STORE")
    if uri is not None:
        uri = uri.strip()
        if uri.lower() in FALSEY or not uri:
            return StoreSettings(False, None, None, mem_entries, max_entries)
    else:
        legacy_on = os.environ.get("REPRO_SOLVER_CACHE")
        if legacy_on is not None:
            _warn_legacy("REPRO_SOLVER_CACHE")
            if legacy_on.strip().lower() in FALSEY:
                return StoreSettings(False, None, None,
                                     mem_entries, max_entries)
        legacy_dir = os.environ.get("REPRO_SOLVER_CACHE_DIR")
        if legacy_dir is not None:
            _warn_legacy("REPRO_SOLVER_CACHE_DIR")
            # empty legacy dir meant "memory-only": enabled, no disk tier
            uri = f"file://{legacy_dir}" if legacy_dir else None
        else:
            uri = f"file://{default_cache_dir()}"

    directory: Optional[Path] = None
    if uri is not None:
        if uri.startswith("memory://"):
            uri_dir = None
        else:
            uri_dir = uri.split("://", 1)[1] if "://" in uri else uri
        directory = Path(uri_dir) if uri_dir else None
    return StoreSettings(True, uri, directory, mem_entries, max_entries)


# ---------------------------------------------------------------------------
# process-wide default store (rebuilt lazily so env changes take effect)
# ---------------------------------------------------------------------------

_default: Optional[ObjectStore] = None
_configured_off = False
_default_lock = threading.Lock()


def default_store(required: bool = False) -> Optional[ObjectStore]:
    """The process-wide :class:`ObjectStore` resolved from the environment;
    None when the store is disabled (or raises with ``required=True``)."""
    global _default
    with _default_lock:
        if _default is None and not _configured_off:
            backend = resolve_settings().make_backend()
            if backend is not None:
                _default = ObjectStore(backend, name="store")
    if _default is None and required:
        raise StoreError(
            "the default store is disabled (REPRO_STORE=off) — enable it or "
            "pass an explicit store")
    return _default


def configure(uri: Optional[str]) -> Optional[ObjectStore]:
    """Replace the process-wide default store (None/'off' disables it)."""
    global _default, _configured_off
    with _default_lock:
        if uri is None or uri.strip().lower() in FALSEY:
            _default, _configured_off = None, True
        else:
            _default = ObjectStore(from_uri(uri), name="store")
            _configured_off = False
    return _default


def reset() -> None:
    """Drop the process default; next use re-resolves from the env."""
    global _default, _configured_off
    with _default_lock:
        _default, _configured_off = None, False
