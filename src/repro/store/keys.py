"""Content addressing for plans and solutions.

A plan is fully identified by three fingerprints (ISSUE/ROADMAP item 2):

- **chain** — :func:`repro.core.solver_cache.chain_fingerprint`: the
  profiled cost/size arrays + host link of the chain being planned;
- **request** — :func:`request_digest`: a canonical hash of the
  :class:`repro.plan.PlanRequest` (strategy, budget, tiers, slots, impl,
  fallback policy);
- **code** — :func:`repro.core.solver_cache.code_fingerprint`: the solver
  implementation sources, so a solver fix invalidates every stale entry
  fleet-wide without any version bookkeeping.

:class:`PlanKey` bundles the three, renders the store key
(``<namespace>/<chain>.<request>.<code>``), and — for staleness
diagnostics — names exactly which component diverged between two keys
(:meth:`PlanKey.diff`), which is what `MemoryPlan.load` reports instead of
a bare "hash mismatch".
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import List, Optional

from repro.core import solver_cache as _sc

#: Hex digits of each fingerprint kept in rendered store keys (96 bits per
#: component — collision-safe for fleet-scale stores, short enough for one
#: filename).
KEY_HEX = 24
PLAN_NAMESPACE = "plans"
FRONTIER_NAMESPACE = "frontiers"


def request_digest(request) -> str:
    """Canonical content hash of a :class:`repro.plan.PlanRequest`.

    Hashes the *semantic* fields only, each tagged by name so field
    reordering can't alias two requests.  ``num_slots`` is hashed resolved
    (``None`` → the default) so an explicit ``num_slots=500`` and the
    default are the same request — they produce bit-identical plans.
    """
    h = hashlib.sha256()
    h.update(b"repro-plan-request\0")
    budget = request.budget
    parts = [
        ("strategy", request.strategy),
        ("budget.kind", "none" if budget is None else budget.kind),
        ("budget.value",
         "" if budget is None or budget.kind == "auto"
         else repr(float(budget.value))),
        ("segments", str(request.segments)),
        ("tiers", "+".join(request.tiers)),
        ("num_slots", str(request.resolved_num_slots)),
        ("impl", request.impl or ""),
        ("on_infeasible", request.on_infeasible),
    ]
    if request.host is None:
        parts.append(("host", "chain-default"))
    else:
        parts.append(("host", repr((
            float(request.host.bandwidth_d2h),
            None if request.host.bandwidth_h2d is None
            else float(request.host.bandwidth_h2d),
            float(request.host.latency),
        ))))
    for name, value in parts:
        h.update(f"{name}={value}".encode())
        h.update(b"\0")
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """The chain × request × code content address of a plan."""

    chain: str
    request: str
    code: str

    @staticmethod
    def for_plan(chain, request, *, code: Optional[str] = None) -> "PlanKey":
        return PlanKey(
            chain=_sc.chain_fingerprint(chain),
            request=request_digest(request),
            code=code if code is not None else _sc.code_fingerprint(),
        )

    def key(self, namespace: str = PLAN_NAMESPACE) -> str:
        return (
            f"{namespace}/{self.chain[:KEY_HEX]}"
            f".{self.request[:KEY_HEX]}.{self.code[:KEY_HEX]}"
        )

    def diff(self, other: "PlanKey") -> List[str]:
        """Which fingerprint components diverge (``chain`` / ``request`` /
        ``code``) — the staleness diagnosis surfaced by plan loads."""
        out = []
        for component in ("chain", "request", "code"):
            a, b = getattr(self, component), getattr(other, component)
            # compare on the shorter prefix so a rendered (truncated) key
            # can be diffed against a freshly computed full-width one
            n = min(len(a), len(b))
            if a[:n] != b[:n] or n == 0:
                out.append(component)
        return out
