"""Byte-level store backends: where content-addressed entries physically live.

A :class:`Backend` is a tiny key→bytes map with three implementations:

- :class:`MemoryBackend` — an in-process LRU, for tests and single-process
  services (``memory://``);
- :class:`LocalDirectoryBackend` — one file per entry under a local
  directory, written atomically (``tempfile`` + ``os.replace``) so a crash
  mid-write never leaves a torn entry (``file://<path>``);
- :class:`SharedDirectoryBackend` — the same layout on a *shared* directory
  (NFS mount, host-local cache shared by many fleet processes): writes are
  additionally fsynced (file and directory) before the atomic rename, so an
  entry observed by one process is durable for every other
  (``shared://<path>``).

Backends store opaque bytes and never deserialize anything — typed access
(and the pickle envelope) is confined to :mod:`repro.store.codec` /
:class:`repro.store.ObjectStore`, which is also where corrupted entries are
detected and routed to :meth:`Backend.quarantine` (directory backends move
the bad file into a ``_quarantine/`` subdirectory for forensics instead of
serving it ever again).

Keys are ``/``-separated namespace paths of ``[A-Za-z0-9._-]`` segments
(``plans/tenant-a/<digest>``); directory backends flatten ``/`` to ``__``
in filenames, so a key segment may not contain ``__``.
"""

from __future__ import annotations

import abc
import os
import re
import tempfile
import threading
import time
from collections import OrderedDict
from pathlib import Path
from typing import Dict, List, Optional

_SEGMENT_RE = re.compile(r"^[A-Za-z0-9._-]+$")
QUARANTINE_DIR = "_quarantine"
#: Filename suffix for directory-backed entries (the payload is a pickle
#: envelope, see :mod:`repro.store.codec`).
ENTRY_SUFFIX = ".pkl"


class StoreError(RuntimeError):
    """A backend operation failed (bad key, unwritable directory, ...)."""


def validate_key(key: str) -> str:
    """Reject keys that cannot round-trip through every backend."""
    if not key:
        raise StoreError("empty store key")
    for seg in key.split("/"):
        if (
            not _SEGMENT_RE.match(seg)
            or "__" in seg
            or seg.strip(".") == ""  # "." / ".." path components
        ):
            raise StoreError(
                f"bad store key {key!r}: segments must match "
                f"[A-Za-z0-9._-]+ (not all dots) and may not contain '__'"
            )
    return key


class Backend(abc.ABC):
    """Abstract byte store: the one persistence API of the repo.

    Every persistent surface (solver-cache Solutions, autotune winners,
    saved MemoryPlans, warm-start frontiers) goes through a Backend — there
    is no other sanctioned way to put bytes on disk and read them back.
    """

    scheme: str = ""

    @abc.abstractmethod
    def get(self, key: str) -> Optional[bytes]:
        """The stored bytes, or None when absent/unreadable."""

    @abc.abstractmethod
    def put(self, key: str, data: bytes) -> None:
        """Store bytes under ``key`` (atomic: readers see old or new)."""

    @abc.abstractmethod
    def delete(self, key: str) -> bool:
        """Remove an entry; True if it existed."""

    @abc.abstractmethod
    def keys(self, prefix: str = "") -> List[str]:
        """All stored keys under a ``/``-path prefix."""

    def quarantine(self, key: str) -> bool:
        """Retire a corrupted entry so it is never served again (directory
        backends keep a forensics copy under ``_quarantine/``); True when
        an entry was actually retired."""
        return self.delete(key)

    def clear(self, prefix: str = "") -> None:
        for key in self.keys(prefix):
            self.delete(key)

    def uri(self) -> str:
        return f"{self.scheme}://"


class MemoryBackend(Backend):
    """In-process LRU over bytes (``memory://``)."""

    scheme = "memory"

    def __init__(self, capacity: int = 1024):
        self.capacity = max(int(capacity), 1)
        self._data: "OrderedDict[str, bytes]" = OrderedDict()
        self._lock = threading.Lock()

    def get(self, key: str) -> Optional[bytes]:
        validate_key(key)
        with self._lock:
            data = self._data.get(key)
            if data is not None:
                self._data.move_to_end(key)
            return data

    def put(self, key: str, data: bytes) -> None:
        validate_key(key)
        with self._lock:
            self._data[key] = bytes(data)
            self._data.move_to_end(key)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)

    def delete(self, key: str) -> bool:
        validate_key(key)
        with self._lock:
            return self._data.pop(key, None) is not None

    def keys(self, prefix: str = "") -> List[str]:
        with self._lock:
            names = list(self._data)
        if not prefix:
            return names
        return [k for k in names if k == prefix or k.startswith(prefix + "/")]


def _fname(key: str) -> str:
    return validate_key(key).replace("/", "__") + ENTRY_SUFFIX


def _unfname(name: str) -> str:
    return name[: -len(ENTRY_SUFFIX)].replace("__", "/")


class LocalDirectoryBackend(Backend):
    """One file per entry under a local directory (``file://<path>``).

    Writes are atomic (temp file + ``os.replace``) so concurrent writers —
    or a crash mid-write — can never produce a torn entry: readers observe
    either the old bytes or the new bytes, never a mix.  ``max_entries``
    bounds the store by evicting the oldest entries (mtime order).
    """

    scheme = "file"
    _fsync = False

    def __init__(self, path, max_entries: Optional[int] = None):
        self.path = Path(path)
        self.max_entries = max_entries

    def uri(self) -> str:
        return f"{self.scheme}://{self.path}"

    def _file(self, key: str) -> Path:
        return self.path / _fname(key)

    def get(self, key: str) -> Optional[bytes]:
        try:
            return self._file(key).read_bytes()
        except OSError:
            return None

    def put(self, key: str, data: bytes) -> None:
        path = self._file(key)
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    f.write(data)
                    if self._fsync:
                        f.flush()
                        os.fsync(f.fileno())
                os.replace(tmp, path)
            finally:
                if os.path.exists(tmp):
                    try:
                        os.unlink(tmp)
                    except OSError:
                        pass
            if self._fsync:
                self._fsync_dir()
        except OSError as e:
            raise StoreError(f"cannot write {path}: {e}") from e
        if self.max_entries is not None:
            self._prune()

    def _fsync_dir(self) -> None:
        try:
            dfd = os.open(self.path, os.O_RDONLY)
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)
        except OSError:
            pass

    def _prune(self) -> None:
        try:
            entries = sorted(
                self.path.glob("*" + ENTRY_SUFFIX),
                key=lambda p: p.stat().st_mtime,
            )
            for p in entries[: max(len(entries) - self.max_entries, 0)]:
                p.unlink()
        except OSError:
            pass

    def delete(self, key: str) -> bool:
        try:
            self._file(key).unlink()
            return True
        except OSError:
            return False

    def keys(self, prefix: str = "") -> List[str]:
        try:
            names = [
                _unfname(p.name)
                for p in self.path.glob("*" + ENTRY_SUFFIX)
            ]
        except OSError:
            return []
        if not prefix:
            return sorted(names)
        return sorted(
            k for k in names if k == prefix or k.startswith(prefix + "/")
        )

    def quarantine(self, key: str) -> bool:
        """Move the entry into ``_quarantine/`` (kept for forensics) so the
        corrupted bytes are never served again; best-effort."""
        src = self._file(key)
        qdir = self.path / QUARANTINE_DIR
        try:
            qdir.mkdir(parents=True, exist_ok=True)
            os.replace(src, qdir / f"{src.name}.{int(time.time() * 1e6)}")
            return True
        except OSError:
            try:
                src.unlink()
                return True
            except OSError:
                return False


class SharedDirectoryBackend(LocalDirectoryBackend):
    """A :class:`LocalDirectoryBackend` hardened for cross-process /
    cross-host sharing (``shared://<path>``): every write is fsynced (file
    and directory) before the atomic rename, so once any fleet process
    observes an entry it is durable for all of them."""

    scheme = "shared"
    _fsync = True


def from_uri(uri: str) -> Backend:
    """Resolve a store URI to a backend: ``memory://`` (in-process LRU),
    ``file://<path>`` (local directory), ``shared://<path>`` (shared
    directory with durable writes).  A bare path means ``file://``."""
    uri = uri.strip()
    if not uri:
        raise StoreError("empty store URI")
    if uri.startswith("memory://"):
        return MemoryBackend()
    for scheme, cls in (
        ("file://", LocalDirectoryBackend),
        ("shared://", SharedDirectoryBackend),
    ):
        if uri.startswith(scheme):
            path = uri[len(scheme):]
            if not path:
                raise StoreError(f"store URI {uri!r} has no path")
            return cls(path)
    if "://" in uri:
        raise StoreError(
            f"unknown store URI scheme {uri!r}: expected memory://, "
            f"file://<path> or shared://<path>"
        )
    return LocalDirectoryBackend(uri)
