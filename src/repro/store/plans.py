"""Content-addressed, verification-gated storage of MemoryPlans.

:class:`PlanStore` is the trust boundary of plan sharing: every plan read
back from a backend — in particular a *shared* backend other hosts and
tenants write to — is admitted only after

1. the codec envelope check (byte tampering, truncation, key renames →
   quarantined, reported as ``store-corrupt``),
2. the fingerprint cross-check (the entry's recorded chain × request ×
   code address must match the one it is served under),
3. the full static gate :meth:`repro.plan.MemoryPlan.verify` — liveness,
   slot discipline, metadata cross-check — so a semantically tampered but
   well-encoded plan (a re-encoded entry with a doctored schedule or
   forged makespan) is rejected with the usual :mod:`repro.check`
   violation kinds and never reaches ``bind``/``execute``.

Rejections quarantine the entry and tick ``plan_store.verify_rejects``;
with ``strict=True`` they raise :class:`repro.check.PlanVerificationError`
instead of reporting a miss.

Keys are per-tenant: ``<namespace>[/<tenant>]/<chain>.<request>.<code>``,
so quotas and eviction (:mod:`repro.runtime.plan_service`) operate on
plain key prefixes.
"""

from __future__ import annotations

from typing import Any, List, Optional

from repro.obs import metrics as _metrics

from .backend import StoreError
from .codec import CorruptEntryError, decode, encode
from .keys import PLAN_NAMESPACE, PlanKey
from .objects import ObjectStore

_KIND = "memory-plan"


def _corrupt_error(context: str, detail: str):
    from repro.check import (PlanVerificationError, VerificationReport,
                             Violation)
    report = VerificationReport(rules=["store"])
    report.violations.append(Violation(kind="store-corrupt", message=detail))
    return PlanVerificationError(report, context=context)


class PlanStore:
    """Typed plan storage over an :class:`ObjectStore`'s backend."""

    def __init__(self, store: ObjectStore, namespace: str = PLAN_NAMESPACE):
        self.store = store
        self.namespace = namespace

    def _ns(self, tenant: Optional[str]) -> str:
        return f"{self.namespace}/{tenant}" if tenant else self.namespace

    def key_for(self, chain, request, *,
                tenant: Optional[str] = None) -> str:
        return PlanKey.for_plan(chain, request).key(self._ns(tenant))

    # -- write -------------------------------------------------------------

    def put(self, plan, *, chain=None, request=None,
            tenant: Optional[str] = None) -> str:
        """Admit a plan into the store (verified first — an invalid plan
        raises and never lands); returns the store key."""
        chain = chain if chain is not None else plan.chain
        request = request if request is not None else plan.request
        if chain is None:
            raise StoreError("cannot store a plan with no profiled chain")
        plan._verify_or_raise("refusing to store an invalid plan")
        pk = PlanKey.for_plan(chain, request)
        key = pk.key(self._ns(tenant))
        payload = {
            "chain": pk.chain,
            "request": pk.request,
            "code": pk.code,
            "plan": plan,
        }
        self.store.backend.put(key, encode(_KIND, key, payload))
        _metrics.counter("plan_store.puts").inc()
        return key

    # -- read --------------------------------------------------------------

    def get(self, chain, request, *, tenant: Optional[str] = None,
            strict: bool = False) -> Optional[Any]:
        """The stored plan for this chain × request × current code, fully
        re-verified; None on miss/rejection (or raises when ``strict``)."""
        pk = PlanKey.for_plan(chain, request)
        return self.get_key(pk.key(self._ns(tenant)), expect=pk,
                            strict=strict)

    def get_key(self, key: str, *, expect: Optional[PlanKey] = None,
                strict: bool = False) -> Optional[Any]:
        data = self.store.backend.get(key)
        if data is None:
            _metrics.counter("plan_store.misses").inc()
            return None
        try:
            _, _, payload = decode(data, kind=_KIND, key=key)
            if not isinstance(payload, dict) or "plan" not in payload:
                raise CorruptEntryError("plan entry payload malformed")
        except CorruptEntryError as e:
            self.store.backend.quarantine(key)
            _metrics.counter("plan_store.corrupt_quarantined").inc()
            _metrics.counter("plan_store.verify_rejects").inc()
            if strict:
                raise _corrupt_error(
                    f"stored plan {key!r} failed integrity check", str(e)
                ) from e
            return None
        plan = payload["plan"]
        if expect is not None:
            got = PlanKey(chain=str(payload.get("chain", "")),
                          request=str(payload.get("request", "")),
                          code=str(payload.get("code", "")))
            diverged = expect.diff(got)
            if diverged:
                self.store.backend.quarantine(key)
                _metrics.counter("plan_store.corrupt_quarantined").inc()
                _metrics.counter("plan_store.verify_rejects").inc()
                if strict:
                    raise _corrupt_error(
                        f"stored plan {key!r} failed integrity check",
                        f"fingerprint mismatch in: {', '.join(diverged)}")
                return None
        report = plan.verify()
        if not report.ok:
            self.store.backend.quarantine(key)
            _metrics.counter("plan_store.verify_rejects").inc()
            if strict:
                from repro.check import PlanVerificationError
                raise PlanVerificationError(
                    report, context=f"stored plan {key!r} failed verification")
            return None
        _metrics.counter("plan_store.hits").inc()
        return plan

    # -- maintenance -------------------------------------------------------

    def keys(self, *, tenant: Optional[str] = None) -> List[str]:
        return self.store.backend.keys(self._ns(tenant))

    def delete(self, key: str) -> bool:
        return self.store.backend.delete(key)

    def clear(self, *, tenant: Optional[str] = None) -> None:
        self.store.backend.clear(self._ns(tenant))
