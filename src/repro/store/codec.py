"""The one (de)serialization point of the repo: a tamper-evident pickle
envelope.

Every object that crosses a process/host boundary through a
:class:`repro.store.Backend` — solver Solutions, autotune winners, saved
MemoryPlans, warm-start frontiers — is wrapped by :func:`encode` and read
back by :func:`decode`.  The envelope carries a magic tag, format version,
the entry *kind*, the store key it was written under, and a SHA-256 digest
of the payload bytes, so

- a byte-tampered or truncated entry fails the digest/structure check and
  raises :class:`CorruptEntryError` instead of deserializing garbage;
- an entry copied under the wrong key (cache-poisoning by rename) is
  rejected by the key cross-check;
- kind confusion (an autotune record where a plan was expected) is caught
  before the caller touches the object.

This module is the only place outside test fixtures allowed to import
:mod:`pickle` — the ``pickle-confinement`` rule in :mod:`repro.check.lint`
enforces that mechanically.  Note the envelope authenticates *integrity*,
not *origin*: a store shared across trust domains still requires the
semantic gate (``MemoryPlan.verify()``) on every admitted plan, which is
exactly what :class:`repro.store.PlanStore` does.
"""

from __future__ import annotations

import hashlib
import pickle
import sys
from typing import Any, Optional, Tuple

MAGIC = "repro-store"
VERSION = 1

#: Deep schedule/solution objects (L≈339 chains) can exceed the default
#: recursion limit while pickling; match the old solver_cache headroom.
_PICKLE_RECURSION_LIMIT = 100_000


class CorruptEntryError(ValueError):
    """The stored bytes are not a valid envelope (tampered, truncated,
    foreign format, digest mismatch, or wrong kind/key)."""


def _payload_digest(payload: bytes) -> str:
    return hashlib.sha256(payload).hexdigest()


def encode(kind: str, key: str, obj: Any) -> bytes:
    """Serialize ``obj`` into a tamper-evident envelope for store ``key``."""
    limit = sys.getrecursionlimit()
    if limit < _PICKLE_RECURSION_LIMIT:
        sys.setrecursionlimit(_PICKLE_RECURSION_LIMIT)
    try:
        payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        envelope = (MAGIC, VERSION, str(kind), str(key),
                    _payload_digest(payload), payload)
        return pickle.dumps(envelope, protocol=pickle.HIGHEST_PROTOCOL)
    finally:
        if sys.getrecursionlimit() != limit:
            sys.setrecursionlimit(limit)


def decode(
    data: bytes,
    *,
    kind: Optional[str] = None,
    key: Optional[str] = None,
) -> Tuple[str, str, Any]:
    """Open an envelope, verifying structure, digest, and (when given) the
    expected ``kind``/``key``.  Returns ``(kind, key, obj)``; raises
    :class:`CorruptEntryError` on any mismatch."""
    try:
        envelope = pickle.loads(data)
    except Exception as e:  # noqa: BLE001 - any unpickle failure is corrupt
        raise CorruptEntryError(f"undecodable store entry: {e}") from e
    if (
        not isinstance(envelope, tuple)
        or len(envelope) != 6
        or envelope[0] != MAGIC
    ):
        raise CorruptEntryError("not a repro-store envelope")
    _, version, got_kind, got_key, digest, payload = envelope
    if version != VERSION:
        raise CorruptEntryError(
            f"unsupported envelope version {version!r} (expected {VERSION})"
        )
    if not isinstance(payload, bytes) or _payload_digest(payload) != digest:
        raise CorruptEntryError("payload digest mismatch (tampered entry)")
    if kind is not None and got_kind != kind:
        raise CorruptEntryError(
            f"entry kind {got_kind!r} where {kind!r} was expected"
        )
    if key is not None and got_key != key:
        raise CorruptEntryError(
            f"entry written for key {got_key!r} served under {key!r}"
        )
    limit = sys.getrecursionlimit()
    if limit < _PICKLE_RECURSION_LIMIT:
        sys.setrecursionlimit(_PICKLE_RECURSION_LIMIT)
    try:
        obj = pickle.loads(payload)
    except Exception as e:  # noqa: BLE001
        raise CorruptEntryError(f"undecodable payload: {e}") from e
    finally:
        if sys.getrecursionlimit() != limit:
            sys.setrecursionlimit(limit)
    return got_kind, got_key, obj
