"""`repro.store` — the repo's one persistence API.

Content-addressed storage for everything the planning stack persists:
solver Solutions, autotune winners, saved MemoryPlans, and warm-start
frontiers.  Layers, bottom to top:

- :mod:`~repro.store.backend` — byte backends (``memory://`` LRU,
  ``file://`` local directory, ``shared://`` fsync-hardened shared
  directory), all with atomic writes and corruption quarantine;
- :mod:`~repro.store.codec` — the tamper-evident pickle envelope, the
  *only* place in the repo allowed to (de)serialize (the
  ``pickle-confinement`` lint rule enforces this);
- :mod:`~repro.store.objects` — :class:`ObjectStore`, typed access with
  metrics and quarantine-on-corrupt;
- :mod:`~repro.store.keys` — the chain × request × code content address
  (:class:`PlanKey`, :func:`request_digest`);
- :mod:`~repro.store.plans` — :class:`PlanStore`, where every foreign
  plan is admitted only through ``MemoryPlan.verify()``;
- :mod:`~repro.store.frontier` — :class:`WarmStartFrontier`, persisted
  ``sweep()`` results answering any budget query with ≤1 solve;
- :mod:`~repro.store.config` — ``REPRO_STORE`` env resolution, legacy
  ``REPRO_SOLVER_CACHE*`` mapping, and the process default store.

Exports resolve lazily (PEP 562) so ``repro.core.solver_cache`` can import
the backend/codec submodules without initializing the higher layers.
"""

from typing import TYPE_CHECKING

_EXPORTS = {
    "Backend": "backend",
    "MemoryBackend": "backend",
    "LocalDirectoryBackend": "backend",
    "SharedDirectoryBackend": "backend",
    "StoreError": "backend",
    "from_uri": "backend",
    "validate_key": "backend",
    "QUARANTINE_DIR": "backend",
    "CorruptEntryError": "codec",
    "encode": "codec",
    "decode": "codec",
    "ObjectStore": "objects",
    "PlanKey": "keys",
    "request_digest": "keys",
    "PLAN_NAMESPACE": "keys",
    "FRONTIER_NAMESPACE": "keys",
    "PlanStore": "plans",
    "WarmStartFrontier": "frontier",
    "FrontierAnswer": "frontier",
    "template_digest": "frontier",
    "StoreSettings": "config",
    "resolve_settings": "config",
    "default_store": "config",
    "default_cache_dir": "config",
    "configure": "config",
    "reset": "config",
}

__all__ = sorted(_EXPORTS)

if TYPE_CHECKING:
    from .backend import (Backend, LocalDirectoryBackend, MemoryBackend,
                          SharedDirectoryBackend, StoreError, from_uri,
                          validate_key, QUARANTINE_DIR)
    from .codec import CorruptEntryError, decode, encode
    from .config import (StoreSettings, configure, default_cache_dir,
                         default_store, reset, resolve_settings)
    from .frontier import FrontierAnswer, WarmStartFrontier, template_digest
    from .keys import (FRONTIER_NAMESPACE, PLAN_NAMESPACE, PlanKey,
                       request_digest)
    from .objects import ObjectStore
    from .plans import PlanStore


def __getattr__(name):
    try:
        submodule = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module 'repro.store' has no attribute {name!r}") from None
    import importlib
    mod = importlib.import_module(f".{submodule}", __name__)
    value = getattr(mod, name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
