"""Quickstart: the paper's optimal checkpointing on a toy chain in ~40 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (Schedule, build_remat_fn, profile_stages_analytic,
                        simulate, solve_optimal)
from repro.core.solver import solve_min_memory

# 1) a heterogeneous chain: 6 MLP stages of varying width + a loss stage
dims = [64, 256, 64, 512, 64, 128, 32]
key = jax.random.PRNGKey(0)
params = [{"w": jax.random.normal(jax.random.fold_in(key, i),
                                  (dims[i], dims[i + 1])) * 0.1}
          for i in range(6)] + [{}]
stages = [lambda p, a: jnp.tanh(a @ p["w"]) for _ in range(6)] \
    + [lambda p, a: jnp.mean(a ** 2)]
x = jax.random.normal(key, (32, dims[0]))

# 2) measure the chain (paper §5.1 parameter estimation — analytic mode)
chain = profile_stages_analytic(stages, params, x, peak_flops=1e9)
store_all = simulate(chain, Schedule.store_all(chain.length))
print(f"store-all: peak={store_all.peak_mem:.0f} B, time={store_all.time:.4f}")

# 3) solve for the optimal persistent schedule midway between the minimum
#    feasible memory and the store-all peak (Theorem 1)
floor = solve_min_memory(chain, num_slots=300)
budget = 0.5 * (floor.mem_limit + store_all.peak_mem)
print(f"minimum feasible activation memory: {floor.mem_limit:.0f} B "
      f"({floor.mem_limit/store_all.peak_mem:.0%} of store-all)")
sol = solve_optimal(chain, budget, num_slots=300)
res = simulate(chain, sol.schedule)
print(f"rotor@50%: peak={res.peak_mem:.0f} B ({res.peak_mem/store_all.peak_mem:.0%}),"
      f" time={res.time:.4f} ({res.time/store_all.time:.2f}x)")
print("schedule:", " ".join(f"{k}{l}" for k, l in sol.schedule.ops))

# 4) run it under jit via the nested-remat compiler — same gradients
f = build_remat_fn(sol.tree, stages)
g_rotor = jax.jit(jax.grad(f))(params, x)


def plain(params, x):
    a = x
    for fn, p in zip(stages, params):
        a = fn(p, a)
    return a


g_ref = jax.jit(jax.grad(plain))(params, x)
err = max(float(jnp.max(jnp.abs(a - b)))
          for a, b in zip(jax.tree.leaves(g_rotor), jax.tree.leaves(g_ref)))
print(f"max |grad_rotor - grad_plain| = {err:.2e}  (exactly the same results)")
