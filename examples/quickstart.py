"""Quickstart: the paper's optimal checkpointing on a toy chain, through the
first-class planning API (`repro.plan`).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import jax
import jax.numpy as jnp

from repro.core import Schedule, profile_stages_analytic, simulate
from repro.plan import (Budget, MemoryPlan, PlanRequest, build_plan,
                        min_memory_plan)

# 1) a heterogeneous chain: 6 MLP stages of varying width + a loss stage
dims = [64, 256, 64, 512, 64, 128, 32]
key = jax.random.PRNGKey(0)
params = [{"w": jax.random.normal(jax.random.fold_in(key, i),
                                  (dims[i], dims[i + 1])) * 0.1}
          for i in range(6)] + [{}]
stages = [lambda p, a: jnp.tanh(a @ p["w"]) for _ in range(6)] \
    + [lambda p, a: jnp.mean(a ** 2)]
x = jax.random.normal(key, (32, dims[0]))

# 2) measure the chain (paper §5.1 parameter estimation — analytic mode)
chain = profile_stages_analytic(stages, params, x, peak_flops=1e9)
store_all = simulate(chain, Schedule.store_all(chain.length))
print(f"store-all: peak={store_all.peak_mem:.0f} B, time={store_all.time:.4f}")

# 3) plan the optimal persistent schedule midway between the minimum
#    feasible memory and the store-all peak (Theorem 1): a typed request in,
#    an inspectable MemoryPlan out
floor = min_memory_plan(chain, num_slots=300)
print(f"minimum feasible activation memory: {floor.budget_bytes:.0f} B "
      f"({floor.budget_bytes/store_all.peak_mem:.0%} of store-all)")
budget = 0.5 * (floor.budget_bytes + store_all.peak_mem)
plan = build_plan(PlanRequest(strategy="optimal",
                              budget=Budget.bytes(budget),
                              num_slots=300), chain)
print(plan.summary())
print("schedule:", " ".join(f"{k}{l}" for k, l in plan.schedule.ops))

# 4) plans are artifacts: save to disk, reload, and the chain hash refuses a
#    plan that was solved for a different chain
path = os.path.join(tempfile.mkdtemp(), "quickstart_plan.pkl")
plan.save(path)
plan = MemoryPlan.load(path, chain=chain)   # validated round-trip
print(f"plan round-tripped through {path}")

# 5) run it under jit via the uniform executor binding — same gradients
bound = plan.bind(stages)
assert bound.jittable  # two-tier plan -> nested jax.checkpoint under jit
g_rotor = jax.jit(jax.grad(bound.forward))(params, x)


def plain(params, x):
    a = x
    for fn, p in zip(stages, params):
        a = fn(p, a)
    return a


g_ref = jax.jit(jax.grad(plain))(params, x)
err = max(float(jnp.max(jnp.abs(a - b)))
          for a, b in zip(jax.tree.leaves(g_rotor), jax.tree.leaves(g_ref)))
print(f"max |grad_rotor - grad_plain| = {err:.2e}  (exactly the same results)")

# 6) observability (opt-in): set REPRO_OBS_OUT=<dir> to execute the plan once
#    with the span tracer and drop trace.json (load at ui.perfetto.dev) + a
#    metrics snapshot + the plan-vs-actual drift report there
obs_out = os.environ.get("REPRO_OBS_OUT")
if obs_out:
    import json

    from repro.obs import metrics
    from repro.obs.trace import Tracer

    os.makedirs(obs_out, exist_ok=True)
    tracer = Tracer(name="quickstart")
    plan.execute(stages, params, x, tracer=tracer)
    tracer.save(os.path.join(obs_out, "trace.json"))
    metrics.save(os.path.join(obs_out, "metrics.json"))
    report = plan.drift(tracer)
    with open(os.path.join(obs_out, "drift.json"), "w") as f:
        json.dump(report.to_json(), f, indent=1)
    print(f"[obs] wrote trace.json / metrics.json / drift.json to {obs_out}")
    print(report.summary())
