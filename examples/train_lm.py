"""End-to-end driver: train a ~100M-parameter decoder LM with the rotor remat
policy, checkpoint/restart, straggler watchdog and deterministic data.

Default sizing (~104M params: d=640, 10 layers, vocab 16384) is real work on
a CPU; use --tiny for a fast demonstration.  Kill it mid-run and re-invoke
with the same --ckpt-dir to watch it resume from the checkpoint.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 300 \
          --ckpt-dir /tmp/rotor_lm_ckpt
"""

import argparse

import jax.numpy as jnp

from repro.configs import smoke_config
from repro.runtime.train_loop import TrainLoopConfig, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--policy", default="rotor:x0.6",
                    help="activation budget: 60%% of the store-all peak "
                         "(any repro.plan policy works, e.g. "
                         "optimal_offload:x0.4)")
    ap.add_argument("--num-slots", type=int, default=None,
                    help="DP discretization slots (default: plan default)")
    args = ap.parse_args()

    if args.tiny:
        cfg = smoke_config("qwen1.5-4b")
        batch, seq = 8, 64
    else:
        cfg = smoke_config(
            "qwen1.5-4b", num_layers=10, layer_kinds=("dense",) * 10,
            d_model=640, n_heads=10, n_kv_heads=10, head_dim=64,
            d_ff=2560, vocab_size=16384, n_chunks=10,
            dtype=jnp.float32, param_dtype=jnp.float32)
        batch, seq = 2, 128
    n = cfg.total_params()
    print(f"[example] {cfg.name}-derived LM: {n/1e6:.1f}M params, "
          f"policy={args.policy}")

    loop = TrainLoopConfig(steps=args.steps, global_batch=batch, seq_len=seq,
                           lr=1e-3, warmup=20, policy=args.policy,
                           num_slots=args.num_slots,
                           ckpt_dir=args.ckpt_dir, ckpt_every=50,
                           log_every=10)
    out = run_training(cfg, loop)
    print(f"[example] loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"over {len(out['losses'])} steps; "
          f"{out['tokens_per_s']:.0f} tokens/s")


if __name__ == "__main__":
    main()
