"""Serve a small model with batched requests: prefill + jitted KV-cache
greedy decode (works for every arch family; SSM archs use recurrent caches).

Run:  PYTHONPATH=src python examples/serve_decode.py --arch zamba2-2.7b
"""

import argparse

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models.lm import StagedLM
from repro.runtime.serve_loop import ServeLoopConfig, run_serving


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    if cfg.modality != "text":
        import dataclasses
        cfg = dataclasses.replace(cfg, modality="text", prefix_len=0)
    model = StagedLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    loop = ServeLoopConfig(max_new_tokens=args.new_tokens,
                           max_len=args.prompt_len + args.new_tokens + 1)
    out = run_serving(cfg, params, prompts, loop, model=model)
    print(f"[serve] {args.arch}: prefill {out['prefill_s']*1e3:.1f} ms, "
          f"decode {out['decode_tokens_per_s']:.1f} tok/s "
          f"(batch={args.batch})")
    print("[serve] first generation:", out["generations"][0].tolist())


if __name__ == "__main__":
    main()
