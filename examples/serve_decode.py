"""Serve a small model with batched requests: prefill + jitted KV-cache
greedy decode (works for every arch family; SSM archs use recurrent caches).

With ``--kv-budget`` the decode cache is planned as a heterogeneous chain
(:func:`repro.plan.plan_serving`): layers whose cold prefix KV doesn't fit
the device budget are staged through the pinned host pool around every step,
and the run reports the transfer traffic next to the unconstrained baseline.

Run:  PYTHONPATH=src python examples/serve_decode.py --arch zamba2-2.7b
      PYTHONPATH=src python examples/serve_decode.py --kv-budget 0.5
"""

import argparse

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models.lm import StagedLM
from repro.runtime.serve_loop import ServeLoopConfig, run_serving


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--kv-budget", type=float, default=None,
                    help="device KV budget as a fraction of the full cache; "
                         "plans host staging for what doesn't fit")
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    if cfg.modality != "text":
        import dataclasses
        cfg = dataclasses.replace(cfg, modality="text", prefix_len=0)
    model = StagedLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    max_len = args.prompt_len + args.new_tokens + 1
    loop = ServeLoopConfig(max_new_tokens=args.new_tokens, max_len=max_len)
    out = run_serving(cfg, params, prompts, loop, model=model)
    print(f"[serve] {args.arch}: prefill {out['prefill_s']*1e3:.1f} ms, "
          f"decode {out['decode_tokens_per_s']:.1f} tok/s "
          f"(batch={args.batch}, kv {out['kv_bytes']} B logical / "
          f"{out['kv_bytes_allocated']} B allocated)")
    print("[serve] first generation:", out["generations"][0].tolist())

    if args.kv_budget is not None:
        from repro.plan import plan_serving

        layout = model.cache_layout(args.batch, max_len)
        budget = args.kv_budget * sum(layout.block_bytes)
        plan = plan_serving(cfg, budget, batch=args.batch,
                            prompt_len=args.prompt_len, max_len=max_len)
        planned = run_serving(cfg, params, prompts, loop, model=model,
                              plan=plan, kv_budget=budget)
        assert np.array_equal(planned["generations"], out["generations"]), (
            "planned KV residency must not change the generations")
        n = len(planned["kv_host_layers"])
        print(f"[serve] planned @ x{args.kv_budget:g}: {n}/{cfg.num_layers} "
              f"layers staged to host, "
              f"{planned['kv_transfer_bytes']:.0f} B moved, "
              f"stall {planned['kv_stall_s']*1e3:.2f} ms "
              f"(generations identical)")


if __name__ == "__main__":
    main()
