"""Reproduce the paper's throughput-vs-memory tradeoff (Figs 3-5) on CPU:
measures per-stage costs, then sweeps memory budgets for the four strategies
and prints the curve points (+ the §5.4 headline gain).

Run:  PYTHONPATH=src python examples/tradeoff_curves.py
"""

from benchmarks.bench_tradeoff import main

if __name__ == "__main__":
    main(small=True)
