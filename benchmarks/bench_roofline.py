"""§Roofline table: aggregate the dry-run JSON records into the per-(arch ×
shape × mesh) roofline report.

Two sets of terms per cell:
- **analytic** (primary): exact cost-model terms — per-stage 2NMK FLOPs ×
  schedule execution counts, modeled HBM traffic, modeled collective bytes
  (see ``repro/launch/analytic.py``); immune to the XLA-CPU cost-analysis
  while-body-once artifact;
- **hlo** (diagnostic): ``cost_analysis``/HLO-parsed terms as prescribed —
  under-counted for scan-in-loop models (documented in EXPERIMENTS §Caveats).
"""

from __future__ import annotations

import glob
import json
import os


def load_records(dry_dir: str = "experiments/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
            r["_file"] = os.path.basename(path)
            recs.append(r)
    return recs


def main(emit=print, dry_dir: str = "experiments/dryrun", small: bool = True):
    recs = load_records(dry_dir)
    emit("arch,shape,mesh,policy,ana_compute_s,ana_memory_s,ana_collective_s,"
         "ana_dominant,hlo_compute_s,hlo_memory_s,hlo_collective_s,"
         "useful_ratio,model_act_peak_GiB,cpu_sched_peak_GiB")
    for r in recs:
        if "__iter" in r["_file"] or "__ctl" in r["_file"]:
            continue  # perf iterations listed in EXPERIMENTS §Perf
        roof = r["roofline"]
        ana = r.get("analytic", {})
        act = r["memory"].get("model_peak_activations")
        emit(f"{r['arch']},{r['shape']},{r['mesh']},{r.get('policy')},"
             f"{ana.get('compute_s', float('nan')):.4f},"
             f"{ana.get('memory_s', float('nan')):.4f},"
             f"{ana.get('collective_s', float('nan')):.4f},"
             f"{ana.get('dominant', '?')},"
             f"{roof['compute_s']:.4f},{roof['memory_s']:.4f},"
             f"{roof['collective_s']:.4f},{roof['useful_ratio']:.3f},"
             f"{'' if act is None else round(act / 2**30, 2)},"
             f"{r['memory']['peak_bytes'] / 2**30:.2f}")
    if not recs:
        emit("# no dry-run records found — run: "
             "PYTHONPATH=src python -m repro.launch.dryrun --all")
    return recs


if __name__ == "__main__":
    main()
