"""Benchmark driver — one bench per paper table/figure.  Prints
``name,us_per_call,derived``-style CSV sections.  ``--full`` runs the
paper-scale variants (L=339 solver, 12-block chains)."""

from __future__ import annotations

import argparse
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: tradeoff,solver,prediction,roofline,"
                         "kernels,offload")
    args = ap.parse_args(argv)
    small = not args.full
    which = set(args.only.split(",")) if args.only else None

    from . import (bench_kernels, bench_offload, bench_prediction,
                   bench_roofline, bench_solver, bench_tradeoff)

    benches = [
        ("tradeoff", bench_tradeoff, "paper Figs 3-13: throughput vs memory"),
        ("solver", bench_solver, "paper §5.2: DP runtime vs chain length"),
        ("prediction", bench_prediction, "paper §5.3: model-vs-measured error"),
        ("roofline", bench_roofline, "§Roofline: dry-run roofline table"),
        ("kernels", bench_kernels, "kernel micro-bench"),
        ("offload", bench_offload,
         "three-tier: time vs device budget with host offload"),
    ]
    for name, mod, desc in benches:
        if which and name not in which:
            continue
        print(f"\n### bench:{name} — {desc}")
        t0 = time.perf_counter()
        res = mod.main(emit=print, small=small)
        if name == "solver":
            # machine-readable perf record, tracked across PRs
            bench_solver.write_json(res, bench_solver.JSON_PATH)
            print(f"### bench:solver wrote {bench_solver.JSON_PATH}")
        print(f"### bench:{name} done in {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
