"""Paper Figures 3–13: throughput vs peak memory for the four strategies —
**PyTorch** (store-all), **sequential** (periodic, best segment count),
**revolve** (AD-model comparator) and **optimal** (this paper) — on a
heterogeneous conv chain and a transformer chain, with *measured* per-stage
costs (paper §5.1) and both model-predicted and wall-clock numbers.

The solver-backed curves come from ``repro.plan.sweep`` — the time-vs-budget
frontier is a first-class API call, not a hand-rolled loop over
``solve_optimal``.

Also reports the paper's headline metric: throughput gain of optimal over
the best sequential point at matching memory (§5.4: +17.2% on their GPU
suite)."""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.core import (Schedule, best_periodic, execute_schedule,
                        profile_stages_measured, simulate)
from repro.plan import (Budget, InfeasiblePlanError, PlanRequest, build_plan,
                        sweep)

from .chains import resnet_ish_chain, transformer_chain


def _wall_time(schedule, stages, params, x, repeats=2) -> float:
    out = execute_schedule(schedule, stages, params, x)  # warm caches
    jax.block_until_ready(out[1])
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = execute_schedule(schedule, stages, params, x)
    jax.block_until_ready(out[1])
    return (time.perf_counter() - t0) / repeats


def run_chain(name: str, stages, params, x, batch: int,
              budgets=(0.35, 0.5, 0.65, 0.8, 1.0), measured_repeats=1,
              emit=print) -> Dict:
    chain = profile_stages_measured(stages, params, x, repeats=1)
    store_all = Schedule.store_all(chain.length)
    base = simulate(chain, store_all)
    rows: List[dict] = []

    def row(strategy, budget_frac, sched, predicted):
        wall = _wall_time(sched, stages, params, x, measured_repeats)
        sim = simulate(chain, sched)
        r = dict(chain=name, strategy=strategy, budget_frac=budget_frac,
                 peak_mem=sim.peak_mem, predicted_s=predicted,
                 wall_s=wall, items_per_s=batch / wall)
        rows.append(r)
        emit(f"{name},{strategy},{budget_frac:.2f},{sim.peak_mem:.3e},"
             f"{predicted:.4f},{wall:.4f},{batch / wall:.2f}")
        return r

    emit("chain,strategy,budget_frac,peak_mem_bytes,predicted_s,wall_s,items_per_s")
    r_store = row("pytorch_store_all", 1.0, store_all, base.time)

    # the two solver-backed frontiers, one sweep() call each
    opt_pts = sweep(chain, budgets,
                    PlanRequest(strategy="optimal", num_slots=300),
                    store_all_peak=base.peak_mem)
    rev_pts = sweep(chain, budgets,
                    PlanRequest(strategy="revolve", num_slots=300),
                    store_all_peak=base.peak_mem)
    for frac, opt, rev in zip(budgets, opt_pts, rev_pts):
        if opt.feasible:
            row("optimal", frac, opt.plan.schedule, opt.plan.expected_time)
        if rev.feasible:
            row("revolve", frac, rev.plan.schedule, rev.plan.expected_time)
        got = best_periodic(chain, base.peak_mem * frac)
        if got is not None:
            k, res, sched = got
            row(f"sequential(k={k})", frac, sched, res.time)

    # headline: optimal-vs-best-sequential gain at equal memory (model time).
    # ceil-discretization can inflate a schedule's footprint by up to ~1 slot
    # per live value (§5.2's 1+1/S is per-size) — grant that slack so the
    # comparison is apples-to-apples with the continuous sequential schedule
    gains = []
    slots = 500
    slack = 1 + (chain.length + 4) / slots
    for r in rows:
        if not r["strategy"].startswith("sequential"):
            continue
        m = r["peak_mem"]
        try:
            plan = build_plan(
                PlanRequest(strategy="optimal",
                            budget=Budget.bytes(m * slack),
                            num_slots=slots), chain)
        except InfeasiblePlanError:
            continue
        gains.append(r["predicted_s"] / plan.expected_time - 1.0)
    gain = float(np.mean(gains)) if gains else float("nan")
    gmax = float(np.max(gains)) if gains else float("nan")
    emit(f"# {name}: optimal-vs-sequential speedup at equal memory: "
         f"mean {gain * 100:+.1f}%, best point {gmax * 100:+.1f}%  "
         f"(paper §5.4, GPU suite: mean +17.2%)")
    return {"rows": rows, "mean_gain": gain, "max_gain": gmax}


def main(emit=print, small: bool = True):
    budgets = (0.45, 0.7, 1.0) if small else (0.35, 0.5, 0.65, 0.8, 1.0)
    stages, params, x = resnet_ish_chain(num_blocks=6 if small else 12,
                                         image=24 if small else 32,
                                         batch=4 if small else 8)
    res_cnn = run_chain("resnet_ish", stages, params, x, batch=x.shape[0],
                        budgets=budgets, emit=emit)
    fns, sp, batch_d = transformer_chain(num_layers=4 if small else 12,
                                         d_model=96 if small else 128,
                                         seq=96 if small else 128,
                                         batch=2 if small else 4)
    res_tr = run_chain("transformer", fns, sp, batch_d,
                       batch=batch_d["tokens"].shape[0], budgets=budgets,
                       emit=emit)
    return {"resnet_ish": res_cnn, "transformer": res_tr}


if __name__ == "__main__":
    main()
