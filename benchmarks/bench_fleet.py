"""Fleet planning benchmark: cold-vs-warm plan time through the plan store.

Simulates a fleet relaunch: ``--configs`` synthetic (chain × budget) plan
requests are resolved twice through :class:`repro.runtime.PlanService` over
one store backend — the **cold** pass solves and admits every plan, the
**warm** pass (fresh service, fresh process-level caches, solver-cache LRU
cleared) answers every request from the store as a verified hit.  The
headline is ``speedup = cold_s / warm_s`` — the committed baseline asserts
it stays ≥ x10 (``compare_trajectory.py`` gates CI on the ``fleet`` section
of ``BENCH_solver.json``).

Also records a **warm-start frontier** interpolation: a two-point sweep at
1.5x / 2.5x the store-all peak is persisted, then an unseen 2.0x budget is
queried — the equal-makespan bracket answers it with **zero** DP solves
(``frontier.query_solves == 0``, also gated).

CLI (used by the CI ``store-smoke`` job, two sequential processes on one
``shared://`` store — the second must be ≥90% cache-hot):

    python -m benchmarks.bench_fleet --store shared:///tmp/fleet \\
        --configs 200 --passes 1 --json out.json --expect-hit-rate 0.9
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import solver_cache
from repro.core.chain import Chain
from repro.plan import Budget, PlanRequest, sweep
from repro.runtime import PlanService, TenantQuota
from repro.store import ObjectStore, WarmStartFrontier, from_uri

NUM_SLOTS = 300
TENANTS = ("tenant-a", "tenant-b", "tenant-c", "tenant-d")


def _chain(L: int, seed: int) -> Chain:
    rng = np.random.default_rng(seed)
    n = L + 1
    return Chain.make(
        uf=rng.uniform(0.5, 2.0, n),
        ub=rng.uniform(1.0, 4.0, n),
        wa=rng.uniform(0.5, 2.0, n),
        wabar=rng.uniform(1.0, 4.0, n),
    )


def _configs(n_configs: int, n_chains: int):
    """Deterministic fleet: ``n_chains`` distinct chains, each planned at a
    spread of budget fractions — ``n_configs`` (chain, request, tenant)
    triples in total."""
    chains = [_chain(12 + 2 * (i % 12), seed=i) for i in range(n_chains)]
    peaks = [ch.store_all_peak() for ch in chains]
    out = []
    for i in range(n_configs):
        ch = chains[i % n_chains]
        frac = 0.35 + 0.5 * ((i // n_chains) % 29) / 29.0
        req = PlanRequest(
            strategy="optimal",
            budget=Budget.bytes(peaks[i % n_chains] * frac),
            num_slots=NUM_SLOTS,
        )
        out.append((ch, req, TENANTS[i % len(TENANTS)]))
    return out


def _reset_process_caches() -> None:
    """Drop every process-level shortcut so a pass's speed comes from the
    plan store alone: memory-only solver cache (no disk tier doubling as a
    warm store), cleared between passes."""
    solver_cache.configure(directory=None)


def _snapshot_counts() -> dict:
    from repro.obs import metrics

    snap = metrics.registry().snapshot()
    return {k: int(v.get("count", 0)) for k, v in snap.items()}


def _run_pass(backend, configs, label: str, emit) -> dict:
    _reset_process_caches()
    store = ObjectStore(backend, name="store")
    quota = TenantQuota(max_inflight=1 << 20, max_plans=1 << 20)
    before = _snapshot_counts()
    t0 = time.perf_counter()
    with PlanService(store, workers=4, default_quota=quota) as svc:
        futures = [
            svc.submit(ch, req, tenant=tenant) for ch, req, tenant in configs
        ]
        plans = [f.result() for f in futures]
    dt = time.perf_counter() - t0
    assert all(p is not None for p in plans)
    after = _snapshot_counts()

    def count(name):
        return after.get(name, 0) - before.get(name, 0)

    hits = count("plan_service.hits")
    misses = count("plan_service.misses")
    total = max(hits + misses, 1)
    result = dict(
        label=label,
        seconds=round(dt, 4),
        requests=len(configs),
        hits=hits,
        misses=misses,
        hit_rate=round(hits / total, 4),
        verify_rejects=count("plan_service.verify_rejects"),
    )
    emit(
        f"# fleet pass {label}: {len(configs)} requests in {dt:.3f}s "
        f"(hits={hits} misses={misses} hit_rate={result['hit_rate']:.0%})"
    )
    return result


def _frontier_section(backend, emit) -> dict:
    """Record a 2-point sweep, then answer an unseen bracketed budget with
    zero DP solves (the equal-makespan interpolation fact)."""
    _reset_process_caches()
    store = ObjectStore(backend, name="store")
    frontier = WarmStartFrontier(store)
    ch = _chain(24, seed=10_007)
    peak = ch.store_all_peak()
    template = PlanRequest(strategy="optimal", num_slots=NUM_SLOTS)
    # budgets clearing the store-all peak plus the worst-case slot-rounding
    # slack: both points are feasible with the identical (recompute-free)
    # optimal makespan, so any budget between them is answered by the
    # bracket without touching the DP
    sweep(
        ch,
        [1.5, 2.5],
        template,
        store_all_peak=peak,
        frontier=frontier,
    )
    solves = [0]

    def counting_solve(budget):
        solves[0] += 1
        from repro.plan import build_plan
        import dataclasses

        return build_plan(
            dataclasses.replace(template, budget=Budget.bytes(budget)), ch
        )

    answer = frontier.query(ch, template, peak * 2.0, solve=counting_solve)
    section = dict(
        query_fraction=2.0,
        query_solves=solves[0] + answer.solves,
        source=answer.source,
        feasible=answer.feasible,
    )
    emit(
        f"# frontier query at 2.0x peak: source={answer.source} "
        f"solves={section['query_solves']}"
    )
    return section


def run(
    backend=None,
    configs: int = 1000,
    chains: int = 40,
    passes: int = 2,
    emit=print,
) -> dict:
    """Cold (and optionally warm) fleet pass + the frontier interpolation
    record; returns the machine-readable ``fleet`` section."""
    if backend is None:
        from repro.store import MemoryBackend

        backend = MemoryBackend(capacity=1 << 20)
    fleet = _configs(configs, chains)
    result = dict(
        bench="fleet",
        configs=configs,
        chains=chains,
        num_slots=NUM_SLOTS,
        passes=[],
    )
    cold = _run_pass(backend, fleet, "cold", emit)
    result["passes"].append(cold)
    if passes > 1:
        warm = _run_pass(backend, fleet, "warm", emit)
        result["passes"].append(warm)
        result["cold_s"] = cold["seconds"]
        result["warm_s"] = warm["seconds"]
        result["speedup"] = round(
            cold["seconds"] / max(warm["seconds"], 1e-9), 2
        )
        result["warm_hit_rate"] = warm["hit_rate"]
        emit(f"# fleet speedup cold/warm: x{result['speedup']}")
    result["frontier"] = _frontier_section(backend, emit)
    result["hit_rate"] = result["passes"][-1]["hit_rate"]
    return result


def main(emit=print, small: bool = True) -> dict:
    if small:
        return run(configs=120, chains=12, emit=emit)
    return run(emit=emit)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--store",
        default=None,
        help="store URI (memory://, file://<dir>, shared://<dir>); "
        "default an in-process memory store",
    )
    ap.add_argument("--configs", type=int, default=1000)
    ap.add_argument("--chains", type=int, default=40)
    ap.add_argument(
        "--passes",
        type=int,
        default=2,
        choices=(1, 2),
        help="1 = single pass (the CI smoke runs two one-pass processes)",
    )
    ap.add_argument("--json", default=None, help="write the fleet section")
    ap.add_argument(
        "--expect-hit-rate",
        type=float,
        default=None,
        help="exit nonzero unless the final pass's hit rate is >= this",
    )
    args = ap.parse_args()
    backend = from_uri(args.store) if args.store else None
    res = run(
        backend=backend,
        configs=args.configs,
        chains=args.chains,
        passes=args.passes,
    )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    if args.expect_hit_rate is not None:
        rate = res["hit_rate"]
        if rate < args.expect_hit_rate:
            raise SystemExit(
                f"hit rate {rate:.0%} below required "
                f"{args.expect_hit_rate:.0%}"
            )
        print(f"hit rate {rate:.0%} >= {args.expect_hit_rate:.0%}")
