"""Paper §5.2: DP solver runtime vs chain length (their C implementation:
<1 s typical, ~20 s at L=339 / S=500; ours is vectorized numpy)."""

from __future__ import annotations

import time

import numpy as np

from repro.core.chain import Chain
from repro.core.schedule import Schedule, simulate
from repro.core.solver import solve_optimal


def run(lengths=(20, 50, 100, 200, 339), num_slots=500, emit=print):
    emit("L,num_slots,solve_s,feasible,expected_time")
    rng = np.random.default_rng(0)
    out = []
    for L in lengths:
        n = L + 1
        ch = Chain.make(
            uf=rng.uniform(0.5, 2.0, n), ub=rng.uniform(1.0, 4.0, n),
            wa=rng.uniform(0.5, 2.0, n), wabar=rng.uniform(1.0, 4.0, n))
        peak = simulate(ch, Schedule.store_all(L)).peak_mem
        t0 = time.perf_counter()
        sol = solve_optimal(ch, peak * 0.4, num_slots=num_slots)
        dt = time.perf_counter() - t0
        emit(f"{L},{num_slots},{dt:.2f},{sol.feasible},"
             f"{sol.expected_time:.2f}")
        out.append((L, dt, sol.feasible))
    return out


def main(emit=print, small: bool = True):
    lengths = (20, 50, 100) if small else (20, 50, 100, 200, 339)
    return run(lengths=lengths, num_slots=200 if small else 500, emit=emit)


if __name__ == "__main__":
    main(small=False)
