"""Paper §5.2: DP solver runtime vs chain length (their C implementation:
<1 s typical, ~20 s at L=339 / S=500).

Times the solver impls per chain length:

- **banded**         — the default two-tier DP on the split-batched float32
  band kernels (``repro.core.dp_kernels``), saturated m-columns pruned,
- **banded-noprune** — the same fill with ``REPRO_DP_PRUNE=0`` (the pruning
  delta is recorded as ``pruning_speedup`` on this row),
- **pallas**         — the per-band Pallas kernel (``repro.kernels.dp_fill``)
  behind ``impl="pallas"``; on this CPU host it runs in interpret mode (the
  TPU dispatch seam's fallback), so it is timed only up to
  ``pallas_max_len`` — the row records the *seam*, not TPU speed,
- **pallas_fused**   — the device-resident fill behind ``impl="pallas_fused"``:
  the whole band recursion in ONE ``pallas_call`` (no per-band host loop) —
  CPU-capped at the same ``pallas_max_len`` for the same reason; the row's
  ``device_dispatches`` field records the kernel-launch count (asserted 1),
- **reference**      — the retained seed per-cell float64 fill (the ≥10×
  claim is measured against it),
- **offload**        — the three-tier DP (same kernels, one extra candidate
  plane) on the same chain priced with a host link.

Also reports ``Solution.table_bytes`` per impl (the banded layout must be
≥4× smaller) and the latency of a *second* identical solve, which is served
by the solver cache without any table fill.

``run()`` returns a machine-readable dict; ``benchmarks/run.py`` (and this
module's CLI) dump it to ``BENCH_solver.json`` so the perf trajectory is
tracked across PRs (``benchmarks/compare_trajectory.py`` gates CI on it).
"""

from __future__ import annotations

import argparse
import contextlib
import json
import os
import time

import numpy as np

from repro.core.chain import Chain, HostTransferModel
from repro.core.schedule import Schedule, simulate
from repro.core.solver import solve_optimal
from repro.offload.solver import solve_optimal_offload

JSON_PATH = "BENCH_solver.json"

#: Interpret-mode Pallas executes kernel bodies in Python — fine for parity,
#: hopeless for timing big chains on CPU.  Lengths above this are skipped
#: (and logged) unless a TPU backend is present.
PALLAS_MAX_LEN = 50


@contextlib.contextmanager
def _count_dispatches():
    """Counting shim on ``pallas_call`` (as seen by the dp_fill kernels):
    yields a one-element list incremented per device dispatch — how the
    single-dispatch claim of ``impl="pallas_fused"`` is recorded."""
    from repro.kernels.dp_fill import kernel as dpk

    calls = [0]
    orig = dpk.pl.pallas_call

    def counting(*args, **kwargs):
        calls[0] += 1
        return orig(*args, **kwargs)

    dpk.pl.pallas_call = counting
    try:
        yield calls
    finally:
        dpk.pl.pallas_call = orig


@contextlib.contextmanager
def _pruning_disabled():
    old = os.environ.get("REPRO_DP_PRUNE")
    os.environ["REPRO_DP_PRUNE"] = "0"
    try:
        yield
    finally:
        if old is None:
            del os.environ["REPRO_DP_PRUNE"]
        else:
            os.environ["REPRO_DP_PRUNE"] = old


def _chain(L: int, rng) -> Chain:
    n = L + 1
    return Chain.make(
        uf=rng.uniform(0.5, 2.0, n), ub=rng.uniform(1.0, 4.0, n),
        wa=rng.uniform(0.5, 2.0, n), wabar=rng.uniform(1.0, 4.0, n))


def _best_of(fn, repeats: int):
    best, out = None, None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return best, out


def run(lengths=(20, 50, 100, 200, 339), num_slots=500, emit=print,
        reference=True, offload=True, repeats=2, pallas=True,
        pallas_max_len=PALLAS_MAX_LEN, prune_rows=True):
    emit("L,num_slots,impl,solve_s,feasible,expected_time,table_bytes")
    rng = np.random.default_rng(0)
    rows = []
    if pallas and any(L <= pallas_max_len for L in lengths):
        # untimed warm-up: the first Pallas dispatch of a process pays
        # one-time tracing/infra costs that would otherwise land on the
        # first timed row (and differ between a cold CI run and the warm
        # process that records the committed baseline)
        wch = _chain(8, np.random.default_rng(123))
        wbudget = simulate(wch, Schedule.store_all(8)).peak_mem * 0.5
        for wimpl in ("pallas", "pallas_fused"):
            solve_optimal(wch, wbudget, num_slots=32, impl=wimpl, cache=False)

    def row(L, impl, dt, sol):
        r = dict(L=L, num_slots=num_slots, impl=impl, solve_s=round(dt, 4),
                 feasible=bool(sol.feasible),
                 expected_time=float(sol.expected_time),
                 table_bytes=int(sol.table_bytes))
        emit(f"{L},{num_slots},{impl},{dt:.3f},{sol.feasible},"
             f"{sol.expected_time:.2f},{sol.table_bytes}")
        rows.append(r)
        return r

    for L in lengths:
        ch = _chain(L, rng)
        peak = simulate(ch, Schedule.store_all(L)).peak_mem
        budget = peak * 0.4
        dt_b, sol_b = _best_of(
            lambda: solve_optimal(ch, budget, num_slots=num_slots,
                                  cache=False), repeats)
        row(L, "banded", dt_b, sol_b)
        if prune_rows:
            with _pruning_disabled():
                dt_np, sol_np = _best_of(
                    lambda: solve_optimal(ch, budget, num_slots=num_slots,
                                          cache=False), repeats)
            r = row(L, "banded-noprune", dt_np, sol_np)
            r["pruning_speedup"] = round(dt_np / max(dt_b, 1e-9), 2)
            assert sol_np.feasible == sol_b.feasible
            if sol_b.feasible:
                assert sol_np.expected_time == sol_b.expected_time
        if pallas:
            if L <= pallas_max_len:
                dt_p, sol_p = _best_of(
                    lambda: solve_optimal(ch, budget, num_slots=num_slots,
                                          impl="pallas", cache=False), 1)
                r = row(L, "pallas", dt_p, sol_p)
                r["ratio_vs_banded"] = round(dt_p / max(dt_b, 1e-9), 2)
                assert sol_p.feasible == sol_b.feasible
                if sol_b.feasible:
                    assert sol_p.expected_time == sol_b.expected_time
                # untimed pre-solve: resolves (and memoizes) the autotuner's
                # block_rows choice so that — under REPRO_DP_AUTOTUNE=1 —
                # calibration fills neither land in the timed window nor in
                # the dispatch count below
                solve_optimal(ch, budget, num_slots=num_slots,
                              impl="pallas_fused", cache=False)
                with _count_dispatches() as calls:
                    dt_f, sol_f = _best_of(
                        lambda: solve_optimal(ch, budget, num_slots=num_slots,
                                              impl="pallas_fused",
                                              cache=False), repeats)
                r = row(L, "pallas_fused", dt_f, sol_f)
                r["ratio_vs_banded"] = round(dt_f / max(dt_b, 1e-9), 2)
                r["device_dispatches"] = calls[0] // repeats
                assert calls[0] == repeats, (
                    f"fused fill made {calls[0]} dispatches over {repeats} "
                    f"fills (expected 1 per fill)")
                assert sol_f.feasible == sol_b.feasible
                if sol_b.feasible:
                    assert sol_f.expected_time == sol_b.expected_time
            else:
                emit(f"# pallas/pallas_fused: skipped at L={L} "
                     f"(interpret-mode CPU fallback; rows capped at "
                     f"L<={pallas_max_len})")
        if reference:
            dt_r, sol_r = _best_of(
                lambda: solve_optimal(ch, budget, num_slots=num_slots,
                                      impl="reference", cache=False), 1)
            r = row(L, "reference", dt_r, sol_r)
            r["speedup_vs_reference"] = round(dt_r / max(dt_b, 1e-9), 2)
            r["table_shrink"] = round(sol_r.table_bytes
                                      / max(sol_b.table_bytes, 1), 2)
            assert sol_b.feasible == sol_r.feasible
            if sol_b.feasible:
                assert abs(sol_b.expected_time - sol_r.expected_time) \
                    <= 1e-6 * sol_r.expected_time
        if offload:
            # host link priced so transfers are comparable to compute —
            # offload-vs-keep decisions stay non-trivial at this scale
            hch = ch.with_host(HostTransferModel(bandwidth_d2h=2.0))
            dt_o, sol_o = _best_of(
                lambda: solve_optimal_offload(hch, budget,
                                              num_slots=num_slots,
                                              cache=False), 1)
            r = row(L, "offload", dt_o, sol_o)
            r["ratio_vs_banded_two_tier"] = round(dt_o / max(dt_b, 1e-9), 2)

    # cached relaunch: the second identical solve skips the DP entirely
    ch = _chain(lengths[-1], np.random.default_rng(1))
    budget = simulate(ch, Schedule.store_all(ch.length)).peak_mem * 0.4
    solve_optimal(ch, budget, num_slots=num_slots)
    t0 = time.perf_counter()
    solve_optimal(ch, budget, num_slots=num_slots)
    cached_s = time.perf_counter() - t0
    emit(f"# cached re-solve at L={ch.length}: {cached_s * 1e3:.2f} ms")

    result = dict(bench="solver", num_slots=num_slots, rows=rows,
                  cached_resolve_s=round(cached_s, 6))
    big = [r for r in rows if r["impl"] == "reference"
           and "speedup_vs_reference" in r]
    if big:
        last = big[-1]
        result["headline"] = dict(
            L=last["L"], num_slots=num_slots,
            reference_s=last["solve_s"],
            banded_s=next(r["solve_s"] for r in rows
                          if r["impl"] == "banded" and r["L"] == last["L"]),
            speedup=last["speedup_vs_reference"],
            table_shrink=last["table_shrink"])
        emit(f"# headline: L={last['L']} speedup={last['speedup_vs_reference']}x "
             f"table_shrink={last['table_shrink']}x")
    return result


def write_json(result: dict, path: str = JSON_PATH) -> None:
    with open(path, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)


def main(emit=print, small: bool = True):
    from .bench_fleet import main as fleet_main
    from .bench_prediction import drift_section
    from .bench_serve import serve_section

    if small:
        result = run(lengths=(20, 50, 100), num_slots=200, emit=emit)
        emit("# prediction drift section (repro.obs trace -> calibrate):")
        result["prediction"] = drift_section(emit=emit, small=True)
        emit("# fleet section (cold-vs-warm plan store, frontier query):")
        result["fleet"] = fleet_main(emit=emit, small=True)
        emit("# serve section (planned vs naive KV residency):")
        result["serve"] = serve_section(emit=emit, small=True)
        return result
    result = run(emit=emit)
    # Embed the CI-sized run too: the bench-trajectory job replays exactly
    # `--small` on the runner and diffs its rows against this section of the
    # committed baseline (same lengths, same slot count — comparable rows).
    emit("# small (CI bench-trajectory baseline) rows:")
    result["small"] = run(lengths=(20, 50, 100), num_slots=200, emit=emit)
    emit("# prediction drift section (repro.obs trace -> calibrate):")
    result["prediction"] = drift_section(emit=emit, small=True)
    emit("# fleet section (cold-vs-warm plan store, frontier query):")
    result["fleet"] = fleet_main(emit=emit, small=False)
    emit("# serve section (planned vs naive KV residency):")
    result["serve"] = serve_section(emit=emit, small=True)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="CI smoke sizes (L<=100, S=200)")
    ap.add_argument("--json", default=JSON_PATH,
                    help="where to write the machine-readable results")
    args = ap.parse_args()
    res = main(small=args.small)
    write_json(res, args.json)
    print(f"wrote {args.json}")
