"""Kernel micro-bench: wall-clock of the jnp oracles on CPU (the Pallas
kernels themselves target TPU; interpret mode is a correctness harness, not a
performance one — so the perf-relevant CSV rows here are oracle timings plus
the kernels' analytic FLOP counts)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, repeats=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6  # us


def main(emit=print, small: bool = True):
    emit("name,us_per_call,derived")
    key = jax.random.PRNGKey(0)

    from repro.kernels.flash_attention import ref as fref
    B, S, H, K, D = 1, 256, 4, 2, 64
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(key, (B, S, K, D))
    v = jax.random.normal(key, (B, S, K, D))
    f = jax.jit(lambda q, k, v: fref.attention(q, k, v, True))
    us = _time(f, q, k, v)
    flops = 4 * B * S * S * H * D
    emit(f"attention_ref_{B}x{S}x{H}x{D},{us:.1f},{flops/us/1e3:.2f}GFLOPs")

    from repro.kernels.rmsnorm import ref as rref
    x = jax.random.normal(key, (4096, 512))
    s = jnp.ones((512,))
    us = _time(jax.jit(rref.rms_norm), x, s)
    emit(f"rmsnorm_ref_4096x512,{us:.1f},{x.size*4*2/us/1e3:.2f}GBps")

    from repro.kernels.ssd import ref as sref
    B2, S2, H2, P2, G2, N2 = 1, 512, 4, 32, 1, 32
    xs = jax.random.normal(key, (B2, S2, H2, P2))
    dt = jax.nn.softplus(jax.random.normal(key, (B2, S2, H2))) * 0.1
    A = -jnp.exp(jax.random.normal(key, (H2,)) * 0.3)
    Bm = jax.random.normal(key, (B2, S2, G2, N2)) * 0.3
    Cm = jax.random.normal(key, (B2, S2, G2, N2)) * 0.3
    f = jax.jit(lambda *a: sref.ssd_chunked(*a, 64)[0])
    us = _time(f, xs, dt, A, Bm, Cm)
    emit(f"ssd_chunked_ref_{S2}x{H2}x{P2}x{N2},{us:.1f},-")

    from repro.kernels.xent import ops as xops
    h = jax.random.normal(key, (4, 128, 64))
    w = jax.random.normal(key, (64, 4096)) * 0.1
    lab = jax.random.randint(key, (4, 128), 0, 4096)
    f = jax.jit(lambda h, w: xops.token_chunked_xent(h, w, lab, None, 128))
    us = _time(f, h, w)
    emit(f"token_chunked_xent_512x4096,{us:.1f},-")
    return True


if __name__ == "__main__":
    main()
