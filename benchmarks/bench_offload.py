"""Three-tier storage benchmark: time vs *device* memory budget on the
paper's ResNet-style chain, with the host tier priced by the measured
device↔host copy bandwidth.

Compares, per device budget (three ``repro.plan.sweep`` frontiers):

- **optimal**  — the paper's two-tier DP (``tiers=("device",)``),
- **revolve**  — the AD-model comparator (activations-only checkpoints),
- **optimal_offload** — the three-tier DP (``tiers=("device", "host")``),
  which stays feasible *below* the two-tier ``min_memory_plan`` floor and
  matches the two-tier schedule wherever PCIe can't pay for itself.

Also asserts the subsystem's exactness claim: the offload simulator's
makespan equals the offload DP's predicted makespan on every feasible point.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import (Schedule, measure_host_bandwidth,
                        profile_stages_measured, simulate)
from repro.plan import PlanRequest, min_memory_plan, sweep

from .chains import resnet_ish_chain


def run_chain(name: str, stages, params, x,
              budgets=(0.15, 0.2, 0.25, 0.3, 0.4, 0.55, 0.7, 0.85, 1.0),
              num_slots: int = 300, emit=print) -> Dict:
    host = measure_host_bandwidth()
    chain = profile_stages_measured(stages, params, x, repeats=1, host=host)
    store_all = simulate(chain, Schedule.store_all(chain.length))
    floor2 = min_memory_plan(chain, num_slots=num_slots)
    floor3 = min_memory_plan(chain, tiers=("device", "host"),
                             num_slots=num_slots)
    emit(f"# {name}: host link d2h {host.bandwidth_d2h/1e9:.2f} GB/s, "
         f"h2d {(host.bandwidth_h2d or host.bandwidth_d2h)/1e9:.2f} GB/s")
    emit(f"# {name}: store-all peak {store_all.peak_mem:.3e} B; two-tier "
         f"floor {floor2.budget_bytes:.3e} B; three-tier device floor "
         f"{floor3.budget_bytes:.3e} B "
         f"({floor3.budget_bytes / floor2.budget_bytes:.2f}x)")

    rows: List[dict] = []
    mismatches = 0
    below_floor_feasible = 0
    emit("chain,strategy,budget_frac,budget_bytes,predicted_s,sim_peak_dev,"
         "sim_host_peak,transfer_stall_s,n_offloads")

    def row(strategy, frac, budget, plan):
        nonlocal mismatches
        sim = simulate(chain, plan.schedule, budget * (1 + 1e-9))
        assert sim.valid, f"{strategy}@{frac}: {sim.error}"
        if abs(sim.time - plan.expected_time) > 1e-9 * max(1.0, sim.time):
            mismatches += 1
        n_off = plan.schedule.count("Foff")
        r = dict(chain=name, strategy=strategy, budget_frac=frac,
                 budget=budget, predicted_s=plan.expected_time,
                 peak_dev=sim.peak_mem, host_peak=sim.host_peak_mem,
                 stall=sim.transfer_stall, n_offloads=n_off, plan=plan)
        rows.append(r)
        emit(f"{name},{strategy},{frac:.2f},{budget:.3e},"
             f"{plan.expected_time:.4f},{sim.peak_mem:.3e},"
             f"{sim.host_peak_mem:.3e},{sim.transfer_stall:.4f},{n_off}")
        return r

    # probe the between-floors band explicitly: that is where the offload
    # plan is feasible while *no* two-tier persistent schedule exists.
    # (floors are reported at store-all-peak slot scale; a solve at a given
    # budget rediscretizes, so check infeasibility per-point.)
    probe = [floor3.budget_bytes
             + f * (floor2.budget_bytes - floor3.budget_bytes)
             for f in (0.25, 0.5, 0.75)]
    points = sorted({b / store_all.peak_mem for b in probe}
                    | set(budgets))

    pts3 = sweep(chain, points,
                 PlanRequest(strategy="optimal", tiers=("device", "host"),
                             num_slots=num_slots),
                 store_all_peak=store_all.peak_mem)
    pts2 = sweep(chain, points,
                 PlanRequest(strategy="optimal", num_slots=num_slots),
                 store_all_peak=store_all.peak_mem)
    ptsr = sweep(chain, points,
                 PlanRequest(strategy="revolve", num_slots=num_slots),
                 store_all_peak=store_all.peak_mem)

    gains = []
    for p3, p2, pr in zip(pts3, pts2, ptsr):
        frac, budget = p2.fraction, p2.budget_bytes
        if p2.feasible:
            row("optimal", frac, budget, p2.plan)
        if pr.feasible:
            row("revolve", frac, budget, pr.plan)
        if p3.feasible:
            row("optimal_offload", frac, budget, p3.plan)
            if not p2.feasible:
                below_floor_feasible += 1
            if p2.feasible:
                gains.append(p2.plan.expected_time
                             / p3.plan.expected_time - 1.0)

    gain = float(np.max(gains)) if gains else 0.0
    emit(f"# {name}: offload feasible at {below_floor_feasible} budget "
         f"point(s) below the two-tier floor; best equal-budget speedup "
         f"over two-tier optimal {gain * 100:+.1f}%")
    emit(f"# {name}: simulator-vs-DP makespan mismatches: {mismatches} "
         f"(must be 0)")
    return {"rows": rows, "mismatches": mismatches,
            "below_floor_feasible": below_floor_feasible,
            "floor2": floor2.budget_bytes, "floor3": floor3.budget_bytes,
            "max_gain": gain}


def wall_clock_point(stages, params, x, rows, emit=print, repeats=2) -> None:
    """Wall-clock one offload-bearing schedule through the real executor
    (``jax.device_put`` copies included) — the model's claim, measured."""
    import time as _time

    from repro.offload.executor import execute_offload_schedule
    from repro.offload.host_buffer import HostBuffer

    offl = [r for r in rows if r["strategy"] == "optimal_offload"
            and r["n_offloads"] > 0]
    if not offl:
        emit("# wall-clock: no offload-bearing point to run")
        return
    r = offl[0]
    plan = r["plan"]
    hb = HostBuffer()
    out = execute_offload_schedule(plan.schedule, stages, params, x,
                                   host_buffer=hb)  # warm caches
    t0 = _time.perf_counter()
    for _ in range(repeats):
        out = execute_offload_schedule(plan.schedule, stages, params, x,
                                       host_buffer=HostBuffer())
    import jax
    jax.block_until_ready(out[1])
    wall = (_time.perf_counter() - t0) / repeats
    emit(f"# wall-clock: offload schedule at budget_frac "
         f"{r['budget_frac']:.2f}: {wall:.4f}s/iter (predicted model time "
         f"{r['predicted_s']:.4f}s), host pool peak {hb.peak_bytes} B")


def main(emit=print, small: bool = True):
    stages, params, x = resnet_ish_chain(num_blocks=6 if small else 10,
                                         image=24 if small else 32,
                                         batch=4 if small else 8)
    res = run_chain("resnet_ish", stages, params, x, emit=emit)
    wall_clock_point(stages, params, x, res["rows"], emit=emit)
    if res["mismatches"]:
        raise AssertionError(
            f"offload DP and simulator disagree on {res['mismatches']} points")
    if not small:
        fns, sp, batch_d = __import__(
            "benchmarks.chains", fromlist=["transformer_chain"]
        ).transformer_chain(num_layers=8, d_model=128, seq=128, batch=4)
        run_chain("transformer", fns, sp, batch_d, emit=emit)
    return res


if __name__ == "__main__":
    main()
