"""Serving-path benchmark: tokens/s vs device-KV-budget, planned vs naive.

For each architecture (one GQA, one MLA) the decode cache is planned as a
heterogeneous chain (:func:`repro.plan.plan_serving`) at a sweep of device
KV budgets, and the planned residency policy is executed against the naive
per-access LRU baseline (:mod:`repro.runtime.kv_residency`).  Both policies
run the real jitted serve loop and must reproduce the unconstrained run's
generations token-for-token; the reported throughputs are *modeled* from the
measured transfer byte counts and the serving link:

- planned overlaps its round-trips with decode compute —
  ``max(base_decode_s, transfer_bytes / link_bw)``;
- the naive cache only fetches on demand, so every miss and write-back
  stalls — ``base_decode_s + transfer_bytes / link_bw``.

Dominance (planned ≥ naive at every budget point, both archs) is the gate
``benchmarks/compare_trajectory.py`` enforces on the ``"serve"`` section of
``BENCH_solver.json``.
"""

from __future__ import annotations

import argparse
import json

ARCHS = ("qwen1.5-4b", "deepseek-v2-lite-16b")
BUDGET_FRACS = (0.4, 0.7, 1.1)

BATCH = 2
PROMPT_LEN = 8
NEW_TOKENS = 6
MAX_LEN = 14


def _bench_arch(name: str, emit) -> list:
    import dataclasses

    import jax
    import numpy as np

    from repro.configs.archs import smoke_config
    from repro.core.chain import HostTransferModel
    from repro.models.lm import StagedLM
    from repro.plan import plan_serving
    from repro.runtime.serve_loop import ServeLoopConfig, run_serving

    cfg = smoke_config(name)
    if cfg.modality != "text":
        cfg = dataclasses.replace(cfg, modality="text")
    model = StagedLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (BATCH, PROMPT_LEN),
                           dtype=np.int32)
    loop = ServeLoopConfig(max_new_tokens=NEW_TOKENS, max_len=MAX_LEN)
    link = HostTransferModel.pcie_gen3()

    run_serving(cfg, params, prompts, loop, model=model)  # warm the jit
    base = run_serving(cfg, params, prompts, loop, model=model)
    base_s = base["decode_s"]
    ntok = base["decode_tokens"]
    layout = model.cache_layout(BATCH, MAX_LEN)
    total = float(sum(layout.block_bytes))
    emit(f"[{name}] attention={cfg.attention_kind} layers={cfg.num_layers} "
         f"kv_total={total:.0f} B  base decode {ntok} tok "
         f"in {base_s * 1e3:.1f} ms")

    def modeled_transfer_s(stats) -> float:
        bw_d2h = link.bandwidth_d2h
        bw_h2d = link.bandwidth_h2d or link.bandwidth_d2h
        return (stats["kv_offload_bytes"] / bw_d2h
                + stats["kv_prefetch_bytes"] / bw_h2d)

    rows = []
    for frac in BUDGET_FRACS:
        budget = total * frac
        plan = plan_serving(cfg, budget, batch=BATCH, prompt_len=PROMPT_LEN,
                            max_len=MAX_LEN, host=link)
        planned = run_serving(cfg, params, prompts, loop, model=model,
                              plan=plan, kv_budget=budget)
        naive = run_serving(cfg, params, prompts, loop, model=model,
                            kv_policy="lru", kv_budget=budget, host=link)
        for tag, out in (("planned", planned), ("lru", naive)):
            if not np.array_equal(out["generations"], base["generations"]):
                raise AssertionError(
                    f"{name} @ x{frac}: {tag} policy changed the generations")
        planned_tok_s = ntok / max(base_s, modeled_transfer_s(planned))
        lru_tok_s = ntok / (base_s + modeled_transfer_s(naive))
        row = {
            "arch": name,
            "attention": cfg.attention_kind,
            "budget_frac": frac,
            "budget_bytes": budget,
            "host_layers": len(planned["kv_host_layers"]),
            "planned_transfer_bytes": planned["kv_transfer_bytes"],
            "lru_transfer_bytes": naive["kv_transfer_bytes"],
            "planned_tok_s": planned_tok_s,
            "lru_tok_s": lru_tok_s,
            "dominates": bool(planned_tok_s + 1e-9 >= lru_tok_s),
        }
        rows.append(row)
        emit(f"  x{frac:<4} staged {row['host_layers']}/{cfg.num_layers} "
             f"layers  planned {planned_tok_s:8.1f} tok/s "
             f"({planned['kv_transfer_bytes']:.0f} B moved)  "
             f"lru {lru_tok_s:8.1f} tok/s "
             f"({naive['kv_transfer_bytes']:.0f} B moved)  "
             f"{'OK' if row['dominates'] else 'REGRESSION'}")
    return rows


def serve_section(emit=print, small: bool = True) -> dict:
    """The ``"serve"`` section of ``BENCH_solver.json``: the planned
    residency policy must match or beat naive LRU at every budget point on
    every arch (``compare_trajectory.check_serve`` gates on ``dominates``)."""
    rows = []
    for arch in ARCHS:
        rows.extend(_bench_arch(arch, emit))
    return {
        "archs": list(ARCHS),
        "batch": BATCH,
        "prompt_len": PROMPT_LEN,
        "new_tokens": NEW_TOKENS,
        "budget_fracs": list(BUDGET_FRACS),
        "rows": rows,
        "dominates": all(r["dominates"] for r in rows),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="print the serve section as JSON")
    args = ap.parse_args()
    section = serve_section(emit=print)
    if args.json:
        print(json.dumps(section, indent=2))
    if not section["dominates"]:
        raise SystemExit("planned KV residency lost to naive LRU — see rows")
    print("planned policy dominates naive LRU at every budget point")


if __name__ == "__main__":
    main()
