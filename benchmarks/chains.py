"""Benchmark model chains: a heterogeneous conv (ResNet-ish) chain — the
paper's own workload family — and a transformer chain, both CPU-sized."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def resnet_ish_chain(num_blocks: int = 8, base_ch: int = 16,
                     image: int = 32, batch: int = 8, seed: int = 0):
    """Heterogeneous conv chain: channel widths double / resolution halves at
    stage boundaries (the paper's ResNet setting, scaled to CPU).  Returns
    (stages, params, x)."""
    key = jax.random.PRNGKey(seed)
    stages, params = [], []
    ch_in = 3
    ch = base_ch
    res = image
    for i in range(num_blocks):
        stride = 2 if (i % 3 == 2 and res > 4) else 1
        k1 = jax.random.normal(jax.random.fold_in(key, 2 * i),
                               (3, 3, ch_in, ch)) * (0.4 / ch_in ** 0.5)
        k2 = jax.random.normal(jax.random.fold_in(key, 2 * i + 1),
                               (3, 3, ch, ch)) * (0.4 / ch ** 0.5)
        skip = (jax.random.normal(jax.random.fold_in(key, 1000 + i),
                                  (1, 1, ch_in, ch)) * (1.0 / ch_in ** 0.5)
                if (ch_in != ch or stride > 1) else None)
        p = {"k1": k1, "k2": k2}
        if skip is not None:
            p["skip"] = skip

        def block(p, a, stride=stride):
            y = jax.lax.conv_general_dilated(
                a, p["k1"], (stride, stride), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            y = jax.nn.relu(y)
            y = jax.lax.conv_general_dilated(
                y, p["k2"], (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            if "skip" in p:
                a = jax.lax.conv_general_dilated(
                    a, p["skip"], (stride, stride), "SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"))
            return jax.nn.relu(y + a)

        stages.append(block)
        params.append(p)
        ch_in = ch
        if stride == 2:
            res //= 2
            ch *= 2
    # loss stage: global pool + mean-square
    params.append({})
    stages.append(lambda p, a: jnp.mean(jnp.mean(a, axis=(1, 2)) ** 2))
    x = jax.random.normal(jax.random.fold_in(key, 9999),
                          (batch, image, image, 3))
    return stages, params, x


def transformer_chain(num_layers: int = 8, d_model: int = 128,
                      seq: int = 128, batch: int = 4, vocab: int = 512,
                      seed: int = 0):
    """Decoder-LM chain via the repro model zoo (one layer per stage)."""
    from repro.configs import smoke_config
    from repro.models.lm import StagedLM

    cfg = smoke_config("qwen1.5-4b", num_layers=num_layers,
                       layer_kinds=("dense",) * num_layers,
                       d_model=d_model, n_heads=4, n_kv_heads=4,
                       head_dim=d_model // 4, d_ff=4 * d_model,
                       vocab_size=vocab, n_chunks=num_layers)
    model = StagedLM(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    key = jax.random.PRNGKey(seed + 1)
    batch_d = {"tokens": jax.random.randint(key, (batch, seq), 0, vocab),
               "labels": jax.random.randint(key, (batch, seq), 0, vocab),
               "loss_mask": jnp.ones((batch, seq), jnp.float32)}
    return model.stage_fns(), model.stage_params(params), batch_d
