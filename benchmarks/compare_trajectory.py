"""Gate a fresh ``bench_solver`` run against the committed baseline.

The ``bench-trajectory`` CI job runs ``python -m benchmarks.bench_solver
--small --json <current>`` on the runner, uploads the JSON as an artifact
(the perf trajectory), and then calls this script to diff the run's
wall-times against the committed ``BENCH_solver.json``.  Rows are matched on
``(L, num_slots, impl)``; the baseline's ``"small"`` section is preferred
when present (it was recorded at the CI sizes, so the rows are comparable).

The committed baseline is recorded on a developer machine, while CI runs on
a shared runner that may simply be slower, so raw ratios would flag phantom
regressions.  The gate therefore *calibrates*: the smallest above-floor
ratio across matched rows estimates the machine-speed delta (a real
regression inflates the rows of the impl it touches, not every impl at
once; a slower machine shifts all of them), clamped to [1, 4] so a uniform
blow-up cannot hide entirely — and the tier1 job's absolute hard-timeout
smoke still bounds the worst case.  A row breaches when
``current > threshold * machine_factor * baseline`` (threshold default
x1.5) *and* the current time is above the noise floor — sub-50 ms solves
are timer noise on shared runners and are reported but never fail.
Unmatched current rows are reported as "new" (that is how first baselines
enter the trajectory) and do not fail.

Stdlib-only on purpose: the gate must run before any heavy dependency is
importable.
"""

from __future__ import annotations

import argparse
import json
import sys

#: Solves faster than this are dominated by timer noise on shared CI
#: runners (sub-100 ms rows swing ±50% run to run even on an idle host);
#: they are printed but never breach the gate.  A *real* complexity
#: regression at the --small sizes lands in whole seconds and still trips
#: both this gate and the tier1 job's absolute hard-timeout smoke.
NOISE_FLOOR_S = 0.1

#: Rows must be at least this slow (in the *current* run) to vote on the
#: machine-speed factor — faster rows are too noisy to calibrate on.
CALIBRATE_FLOOR_S = 0.02

#: Machine-speed factor clamp: never "explain away" more than a 4x uniform
#: slowdown, and never scale the baseline down (a faster runner must not
#: loosen the gate).
MAX_MACHINE_FACTOR = 4.0

#: The fleet section's cold/warm plan-time ratio must stay at least this —
#: a warm fleet relaunch that re-solves (or re-verifies slowly) erodes the
#: "plan once, bind anywhere" claim.  Checked on the *current* run, so it
#: holds on the runner itself, not just on the baseline machine.
FLEET_MIN_SPEEDUP = 10.0

_COLS = f"{'L':>5} {'slots':>6} {'impl':<16} {'base_s':>9} {'cur_s':>9}"
HEADER = f"{_COLS} {'ratio':>7}  verdict"


def _rows(doc: dict, prefer_small: bool) -> list:
    if prefer_small and "small" in doc:
        return doc["small"]["rows"]
    return doc["rows"]


def _key(row: dict) -> tuple:
    return (row["L"], row["num_slots"], row["impl"])


def _matched(baseline: dict, current: dict) -> list:
    base = {_key(r): r for r in _rows(baseline, prefer_small=True)}
    out = []
    for row in _rows(current, prefer_small=False):
        out.append((row, base.get(_key(row))))
    return out


def machine_factor(pairs: list) -> float:
    """The least-regressed above-floor ratio, clamped to [1, MAX]."""
    ratios = []
    for row, b in pairs:
        if b is None or b["solve_s"] <= 0:
            continue
        if row["solve_s"] >= CALIBRATE_FLOOR_S:
            ratios.append(row["solve_s"] / b["solve_s"])
    if not ratios:
        return 1.0
    return min(MAX_MACHINE_FACTOR, max(1.0, min(ratios)))


def compare(baseline: dict, current: dict, threshold: float,
            calibrate: bool = True) -> int:
    pairs = _matched(baseline, current)
    factor = machine_factor(pairs) if calibrate else 1.0
    limit = threshold * factor
    print(f"machine-speed factor: x{factor:.2f} "
          f"(effective threshold x{limit:.2f})")
    breaches = 0
    print(HEADER)
    print("-" * len(HEADER))
    for row, b in pairs:
        k = _key(row)
        cur_s = row["solve_s"]
        prefix = f"{k[0]:>5} {k[1]:>6} {k[2]:<16}"
        if b is None:
            line = f"{prefix} {'-':>9} {cur_s:>9.3f} {'-':>7}  new (no baseline)"
            print(line)
            continue
        base_s = b["solve_s"]
        ratio = cur_s / base_s if base_s > 0 else float("inf")
        if cur_s <= NOISE_FLOOR_S:
            verdict = "ok (noise floor)"
        elif ratio > limit:
            verdict = f"REGRESSION (> x{limit:.2f})"
            breaches += 1
        else:
            verdict = "ok"
        print(f"{prefix} {base_s:>9.3f} {cur_s:>9.3f} {ratio:>7.2f}  {verdict}")
    breaches += check_fleet(current.get("fleet"))
    breaches += check_serve(current.get("serve"))
    return breaches


def check_fleet(fleet) -> int:
    """Gate the ``fleet`` section: warm plan time >= x10 below cold, and the
    frontier-interpolated budget query resolved with zero DP solves.  Absent
    section (pre-store baselines, single-pass smoke runs) passes."""
    if not isinstance(fleet, dict):
        return 0
    breaches = 0
    speedup = fleet.get("speedup")
    if speedup is not None:
        verdict = "ok" if speedup >= FLEET_MIN_SPEEDUP else (
            f"REGRESSION (< x{FLEET_MIN_SPEEDUP:g})"
        )
        breaches += speedup < FLEET_MIN_SPEEDUP
        print(f"fleet: cold/warm speedup x{speedup:.2f}  {verdict}")
    frontier = fleet.get("frontier")
    if isinstance(frontier, dict):
        solves = frontier.get("query_solves")
        ok = solves == 0 and frontier.get("source") == "interpolated"
        breaches += not ok
        verdict = (
            "ok"
            if ok
            else "REGRESSION (expected an interpolated zero-solve answer)"
        )
        source = frontier.get("source")
        print(f"fleet: frontier query source={source} solves={solves}  {verdict}")
    return breaches


def check_serve(serve) -> int:
    """Gate the ``serve`` section: the planned KV-residency policy must match
    or beat naive LRU (modeled tokens/s) at every budget point on every
    arch.  Absent section (pre-serving baselines) passes."""
    if not isinstance(serve, dict):
        return 0
    breaches = 0
    for row in serve.get("rows", []):
        planned = row.get("planned_tok_s")
        lru = row.get("lru_tok_s")
        if planned is None or lru is None:
            continue
        ok = planned + 1e-9 >= lru
        breaches += not ok
        verdict = "ok" if ok else "REGRESSION (planned lost to naive LRU)"
        print(
            f"serve: {row.get('arch'):<22} x{row.get('budget_frac'):<4} "
            f"planned {planned:9.1f} tok/s  lru {lru:9.1f} tok/s  {verdict}"
        )
    return breaches


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_solver.json")
    ap.add_argument("--current", required=True)
    ap.add_argument(
        "--threshold",
        type=float,
        default=1.5,
        help="fail when current > threshold * machine_factor * baseline",
    )
    ap.add_argument(
        "--no-calibrate",
        action="store_true",
        help="compare raw wall-times (baseline and current on the same host)",
    )
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    breaches = compare(
        baseline, current, args.threshold, calibrate=not args.no_calibrate
    )
    if breaches:
        print(f"{breaches} row(s) regressed beyond x{args.threshold:g} baseline")
        return 1
    print("bench trajectory within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
