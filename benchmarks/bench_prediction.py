"""Paper §5.3: accuracy of the cost model — predicted vs measured throughput
(paper: 7.8% MAPE) across strategies/budgets on the CPU chains.

``drift_section`` is the observability counterpart: execute one plan with
the span tracer, compare predicted vs measured (``repro.obs.drift``), feed
the measured per-stage times back through ``Chain.calibrate``, re-plan, and
re-measure — the number that matters is how much one calibration pass
shrinks the makespan prediction error.  ``benchmarks/bench_solver.py``
embeds the result as ``BENCH_solver.json``'s ``prediction`` section."""

from __future__ import annotations

import numpy as np

from .bench_tradeoff import run_chain
from .chains import resnet_ish_chain


def _measure_traced(plan, stages, params, x):
    """Warm, then trace one execution of ``plan``.  The warm-up run pays the
    one-time jit/vjp tracing of each stage so the recorded spans are
    steady-state compute, not compilation."""
    from repro.obs.trace import Tracer

    plan.execute(stages, params, x)
    tracer = Tracer(name="bench_prediction")
    plan.execute(stages, params, x, tracer=tracer)
    return tracer


def drift_section(emit=print, small: bool = True):
    """One calibration pass of the drift loop on a tiny conv chain; returns
    the machine-readable record for ``BENCH_solver.json``."""
    from repro.core import profile_stages_measured
    from repro.obs.drift import calibrate_from_trace, compare
    from repro.plan import Budget, PlanRequest, build_plan

    stages, params, x = resnet_ish_chain(num_blocks=4,
                                         image=32 if small else 64,
                                         batch=4 if small else 8,
                                         base_ch=16)
    chain = profile_stages_measured(stages, params, x, repeats=2)
    req = PlanRequest(strategy="optimal", budget=Budget.fraction(0.6),
                      num_slots=200)
    plan = build_plan(req, chain)

    trace = _measure_traced(plan, stages, params, x)
    before = compare(plan, trace)

    calibrated = calibrate_from_trace(chain, trace)
    plan2 = build_plan(req, calibrated)
    trace2 = _measure_traced(plan2, stages, params, x)
    after = compare(plan2, trace2)

    rec = {
        "chain": "resnet_ish(4 blocks)",
        "spans_per_execution": len(trace.spans),
        "before": {"predicted_s": before.predicted_makespan,
                   "measured_s": before.measured_makespan,
                   "makespan_ratio": before.makespan_ratio,
                   "layer_mape_percent": before.layer_mape},
        "after": {"predicted_s": after.predicted_makespan,
                  "measured_s": after.measured_makespan,
                  "makespan_ratio": after.makespan_ratio,
                  "layer_mape_percent": after.layer_mape},
    }
    err_before = abs(before.makespan_ratio - 1.0)
    err_after = abs(after.makespan_ratio - 1.0)
    rec["error_before"] = err_before
    rec["error_after"] = err_after
    emit("phase,predicted_s,measured_s,makespan_ratio,layer_mape_percent")
    emit(f"before,{before.predicted_makespan:.4f},"
         f"{before.measured_makespan:.4f},{before.makespan_ratio:.3f},"
         f"{before.layer_mape:.1f}")
    emit(f"after,{after.predicted_makespan:.4f},"
         f"{after.measured_makespan:.4f},{after.makespan_ratio:.3f},"
         f"{after.layer_mape:.1f}")
    emit(f"# one Chain.calibrate pass: |ratio-1| {err_before:.3f} -> "
         f"{err_after:.3f}")
    return rec


def main(emit=print, small: bool = True):
    # stages must be heavy enough that eager per-op dispatch is small vs
    # compute (the paper's GPU stages are ms-scale); the Python dispatch
    # overhead per op is *calibrated on the store-all row only* and the
    # error is evaluated on the remaining (checkpointing) rows
    stages, params, x = resnet_ish_chain(num_blocks=5, image=64,
                                         batch=8 if small else 16,
                                         base_ch=24)
    res = run_chain("prediction_probe", stages, params, x, batch=x.shape[0],
                    budgets=(0.6, 1.0), measured_repeats=2,
                    emit=lambda *_: None)
    rows = res["rows"]
    calib = next(r for r in rows if r["strategy"] == "pytorch_store_all")
    n_ops_calib = 2 * (len(stages))  # fwd+bwd per stage
    per_op = max(calib["wall_s"] - calib["predicted_s"], 0.0) / n_ops_calib

    def n_ops(strategy, predicted):
        # approximate op count from the time ratio (recompute ⇒ more ops)
        return n_ops_calib * predicted / max(calib["predicted_s"], 1e-12)

    errs = []
    for r in rows:
        if r is calib:
            continue
        adj = r["predicted_s"] + per_op * n_ops(r["strategy"], r["predicted_s"])
        errs.append(abs(adj - r["wall_s"]) / r["wall_s"])
    mape = float(np.mean(errs)) * 100
    emit("metric,value")
    emit(f"throughput_prediction_mape_percent,{mape:.1f}")
    emit(f"dispatch_overhead_per_op_us,{per_op*1e6:.0f}")
    emit(f"# paper §5.3 reports 7.8% throughput MAPE on GPU; CPU eager adds "
         f"per-op dispatch, calibrated on the store-all row only")
    return {"mape_percent": mape}


if __name__ == "__main__":
    main()
