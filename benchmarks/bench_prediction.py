"""Paper §5.3: accuracy of the cost model — predicted vs measured throughput
(paper: 7.8% MAPE) across strategies/budgets on the CPU chains."""

from __future__ import annotations

import numpy as np

from .bench_tradeoff import run_chain
from .chains import resnet_ish_chain


def main(emit=print, small: bool = True):
    # stages must be heavy enough that eager per-op dispatch is small vs
    # compute (the paper's GPU stages are ms-scale); the Python dispatch
    # overhead per op is *calibrated on the store-all row only* and the
    # error is evaluated on the remaining (checkpointing) rows
    stages, params, x = resnet_ish_chain(num_blocks=5, image=64,
                                         batch=8 if small else 16,
                                         base_ch=24)
    res = run_chain("prediction_probe", stages, params, x, batch=x.shape[0],
                    budgets=(0.6, 1.0), measured_repeats=2,
                    emit=lambda *_: None)
    rows = res["rows"]
    calib = next(r for r in rows if r["strategy"] == "pytorch_store_all")
    n_ops_calib = 2 * (len(stages))  # fwd+bwd per stage
    per_op = max(calib["wall_s"] - calib["predicted_s"], 0.0) / n_ops_calib

    def n_ops(strategy, predicted):
        # approximate op count from the time ratio (recompute ⇒ more ops)
        return n_ops_calib * predicted / max(calib["predicted_s"], 1e-12)

    errs = []
    for r in rows:
        if r is calib:
            continue
        adj = r["predicted_s"] + per_op * n_ops(r["strategy"], r["predicted_s"])
        errs.append(abs(adj - r["wall_s"]) / r["wall_s"])
    mape = float(np.mean(errs)) * 100
    emit("metric,value")
    emit(f"throughput_prediction_mape_percent,{mape:.1f}")
    emit(f"dispatch_overhead_per_op_us,{per_op*1e6:.0f}")
    emit(f"# paper §5.3 reports 7.8% throughput MAPE on GPU; CPU eager adds "
         f"per-op dispatch, calibrated on the store-all row only")
    return {"mape_percent": mape}


if __name__ == "__main__":
    main()
