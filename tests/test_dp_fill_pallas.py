"""``impl="pallas"`` / ``impl="pallas_fused"`` parity: both Pallas DP fills
(``repro.kernels.dp_fill``) must produce **band-identical** cost tables to
the numpy banded fill (``impl="banded"``) in interpret mode, on the same
f32-exact chains ``tests/test_dp_kernels.py`` uses (integer stage costs,
dyadic transfer times — every DP quantity exactly representable in float32,
so equality is bit-exact, not approximate).

Interpret mode executes the kernel bodies in Python on CPU — the same
dispatch seam both impls fall back to automatically off-TPU — so this suite
runs in CPU CI and kernel regressions no longer need a TPU to surface.  The
fused impl additionally carries a *single-dispatch* contract: one
``pallas_call`` per fill, no per-band host loop — asserted below via a
counting shim on ``pallas_call``.
"""

import math

import jax
import numpy as np
import pytest

from repro.core import dp_kernels, solver_cache
from repro.core.chain import Chain, HostTransferModel
from repro.core.schedule import Schedule, simulate
from repro.core.solver import solve_min_memory, solve_optimal
from repro.kernels.dp_fill import autotune
from repro.kernels.dp_fill import kernel as dpk
from repro.kernels.dp_fill import ops as dpo
from repro.kernels.dp_fill import ref as dpr
from repro.offload.solver import solve_optimal_offload
from repro.plan import PlanRequest, build_plan

from helpers import random_chain


@pytest.fixture(autouse=True)
def interpret_mode():
    dpo.set_interpret(True)
    yield
    dpo.set_interpret(None)


#: Both Pallas two-tier fills behind one parametrization knob.
TWO_TIER_FILLS = {"pallas": dpo.fill_two_tier, "pallas_fused": dpo.fill_two_tier_fused}
OFFLOAD_FILLS = {"pallas": dpo.fill_offload, "pallas_fused": dpo.fill_offload_fused}


def _dyadic_host(rng) -> HostTransferModel:
    return HostTransferModel(
        bandwidth_d2h=float(rng.choice([0.5, 1.0, 4.0])),
        latency=float(rng.choice([0.0, 0.25])))


def _budgets(ch, fracs):
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    return [float(math.ceil(peak * f)) for f in fracs]


# ---------------------------------------------------------------------------
# kernel-level parity vs the pure-jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,ns,w", [(1, 1, 4), (3, 5, 17), (7, 300, 33)])
def test_band_min_two_tier_matches_oracle(d, ns, w):
    rng = np.random.default_rng(d * 100 + ns)
    r = rng.uniform(0, 8, (d, ns, w)).astype(np.float32)
    lm = rng.uniform(-4, 4, (d, ns, w)).astype(np.float32)
    r[rng.uniform(size=r.shape) < 0.3] = np.inf   # out-of-budget sentinels
    out = dpk.band_min_two_tier(r, lm, interpret=True)
    exp = dpr.band_min_two_tier(r, lm)
    assert np.array_equal(np.asarray(out), np.asarray(exp))


def test_band_min_two_tier_row_tiling():
    """ns above the block size exercises the padded multi-tile grid path."""
    rng = np.random.default_rng(0)
    r = rng.uniform(0, 8, (4, 37, 9)).astype(np.float32)
    lm = rng.uniform(-4, 4, (4, 37, 9)).astype(np.float32)
    out = dpk.band_min_two_tier(r, lm, block_rows=16, interpret=True)
    exp = dpr.band_min_two_tier(r, lm)
    assert np.array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("d,ns,w", [(1, 1, 4), (4, 23, 11)])
def test_band_min_offload_matches_oracle(d, ns, w):
    rng = np.random.default_rng(d * 10 + ns)

    def plane(lo, hi):
        return rng.uniform(lo, hi, (d, ns, w)).astype(np.float32)

    r, r3 = plane(0, 8), plane(0, 8)
    r[rng.uniform(size=r.shape) < 0.3] = np.inf
    r3[rng.uniform(size=r3.shape) < 0.3] = np.inf
    lmb, lme, lmb3 = plane(-4, 4), plane(-4, 4), plane(-4, 4)
    toff = rng.uniform(0, 6, (ns, 1)).astype(np.float32)
    outs = dpk.band_min_offload(r, r3, lmb, lme, lmb3, toff, interpret=True)
    exps = dpr.band_min_offload(r, r3, lmb, lme, lmb3, toff)
    for o, e in zip(outs, exps):
        assert np.array_equal(np.asarray(o), np.asarray(e))


# ---------------------------------------------------------------------------
# band-exact table agreement with impl="banded" on f32-exact chains
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fill", sorted(TWO_TIER_FILLS))
@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("allow_fall", [True, False])
def test_two_tier_tables_band_exact(seed, allow_fall, fill):
    rng = np.random.default_rng(seed)
    ch = random_chain(rng, max_len=5)
    for m in _budgets(ch, (0.4, 0.7, 1.0)):
        S = int(m)
        dchain = ch.discretize(m, S)
        band = dp_kernels.fill_two_tier(dchain, S, allow_fall=allow_fall)
        pall = TWO_TIER_FILLS[fill](dchain, S, allow_fall=allow_fall)
        assert np.array_equal(band.data, pall.data, equal_nan=True)


@pytest.mark.parametrize("fill", sorted(OFFLOAD_FILLS))
@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("allow_fall", [True, False])
def test_offload_tables_band_exact(seed, allow_fall, fill):
    rng = np.random.default_rng(100 + seed)
    ch = random_chain(rng, max_len=4).with_host(_dyadic_host(rng))
    for m in _budgets(ch, (0.4, 1.0)):
        S = int(m)
        dchain = ch.discretize(m, S)
        tb, te = dp_kernels.fill_offload(dchain, S, allow_fall=allow_fall)
        pb, pe = OFFLOAD_FILLS[fill](dchain, S, allow_fall=allow_fall)
        assert np.array_equal(tb.data, pb.data, equal_nan=True)
        assert np.array_equal(te.data, pe.data, equal_nan=True)


@pytest.mark.parametrize("fill", sorted(OFFLOAD_FILLS))
def test_offload_gather_path_band_exact(fill):
    """An activation bigger than the whole budget forces the non-sliced C3
    gather path in every fill."""
    ch = Chain.make(uf=[1.0, 1.0, 0.0], ub=[1.0, 1.0, 0.0],
                    wa=[1.0, 40.0, 1.0], wabar=[2.0, 2.0, 0.0],
                    host=HostTransferModel(bandwidth_d2h=1.0))
    dchain = ch.discretize(8.0, 8)
    tb, te = dp_kernels.fill_offload(dchain, 8)
    pb, pe = OFFLOAD_FILLS[fill](dchain, 8)
    assert np.array_equal(tb.data, pb.data, equal_nan=True)
    assert np.array_equal(te.data, pe.data, equal_nan=True)


# ---------------------------------------------------------------------------
# fused-fill edge cases: tiling, tiny chains, saturation, dispatch count
# ---------------------------------------------------------------------------

def test_fused_block_rows_not_dividing_band():
    """L not divisible by block_rows exercises masked partial row tiles."""
    rng = np.random.default_rng(5)
    ch = random_chain(rng, max_len=7)
    m = _budgets(ch, (0.6,))[0]
    S = int(m)
    dchain = ch.discretize(m, S)
    band = dp_kernels.fill_two_tier(dchain, S)
    for br in (1, 2, 3, 64):
        fus = dpo.fill_two_tier_fused(dchain, S, block_rows=br)
        assert np.array_equal(band.data, fus.data, equal_nan=True), br


def test_fused_single_stage_chain():
    """d = 1 is the smallest grid the fused recursion can run (L = 1)."""
    rng = np.random.default_rng(8)
    ch = random_chain(rng, max_len=1)
    assert ch.length == 1
    for S in (3, 12):
        dchain = ch.discretize(float(S), S)
        band = dp_kernels.fill_two_tier(dchain, S)
        fus = dpo.fill_two_tier_fused(dchain, S)
        assert np.array_equal(band.data, fus.data, equal_nan=True)
        tbb, teb = dp_kernels.fill_offload(dchain, S)
        tbf, tef = dpo.fill_offload_fused(dchain, S)
        assert np.array_equal(tbb.data, tbf.data, equal_nan=True)
        assert np.array_equal(teb.data, tef.data, equal_nan=True)


def test_fused_saturated_tails():
    """A budget far above every threshold saturates cap_d well below S: the
    fused fill computes the capped width and the host broadcasts a wide
    tail — bit-identical to banded with pruning on *and* off."""
    rng = np.random.default_rng(13)
    ch = random_chain(rng, max_len=4)
    S = 96  # weights in random chains are <= 5, so caps sit far below S
    dchain = ch.discretize(float(S), S)
    caps = dp_kernels.saturation_caps(dp_kernels._views(dchain), S)
    assert caps[-1] < S, "budget not saturating — test premise broken"
    band = dp_kernels.fill_two_tier(dchain, S)
    fus = dpo.fill_two_tier_fused(dchain, S)
    nop = dp_kernels.fill_two_tier(dchain, S, prune=False)
    assert np.array_equal(band.data, fus.data, equal_nan=True)
    assert np.array_equal(nop.data, fus.data, equal_nan=True)
    fus_nop = dpo.fill_two_tier_fused(dchain, S, prune=False)
    assert np.array_equal(nop.data, fus_nop.data, equal_nan=True)


@pytest.fixture
def dispatch_counter(monkeypatch):
    calls = []
    orig = dpk.pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(1)
        return orig(*args, **kwargs)

    monkeypatch.setattr(dpk.pl, "pallas_call", counting)
    return calls


def test_fused_fill_is_single_dispatch(dispatch_counter):
    """The fused impl's contract: ONE pallas_call per fill — the per-band
    impl costs O(L) dispatches on the same problem."""
    rng = np.random.default_rng(21)
    ch = random_chain(rng, max_len=5)
    m = _budgets(ch, (0.6,))[0]
    S = int(m)
    dchain = ch.discretize(m, S)
    dpo.fill_two_tier_fused(dchain, S)
    assert len(dispatch_counter) == 1
    del dispatch_counter[:]
    dpo.fill_offload_fused(dchain, S)
    assert len(dispatch_counter) == 1
    del dispatch_counter[:]
    dpo.fill_two_tier(dchain, S)          # per-band: one launch per length
    assert len(dispatch_counter) == ch.length


def test_fused_solver_is_single_dispatch(dispatch_counter):
    """End to end through solve_optimal: the whole plan costs one device
    dispatch with impl="pallas_fused"."""
    rng = np.random.default_rng(22)
    ch = random_chain(rng, max_len=4)
    m = _budgets(ch, (0.7,))[0]
    sol = solve_optimal(ch, m, num_slots=int(m), impl="pallas_fused",
                        cache=False)
    assert sol.feasible
    assert len(dispatch_counter) == 1


# ---------------------------------------------------------------------------
# block_rows autotuner: persisted choice round-trip, corruption semantics
# ---------------------------------------------------------------------------

@pytest.fixture
def disk_cache(tmp_path):
    solver_cache.configure(directory=tmp_path)
    autotune._memo.clear()
    yield solver_cache.get_cache()
    autotune._memo.clear()
    solver_cache.reset()


def test_autotune_persists_choice(disk_cache):
    key = autotune.cache_key(10, 24, True)
    br = autotune.autotune_block_rows(10, 24, interpret=True,
                                      candidates=(2, 4))
    assert br in (2, 4)
    assert (disk_cache.directory / f"{key}.pkl").is_file()
    # second resolve is served from the store (no re-measure): poison
    # measure() and expect the cached answer
    import repro.kernels.dp_fill.autotune as at

    def boom(*a, **k):
        raise AssertionError("measured despite a persisted choice")

    orig = at.measure
    at.measure = boom
    try:
        assert autotune.autotune_block_rows(10, 24, interpret=True,
                                            candidates=(2, 4)) == br
    finally:
        at.measure = orig


def test_autotune_recalibrates_on_corrupted_entry(disk_cache):
    key = autotune.cache_key(10, 24, True)
    br = autotune.autotune_block_rows(10, 24, interpret=True,
                                      candidates=(2, 4))
    path = disk_cache.directory / f"{key}.pkl"
    path.write_bytes(b"\x00garbage, not a pickle")
    solver_cache.configure(directory=disk_cache.directory)  # drop the LRU
    autotune._memo.clear()                                  # fresh process
    br2 = autotune.autotune_block_rows(10, 24, interpret=True,
                                       candidates=(2, 4))
    assert br2 in (2, 4)
    # the corrupted entry was replaced by a readable one
    assert autotune._valid_entry(solver_cache.get_cache().get(key))


def test_autotune_rejects_wrong_shaped_entry(disk_cache):
    """A decodable pickle with the wrong shape (version skew) must also
    recalibrate — mirroring solver_cache's header semantics."""
    key = autotune.cache_key(10, 24, True)
    disk_cache.put(key, {"version": -1, "block_rows": "huge"})
    br = autotune.autotune_block_rows(10, 24, interpret=True,
                                      candidates=(2, 4))
    assert br in (2, 4)


def test_resolve_block_rows_env_pin(monkeypatch):
    monkeypatch.setenv("REPRO_DP_BLOCK_ROWS", "7")
    assert autotune.resolve_block_rows(100, 100, interpret=True) == 7
    monkeypatch.delenv("REPRO_DP_BLOCK_ROWS")
    monkeypatch.delenv("REPRO_DP_AUTOTUNE", raising=False)
    assert (autotune.resolve_block_rows(100, 100, interpret=True)
            == dpk.DEFAULT_BLOCK_ROWS)


def test_resolve_block_rows_rejects_garbage_pin(monkeypatch):
    """A mistyped pin must raise, not silently fall back to the default
    (matching the repo's strict size/budget parsing)."""
    monkeypatch.setenv("REPRO_DP_BLOCK_ROWS", "8x")
    with pytest.raises(ValueError, match="REPRO_DP_BLOCK_ROWS"):
        autotune.resolve_block_rows(100, 100, interpret=True)


def test_measure_dedupes_clamped_candidates():
    """Candidates above the calibration length collapse to one effective
    tile height — they must be measured once, and the stored winner must be
    a height that was actually run."""
    result = autotune.measure(10, 24, True, candidates=(2, 64, 128, 256))
    assert set(result["timings"]) <= {2, 10}   # effective heights only
    assert result["block_rows"] in result["timings"]


# ---------------------------------------------------------------------------
# solver / plan surface threading
# ---------------------------------------------------------------------------

PALLAS_IMPLS = ("pallas", "pallas_fused")


@pytest.mark.parametrize("impl", PALLAS_IMPLS)
@pytest.mark.parametrize("seed", range(3))
def test_solutions_match_banded(seed, impl):
    rng = np.random.default_rng(200 + seed)
    ch = random_chain(rng, max_len=5)
    for m in _budgets(ch, (0.5, 1.0)):
        S = int(m)
        b = solve_optimal(ch, m, num_slots=S, cache=False)
        p = solve_optimal(ch, m, num_slots=S, impl=impl, cache=False)
        assert b.feasible == p.feasible
        if not b.feasible:
            continue
        assert b.expected_time == p.expected_time
        res = simulate(ch, p.schedule, m + 1e-6)
        assert res.valid, res.error


@pytest.mark.parametrize("impl", PALLAS_IMPLS)
def test_min_memory_matches_banded(impl):
    rng = np.random.default_rng(42)
    ch = random_chain(rng, max_len=5)
    b = solve_min_memory(ch, num_slots=60, cache=False)
    p = solve_min_memory(ch, num_slots=60, impl=impl, cache=False)
    assert b.feasible == p.feasible
    if b.feasible:
        assert b.slots_used == p.slots_used
        assert b.expected_time == p.expected_time


@pytest.mark.parametrize("impl", PALLAS_IMPLS)
def test_offload_solution_matches_banded(impl):
    rng = np.random.default_rng(77)
    ch = random_chain(rng, max_len=4).with_host(_dyadic_host(rng))
    m = _budgets(ch, (0.6,))[0]
    S = int(m)
    b = solve_optimal_offload(ch, m, num_slots=S, cache=False)
    p = solve_optimal_offload(ch, m, num_slots=S, impl=impl, cache=False)
    assert b.feasible == p.feasible
    if b.feasible:
        assert b.expected_time == p.expected_time


@pytest.mark.parametrize("impl", PALLAS_IMPLS)
def test_plan_request_accepts_pallas(impl):
    rng = np.random.default_rng(9)
    ch = random_chain(rng, max_len=4)
    from repro.plan import Budget
    plan_b = build_plan(PlanRequest(strategy="optimal",
                                    budget=Budget.fraction(0.8),
                                    num_slots=40), ch)
    plan_p = build_plan(PlanRequest(strategy="optimal",
                                    budget=Budget.fraction(0.8),
                                    num_slots=40, impl=impl), ch)
    assert plan_p.expected_time == plan_b.expected_time
    assert plan_p.schedule.ops == plan_b.schedule.ops


def test_plan_request_rejects_unknown_impl():
    with pytest.raises(ValueError, match="unknown DP impl"):
        PlanRequest(strategy="optimal", impl="cuda")


def test_interpret_dispatch_default_is_backend_based():
    dpo.set_interpret(None)
    assert dpo.interpret_mode() == (jax.default_backend() != "tpu")
    dpo.set_interpret(True)
    assert dpo.interpret_mode() is True
