"""``impl="pallas"`` parity: the Pallas DP band-fill kernel
(``repro.kernels.dp_fill``) must produce **band-identical** cost tables to
the numpy banded fill (``impl="banded"``) in interpret mode, on the same
f32-exact chains ``tests/test_dp_kernels.py`` uses (integer stage costs,
dyadic transfer times — every DP quantity exactly representable in float32,
so equality is bit-exact, not approximate).

Interpret mode executes the kernel bodies in Python on CPU — the same
dispatch seam ``impl="pallas"`` falls back to automatically off-TPU — so
this suite runs in CPU CI and kernel regressions no longer need a TPU to
surface.
"""

import math

import jax
import numpy as np
import pytest

from repro.core import dp_kernels
from repro.core.chain import Chain, HostTransferModel
from repro.core.schedule import Schedule, simulate
from repro.core.solver import solve_min_memory, solve_optimal
from repro.kernels.dp_fill import kernel as dpk
from repro.kernels.dp_fill import ops as dpo
from repro.kernels.dp_fill import ref as dpr
from repro.offload.solver import solve_optimal_offload
from repro.plan import PlanRequest, build_plan

from helpers import random_chain


@pytest.fixture(autouse=True)
def interpret_mode():
    dpo.set_interpret(True)
    yield
    dpo.set_interpret(None)


def _dyadic_host(rng) -> HostTransferModel:
    return HostTransferModel(
        bandwidth_d2h=float(rng.choice([0.5, 1.0, 4.0])),
        latency=float(rng.choice([0.0, 0.25])))


def _budgets(ch, fracs):
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    return [float(math.ceil(peak * f)) for f in fracs]


# ---------------------------------------------------------------------------
# kernel-level parity vs the pure-jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,ns,w", [(1, 1, 4), (3, 5, 17), (7, 300, 33)])
def test_band_min_two_tier_matches_oracle(d, ns, w):
    rng = np.random.default_rng(d * 100 + ns)
    r = rng.uniform(0, 8, (d, ns, w)).astype(np.float32)
    lm = rng.uniform(-4, 4, (d, ns, w)).astype(np.float32)
    r[rng.uniform(size=r.shape) < 0.3] = np.inf   # out-of-budget sentinels
    out = dpk.band_min_two_tier(r, lm, interpret=True)
    exp = dpr.band_min_two_tier(r, lm)
    assert np.array_equal(np.asarray(out), np.asarray(exp))


def test_band_min_two_tier_row_tiling():
    """ns above the block size exercises the padded multi-tile grid path."""
    rng = np.random.default_rng(0)
    r = rng.uniform(0, 8, (4, 37, 9)).astype(np.float32)
    lm = rng.uniform(-4, 4, (4, 37, 9)).astype(np.float32)
    out = dpk.band_min_two_tier(r, lm, block_rows=16, interpret=True)
    exp = dpr.band_min_two_tier(r, lm)
    assert np.array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("d,ns,w", [(1, 1, 4), (4, 23, 11)])
def test_band_min_offload_matches_oracle(d, ns, w):
    rng = np.random.default_rng(d * 10 + ns)

    def plane(lo, hi):
        return rng.uniform(lo, hi, (d, ns, w)).astype(np.float32)

    r, r3 = plane(0, 8), plane(0, 8)
    r[rng.uniform(size=r.shape) < 0.3] = np.inf
    r3[rng.uniform(size=r3.shape) < 0.3] = np.inf
    lmb, lme, lmb3 = plane(-4, 4), plane(-4, 4), plane(-4, 4)
    toff = rng.uniform(0, 6, (ns, 1)).astype(np.float32)
    outs = dpk.band_min_offload(r, r3, lmb, lme, lmb3, toff, interpret=True)
    exps = dpr.band_min_offload(r, r3, lmb, lme, lmb3, toff)
    for o, e in zip(outs, exps):
        assert np.array_equal(np.asarray(o), np.asarray(e))


# ---------------------------------------------------------------------------
# band-exact table agreement with impl="banded" on f32-exact chains
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("allow_fall", [True, False])
def test_two_tier_tables_band_exact(seed, allow_fall):
    rng = np.random.default_rng(seed)
    ch = random_chain(rng, max_len=5)
    for m in _budgets(ch, (0.4, 0.7, 1.0)):
        S = int(m)
        dchain = ch.discretize(m, S)
        band = dp_kernels.fill_two_tier(dchain, S, allow_fall=allow_fall)
        pall = dpo.fill_two_tier(dchain, S, allow_fall=allow_fall)
        assert np.array_equal(band.data, pall.data, equal_nan=True)


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("allow_fall", [True, False])
def test_offload_tables_band_exact(seed, allow_fall):
    rng = np.random.default_rng(100 + seed)
    ch = random_chain(rng, max_len=4).with_host(_dyadic_host(rng))
    for m in _budgets(ch, (0.4, 1.0)):
        S = int(m)
        dchain = ch.discretize(m, S)
        tb, te = dp_kernels.fill_offload(dchain, S, allow_fall=allow_fall)
        pb, pe = dpo.fill_offload(dchain, S, allow_fall=allow_fall)
        assert np.array_equal(tb.data, pb.data, equal_nan=True)
        assert np.array_equal(te.data, pe.data, equal_nan=True)


def test_offload_gather_path_band_exact():
    """An activation bigger than the whole budget forces the non-sliced C3
    gather path in both fills."""
    ch = Chain.make(uf=[1.0, 1.0, 0.0], ub=[1.0, 1.0, 0.0],
                    wa=[1.0, 40.0, 1.0], wabar=[2.0, 2.0, 0.0],
                    host=HostTransferModel(bandwidth_d2h=1.0))
    dchain = ch.discretize(8.0, 8)
    tb, te = dp_kernels.fill_offload(dchain, 8)
    pb, pe = dpo.fill_offload(dchain, 8)
    assert np.array_equal(tb.data, pb.data, equal_nan=True)
    assert np.array_equal(te.data, pe.data, equal_nan=True)


# ---------------------------------------------------------------------------
# solver / plan surface threading
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(3))
def test_solutions_match_banded(seed):
    rng = np.random.default_rng(200 + seed)
    ch = random_chain(rng, max_len=5)
    for m in _budgets(ch, (0.5, 1.0)):
        S = int(m)
        b = solve_optimal(ch, m, num_slots=S, cache=False)
        p = solve_optimal(ch, m, num_slots=S, impl="pallas", cache=False)
        assert b.feasible == p.feasible
        if not b.feasible:
            continue
        assert b.expected_time == p.expected_time
        res = simulate(ch, p.schedule, m + 1e-6)
        assert res.valid, res.error


def test_min_memory_matches_banded():
    rng = np.random.default_rng(42)
    ch = random_chain(rng, max_len=5)
    b = solve_min_memory(ch, num_slots=60, cache=False)
    p = solve_min_memory(ch, num_slots=60, impl="pallas", cache=False)
    assert b.feasible == p.feasible
    if b.feasible:
        assert b.slots_used == p.slots_used
        assert b.expected_time == p.expected_time


def test_offload_solution_matches_banded():
    rng = np.random.default_rng(77)
    ch = random_chain(rng, max_len=4).with_host(_dyadic_host(rng))
    m = _budgets(ch, (0.6,))[0]
    S = int(m)
    b = solve_optimal_offload(ch, m, num_slots=S, cache=False)
    p = solve_optimal_offload(ch, m, num_slots=S, impl="pallas", cache=False)
    assert b.feasible == p.feasible
    if b.feasible:
        assert b.expected_time == p.expected_time


def test_plan_request_accepts_pallas():
    rng = np.random.default_rng(9)
    ch = random_chain(rng, max_len=4)
    from repro.plan import Budget
    plan_b = build_plan(PlanRequest(strategy="optimal",
                                    budget=Budget.fraction(0.8),
                                    num_slots=40), ch)
    plan_p = build_plan(PlanRequest(strategy="optimal",
                                    budget=Budget.fraction(0.8),
                                    num_slots=40, impl="pallas"), ch)
    assert plan_p.expected_time == plan_b.expected_time
    assert plan_p.schedule.ops == plan_b.schedule.ops


def test_plan_request_rejects_unknown_impl():
    with pytest.raises(ValueError, match="unknown DP impl"):
        PlanRequest(strategy="optimal", impl="cuda")


def test_interpret_dispatch_default_is_backend_based():
    dpo.set_interpret(None)
    assert dpo.interpret_mode() == (jax.default_backend() != "tpu")
    dpo.set_interpret(True)
    assert dpo.interpret_mode() is True
