import os

# Tests must see 1 CPU device (the dry-run sets its own 512-device flag in a
# subprocess); also keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
