import os

# Tests must see 1 CPU device (the dry-run sets its own 512-device flag in a
# subprocess); also keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Keep the solver cache memory-only during tests: a fresh process state per
# run, no reads from (or writes to) the developer's ~/.cache — otherwise a
# broken DP fill could go green against Solutions cached by an earlier run.
# Cache tests point REPRO_SOLVER_CACHE_DIR at a tmpdir explicitly.
os.environ.setdefault("REPRO_SOLVER_CACHE_DIR", "")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
