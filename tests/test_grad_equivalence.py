"""The paper's §1 guarantee: checkpointing computes *exactly the same
results* as plain autograd — for both execution paths (faithful op-sequence
executor and the nested-remat compiler), across policies and budgets."""


import jax
import numpy as np
import pytest

from repro.core import (Schedule, best_periodic, build_remat_fn,
                        execute_schedule, full_remat_tree, periodic_tree,
                        profile_stages_analytic, reference_grads,
                        sequential_tree, simulate, solve_optimal,
                        tree_to_schedule)

from helpers import make_mlp_chain, tree_allclose

L = 5


@pytest.fixture(scope="module")
def setup():
    stages, params, x = make_mlp_chain(L)
    chain = profile_stages_analytic(stages, params, x, peak_flops=1e9)
    out, grads, dx = reference_grads(stages, params, x)
    return stages, params, x, chain, (out, grads, dx)


@pytest.mark.parametrize("frac", [0.35, 0.5, 0.75, 1.0])
def test_executor_matches_autograd(setup, frac):
    stages, params, x, chain, (out_ref, g_ref, dx_ref) = setup
    peak = simulate(chain, Schedule.store_all(L)).peak_mem
    sol = solve_optimal(chain, peak * frac, num_slots=300)
    if not sol.feasible:
        pytest.skip("budget infeasible")
    out, grads, dx = execute_schedule(sol.schedule, stages, params, x)
    np.testing.assert_allclose(out, out_ref, rtol=1e-6)
    tree_allclose(grads, g_ref)
    tree_allclose(dx, dx_ref)


@pytest.mark.parametrize("frac", [0.35, 0.5, 0.75, 1.0])
def test_remat_tree_matches_autograd(setup, frac):
    stages, params, x, chain, (out_ref, g_ref, dx_ref) = setup
    peak = simulate(chain, Schedule.store_all(L)).peak_mem
    sol = solve_optimal(chain, peak * frac, num_slots=300)
    if not sol.feasible:
        pytest.skip("budget infeasible")
    f = build_remat_fn(sol.tree, stages)
    out = jax.jit(f)(params, x)
    np.testing.assert_allclose(out, out_ref, rtol=1e-6)
    g, dx = jax.jit(jax.grad(f, argnums=(0, 1)))(params, x)
    tree_allclose(list(g), g_ref)
    tree_allclose(dx, dx_ref)


@pytest.mark.parametrize("treefn", [
    lambda: sequential_tree(L),
    lambda: full_remat_tree(L),
    lambda: periodic_tree(L, 2),
    lambda: periodic_tree(L, 3),
])
def test_canned_trees_match(setup, treefn):
    stages, params, x, chain, (out_ref, g_ref, dx_ref) = setup
    tree = treefn()
    # flattened schedule is valid
    assert simulate(chain, tree_to_schedule(tree, L)).valid
    f = build_remat_fn(tree, stages)
    g, dx = jax.jit(jax.grad(f, argnums=(0, 1)))(params, x)
    tree_allclose(list(g), g_ref)
    tree_allclose(dx, dx_ref)


@pytest.mark.parametrize("impl", ["banded", "pallas", "pallas_fused"])
def test_planned_execution_matches_autograd_per_impl(setup, impl):
    """End-to-end grad equivalence for a *built and executed* MemoryPlan per
    DP impl: the plan is bound (jitted nested-remat executor) and run, and
    its faithful op-sequence execution is run too — gradients must equal the
    store-all baseline bit-for-tolerance, not just the DP tables.  The
    Pallas impls exercise the interpret-mode dispatch seam on CPU."""
    from repro.plan import Budget, PlanRequest, build_plan

    stages, params, x, chain, (out_ref, g_ref, dx_ref) = setup
    req = PlanRequest(strategy="optimal", budget=Budget.fraction(0.5),
                      num_slots=120, impl=impl)
    plan = build_plan(req, chain)
    assert plan.request.impl == impl
    bound = plan.bind(stages)
    assert bound.jittable
    out, grads, dx = bound.value_and_grad(params, x)
    np.testing.assert_allclose(out, out_ref, rtol=1e-6)
    tree_allclose(grads, g_ref)
    tree_allclose(dx, dx_ref)
    # the faithful executor runs the exact op sequence the plan carries
    out2, grads2, dx2 = plan.execute(stages, params, x)
    np.testing.assert_allclose(out2, out_ref, rtol=1e-6)
    tree_allclose(grads2, g_ref)
    tree_allclose(dx2, dx_ref)


def test_executor_runs_baseline_schedules(setup):
    stages, params, x, chain, (out_ref, g_ref, dx_ref) = setup
    peak = simulate(chain, Schedule.store_all(L)).peak_mem
    got = best_periodic(chain, peak * 0.7)
    assert got is not None
    k, res, sched = got
    out, grads, dx = execute_schedule(sched, stages, params, x)
    tree_allclose(grads, g_ref)


def test_rotor_beats_periodic_in_model_time(setup):
    """The paper's headline: at equal memory, optimal ≥ best periodic."""
    stages, params, x, chain, _ = setup
    peak = simulate(chain, Schedule.store_all(L)).peak_mem
    for frac in (0.4, 0.6, 0.8):
        m = peak * frac
        got = best_periodic(chain, m)
        sol = solve_optimal(chain, m, num_slots=400)
        if got is None:
            continue
        assert sol.feasible  # anywhere periodic fits, optimal fits
        assert sol.expected_time <= got[1].time + 1e-9
