"""The paper's §1 guarantee: checkpointing computes *exactly the same
results* as plain autograd — for both execution paths (faithful op-sequence
executor and the nested-remat compiler), across policies and budgets."""


import jax
import numpy as np
import pytest

from repro.core import (Schedule, best_periodic, build_remat_fn,
                        execute_schedule, full_remat_tree, periodic_tree,
                        profile_stages_analytic, reference_grads,
                        sequential_tree, simulate, solve_optimal,
                        tree_to_schedule)

from helpers import make_mlp_chain, tree_allclose

L = 5


@pytest.fixture(scope="module")
def setup():
    stages, params, x = make_mlp_chain(L)
    chain = profile_stages_analytic(stages, params, x, peak_flops=1e9)
    out, grads, dx = reference_grads(stages, params, x)
    return stages, params, x, chain, (out, grads, dx)


@pytest.mark.parametrize("frac", [0.35, 0.5, 0.75, 1.0])
def test_executor_matches_autograd(setup, frac):
    stages, params, x, chain, (out_ref, g_ref, dx_ref) = setup
    peak = simulate(chain, Schedule.store_all(L)).peak_mem
    sol = solve_optimal(chain, peak * frac, num_slots=300)
    if not sol.feasible:
        pytest.skip("budget infeasible")
    out, grads, dx = execute_schedule(sol.schedule, stages, params, x)
    np.testing.assert_allclose(out, out_ref, rtol=1e-6)
    tree_allclose(grads, g_ref)
    tree_allclose(dx, dx_ref)


@pytest.mark.parametrize("frac", [0.35, 0.5, 0.75, 1.0])
def test_remat_tree_matches_autograd(setup, frac):
    stages, params, x, chain, (out_ref, g_ref, dx_ref) = setup
    peak = simulate(chain, Schedule.store_all(L)).peak_mem
    sol = solve_optimal(chain, peak * frac, num_slots=300)
    if not sol.feasible:
        pytest.skip("budget infeasible")
    f = build_remat_fn(sol.tree, stages)
    out = jax.jit(f)(params, x)
    np.testing.assert_allclose(out, out_ref, rtol=1e-6)
    g, dx = jax.jit(jax.grad(f, argnums=(0, 1)))(params, x)
    tree_allclose(list(g), g_ref)
    tree_allclose(dx, dx_ref)


@pytest.mark.parametrize("treefn", [
    lambda: sequential_tree(L),
    lambda: full_remat_tree(L),
    lambda: periodic_tree(L, 2),
    lambda: periodic_tree(L, 3),
])
def test_canned_trees_match(setup, treefn):
    stages, params, x, chain, (out_ref, g_ref, dx_ref) = setup
    tree = treefn()
    # flattened schedule is valid
    assert simulate(chain, tree_to_schedule(tree, L)).valid
    f = build_remat_fn(tree, stages)
    g, dx = jax.jit(jax.grad(f, argnums=(0, 1)))(params, x)
    tree_allclose(list(g), g_ref)
    tree_allclose(dx, dx_ref)


def test_executor_runs_baseline_schedules(setup):
    stages, params, x, chain, (out_ref, g_ref, dx_ref) = setup
    peak = simulate(chain, Schedule.store_all(L)).peak_mem
    got = best_periodic(chain, peak * 0.7)
    assert got is not None
    k, res, sched = got
    out, grads, dx = execute_schedule(sched, stages, params, x)
    tree_allclose(grads, g_ref)


def test_rotor_beats_periodic_in_model_time(setup):
    """The paper's headline: at equal memory, optimal ≥ best periodic."""
    stages, params, x, chain, _ = setup
    peak = simulate(chain, Schedule.store_all(L)).peak_mem
    for frac in (0.4, 0.6, 0.8):
        m = peak * frac
        got = best_periodic(chain, m)
        sol = solve_optimal(chain, m, num_slots=400)
        if got is None:
            continue
        assert sol.feasible  # anywhere periodic fits, optimal fits
        assert sol.expected_time <= got[1].time + 1e-9
