"""Integration tests: the training loop end-to-end (loss drops, checkpoint/
restart resumes exactly, watchdog fires), the serving loop, and rotor-policy
plumbing through the runtime."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.lm import StagedLM
from repro.runtime.serve_loop import ServeLoopConfig, run_serving
from repro.runtime.train_loop import TrainLoopConfig, run_training


def test_train_loop_loss_drops(tmp_path):
    cfg = smoke_config("qwen1.5-4b")
    loop = TrainLoopConfig(steps=12, global_batch=4, seq_len=32, lr=3e-3,
                           warmup=2, log_every=100,
                           ckpt_dir=str(tmp_path), ckpt_every=5)
    out = run_training(cfg, loop, log_fn=lambda *_: None)
    assert len(out["losses"]) == 12
    assert out["losses"][-1] < out["losses"][0]


def test_train_loop_restart_is_exact(tmp_path):
    """Run 4 steps with checkpointing, then restart: the restored state must
    be *bitwise* identical to the in-memory end state (the system guarantee),
    and the resumed run must cover exactly steps 4..7 on the same data.

    (Loss-trajectory equality across separate jit compilations is NOT
    asserted bit-exactly: XLA-CPU recompilations of a fresh step closure can
    differ at ~1e-7, which training chaos amplifies — the state restore and
    data resume themselves are exact, asserted below.)"""
    import jax
    import jax.numpy as jnp
    from repro.ckpt.manager import CheckpointManager
    from repro.models.lm import StagedLM
    from repro.optim.adamw import adamw_init

    cfg = smoke_config("qwen1.5-4b")
    base = dict(global_batch=4, seq_len=32, lr=3e-3, warmup=2, log_every=100)
    d = str(tmp_path / "ck")
    r1 = run_training(cfg, TrainLoopConfig(steps=4, ckpt_dir=d, ckpt_every=0,
                                           **base), log_fn=lambda *_: None)
    # bitwise restore of params + optimizer state + step
    model = StagedLM(cfg)
    pspec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    target = {"params": pspec, "opt": jax.eval_shape(adamw_init, pspec),
              "step": jax.ShapeDtypeStruct((), jnp.int32)}
    s, st = CheckpointManager(d).restore(target)
    assert s == 3 and int(st["step"]) == 3
    for a, b in zip(jax.tree.leaves(st["params"]),
                    jax.tree.leaves(r1["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(st["opt"]),
                    jax.tree.leaves(r1["opt_state"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # resumed run continues at step 4 and keeps training sanely
    out = run_training(cfg, TrainLoopConfig(steps=8, ckpt_dir=d, ckpt_every=0,
                                            **base), log_fn=lambda *_: None)
    assert len(out["losses"]) == 4  # steps 4..7 only
    assert out["last_step"] == 7
    assert np.isfinite(out["losses"]).all()


@pytest.mark.parametrize("policy", ["none", "full", "periodic:2",
                                    "rotor:x0.7", "revolve:x0.9"])
def test_train_loop_policies(policy):
    cfg = smoke_config("qwen1.5-4b")
    loop = TrainLoopConfig(steps=3, global_batch=2, seq_len=16, policy=policy,
                           log_every=100)
    out = run_training(cfg, loop, log_fn=lambda *_: None)
    assert np.isfinite(out["losses"][-1])


def test_policies_same_loss_trajectory():
    """Remat policies change memory/compute, never the math."""
    cfg = smoke_config("qwen1.5-4b")
    base = dict(steps=3, global_batch=2, seq_len=16, lr=1e-3, log_every=100)
    ref = run_training(cfg, TrainLoopConfig(policy="none", **base),
                       log_fn=lambda *_: None)["losses"]
    for policy in ("full", "rotor:x0.8"):
        got = run_training(cfg, TrainLoopConfig(policy=policy, **base),
                           log_fn=lambda *_: None)["losses"]
        np.testing.assert_allclose(got, ref, rtol=1e-5)


def test_serve_loop():
    cfg = smoke_config("qwen1.5-4b")
    model = StagedLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (3, 8)).astype(np.int32)
    out = run_serving(cfg, params, prompts,
                      ServeLoopConfig(max_new_tokens=6, max_len=16),
                      model=model)
    assert out["generations"].shape == (3, 6)
    assert out["decode_tokens_per_s"] > 0
    # greedy decode from the same state is deterministic
    out2 = run_serving(cfg, params, prompts,
                       ServeLoopConfig(max_new_tokens=6, max_len=16),
                       model=model)
    np.testing.assert_array_equal(out["generations"], out2["generations"])
