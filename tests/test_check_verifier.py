"""Static schedule verifier (`repro.check.schedule_verifier`) tests.

Three layers:

- **equivalence**: `verify_schedule` must agree with the float64 simulator
  (`simulate(...).valid ⟺ report.ok`, first violation kind == the
  simulator's `error_kind`) on every solver-produced schedule in the matrix
  (two-tier + offload, all DP impls, baselines) — plus a hypothesis
  property over random chains when the `test` extra is installed;
- **mutation**: ≥95% of single-op corruptions (drop / duplicate / swap /
  index-shift) of valid solver schedules must be rejected, with the
  verifier and simulator agreeing on validity and on the violation kind;
- **wiring**: `MemoryPlan.verify` passes on every built plan, `save`/`load`
  refuse corrupted plans, `REPRO_CHECK=1` gates `bind`/`execute`, and
  `assert_valid` raises a structured `ScheduleViolationError` carrying the
  same `Violation` (op index + residency summary) the verifier reports.
"""

import dataclasses
import os
import re

import numpy as np
import pytest

from repro.check import (
    PlanVerificationError,
    VIOLATION_KINDS,
    verify_schedule,
    verify_slot_discipline,
)
from repro.core import baselines
from repro.core.chain import Chain, HostTransferModel
from repro.core.schedule import (
    Schedule,
    ScheduleViolationError,
    assert_valid,
    simulate,
)
from repro.core.solver import solve_min_memory, solve_optimal
from repro.offload.solver import solve_optimal_offload
from repro.plan import Budget, MemoryPlan, PlanRequest, build_plan

from helpers import random_chain

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover — CI always installs the test extra
    HAVE_HYPOTHESIS = False


def _host_chain(rng, max_len=5):
    ch = random_chain(rng, max_len=max_len)
    return ch.with_host(HostTransferModel(bandwidth_d2h=2.0))


def _assert_equivalent(chain, schedule, budget=None):
    sim = simulate(chain, schedule, budget)
    rep = verify_schedule(schedule, chain=chain, device_budget=budget)
    assert sim.valid == rep.ok, (
        f"simulator says valid={sim.valid} ({sim.error}), verifier says "
        f"{rep.summary()}")
    if not sim.valid:
        assert rep.first_kind == sim.error_kind, (
            rep.first_kind, sim.error_kind, sim.error)
    return sim, rep


# -- equivalence over the solver matrix --------------------------------------


@pytest.mark.parametrize("prune", ["1", "0"])
@pytest.mark.parametrize("impl", ["banded", "reference"])
@pytest.mark.parametrize("seed", range(4))
def test_two_tier_solver_schedules_verify(seed, impl, prune, monkeypatch):
    monkeypatch.setenv("REPRO_DP_PRUNE", prune)
    rng = np.random.default_rng(seed)
    ch = random_chain(rng, max_len=5)
    peak = ch.store_all_peak()
    for frac in (0.5, 0.75, 1.0):
        for S in (13, 40):
            sol = solve_optimal(ch, peak * frac, num_slots=S, impl=impl,
                                cache=False)
            if not sol.feasible or sol.schedule is None:
                continue
            sim, _ = _assert_equivalent(ch, sol.schedule, peak * frac)
            assert sim.valid, sim.error
            rep = verify_slot_discipline(sol.schedule, ch, peak * frac, S)
            assert rep.ok, rep.summary()


@pytest.mark.parametrize("impl", ["pallas", "pallas_fused"])
def test_pallas_impl_schedules_verify(impl):
    rng = np.random.default_rng(3)
    ch = random_chain(rng, max_len=3)
    peak = ch.store_all_peak()
    sol = solve_optimal(ch, peak * 0.75, num_slots=12, impl=impl,
                        cache=False)
    assert sol.feasible and sol.schedule is not None
    sim, _ = _assert_equivalent(ch, sol.schedule, peak * 0.75)
    assert sim.valid, sim.error


@pytest.mark.parametrize("seed", range(4))
def test_offload_solver_schedules_verify(seed):
    rng = np.random.default_rng(100 + seed)
    ch = _host_chain(rng)
    peak = ch.store_all_peak()
    for frac in (0.45, 0.6, 0.8):
        sol = solve_optimal_offload(ch, peak * frac, num_slots=24,
                                    cache=False)
        if not sol.feasible or sol.schedule is None:
            continue
        sim, _ = _assert_equivalent(ch, sol.schedule, peak * frac)
        assert sim.valid, sim.error


@pytest.mark.parametrize("seed", range(4))
def test_min_memory_and_baseline_schedules_verify(seed):
    rng = np.random.default_rng(200 + seed)
    ch = random_chain(rng, max_len=5)
    scheds = [solve_min_memory(ch, cache=False).schedule,
              Schedule.store_all(ch.length),
              baselines.periodic(ch, max(1, ch.length // 2)),
              baselines.chen_sqrt(ch)]
    for sched in scheds:
        if sched is None:
            continue
        sim, _ = _assert_equivalent(ch, sched)
        assert sim.valid, sim.error


if HAVE_HYPOTHESIS:
    @st.composite
    def chain_and_budget(draw):
        L = draw(st.integers(min_value=1, max_value=5))
        n = L + 1
        ints = st.lists(st.integers(1, 5), min_size=n, max_size=n)
        ch = Chain.make(
            uf=[float(x) for x in draw(ints)],
            ub=[float(x) for x in draw(ints)],
            wa=[float(x) for x in draw(ints)],
            wabar=[float(x) for x in draw(ints)],
        )
        if draw(st.booleans()):
            ch = ch.with_host(HostTransferModel(
                bandwidth_d2h=float(draw(st.integers(1, 4)))))
        frac = draw(st.sampled_from([0.5, 0.7, 0.9, 1.0]))
        return ch, frac

    @pytest.mark.slow
    @settings(max_examples=40, deadline=None)
    @given(chain_and_budget())
    def test_every_solver_plan_verifies(cb):
        """Property: every feasible plan the planning API produces passes
        MemoryPlan.verify() — two-tier and offload tiers alike."""
        ch, frac = cb
        tiers = (("device", "host") if ch.host is not None
                 else ("device",))
        try:
            plan = build_plan(
                PlanRequest(budget=Budget.fraction(frac), tiers=tiers,
                            num_slots=20), ch)
        except MemoryError:
            return
        rep = plan.verify()
        assert rep.ok, rep.summary()


# -- mutation suite ----------------------------------------------------------


def _mutations(rng, ops, n_per_kind=None):
    """Single-op corruptions of an op list: drop, duplicate, swap with the
    next op, shift a stage index."""
    out = []
    idxs = range(len(ops))
    for i in idxs:
        out.append(("drop", ops[:i] + ops[i + 1:]))
        out.append(("dup", ops[:i] + [ops[i]] + ops[i:]))
    for i in range(len(ops) - 1):
        if ops[i] != ops[i + 1]:
            swapped = list(ops)
            swapped[i], swapped[i + 1] = swapped[i + 1], swapped[i]
            out.append(("swap", swapped))
    for i in idxs:
        kind, arg = ops[i]
        if isinstance(arg, int):
            shifted = list(ops)
            shifted[i] = (kind, arg + int(rng.choice([-1, 1])))
            out.append(("shift", shifted))
    return out


@pytest.mark.parametrize("seed", range(3))
def test_mutation_suite_rejects_corruptions(seed):
    """≥95% of single-op corruptions of a solved plan fail
    MemoryPlan.verify() — via the liveness/budget walk for semantically
    broken schedules, via the metadata cross-check for valid-but-different
    ones (e.g. a duplicated forward).  The schedule-level verifier must
    stay check-for-check equivalent to the simulator throughout."""
    rng = np.random.default_rng(300 + seed)
    total = rejected = 0
    for draw in range(4):
        ch = random_chain(rng, max_len=4)
        try:
            plan = build_plan(
                PlanRequest(budget=Budget.fraction(0.6), num_slots=25), ch)
        except MemoryError:
            plan = build_plan(PlanRequest(strategy="min_memory"), ch)
        sched = plan.schedule
        budget = plan.budget_bytes
        assert plan.verify().ok
        for tag, ops in _mutations(rng, list(sched.ops)):
            bad_sched = Schedule(ops=ops, length=sched.length)
            total += 1
            sim = simulate(ch, bad_sched, budget)
            rep = verify_schedule(bad_sched, chain=ch, device_budget=budget)
            # verifier and simulator must agree op-for-op — on validity
            # and, when invalid, on the violation kind
            assert sim.valid == rep.ok, (tag, sim.error, rep.summary())
            if not rep.ok:
                assert rep.first_kind == sim.error_kind, (
                    tag, rep.first_kind, sim.error_kind)
                assert rep.first_kind in VIOLATION_KINDS
            plan_rep = dataclasses.replace(plan, schedule=bad_sched).verify()
            if not plan_rep.ok:
                rejected += 1
    assert total > 40
    assert rejected / total >= 0.95, (
        f"only {rejected}/{total} corruptions rejected")


def test_violation_carries_op_index_and_residency():
    """Satellite: validation errors carry the op position and a short
    residency summary, in both the simulator string and the Violation."""
    ch = Chain.homogeneous(3)
    sched = solve_min_memory(ch, cache=False).schedule
    ops = list(sched.ops)
    # drop the first backward's gradient producer: find a B op and damage it
    b_at = next(i for i, (k, _) in enumerate(ops) if k == "B")
    del ops[b_at]
    bad = Schedule(ops=ops, length=sched.length)
    sim = simulate(ch, bad)
    assert not sim.valid
    assert sim.error_index >= 0
    assert f"at op[{sim.error_index}]" in sim.error
    assert sim.error_state  # residency summary, e.g. "dev a{0} δ{4} | ..."
    rep = verify_schedule(bad, chain=ch)
    v = rep.violations[0]
    assert v.kind == sim.error_kind
    assert v.op_index == sim.error_index
    assert v.state
    with pytest.raises(ScheduleViolationError) as exc:
        assert_valid(ch, bad)
    assert exc.value.violation.kind == sim.error_kind
    assert re.search(r"at op\[\d+\]", str(exc.value))


# -- plan wiring -------------------------------------------------------------


def _plan(seed=5, frac=0.7):
    rng = np.random.default_rng(seed)
    for _ in range(10):
        ch = random_chain(rng, max_len=4)
        try:
            return build_plan(
                PlanRequest(budget=Budget.fraction(frac),
                            num_slots=24), ch), ch
        except MemoryError:
            continue
    raise AssertionError("no feasible draw in 10 tries")


def test_plan_save_load_verify(tmp_path):
    plan, ch = _plan()
    assert plan.verify().ok
    p = os.path.join(tmp_path, "a.plan")
    plan.save(p)
    loaded = MemoryPlan.load(p, ch)
    assert loaded.verify().ok


def test_plan_save_refuses_corrupt_schedule(tmp_path):
    plan, _ = _plan()
    ops = list(plan.schedule.ops)
    del ops[len(ops) // 2]
    bad = dataclasses.replace(
        plan, schedule=Schedule(ops=ops, length=plan.schedule.length))
    with pytest.raises(PlanVerificationError) as exc:
        bad.save(os.path.join(tmp_path, "bad.plan"))
    assert exc.value.report.violations


def test_repro_check_gates_bind_and_execute(monkeypatch):
    plan, _ = _plan()
    ops = list(plan.schedule.ops)
    del ops[len(ops) // 2]
    bad = dataclasses.replace(
        plan, schedule=Schedule(ops=ops, length=plan.schedule.length))
    # without the env gate, bind does not verify (fast path untouched)
    monkeypatch.delenv("REPRO_CHECK", raising=False)
    bad.bind([lambda p, a: a] * bad.length)
    monkeypatch.setenv("REPRO_CHECK", "1")
    with pytest.raises(PlanVerificationError):
        bad.bind([lambda p, a: a] * bad.length)
    with pytest.raises(PlanVerificationError):
        bad.execute([lambda p, a: a] * bad.length, [None] * bad.length, 0.0)
