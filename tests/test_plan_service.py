"""Multi-tenant plan service tests: hit/miss flow, single-flight dedup,
per-tenant namespaces and quotas, and the verification gate — a tampered
plan in a shared store (byte-level OR semantic) is quarantined and
re-solved; it never crosses the service boundary into ``bind``/``execute``.
"""

import threading

import numpy as np
import pytest

from repro.check import PlanVerificationError
from repro.core.chain import Chain
from repro.plan import Budget, PlanRequest, build_plan
from repro.runtime import PlanService, QuotaExceededError, TenantQuota
from repro.store import (
    LocalDirectoryBackend,
    MemoryBackend,
    ObjectStore,
    PlanStore,
    decode,
    encode,
)

NUM_SLOTS = 48


def _chain(L: int = 8, seed: int = 0) -> Chain:
    rng = np.random.default_rng(seed)
    n = L + 1
    return Chain.make(
        uf=rng.integers(1, 5, n).astype(float),
        ub=rng.integers(1, 5, n).astype(float),
        wa=rng.integers(1, 4, n).astype(float),
        wabar=rng.integers(1, 6, n).astype(float),
    )


def _request(chain: Chain, frac: float = 0.6) -> PlanRequest:
    return PlanRequest(
        strategy="optimal",
        budget=Budget.bytes(chain.store_all_peak() * frac),
        num_slots=NUM_SLOTS,
    )


def _counts():
    from repro.obs import metrics

    snap = metrics.registry().snapshot()
    return {k: int(v.get("count", 0)) for k, v in snap.items()}


def test_miss_solve_then_verified_hit():
    ch = _chain()
    req = _request(ch)
    before = _counts()
    with PlanService(ObjectStore(MemoryBackend())) as svc:
        first = svc.plan(ch, req)
        second = svc.plan(ch, req)
    after = _counts()
    assert first.expected_time == second.expected_time
    assert first.verify().ok and second.verify().ok
    delta = lambda k: after.get(k, 0) - before.get(k, 0)  # noqa: E731
    assert delta("plan_service.misses") == 1
    assert delta("plan_service.solves") == 1
    assert delta("plan_service.hits") == 1


def test_single_flight_dedup(monkeypatch):
    release = threading.Event()
    orig = PlanService._solve

    def slow_solve(chain, request):
        assert release.wait(10)
        return orig(chain, request)

    monkeypatch.setattr(PlanService, "_solve", staticmethod(slow_solve))
    ch = _chain()
    req = _request(ch)
    with PlanService(ObjectStore(MemoryBackend()), workers=1) as svc:
        f1 = svc.submit(ch, req)
        f2 = svc.submit(ch, req)
        assert f2 is f1, "same content key must share one solve"
        release.set()
        assert f1.result(timeout=30).verify().ok


def test_inflight_quota_rejects_excess(monkeypatch):
    release = threading.Event()
    orig = PlanService._solve

    def slow_solve(chain, request):
        assert release.wait(10)
        return orig(chain, request)

    monkeypatch.setattr(PlanService, "_solve", staticmethod(slow_solve))
    ch = _chain()
    quota = TenantQuota(max_inflight=1)
    with PlanService(
        ObjectStore(MemoryBackend()), workers=1, default_quota=quota
    ) as svc:
        f1 = svc.submit(ch, _request(ch, 0.5))
        with pytest.raises(QuotaExceededError):
            svc.submit(ch, _request(ch, 0.9))
        # a different tenant is unaffected by this tenant's pressure
        f3 = svc.submit(ch, _request(ch, 0.9), tenant="other")
        release.set()
        assert f1.result(timeout=30) is not None
        assert f3.result(timeout=30) is not None


def test_max_plans_evicts_oldest():
    ch = _chain()
    store = ObjectStore(MemoryBackend())
    quota = TenantQuota(max_inflight=64, max_plans=2)
    with PlanService(store, default_quota=quota) as svc:
        for frac in (0.5, 0.7, 0.9):
            svc.plan(ch, _request(ch, frac))
    remaining = PlanStore(store).keys(tenant="default")
    assert len(remaining) == 2, remaining


def test_tenant_namespaces_are_disjoint():
    ch = _chain()
    req = _request(ch)
    store = ObjectStore(MemoryBackend())
    with PlanService(store) as svc:
        svc.plan(ch, req, tenant="alice")
        svc.plan(ch, req, tenant="bob")
    plans = PlanStore(store)
    assert len(plans.keys(tenant="alice")) == 1
    assert len(plans.keys(tenant="bob")) == 1
    a, b = plans.keys(tenant="alice")[0], plans.keys(tenant="bob")[0]
    assert a != b and a.startswith("plans/alice/")


# -- the verification gate ---------------------------------------------------


def _store_one_plan(tmp_path, tenant=None):
    backend = LocalDirectoryBackend(tmp_path)
    store = ObjectStore(backend)
    plans = PlanStore(store)
    ch = _chain()
    req = _request(ch)
    plan = build_plan(req, ch)
    key = plans.put(plan, chain=ch, request=req, tenant=tenant)
    (entry,) = [
        p
        for p in tmp_path.iterdir()
        if p.suffix == ".pkl" and p.name.startswith("plans__")
    ]
    return backend, plans, ch, req, key, entry


def test_byte_tampered_plan_rejected_as_store_corrupt(tmp_path):
    _, plans, ch, req, key, entry = _store_one_plan(tmp_path)
    data = bytearray(entry.read_bytes())
    data[len(data) // 2] ^= 0xFF
    entry.write_bytes(bytes(data))
    with pytest.raises(PlanVerificationError) as ei:
        plans.get(ch, req, strict=True)
    assert [v.kind for v in ei.value.report.violations] == ["store-corrupt"]
    # quarantined on first contact: now a plain miss, and never served
    assert plans.get(ch, req) is None
    assert (tmp_path / "_quarantine").exists()


def test_semantically_tampered_plan_fails_verify(tmp_path):
    backend, plans, ch, req, key, entry = _store_one_plan(tmp_path)
    # a *well-encoded* forgery: doctor the makespan and re-envelope with the
    # correct kind/key — the codec accepts it, MemoryPlan.verify() must not
    _, _, payload = decode(entry.read_bytes(), key=key)
    payload["plan"].expected_time += 5.0
    backend.put(key, encode("memory-plan", key, payload))
    with pytest.raises(PlanVerificationError) as ei:
        plans.get(ch, req, strict=True)
    kinds = {v.kind for v in ei.value.report.violations}
    assert "metadata-drift" in kinds
    assert plans.get(ch, req) is None  # quarantined


def test_service_never_serves_tampered_plan(tmp_path):
    backend, plans, ch, req, key, entry = _store_one_plan(
        tmp_path, tenant="default"
    )
    data = bytearray(entry.read_bytes())
    data[-10] ^= 0xFF
    entry.write_bytes(bytes(data))
    before = _counts()
    with PlanService(ObjectStore(backend)) as svc:
        served = svc.plan(ch, req)
    after = _counts()
    # the tampered entry was rejected and the service re-solved: the caller
    # still gets a plan, and it is a verified fresh one
    assert served.verify().ok
    assert after.get("plan_service.verify_rejects", 0) - before.get(
        "plan_service.verify_rejects", 0
    ) == 1
    assert after.get("plan_service.solves", 0) - before.get(
        "plan_service.solves", 0
    ) == 1


def test_wrong_chain_fingerprint_rejected(tmp_path):
    backend, plans, ch, req, key, entry = _store_one_plan(tmp_path)
    # re-home the entry under a different chain's address: the fingerprint
    # cross-check must refuse to serve it there
    other = _chain(seed=99)
    other_key = plans.key_for(other, req)
    _, _, payload = decode(entry.read_bytes(), key=key)
    backend.put(other_key, encode("memory-plan", other_key, payload))
    assert plans.get(other, req) is None
