"""Repo-invariant linter (`repro.check.lint`) tests + the jax-free import
guard.

Two halves:

- unit tests of the three lint rules against synthetic trees written to
  ``tmp_path`` (so the expectations are explicit, not inherited from
  whatever the live tree happens to contain), plus ``lint_repo() == []`` on
  the shipped tree — the same gate CI runs via ``python -m repro.check``;
- the *dynamic* side of the jax-import rule: a subprocess with ``jax`` /
  ``jaxlib`` blocked at the meta-path level must still import
  ``repro.core``, ``repro.obs.metrics``, ``repro.obs.trace`` and
  ``repro.check``, run a solve, and fail only (and cleanly) when touching a
  lazy jax-side export.
"""

import os
import subprocess
import sys
import textwrap

from repro.check import LintViolation, lint_repo
from repro.check.lint import lint_file, lint_paths

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _lint_snippet(tmp_path, rel, code):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(code))
    return lint_file(str(path), str(tmp_path))


# -- the shipped tree is clean -----------------------------------------------


def test_lint_repo_clean():
    violations = lint_repo()
    assert violations == [], "\n".join(str(v) for v in violations)


# -- jax-import rule ---------------------------------------------------------


def test_module_level_jax_import_flagged(tmp_path):
    vs = _lint_snippet(tmp_path, "core/foo.py", """
        import jax
    """)
    assert [v.rule for v in vs] == ["jax-import"]
    assert vs[0].line == 2


def test_function_local_jax_import_allowed(tmp_path):
    vs = _lint_snippet(tmp_path, "core/foo.py", """
        def f():
            import jax
            return jax
    """)
    assert vs == []


def test_type_checking_guard_allowed(tmp_path):
    vs = _lint_snippet(tmp_path, "obs/foo.py", """
        from typing import TYPE_CHECKING
        if TYPE_CHECKING:
            import jax
    """)
    assert vs == []


def test_try_guarded_jax_import_still_flagged(tmp_path):
    # a try/except around the import does not make it lazy
    vs = _lint_snippet(tmp_path, "core/foo.py", """
        try:
            import jaxlib
        except ImportError:
            jaxlib = None
    """)
    assert [v.rule for v in vs] == ["jax-import"]


def test_jax_boundary_modules_exempt(tmp_path):
    vs = _lint_snippet(tmp_path, "core/executor.py", """
        import jax
    """)
    assert vs == []


def test_relative_import_of_boundary_module_flagged(tmp_path):
    vs = _lint_snippet(tmp_path, "core/foo.py", """
        from . import executor
    """)
    assert [v.rule for v in vs] == ["jax-import"]


def test_transitive_repro_jax_module_flagged(tmp_path):
    vs = _lint_snippet(tmp_path, "check/foo.py", """
        from repro.core.executor import execute_schedule
    """)
    assert [v.rule for v in vs] == ["jax-import"]


def test_outside_scope_modules_unconstrained(tmp_path):
    vs = _lint_snippet(tmp_path, "kernels/foo.py", """
        import jax
    """)
    assert vs == []


# -- policy-parse rule -------------------------------------------------------


def test_policy_prefix_parse_flagged_outside_compat(tmp_path):
    vs = _lint_snippet(tmp_path, "plan/plan.py", """
        def f(policy):
            if policy.startswith("periodic:"):
                return 1
    """)
    assert [v.rule for v in vs] == ["policy-parse"]


def test_policy_prefix_tuple_flagged(tmp_path):
    vs = _lint_snippet(tmp_path, "core/solver.py", """
        def f(policy):
            return policy.startswith(("optimal", "revolve:"))
    """)
    assert [v.rule for v in vs] == ["policy-parse"]


def test_policy_parse_allowed_in_compat(tmp_path):
    vs = _lint_snippet(tmp_path, "plan/compat.py", """
        def f(policy):
            if policy.startswith("periodic:"):
                return 1
    """)
    assert vs == []


def test_unrelated_startswith_allowed(tmp_path):
    vs = _lint_snippet(tmp_path, "plan/plan.py", """
        def f(name):
            return name.startswith("repro.")
    """)
    assert vs == []


# -- metric-name rule --------------------------------------------------------


def test_bad_metric_name_flagged(tmp_path):
    vs = _lint_snippet(tmp_path, "obs/foo.py", """
        def f(metrics):
            metrics.counter("SolverCacheHits")
    """)
    assert [v.rule for v in vs] == ["metric-name"]


def test_dotted_metric_name_allowed(tmp_path):
    vs = _lint_snippet(tmp_path, "obs/foo.py", """
        def f(metrics):
            metrics.counter("solver_cache.hits")
            metrics.gauge("plan.peak_device_bytes", 2)
    """)
    assert vs == []


def test_fstring_metric_name_placeholders_substituted(tmp_path):
    # placeholders become "x" — still must land in noun.verb shape
    vs = _lint_snippet(tmp_path, "obs/foo.py", """
        def f(metrics, stage):
            metrics.histogram(f"stage.{stage}.seconds", 1.0)
            metrics.counter(f"{stage}")
    """)
    assert [v.rule for v in vs] == ["metric-name"]
    assert vs[0].line == 4


def test_imported_metric_fn_checked(tmp_path):
    vs = _lint_snippet(tmp_path, "obs/foo.py", """
        from repro.obs.metrics import counter

        def f():
            counter("BadName")
    """)
    assert [v.rule for v in vs] == ["metric-name"]


# -- pickle-confinement rule -------------------------------------------------


def test_pickle_import_flagged_outside_store(tmp_path):
    vs = _lint_snippet(tmp_path, "plan/plan.py", """
        import pickle
    """)
    assert [v.rule for v in vs] == ["pickle-confinement"]


def test_function_local_pickle_still_flagged(tmp_path):
    # unlike the jax rule, laziness does not make a pickle safe
    vs = _lint_snippet(tmp_path, "core/solver_cache.py", """
        def load(path):
            import pickle
            return pickle.load(open(path, "rb"))
    """)
    assert [v.rule for v in vs] == ["pickle-confinement"]


def test_pickle_variants_flagged(tmp_path):
    vs = _lint_snippet(tmp_path, "ckpt/manager.py", """
        from marshal import loads

        def f():
            import dill
    """)
    assert [v.rule for v in vs] == ["pickle-confinement"] * 2


def test_pickle_allowed_under_store(tmp_path):
    vs = _lint_snippet(tmp_path, "store/codec.py", """
        import pickle

        def decode(data):
            return pickle.loads(data)
    """)
    assert vs == []


def test_unrelated_import_not_flagged_as_pickle(tmp_path):
    vs = _lint_snippet(tmp_path, "plan/plan.py", """
        import pathlib
        from pickletools import dis  # not a (de)serializer
    """)
    assert vs == []


def test_lint_paths_sorts_and_aggregates(tmp_path):
    a = tmp_path / "core" / "a.py"
    b = tmp_path / "core" / "b.py"
    a.parent.mkdir(parents=True)
    a.write_text("import jax\n")
    b.write_text("import jaxlib\n")
    vs = lint_paths([str(b), str(a)], str(tmp_path))
    assert [v.path for v in vs] == ["core/a.py", "core/b.py"]
    assert all(isinstance(v, LintViolation) for v in vs)


def test_syntax_error_reported_not_raised(tmp_path):
    vs = _lint_snippet(tmp_path, "core/foo.py", """
        def f(:
    """)
    assert [v.rule for v in vs] == ["syntax"]


# -- jax-blocked import guard (dynamic side of the jax-import rule) ----------


_JAX_BLOCKED_PROBE = """
import sys

class _Blocker:
    ROOTS = ("jax", "jaxlib")
    def find_module(self, name, path=None):
        return self.find_spec(name, path)
    def find_spec(self, name, path=None, target=None):
        if name.split(".")[0] in self.ROOTS:
            raise ImportError(f"jax blocked for this test: {name}")
        return None

sys.meta_path.insert(0, _Blocker())

# the numpy-only surface must import and work
import repro.core
import repro.obs.metrics
import repro.obs.trace
import repro.check
from repro.core.chain import Chain
from repro.core.solver import solve_optimal

ch = Chain.homogeneous(4)
sol = solve_optimal(ch, ch.store_all_peak() * 0.7, num_slots=16,
                    impl="banded", cache=False)
assert sol.feasible and sol.schedule is not None
rep = repro.check.verify_schedule(sol.schedule, chain=ch,
                                  device_budget=ch.store_all_peak() * 0.7)
assert rep.ok, rep.summary()

# lazy jax-side exports must fail *cleanly* (ImportError at the boundary,
# not an AttributeError or a partial import)
try:
    repro.core.execute_schedule
except ImportError:
    pass
else:
    raise SystemExit("execute_schedule imported with jax blocked")

print("JAX-FREE-OK")
"""


def test_core_obs_check_import_with_jax_blocked():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    proc = subprocess.run(
        [sys.executable, "-c", _JAX_BLOCKED_PROBE],
        capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "JAX-FREE-OK" in proc.stdout
