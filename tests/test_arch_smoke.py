"""Per-arch smoke tests: reduced same-family configs run a real forward +
train-step on CPU, asserting output shapes and finite values (assignment
requirement (f))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models.lm import StagedLM
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def make_batch(cfg, B=2, S=16, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.modality == "text":
        return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
                "loss_mask": jnp.ones((B, S), jnp.float32)}
    if cfg.modality == "audio_embed":
        return {"embeds": jax.random.normal(key, (B, S, cfg.d_model)),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
                "loss_mask": jnp.ones((B, S), jnp.float32)}
    P = cfg.prefix_len
    return {"image_embeds": jax.random.normal(key, (B, P, cfg.d_model)),
            "tokens": jax.random.randint(key, (B, S - P), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (B, S - P), 0, cfg.vocab_size),
            "loss_mask": jnp.ones((B, S - P), jnp.float32)}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    model = StagedLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss = model.loss_fn(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # one full optimizer step
    grads = jax.grad(lambda p: model.loss_fn(p, batch))(params)
    opt = adamw_init(params)
    new_p, new_o, metrics = adamw_update(AdamWConfig(lr=1e-3), grads, opt,
                                         params)
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_p)))
    assert moved


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_loss_decreases_under_training(arch):
    """A few steps on a fixed batch must reduce the loss (learning sanity)."""
    cfg = smoke_config(arch)
    model = StagedLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=3e-3, weight_decay=0.0)

    @jax.jit
    def step(params, opt):
        loss, g = jax.value_and_grad(model.loss_fn)(params, batch)
        p2, o2, _ = adamw_update(ocfg, g, opt, params)
        return p2, o2, loss

    losses = []
    for _ in range(8):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.05, losses


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_forward(arch):
    """Greedy decode with the KV/SSM cache must reproduce full-forward
    logits position by position (prefill + N decode steps vs one forward).

    MoE capacity is raised so no tokens are dropped: capacity-based routing
    legitimately drops different tokens at different batch shapes, which is
    a serving-vs-training semantic difference, not a bug."""
    cfg = smoke_config(arch, moe_capacity_factor=16.0)
    if cfg.modality == "vlm":
        import dataclasses
        cfg = dataclasses.replace(cfg, modality="text", prefix_len=0)
    model = StagedLM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S0, N = 2, 8, 4
    key = jax.random.PRNGKey(2)
    if cfg.modality == "audio_embed":
        full_in = jax.random.normal(key, (B, S0 + N, cfg.d_model))
        batch0 = {"embeds": full_in[:, :S0]}
    else:
        full_in = jax.random.randint(key, (B, S0 + N), 0, cfg.vocab_size)
        batch0 = {"tokens": full_in[:, :S0]}

    # reference: full forward logits
    if cfg.modality == "audio_embed":
        ref_logits = model.forward_logits(params, {"embeds": full_in})
    else:
        ref_logits = model.forward_logits(params, {"tokens": full_in})

    logits, cache = model.prefill(params, batch0, max_len=S0 + N)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(ref_logits[:, S0 - 1]),
                               rtol=2e-3, atol=2e-3)
    for t in range(N):
        tok = (full_in[:, S0 + t][:, None] if cfg.modality != "audio_embed"
               else full_in[:, S0 + t][:, None, :])
        logits, cache = model.decode_step(params, cache, tok)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(ref_logits[:, S0 + t]),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_rotor_loss_matches_plain(arch):
    """The rotor execution path gives bitwise-same loss as the plain path."""
    from repro.core.rematerialize import full_remat_tree
    cfg = smoke_config(arch)
    model = StagedLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    plain = model.loss_fn(params, batch)
    L = model.n_stages() - 1
    tree = full_remat_tree(L)
    remat = model.loss_fn(params, batch, tree=tree)
    np.testing.assert_allclose(float(plain), float(remat), rtol=1e-6)
    g1 = jax.grad(lambda p: model.loss_fn(p, batch))(params)
    g2 = jax.grad(lambda p: model.loss_fn(p, batch, tree=tree))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


def test_full_configs_construct():
    """The 40-cell full configs build and report sane parameter counts."""
    from repro.configs import get_config
    expected_params = {
        "codeqwen1.5-7b": (6e9, 9e9),
        "qwen1.5-4b": (3e9, 5e9),
        "starcoder2-7b": (6e9, 9e9),
        "qwen1.5-110b": (90e9, 130e9),
        "musicgen-medium": (1e9, 2.5e9),
        "paligemma-3b": (2e9, 4e9),
        "deepseek-v2-lite-16b": (12e9, 20e9),
        # the assignment sheet pins 48L×64e for moonshot (HF Moonlight has
        # 27L); at the sheet's dims the total is ~28B — we follow the sheet
        "moonshot-v1-16b-a3b": (12e9, 30e9),
        "mamba2-1.3b": (0.9e9, 2e9),
        "zamba2-2.7b": (2e9, 4e9),
    }
    for arch, (lo, hi) in expected_params.items():
        cfg = get_config(arch)
        n = cfg.total_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of [{lo},{hi}]"
        assert cfg.active_params() <= n
