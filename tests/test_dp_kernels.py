"""Kernel equivalence: the banded, split-batched DP kernels (the default
``impl="banded"``) must reproduce the retained slow reference path
(``impl="reference"``, the seed per-cell float64 fill) exactly — same
``expected_time``, same feasibility frontier, and simulator-valid schedules —
on randomized chains with and without a host model.

The test chains have integer stage costs and dyadic host-transfer times, so
every DP quantity is exactly representable in float32 and the comparison is
bit-exact, not approximate.
"""

import math

import numpy as np
import pytest

from repro.core import dp_kernels
from repro.core.chain import Chain, HostTransferModel
from repro.core.schedule import Schedule, simulate
from repro.core.solver import _Tables, _fill_tables, solve_min_memory, solve_optimal
from repro.offload.solver import (_OffloadTables, _fill_tables_offload,
                                  solve_min_device_memory,
                                  solve_optimal_offload)

from helpers import random_chain


def _dyadic_host(rng) -> HostTransferModel:
    """Host link whose transfer times are exact in float32 (dyadic)."""
    return HostTransferModel(
        bandwidth_d2h=float(rng.choice([0.5, 1.0, 4.0])),
        latency=float(rng.choice([0.0, 0.25])))


def _budgets(ch, fracs):
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    return [float(math.ceil(peak * f)) for f in fracs]


# ---------------------------------------------------------------------------
# table-level equality
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("allow_fall", [True, False])
def test_two_tier_tables_bit_equal(seed, allow_fall):
    rng = np.random.default_rng(seed)
    ch = random_chain(rng, max_len=6)
    for m in _budgets(ch, (0.4, 0.7, 1.0)):
        S = int(m)
        dchain = ch.discretize(m, S)
        ref = _Tables(dchain.length, S)
        _fill_tables(dchain, ref, allow_fall=allow_fall)
        band = dp_kernels.fill_two_tier(dchain, S, allow_fall=allow_fall)
        L = dchain.length
        for s in range(1, L + 2):
            for t in range(s, L + 2):
                assert np.array_equal(ref.C[s, t].astype(np.float32),
                                      band.row(s, t), equal_nan=True), (s, t)


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("allow_fall", [True, False])
def test_offload_tables_bit_equal(seed, allow_fall):
    rng = np.random.default_rng(100 + seed)
    ch = random_chain(rng, max_len=5).with_host(_dyadic_host(
        np.random.default_rng(100 + seed)))
    for m in _budgets(ch, (0.3, 0.6, 1.0)):
        S = int(m)
        dchain = ch.discretize(m, S)
        ref = _OffloadTables(dchain.length, S)
        _fill_tables_offload(dchain, ref, allow_fall=allow_fall)
        tb, te = dp_kernels.fill_offload(dchain, S, allow_fall=allow_fall)
        L = dchain.length
        for s in range(1, L + 2):
            for t in range(s, L + 2):
                assert np.array_equal(ref.Cb[s, t].astype(np.float32),
                                      tb.row(s, t), equal_nan=True), (s, t)
                assert np.array_equal(ref.Ce[s, t].astype(np.float32),
                                      te.row(s, t), equal_nan=True), (s, t)


# ---------------------------------------------------------------------------
# solution-level equivalence (schedules validated by the simulator)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(10))
def test_two_tier_solutions_match_reference(seed):
    rng = np.random.default_rng(seed)
    ch = random_chain(rng, max_len=6)
    for m in _budgets(ch, (0.4, 0.7, 1.0)):
        S = int(m)
        for allow_fall in (True, False):
            b = solve_optimal(ch, m, num_slots=S, allow_fall=allow_fall,
                              cache=False)
            r = solve_optimal(ch, m, num_slots=S, allow_fall=allow_fall,
                              impl="reference", cache=False)
            assert b.feasible == r.feasible
            if not b.feasible:
                continue
            assert b.expected_time == r.expected_time
            res = simulate(ch, b.schedule, m + 1e-6)
            assert res.valid, res.error
            assert abs(res.time - b.expected_time) < 1e-12
            # the ISSUE's table-memory criterion: >= 4x smaller than the seed
            assert b.table_bytes * 4 <= r.table_bytes


@pytest.mark.parametrize("seed", range(10))
def test_offload_solutions_match_reference(seed):
    rng = np.random.default_rng(500 + seed)
    ch = random_chain(rng, max_len=5).with_host(_dyadic_host(rng))
    for m in _budgets(ch, (0.3, 0.6, 1.0)):
        S = int(m)
        b = solve_optimal_offload(ch, m, num_slots=S, cache=False)
        r = solve_optimal_offload(ch, m, num_slots=S, impl="reference",
                                  cache=False)
        assert b.feasible == r.feasible
        if not b.feasible:
            continue
        assert b.expected_time == r.expected_time
        res = simulate(ch, b.schedule, m + 1e-6)
        assert res.valid, res.error
        assert abs(res.time - b.expected_time) < 1e-12
        assert b.table_bytes * 4 <= r.table_bytes


def test_feasibility_frontier_matches_reference():
    """solve_min_memory picks the same smallest feasible slot count (the
    frontier of finite top-row entries) on both implementations."""
    for seed in range(8):
        rng = np.random.default_rng(50 + seed)
        ch = random_chain(rng, max_len=5)
        b = solve_min_memory(ch, num_slots=120, cache=False)
        r = solve_min_memory(ch, num_slots=120, impl="reference", cache=False)
        assert b.feasible == r.feasible
        if b.feasible:
            assert b.slots_used == r.slots_used
            assert b.mem_limit == r.mem_limit
            assert b.expected_time == r.expected_time


def test_min_device_memory_matches_reference():
    for seed in range(8):
        rng = np.random.default_rng(70 + seed)
        ch = random_chain(rng, max_len=5).with_host(_dyadic_host(rng))
        b = solve_min_device_memory(ch, num_slots=120, cache=False)
        r = solve_min_device_memory(ch, num_slots=120, impl="reference",
                                    cache=False)
        assert b.feasible == r.feasible
        if b.feasible:
            assert b.slots_used == r.slots_used
            assert b.mem_limit == r.mem_limit
            assert b.expected_time == r.expected_time


def test_oversized_activation_falls_back_to_gather():
    """Chains with an activation bigger than the whole budget exercise the
    capped (non-sliced) C3 path and the all-inf R rows."""
    ch = Chain.make(uf=[1.0, 1.0, 0.0], ub=[1.0, 1.0, 0.0],
                    wa=[1.0, 40.0, 1.0], wabar=[2.0, 2.0, 0.0],
                    host=HostTransferModel(bandwidth_d2h=1.0))
    # budget of 8 slots, slot size 1: WA = [1, 40, 1] — 40 > S+1
    b = solve_optimal_offload(ch, 8.0, num_slots=8, cache=False)
    r = solve_optimal_offload(ch, 8.0, num_slots=8, impl="reference",
                              cache=False)
    assert b.feasible == r.feasible
    if b.feasible:
        assert b.expected_time == r.expected_time


# ---------------------------------------------------------------------------
# saturated m-column pruning (shared by all impls) is exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("allow_fall", [True, False])
def test_pruned_fills_bit_equal_unpruned(seed, allow_fall):
    """Pruning computes each band only up to its saturation frontier and
    broadcasts the tail — the tables must stay bit-identical, for every impl
    (the *unpruned reference* is the independent oracle here)."""
    rng = np.random.default_rng(300 + seed)
    ch = random_chain(rng, max_len=6)
    for m in _budgets(ch, (0.4, 1.0)):
        S = int(m)
        dchain = ch.discretize(m, S)
        ref_off = _Tables(dchain.length, S)
        _fill_tables(dchain, ref_off, allow_fall=allow_fall, prune=False)
        ref_on = _Tables(dchain.length, S)
        _fill_tables(dchain, ref_on, allow_fall=allow_fall, prune=True)
        assert np.array_equal(ref_off.C, ref_on.C, equal_nan=True)
        off = dp_kernels.fill_two_tier(dchain, S, allow_fall=allow_fall,
                                       prune=False)
        on = dp_kernels.fill_two_tier(dchain, S, allow_fall=allow_fall,
                                      prune=True)
        assert np.array_equal(off.data, on.data, equal_nan=True)
        L = dchain.length
        for s in range(1, L + 2):
            for t in range(s, L + 2):
                assert np.array_equal(ref_off.C[s, t].astype(np.float32),
                                      on.row(s, t), equal_nan=True), (s, t)


@pytest.mark.parametrize("seed", range(6))
def test_pruned_offload_fills_bit_equal_unpruned(seed):
    rng = np.random.default_rng(400 + seed)
    ch = random_chain(rng, max_len=5).with_host(_dyadic_host(rng))
    for m in _budgets(ch, (0.3, 1.0)):
        S = int(m)
        dchain = ch.discretize(m, S)
        ref_off = _OffloadTables(dchain.length, S)
        _fill_tables_offload(dchain, ref_off, prune=False)
        ref_on = _OffloadTables(dchain.length, S)
        _fill_tables_offload(dchain, ref_on, prune=True)
        assert np.array_equal(ref_off.Cb, ref_on.Cb, equal_nan=True)
        assert np.array_equal(ref_off.Ce, ref_on.Ce, equal_nan=True)
        ob, oe = dp_kernels.fill_offload(dchain, S, prune=False)
        nb, ne = dp_kernels.fill_offload(dchain, S, prune=True)
        assert np.array_equal(ob.data, nb.data, equal_nan=True)
        assert np.array_equal(oe.data, ne.data, equal_nan=True)


def test_saturation_caps_are_monotone_and_bounded():
    rng = np.random.default_rng(5)
    ch = random_chain(rng, max_len=6)
    m = _budgets(ch, (0.5,))[0]
    S = int(m)
    dchain = ch.discretize(m, S)
    v = dp_kernels._views(dchain)
    caps = dp_kernels.saturation_caps(v, S)
    assert caps.shape == (dchain.length + 1,)
    assert (caps >= 0).all() and (caps <= S).all()
    assert (np.diff(caps) >= 0).all()   # children always saturate first


def test_banded_rebuild_matches_stored_costs():
    """The recomputed branch decisions reconstruct schedules whose simulated
    cost equals the banded table's top-cell value (float32)."""
    rng = np.random.default_rng(3)
    ch = random_chain(rng, max_len=6)
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    m = float(math.ceil(peak * 0.6))
    S = int(m)
    sol = solve_optimal(ch, m, num_slots=S, cache=False)
    if sol.feasible:
        dchain = ch.discretize(m, S)
        tab = dp_kernels.fill_two_tier(dchain, S)
        top = tab.row(1, dchain.length + 1)[sol.slots_used]
        assert np.float32(sol.expected_time) == top
