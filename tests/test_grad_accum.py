"""Gradient-accumulation microbatching: accum=K must match accum=1 (same
global batch, mean-of-token loss), and compose with the rotor remat tree."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.launch.steps import make_train_step
from repro.models.lm import StagedLM
from repro.optim.adamw import AdamWConfig, adamw_init


def _setup(arch="qwen1.5-4b", B=4, S=16):
    cfg = smoke_config(arch)
    model = StagedLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "loss_mask": jnp.ones((B, S), jnp.float32)}
    return cfg, model, params, batch


@pytest.mark.parametrize("accum", [2, 4])
def test_accum_matches_single_step(accum):
    cfg, model, params, batch = _setup()
    ocfg = AdamWConfig(lr=1e-3, clip_norm=None, weight_decay=0.0)

    f1 = jax.jit(make_train_step(model, ocfg, None, grad_accum=1))
    fk = jax.jit(make_train_step(model, ocfg, None, grad_accum=accum))
    step = jnp.zeros((), jnp.int32)
    p1, o1, m1 = f1(params, adamw_init(params), batch, step)
    pk, ok, mk = fk(params, adamw_init(params), batch, step)
    np.testing.assert_allclose(float(m1["loss"]), float(mk["loss"]),
                               rtol=1e-5)
    # Adam divides by sqrt(v): where gradients are ~1e-7 noise, the
    # normalized step direction is not robust to summation order — compare
    # post-update params at the step-size scale (lr=1e-3), not bitwise
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(pk)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-4)


def test_accum_with_rotor_tree():
    from repro.core.rematerialize import full_remat_tree
    cfg, model, params, batch = _setup()
    ocfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
    tree = full_remat_tree(model.n_stages() - 1)
    f_plain = jax.jit(make_train_step(model, ocfg, None, grad_accum=2))
    f_tree = jax.jit(make_train_step(model, ocfg, tree, grad_accum=2))
    step = jnp.zeros((), jnp.int32)
    _, _, m1 = f_plain(params, adamw_init(params), batch, step)
    _, _, m2 = f_tree(params, adamw_init(params), batch, step)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-6)
