"""Solver-cache semantics: hit/miss accounting, key sensitivity to chain
edits and solve flags, on-disk round-trips across cache instances, and the
corrupted-entry fallback to a fresh solve."""

import math
import pickle

import numpy as np
import pytest

from repro.core import dp_kernels, solver_cache
from repro.core.chain import Chain, HostTransferModel
from repro.core.schedule import Schedule, simulate
from repro.core.solver import solve_optimal
from repro.offload.solver import solve_optimal_offload

from helpers import random_chain


@pytest.fixture
def cache_dir(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_SOLVER_CACHE", raising=False)
    monkeypatch.setenv("REPRO_SOLVER_CACHE_DIR", str(tmp_path))
    solver_cache.configure()
    yield tmp_path
    # drop the singleton; the next user lazily rebuilds it from the (restored)
    # environment
    solver_cache.reset()


def _chain_and_budget(seed=0, frac=0.6):
    rng = np.random.default_rng(seed)
    ch = random_chain(rng, max_len=5)
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    return ch, float(math.ceil(peak * frac))


def test_second_solve_is_served_from_cache(cache_dir, monkeypatch):
    ch, m = _chain_and_budget()
    sol1 = solve_optimal(ch, m, num_slots=int(m))
    stats0 = solver_cache.stats()
    assert stats0["puts"] == 1 and stats0["misses"] == 1

    # a cached call must not touch the fill kernels at all
    def boom(*a, **k):
        raise AssertionError("table fill ran on a cache hit")
    monkeypatch.setattr(dp_kernels, "fill_two_tier", boom)

    sol2 = solve_optimal(ch, m, num_slots=int(m))
    stats1 = solver_cache.stats()
    assert stats1["hits"] == 1
    assert sol2.expected_time == sol1.expected_time
    assert sol2.schedule.ops == sol1.schedule.ops
    assert sol2.mem_limit == sol1.mem_limit


def test_offload_solve_cached(cache_dir, monkeypatch):
    rng = np.random.default_rng(4)
    ch = random_chain(rng, max_len=4).with_host(
        HostTransferModel(bandwidth_d2h=1.0))
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    m = float(math.ceil(peak))
    sol1 = solve_optimal_offload(ch, m, num_slots=int(m))
    assert sol1.feasible

    def boom(*a, **k):
        raise AssertionError("offload fill ran on a cache hit")
    monkeypatch.setattr(dp_kernels, "fill_offload", boom)

    sol2 = solve_optimal_offload(ch, m, num_slots=int(m))
    assert sol2.expected_time == sol1.expected_time
    assert sol2.schedule.ops == sol1.schedule.ops


def test_key_sensitivity(cache_dir):
    ch, m = _chain_and_budget(seed=1)
    S = int(m)
    solve_optimal(ch, m, num_slots=S)
    base = solver_cache.stats()["misses"]

    # a chain edit must miss
    edited = Chain.make(uf=np.asarray(ch.uf) + 0.5, ub=ch.ub, wa=ch.wa,
                        wabar=ch.wabar, of=ch.of, ob=ch.ob)
    solve_optimal(edited, m, num_slots=S)
    # allow_fall flips must miss
    solve_optimal(ch, m, num_slots=S, allow_fall=False)
    # slot-count changes must miss
    solve_optimal(ch, m, num_slots=S + 7)
    # budget changes must miss
    solve_optimal(ch, m + 1.0, num_slots=S)
    # attaching a host model must miss (offload delegates two-tier when the
    # host link is absent, so key on the host params too)
    solve_optimal(ch.with_host(HostTransferModel(bandwidth_d2h=2.0)), m,
                  num_slots=S)
    assert solver_cache.stats()["misses"] == base + 5
    # and the original still hits
    solve_optimal(ch, m, num_slots=S)
    assert solver_cache.stats()["hits"] == 1


def test_disk_roundtrip(cache_dir):
    ch, m = _chain_and_budget(seed=2)
    sol1 = solve_optimal(ch, m, num_slots=int(m))
    assert len(list(cache_dir.glob("*.pkl"))) == 1

    # a fresh cache instance (same directory): memory LRU is empty, the
    # entry must come back from disk
    solver_cache.configure()
    sol2 = solve_optimal(ch, m, num_slots=int(m))
    st = solver_cache.stats()
    assert st["disk_hits"] == 1 and st["hits"] == 1
    assert sol2.feasible == sol1.feasible
    assert sol2.expected_time == sol1.expected_time
    assert sol2.schedule.ops == sol1.schedule.ops
    assert type(sol2.tree) is type(sol1.tree)


def test_corrupted_entry_falls_back_to_fresh_solve(cache_dir):
    ch, m = _chain_and_budget(seed=3)
    sol1 = solve_optimal(ch, m, num_slots=int(m))
    [entry] = list(cache_dir.glob("*.pkl"))

    entry.write_bytes(b"not a pickle at all")
    solver_cache.configure()
    sol2 = solve_optimal(ch, m, num_slots=int(m))
    st = solver_cache.stats()
    assert st["disk_errors"] >= 1 and st["misses"] == 1
    assert sol2.expected_time == sol1.expected_time

    # header/key mismatch (a valid pickle of the wrong thing) also misses
    entry2 = list(cache_dir.glob("*.pkl"))[0]
    entry2.write_bytes(pickle.dumps(("wrong-magic", 0, "key", None)))
    solver_cache.configure()
    sol3 = solve_optimal(ch, m, num_slots=int(m))
    assert sol3.expected_time == sol1.expected_time


def test_cache_disabled_by_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_SOLVER_CACHE", "0")
    monkeypatch.setenv("REPRO_SOLVER_CACHE_DIR", str(tmp_path))
    solver_cache.configure()
    try:
        ch, m = _chain_and_budget(seed=5)
        solve_optimal(ch, m, num_slots=int(m))
        solve_optimal(ch, m, num_slots=int(m))
        st = solver_cache.stats()
        assert st["hits"] == 0 and st["puts"] == 0
        assert list(tmp_path.glob("*.pkl")) == []
    finally:
        solver_cache.reset()


def test_cache_param_bypass(cache_dir):
    """cache=False neither reads nor writes the cache (used by benchmarks)."""
    ch, m = _chain_and_budget(seed=6)
    solve_optimal(ch, m, num_slots=int(m), cache=False)
    st = solver_cache.stats()
    assert st["puts"] == 0 and st["misses"] == 0
    assert list(cache_dir.glob("*.pkl")) == []


def test_obs_counters_mirror_cache_stats(cache_dir):
    """Every stats bump lands in the process metrics registry too
    (``solver_cache.*`` counters, repro.obs.metrics)."""
    from repro.obs import metrics as obs_metrics

    obs_metrics.reset()
    ch, m = _chain_and_budget(seed=7)
    solve_optimal(ch, m, num_slots=int(m))
    assert obs_metrics.value("solver_cache.misses") == 1
    assert obs_metrics.value("solver_cache.puts") == 1
    assert obs_metrics.value("solver_cache.hits") == 0
    solve_optimal(ch, m, num_slots=int(m))
    assert obs_metrics.value("solver_cache.hits") == 1
    assert obs_metrics.value("solver_cache.misses") == 1
    # and they agree with the instance stats
    st = solver_cache.stats()
    assert obs_metrics.value("solver_cache.hits") == st["hits"]
    assert obs_metrics.value("solver_cache.misses") == st["misses"]


def test_lru_evictions_are_counted(tmp_path, monkeypatch):
    """Overflowing a capacity-2 memory LRU evicts oldest entries and counts
    each one, in both the instance stats and the obs registry."""
    from repro.obs import metrics as obs_metrics

    monkeypatch.delenv("REPRO_SOLVER_CACHE", raising=False)
    obs_metrics.reset()
    solver_cache.configure(capacity=2, directory=None)
    try:
        for seed in range(4):
            ch, m = _chain_and_budget(seed=20 + seed)
            solve_optimal(ch, m, num_slots=int(m))
        st = solver_cache.stats()
        assert st["puts"] == 4
        assert st["evictions"] == 2
        assert obs_metrics.value("solver_cache.evictions") == 2
        # the two most-recent entries survived and still hit
        for seed in (2, 3):
            ch, m = _chain_and_budget(seed=20 + seed)
            solve_optimal(ch, m, num_slots=int(m))
        st = solver_cache.stats()
        assert st["hits"] == 2
        assert st["misses"] == 4
        assert st["evictions"] == 2
    finally:
        solver_cache.reset()
