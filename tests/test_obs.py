"""Observability stack: metrics registry semantics, span tracing +
Perfetto/timeline export schemas, plan-vs-actual drift reports, and the
measure -> calibrate -> re-plan convergence loop (tentpole of repro.obs)."""

import json
import math

import numpy as np
import pytest

from repro.core import profile_stages_measured
from repro.core.chain import Chain
from repro.core.schedule import Schedule, simulate
from repro.obs import metrics
from repro.obs.drift import calibrate_from_trace, compare
from repro.obs.trace import (Tracer, category_of, measured_stage_times,
                             validate_perfetto, validate_trace_file)
from repro.plan import Budget, PlanRequest, build_plan

from helpers import make_mlp_chain


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_registry_kinds():
    reg = metrics.MetricsRegistry(enabled=True)
    c = reg.counter("c")
    c.inc()
    c.inc(5)
    assert c.count == 2 and c.total == 6.0
    g = reg.gauge("g")
    g.set(3.0)
    g.set(1.0)
    assert g.value == 1.0 and g.max == 3.0 and g.updates == 2
    h = reg.histogram("h")
    h.observe(2.0)
    h.observe(4.0)
    assert h.count == 2 and h.mean == 3.0 and h.min == 2.0 and h.max == 4.0
    with h.time():
        pass
    assert h.count == 3
    # same name, wrong kind: loud error, not silent shadowing
    with pytest.raises(TypeError):
        reg.gauge("c")
    snap = reg.snapshot()
    json.dumps(snap)  # JSON-serializable by construction
    assert snap["c"]["type"] == "counter" and snap["c"]["total"] == 6.0
    assert reg.value("c") == 2 and reg.value("g") == 1.0
    assert reg.value("absent", default=-1.0) == -1.0


def test_metrics_registry_disabled_is_noop():
    reg = metrics.MetricsRegistry(enabled=False)
    reg.counter("x").inc()
    reg.gauge("y").set(5)
    with reg.histogram("z").time():
        pass
    assert reg.snapshot() == {}
    assert reg.value("x", default=0.0) == 0.0


def test_metrics_save_roundtrip(tmp_path):
    reg = metrics.MetricsRegistry(enabled=True)
    reg.counter("a.b").inc(7)
    path = tmp_path / "metrics.json"
    reg.save(str(path))
    doc = json.loads(path.read_text())
    assert doc["a.b"]["total"] == 7.0


# ---------------------------------------------------------------------------
# tracer + exporters
# ---------------------------------------------------------------------------

def _tiny_plan(L=4, frac=0.6, seed=0):
    stages, params, x = make_mlp_chain(L, seed=seed)
    chain = profile_stages_measured(stages, params, x, repeats=1)
    plan = build_plan(PlanRequest(strategy="optimal",
                                  budget=Budget.fraction(frac),
                                  num_slots=200), chain)
    return plan, stages, params, x


def test_traced_execution_emits_one_span_per_op(tmp_path):
    plan, stages, params, x = _tiny_plan()
    tr = Tracer(name="test")
    out, grads, dx = plan.execute(stages, params, x, tracer=tr)
    assert len(tr.spans) == len(plan.schedule.ops)
    assert [s.op for s in tr.spans] == [k for k, _ in plan.schedule.ops]
    assert all(s.t_end >= s.t_start for s in tr.spans)
    assert tr.makespan > 0
    # an untraced execution returns identical gradients (tracing is
    # observability, not a different numeric path)
    out2, grads2, dx2 = plan.execute(stages, params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2))


def test_perfetto_export_is_wellformed(tmp_path):
    plan, stages, params, x = _tiny_plan()
    tr = Tracer(name="test")
    plan.execute(stages, params, x, tracer=tr)
    doc = tr.to_perfetto()
    events = validate_perfetto(doc)
    assert len(events) == len(tr.spans)
    # one metadata track per category, names resolve
    meta = [e for e in doc["traceEvents"] if e.get("ph") == "M"]
    assert {m["args"]["name"] for m in meta} >= {"forward", "backward"}
    for e in events:
        assert e["dur"] >= 0
    path = tmp_path / "trace.json"
    tr.save(str(path))
    assert validate_trace_file(str(path)) == len(tr.spans)


def test_perfetto_validation_rejects_bad_traces():
    with pytest.raises(ValueError):
        validate_perfetto({})
    with pytest.raises(ValueError):
        validate_perfetto({"traceEvents": []})
    good = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 10.0, "dur": 1.0}]}
    validate_perfetto(good)
    bad_order = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 10.0, "dur": 1.0},
        {"name": "b", "ph": "X", "pid": 1, "tid": 1, "ts": 5.0, "dur": 1.0}]}
    with pytest.raises(ValueError):
        validate_perfetto(bad_order)
    bad_dur = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 1, "tid": 1, "ts": 0.0, "dur": -1.0}]}
    with pytest.raises(ValueError):
        validate_perfetto(bad_dur)


def test_timeline_export_matches_plan_schema():
    plan, stages, params, x = _tiny_plan()
    tr = Tracer(name="test")
    plan.execute(stages, params, x, tracer=tr)
    predicted = plan.timeline()
    measured = tr.to_timeline()
    assert len(measured) == len(predicted)
    assert set(measured[0]) == set(predicted[0])
    assert [(r["op"], r["arg"]) for r in measured] \
        == [(r["op"], r["arg"]) for r in predicted]


def test_span_categories():
    assert category_of("Fall") == "forward"
    assert category_of("Fck") == "forward"
    assert category_of("B") == "backward"
    assert category_of("Foff") == "transfer"
    assert category_of("Prefetch") == "transfer"
    assert category_of("Decode") == "decode"
    assert category_of("whatever") == "misc"


def test_measured_stage_times_pools_and_nans():
    from repro.obs.trace import Span
    spans = [Span("Fall", 1, 0.0, 1.0), Span("Fck", 1, 1.0, 4.0),
             Span("B", 2, 4.0, 6.0)]
    uf, ub = measured_stage_times(spans, length=2)
    assert uf[0] == pytest.approx(2.0)     # mean of the two stage-1 samples
    assert math.isnan(uf[1]) and math.isnan(uf[2])
    assert ub[1] == pytest.approx(2.0)
    assert math.isnan(ub[0]) and math.isnan(ub[2])


# ---------------------------------------------------------------------------
# drift: compare / replay / calibrate
# ---------------------------------------------------------------------------

def test_zero_drift_on_simulator_replay():
    """Replaying the plan's own predicted timeline through compare() must
    report a ratio of exactly 1 — the simulator agrees with itself."""
    plan, *_ = _tiny_plan()
    sim = Tracer.from_timeline(plan.timeline())
    report = plan.drift(sim)
    assert report.makespan_ratio == pytest.approx(1.0, abs=1e-9)
    assert report.layer_mape == pytest.approx(0.0, abs=1e-6)
    assert report.span_count == len(plan.schedule.ops)
    json.dumps(report.to_json())
    assert "DriftReport" in report.summary()


def test_chain_calibrate_roundtrip_and_validation():
    rng = np.random.default_rng(0)
    n = 5
    ch = Chain.make(uf=rng.uniform(1, 2, n), ub=rng.uniform(1, 2, n),
                    wa=np.ones(n), wabar=np.ones(n))
    # calibrating with the chain's own times is the identity
    same = ch.calibrate(uf=ch.uf, ub=ch.ub)
    np.testing.assert_allclose(same.uf, ch.uf)
    np.testing.assert_allclose(same.ub, ch.ub)
    # NaN entries keep the modeled value
    uf = np.full(n, np.nan)
    uf[2] = 9.0
    cal = ch.calibrate(uf=uf)
    assert cal.uf[2] == pytest.approx(9.0)
    np.testing.assert_allclose(np.delete(cal.uf, 2), np.delete(ch.uf, 2))
    np.testing.assert_allclose(cal.ub, ch.ub)
    # blend interpolates model -> measurement
    half = ch.calibrate(uf=np.full(n, 3.0), blend=0.5)
    np.testing.assert_allclose(half.uf, (np.asarray(ch.uf) + 3.0) / 2)
    with pytest.raises(ValueError):
        ch.calibrate(uf=ch.uf, blend=1.5)
    with pytest.raises(ValueError):
        ch.calibrate(uf=np.ones(n - 1))
    with pytest.raises(ValueError):
        ch.calibrate(ub=np.full(n, -1.0))


def test_calibration_closes_drift_on_perturbed_chain():
    """Plan on a mispriced chain, 'measure' by simulating the schedule on
    the true chain, calibrate, re-plan: the drift must close exactly (the
    simulator sums per-op costs, and calibration recovers them all)."""
    rng = np.random.default_rng(7)
    n = 7
    true = Chain.make(uf=rng.uniform(1, 3, n), ub=rng.uniform(2, 5, n),
                      wa=rng.integers(1, 4, n).astype(float),
                      wabar=rng.integers(1, 6, n).astype(float))
    wrong = Chain.make(uf=np.asarray(true.uf) * 3.0,
                       ub=np.asarray(true.ub) * 0.4,
                       wa=true.wa, wabar=true.wabar)
    peak = simulate(wrong, Schedule.store_all(wrong.length)).peak_mem
    req = PlanRequest(strategy="optimal", budget=Budget.bytes(peak * 0.6),
                      num_slots=200)
    plan = build_plan(req, wrong)

    def measure(p):
        rows = []
        res = simulate(true, p.schedule, trace=rows)
        assert res.valid
        return Tracer.from_timeline(rows, name="measured")

    before = compare(plan, measure(plan))
    err_before = abs(before.makespan_ratio - 1.0)
    assert err_before > 0.2  # the misprice is visible

    calibrated = calibrate_from_trace(plan.chain, measure(plan))
    np.testing.assert_allclose(calibrated.uf, true.uf, rtol=1e-12)
    np.testing.assert_allclose(calibrated.ub, true.ub, rtol=1e-12)
    plan2 = build_plan(req, calibrated)
    after = compare(plan2, measure(plan2))
    err_after = abs(after.makespan_ratio - 1.0)
    assert err_after < 1e-9
    assert err_after < err_before


def test_partial_trace_calibrates_only_sampled_stages():
    rng = np.random.default_rng(1)
    n = 4
    ch = Chain.make(uf=rng.uniform(1, 2, n), ub=rng.uniform(1, 2, n),
                    wa=np.ones(n), wabar=np.ones(n))
    from repro.obs.trace import Span
    spans = [Span("Fall", 1, 0.0, 5.0)]   # only stage 1's forward sampled
    cal = calibrate_from_trace(ch, spans)
    assert cal.uf[0] == pytest.approx(5.0)
    np.testing.assert_allclose(cal.uf[1:], np.asarray(ch.uf)[1:])
    np.testing.assert_allclose(cal.ub, ch.ub)


# ---------------------------------------------------------------------------
# runtime instrumentation
# ---------------------------------------------------------------------------

def test_serve_loop_traces_decode_spans():
    import jax
    from repro.configs import smoke_config
    from repro.models.lm import StagedLM
    from repro.runtime.serve_loop import ServeLoopConfig, run_serving

    cfg = smoke_config("qwen1.5-4b")
    model = StagedLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.arange(8, dtype=np.int32).reshape(2, 4) % cfg.vocab_size
    metrics.reset()
    tr = Tracer(name="serve")
    out = run_serving(cfg, params, prompts,
                      ServeLoopConfig(max_new_tokens=5, max_len=16),
                      model=model, tracer=tr)
    decodes = [s for s in tr.spans if s.op == "Decode"]
    assert len(decodes) == 4                       # max_new_tokens - 1
    assert [s.arg for s in decodes] == [1, 2, 3, 4]
    # each span reports the *logical* residency at that step: prompt tokens
    # plus the tokens decoded so far — strictly increasing, not the padded
    # allocation, ending at the run's reported kv_bytes
    span_bytes = [s.bytes for s in decodes]
    assert span_bytes == sorted(span_bytes) and len(set(span_bytes)) == 4
    assert span_bytes[-1] == out["kv_bytes"]
    steps = [s for s in tr.spans if s.op == "Step"]
    assert len(steps) == 1                         # the prefill
    assert steps[0].t_start >= 0                   # same clock as t_end
    assert metrics.value("serve.kv_bytes") == out["kv_bytes"] > 0
    assert (metrics.value("serve.kv_bytes_allocated")
            == out["kv_bytes_allocated"] > out["kv_bytes"])
    # no EOS configured: every decoded token is live (B=2, 4 decode steps)
    assert metrics.counter("serve.decode_tokens").value == 8
    assert out["decode_tokens"] == 8
    validate_perfetto(tr.to_perfetto())


# ---------------------------------------------------------------------------
# acceptance: measured execution -> calibrate -> re-plan convergence
# ---------------------------------------------------------------------------

def test_calibration_converges_on_executed_plan():
    """One calibrate pass from a *measured* trace brings the re-planned
    predicted makespan close to the measured one.  Tolerance is generous:
    this runs on shared CPU runners where per-op wall times wobble; the
    exact numbers live in BENCH_solver.json's prediction section."""
    plan, stages, params, x = _tiny_plan(L=4, frac=0.7, seed=3)

    def measure(p):
        p.execute(stages, params, x)          # warm jit/vjp caches
        tr = Tracer(name="acceptance")
        p.execute(stages, params, x, tracer=tr)
        return tr

    trace = measure(plan)
    calibrated = calibrate_from_trace(plan.chain, trace)
    plan2 = build_plan(PlanRequest(strategy="optimal",
                                   budget=Budget.fraction(0.7),
                                   num_slots=200), calibrated)
    after = compare(plan2, measure(plan2))
    # generous CPU-CI band around predicted == measured
    assert 1 / 2.0 < after.makespan_ratio < 2.0
    # and the drift did not get worse than the uncalibrated prediction
    before = compare(plan, trace)
    err_before = abs(math.log(before.makespan_ratio))
    err_after = abs(math.log(after.makespan_ratio))
    assert err_after <= err_before + math.log(1.5)
