"""Serving-path tests: KV-cache byte accounting (logical vs allocated),
live-token decode counters under EOS, span clock sanity, and the planned
KV-residency policy against the naive LRU baseline."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.lm import CacheLayout, StagedLM
from repro.runtime.serve_loop import ServeLoopConfig, run_serving


# ---------------------------------------------------------------------------
# cache layout accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen1.5-4b", "deepseek-v2-lite-16b", "zamba2-2.7b"])
def test_cache_layout_accounts_for_every_byte(arch):
    """logical_bytes(max_len) is exactly the allocation, every non-``pos``
    byte is attributed to exactly one layer block, and logical residency
    grows linearly in ``pos`` (attention KV) from the static floor
    (recurrent state has no sequence axis)."""
    cfg = smoke_config(arch)
    if cfg.modality != "text":
        cfg = dataclasses.replace(cfg, modality="text")
    layout = StagedLM(cfg).cache_layout(2, 12)
    assert len(layout.block_bytes) == cfg.num_layers
    assert layout.logical_bytes(layout.max_len) == layout.allocated_bytes
    pos_bytes = 4  # the int32 position scalar, the only un-attributed leaf
    assert sum(layout.block_bytes) + pos_bytes == layout.allocated_bytes
    assert layout.logical_bytes(0) == layout.static_bytes
    assert layout.logical_bytes(5) == layout.static_bytes + 5 * layout.token_bytes


def test_cache_layout_recurrent_state_is_static():
    """A pure-SSM arch holds conv/ssm state only: residency must not grow
    with ``pos`` at all."""
    cfg = smoke_config("mamba2-1.3b")
    layout = StagedLM(cfg).cache_layout(2, 12)
    assert layout.token_bytes == 0
    assert layout.logical_bytes(0) == layout.logical_bytes(12)


# ---------------------------------------------------------------------------
# telemetry fixes: a scripted model with controllable EOS timing
# ---------------------------------------------------------------------------


class _ScriptedLM:
    """Serve-loop stand-in: decode step ``k`` (0-based) emits ``eos_id`` for
    sequence ``b`` once ``k >= finish[b]``, token 7 before that; prefill
    emits token 5.  Jittable, with a minimal {pos, step} cache."""

    eos_id = 3
    vocab = 10

    def __init__(self, finish):
        self.cfg = None
        self.finish = jnp.asarray(finish, jnp.int32)

    def cache_layout(self, batch, max_len):
        return CacheLayout(
            block_bytes=(128, 128),
            token_bytes=16 * batch,
            static_bytes=4,
            allocated_bytes=4 + 16 * batch * max_len,
            max_len=max_len,
        )

    def prefill(self, params, batch, max_len=None):
        tokens = batch["tokens"]
        B, S0 = tokens.shape
        logits = jnp.zeros((B, S0, self.vocab)).at[:, :, 5].set(1.0)
        cache = {"pos": jnp.asarray(S0, jnp.int32), "step": jnp.zeros((), jnp.int32)}
        return logits, cache

    def decode_step(self, params, cache, tokens):
        B = tokens.shape[0]
        tok = jnp.where(cache["step"] >= self.finish, self.eos_id, 7)
        logits = jnp.zeros((B, 1, self.vocab))
        logits = logits.at[jnp.arange(B), 0, tok].set(1.0)
        return logits, {"pos": cache["pos"] + 1, "step": cache["step"] + 1}


def test_decode_token_counter_skips_padding_after_eos():
    """seq 0 finishes on the first decode step, seq 1 on the third: only the
    4 live tokens count, not the padding the finished slot keeps decoding
    (the old counter charged B * steps = 6)."""
    from repro.obs import metrics

    model = _ScriptedLM(finish=(0, 2))
    prompts = np.zeros((2, 4), np.int32)
    loop = ServeLoopConfig(max_new_tokens=6, max_len=16, eos_id=3)
    metrics.reset()
    out = run_serving(None, None, prompts, loop, model=model)
    assert out["generations"].shape == (2, 4)  # prefill token + 3 steps
    assert out["decode_tokens"] == 4
    assert metrics.counter("serve.decode_tokens").value == 4
    assert out["decode_tokens_per_s"] > 0


def test_decode_token_counter_counts_the_eos_itself():
    """The EOS a live sequence emits is real output; only tokens *after* it
    are padding."""
    model = _ScriptedLM(finish=(1, 1))
    prompts = np.zeros((2, 4), np.int32)
    loop = ServeLoopConfig(max_new_tokens=5, max_len=16, eos_id=3)
    out = run_serving(None, None, prompts, loop, model=model)
    # step 0 emits 7,7 (live); step 1 emits eos,eos (live) -> all done
    assert out["decode_tokens"] == 4
    assert out["generations"].shape == (2, 3)


def test_logical_kv_gauge_tracks_pos_not_allocation():
    """The kv_bytes gauge and Decode spans report what the cache holds
    (static + pos * per-token), not the padded max_len allocation."""
    from repro.obs import metrics
    from repro.obs.trace import Tracer

    model = _ScriptedLM(finish=(99, 99))
    prompts = np.zeros((2, 4), np.int32)
    loop = ServeLoopConfig(max_new_tokens=4, max_len=16)
    layout = model.cache_layout(2, 16)
    metrics.reset()
    tr = Tracer(name="serve")
    out = run_serving(None, None, prompts, loop, model=model, tracer=tr)
    spans = [s for s in tr.spans if s.op == "Decode"]
    assert [s.bytes for s in spans] == [layout.logical_bytes(p) for p in (5, 6, 7)]
    assert out["kv_bytes"] == layout.logical_bytes(7)
    assert out["kv_bytes_allocated"] == layout.allocated_bytes
    assert metrics.value("serve.kv_bytes") == out["kv_bytes"]
    assert metrics.value("serve.kv_bytes_allocated") == layout.allocated_bytes
    assert out["kv_bytes"] < out["kv_bytes_allocated"]


def test_spans_share_one_clock():
    """Prefill Step span endpoints both come from the tracer clock — the old
    mixed perf_counter/tracer arithmetic pushed t_start negative whenever
    prefill (jit compile included) outlasted the tracer epoch offset."""
    from repro.obs.trace import Tracer

    model = _ScriptedLM(finish=(99,))
    tr = Tracer(name="serve")
    run_serving(
        None,
        None,
        np.zeros((1, 4), np.int32),
        ServeLoopConfig(max_new_tokens=3, max_len=16),
        model=model,
        tracer=tr,
    )
    for s in tr.spans:
        assert 0 <= s.t_start <= s.t_end


def test_prompt_overflow_raises_value_error():
    model = _ScriptedLM(finish=(99,))
    with pytest.raises(ValueError, match="max_len"):
        run_serving(
            None,
            None,
            np.zeros((1, 8), np.int32),
            ServeLoopConfig(max_new_tokens=10, max_len=16),
            model=model,
        )


# ---------------------------------------------------------------------------
# the planned KV-residency policy
# ---------------------------------------------------------------------------


def test_kv_tier_is_registered():
    from repro.plan import available_solvers

    assert "device+kv" in available_solvers()


def test_kv_residency_layers_clamp():
    """At budgets >= the full cache the staged set must be empty (nothing to
    move, planned ties LRU); below it the executable set must actually fit
    resident-remainder + one in-flight block under the budget."""
    from repro.plan import kv_residency_layers, plan_serving

    cfg = smoke_config("qwen1.5-4b")
    layout = StagedLM(cfg).cache_layout(2, 14)
    total = sum(layout.block_bytes)
    roomy = plan_serving(cfg, 2.0 * total, batch=2, prompt_len=8, max_len=14)
    assert kv_residency_layers(roomy, budget_bytes=2.0 * total) == []
    tight = plan_serving(cfg, 0.5 * total, batch=2, prompt_len=8, max_len=14)
    layers = kv_residency_layers(tight, budget_bytes=0.5 * total)
    assert layers
    blocks = layout.block_bytes
    resident = total - sum(blocks[j] for j in layers)
    assert resident + max(blocks[j] for j in layers) <= 0.5 * total


def test_planned_beats_naive_lru_and_preserves_generations():
    """The tentpole acceptance at one budget point: a verified kv plan,
    token-identical generations under planned / naive / unconstrained
    serving, and planned transfer traffic no worse than the LRU baseline."""
    from repro.plan import plan_serving

    cfg = smoke_config("qwen1.5-4b")
    model = StagedLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8))
    prompts = prompts.astype(np.int32)
    loop = ServeLoopConfig(max_new_tokens=6, max_len=14)
    layout = model.cache_layout(2, 14)
    budget = 0.5 * sum(layout.block_bytes)

    plan = plan_serving(cfg, budget, batch=2, prompt_len=8, max_len=14)
    assert plan.verify().ok

    base = run_serving(cfg, params, prompts, loop, model=model)
    planned = run_serving(
        cfg, params, prompts, loop, model=model, plan=plan, kv_budget=budget
    )
    naive = run_serving(
        cfg, params, prompts, loop, model=model, kv_policy="lru", kv_budget=budget
    )
    np.testing.assert_array_equal(planned["generations"], base["generations"])
    np.testing.assert_array_equal(naive["generations"], base["generations"])
    assert planned["kv_host_layers"]
    assert planned["kv_policy"] == "planned"
    assert naive["kv_policy"] == "lru"
    assert 0 < planned["kv_transfer_bytes"] <= naive["kv_transfer_bytes"]
    assert naive["kv_stall_s"] > 0  # demand misses stall the naive cache


def test_lru_policy_requires_budget():
    cfg = smoke_config("qwen1.5-4b")
    model = StagedLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.zeros((1, 4), np.int32)
    with pytest.raises(ValueError, match="kv_budget"):
        run_serving(
            cfg,
            params,
            prompts,
            ServeLoopConfig(max_new_tokens=3, max_len=8),
            model=model,
            kv_policy="lru",
        )
