"""repro.store tests: backend semantics, codec tamper-evidence, URI/env
resolution, and cross-process atomicity of the shared directory backend.

The multiprocess test is the dynamic side of the "never serve a torn
entry" claim: concurrent spawn-context writers hammer one key while
readers decode everything they see — a non-atomic write (plain
``open(...).write``) fails it reliably.
"""

import multiprocessing
import os

import pytest

from repro.store import (
    CorruptEntryError,
    LocalDirectoryBackend,
    MemoryBackend,
    ObjectStore,
    QUARANTINE_DIR,
    SharedDirectoryBackend,
    StoreError,
    decode,
    encode,
    from_uri,
    resolve_settings,
    validate_key,
)

# -- keys --------------------------------------------------------------------


def test_validate_key_accepts_namespaced_keys():
    assert validate_key("plans/tenant-a/abc.def.123") is not None


@pytest.mark.parametrize(
    "key", ["", "bad key", "a//b", "seg__ment/x", "a/:b", "../escape"]
)
def test_validate_key_rejects_unportable_keys(key):
    with pytest.raises(StoreError):
        validate_key(key)


# -- codec -------------------------------------------------------------------


def test_codec_roundtrip():
    data = encode("object", "ns/k", {"x": 1, "y": [1.5, None]})
    kind, key, obj = decode(data, kind="object", key="ns/k")
    assert (kind, key) == ("object", "ns/k")
    assert obj == {"x": 1, "y": [1.5, None]}


def test_codec_detects_byte_tamper():
    data = bytearray(encode("object", "k", {"payload": "x" * 256}))
    data[len(data) // 2] ^= 0xFF
    with pytest.raises(CorruptEntryError):
        decode(bytes(data), key="k")


def test_codec_detects_truncation():
    data = encode("object", "k", {"payload": "x" * 256})
    with pytest.raises(CorruptEntryError):
        decode(data[: len(data) // 2], key="k")


def test_codec_rejects_wrong_kind_and_key():
    data = encode("object", "k", 42)
    with pytest.raises(CorruptEntryError):
        decode(data, kind="frontier", key="k")
    with pytest.raises(CorruptEntryError):
        decode(data, kind="object", key="other")


def test_codec_rejects_foreign_bytes():
    with pytest.raises(CorruptEntryError):
        decode(b"not an envelope at all")


# -- backends ----------------------------------------------------------------


def test_memory_backend_lru_eviction():
    b = MemoryBackend(capacity=2)
    b.put("ns/a", b"1")
    b.put("ns/b", b"2")
    assert b.get("ns/a") == b"1"  # refresh a
    b.put("ns/c", b"3")  # evicts b
    assert b.get("ns/b") is None
    assert b.get("ns/a") == b"1" and b.get("ns/c") == b"3"
    assert sorted(b.keys("ns")) == ["ns/a", "ns/c"]


def test_directory_backend_roundtrip_and_layout(tmp_path):
    b = LocalDirectoryBackend(tmp_path)
    b.put("plans/tenant-a/k1", b"payload")
    assert b.get("plans/tenant-a/k1") == b"payload"
    # '/' flattens to '__' in filenames so namespaces survive one flat dir
    (entry,) = [p for p in os.listdir(tmp_path) if p.endswith(".pkl")]
    assert entry == "plans__tenant-a__k1.pkl"
    assert b.keys("plans/tenant-a") == ["plans/tenant-a/k1"]
    assert b.delete("plans/tenant-a/k1")
    assert b.get("plans/tenant-a/k1") is None


def test_directory_backend_prunes_oldest(tmp_path):
    b = LocalDirectoryBackend(tmp_path, max_entries=2)
    for i in range(4):
        b.put(f"ns/k{i}", bytes([i]))
        os.utime(
            tmp_path / f"ns__k{i}.pkl", (1_000_000 + i, 1_000_000 + i)
        )
    b.put("ns/k4", b"\x04")
    names = sorted(p for p in os.listdir(tmp_path) if p.endswith(".pkl"))
    assert len(names) <= 2
    assert "ns__k4.pkl" in names  # newest survives


def test_quarantine_moves_entry_aside(tmp_path):
    b = LocalDirectoryBackend(tmp_path)
    b.put("ns/bad", b"zzz")
    assert b.quarantine("ns/bad")
    assert b.get("ns/bad") is None
    qdir = tmp_path / QUARANTINE_DIR
    assert len(list(qdir.iterdir())) == 1


def test_object_store_quarantines_corrupt_entries(tmp_path):
    b = LocalDirectoryBackend(tmp_path)
    store = ObjectStore(b, name="store")
    store.put("ns/k", {"fine": True})
    # corrupt the bytes behind the store's back
    (tmp_path / "ns__k.pkl").write_bytes(b"garbage")
    assert store.get("ns/k") is None
    assert store.stats()["corrupt"] == 1
    assert (tmp_path / QUARANTINE_DIR).exists()
    # quarantined: the next read is a plain miss, not another corruption
    assert store.get("ns/k") is None
    assert store.stats()["corrupt"] == 1


# -- URI / env resolution ----------------------------------------------------


def test_from_uri_schemes(tmp_path):
    assert isinstance(from_uri("memory://"), MemoryBackend)
    local = from_uri(f"file://{tmp_path}/sub")
    assert isinstance(local, LocalDirectoryBackend)
    shared = from_uri(f"shared://{tmp_path}/sub2")
    assert isinstance(shared, SharedDirectoryBackend)
    bare = from_uri(str(tmp_path / "sub3"))
    assert isinstance(bare, LocalDirectoryBackend)
    with pytest.raises(StoreError):
        from_uri("s3://nope")


def test_repro_store_env(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_STORE", f"file://{tmp_path}")
    s = resolve_settings()
    assert s.enabled and s.uri == f"file://{tmp_path}"
    monkeypatch.setenv("REPRO_STORE", "off")
    assert not resolve_settings().enabled


def test_legacy_env_mapped_with_deprecation(monkeypatch, tmp_path):
    monkeypatch.delenv("REPRO_STORE", raising=False)
    monkeypatch.setenv("REPRO_SOLVER_CACHE_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_SOLVER_CACHE_SIZE", "7")
    with pytest.warns(DeprecationWarning, match="REPRO_SOLVER_CACHE_DIR"):
        s = resolve_settings()
    assert s.enabled and s.uri == f"file://{tmp_path}"
    assert s.mem_entries == 7
    monkeypatch.setenv("REPRO_SOLVER_CACHE", "0")
    with pytest.warns(DeprecationWarning, match="REPRO_SOLVER_CACHE"):
        assert not resolve_settings().enabled


def test_repro_store_wins_over_legacy(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_STORE", "memory://")
    monkeypatch.setenv("REPRO_SOLVER_CACHE_DIR", str(tmp_path))
    s = resolve_settings()
    assert s.enabled and s.uri == "memory://"


# -- cross-process atomicity -------------------------------------------------

_N_WRITES = 40
_BLOB = b"x" * 8192


def _writer(dirpath: str, wid: int) -> None:
    from repro.store.backend import SharedDirectoryBackend
    from repro.store.codec import encode

    b = SharedDirectoryBackend(dirpath)
    for i in range(_N_WRITES):
        payload = {"writer": wid, "i": i, "blob": _BLOB}
        b.put("ns/contended", encode("object", "ns/contended", payload))


def _reader(dirpath: str, queue) -> None:
    from repro.store.backend import SharedDirectoryBackend
    from repro.store.codec import CorruptEntryError, decode

    b = SharedDirectoryBackend(dirpath)
    seen, torn = 0, 0
    for _ in range(3 * _N_WRITES):
        data = b.get("ns/contended")
        if data is None:
            continue
        try:
            decode(data, key="ns/contended")
            seen += 1
        except CorruptEntryError:
            torn += 1
    queue.put((seen, torn))


@pytest.mark.slow
def test_shared_backend_concurrent_writers_never_torn(tmp_path):
    ctx = multiprocessing.get_context("spawn")
    queue = ctx.Queue()
    writers = [
        ctx.Process(target=_writer, args=(str(tmp_path), w)) for w in range(3)
    ]
    readers = [
        ctx.Process(target=_reader, args=(str(tmp_path), queue))
        for _ in range(2)
    ]
    for p in writers + readers:
        p.start()
    for p in writers + readers:
        p.join(timeout=120)
        assert p.exitcode == 0
    total_seen, total_torn = 0, 0
    for _ in readers:
        seen, torn = queue.get(timeout=10)
        total_seen += seen
        total_torn += torn
    assert total_torn == 0, f"{total_torn} torn reads"
    assert total_seen > 0
    # and the final entry decodes
    b = SharedDirectoryBackend(str(tmp_path))
    _, _, obj = decode(b.get("ns/contended"), key="ns/contended")
    assert obj["i"] == _N_WRITES - 1
