"""Substrate tests: checkpoint manager (atomic/async/keep-k/torn-write
fallback/elastic), data pipeline determinism, optimizer, schedules, fault
tolerance logic, gradient compression math."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import SyntheticLMData
from repro.configs import smoke_config
from repro.distributed.compression import compressed_psum_mean, ef_init
from repro.distributed.fault_tolerance import (HeartbeatRegistry,
                                               StragglerWatchdog,
                                               elastic_plan)
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedules import linear_warmup_cosine


# -- checkpoint manager -------------------------------------------------------

def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,))},
            "step": jnp.asarray(7, jnp.int32)}


def test_ckpt_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    st = _state()
    m.save(7, st)
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    step, restored = m.restore(target)
    assert step == 7
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_async_and_keep(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, _state(s), blocking=False)
    m.wait()
    assert m.all_steps() == [3, 4]


def test_ckpt_torn_write_fallback(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(1, _state(1))
    m.save(2, _state(2))
    # corrupt the newest checkpoint (simulated torn write on a failed node)
    d = os.path.join(str(tmp_path), "step_00000002")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(d, victim))
    np.save(os.path.join(d, victim), arr + 1.0)  # crc mismatch
    st = _state(1)
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    step, restored = m.restore(target)
    assert step == 1  # fell back past the corrupted step 2
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ckpt_atomic_no_partial_dir(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(5, _state())
    assert not any(n.endswith(".tmp") for n in os.listdir(str(tmp_path)))


# -- data pipeline --------------------------------------------------------------

def test_data_deterministic_resume():
    cfg = smoke_config("qwen1.5-4b")
    d1 = SyntheticLMData(cfg, global_batch=4, seq_len=32, seed=1)
    d2 = SyntheticLMData(cfg, global_batch=4, seq_len=32, seed=1)
    b5 = d1.batch_at(5)
    b5b = d2.batch_at(5)
    for k in b5:
        np.testing.assert_array_equal(b5[k], b5b[k])
    # restart-from-step yields the identical stream (fault tolerance)
    d2.start(from_step=5)
    nxt = d2.next()
    d2.stop()
    for k in b5:
        np.testing.assert_array_equal(b5[k], nxt[k])


def test_data_host_sharding():
    cfg = smoke_config("qwen1.5-4b")
    full = SyntheticLMData(cfg, global_batch=4, seq_len=16, seed=3,
                           host_index=0, host_count=1)
    h0 = SyntheticLMData(cfg, global_batch=4, seq_len=16, seed=3,
                         host_index=0, host_count=2)
    h1 = SyntheticLMData(cfg, global_batch=4, seq_len=16, seed=3,
                         host_index=1, host_count=2)
    assert h0.batch_at(0)["tokens"].shape[0] == 2
    # different hosts generate different (independent) slices
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])


def test_labels_are_next_tokens():
    cfg = smoke_config("qwen1.5-4b")
    b = SyntheticLMData(cfg, 2, 16, seed=0).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# -- optimizer -------------------------------------------------------------------

def test_adamw_matches_reference_formula():
    p = {"w": jnp.ones((3,)) * 2.0}
    g = {"w": jnp.asarray([0.1, -0.2, 0.3])}
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      clip_norm=None)
    st = adamw_init(p)
    p2, st2, _ = adamw_update(cfg, g, st, p)
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    step = (m / 0.1) / (np.sqrt(v / 0.001) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), 2.0 - 1e-2 * step,
                               rtol=1e-6)


def test_adamw_clipping():
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    _, _, metrics = adamw_update(cfg, g, adamw_init(p), p)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_warmup_cosine_shape():
    fn = linear_warmup_cosine(1.0, warmup=10, total_steps=100)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert float(fn(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-2)
    assert float(fn(jnp.asarray(100))) <= 0.2


# -- fault tolerance ---------------------------------------------------------------

def test_straggler_watchdog_fake_clock():
    t = [0.0]
    wd = StragglerWatchdog(threshold=2.0, max_flags=2, clock=lambda: t[0])

    def run_step(dur, step):
        wd.step_begin()
        t[0] += dur
        return wd.step_end(step)

    for i in range(8):
        assert run_step(1.0, i) is None
    ev = run_step(5.0, 8)
    assert ev is not None and ev.duration == 5.0
    assert not wd.should_restart
    run_step(5.0, 9)
    assert wd.should_restart


def test_heartbeats():
    t = [0.0]
    hb = HeartbeatRegistry(hosts=3, timeout=10.0, clock=lambda: t[0])
    t[0] = 5.0
    hb.beat(0)
    hb.beat(1)
    t[0] = 12.0
    assert hb.dead() == [2]


def test_elastic_plan():
    shape, axes, accum = elastic_plan(256, model_parallel=16, global_batch=256)
    assert shape == (16, 16)
    # lose a host (8 chips): data axis shrinks to a divisor of the batch
    shape2, _, _ = elastic_plan(248, model_parallel=16, global_batch=256)
    assert shape2[1] == 16 and shape2[0] <= 15 and 256 % shape2[0] == 0
    with pytest.raises(ValueError):
        elastic_plan(8, model_parallel=16, global_batch=64)


# -- gradient compression ------------------------------------------------------------

def test_compression_error_feedback_single_member():
    g = {"w": jnp.asarray([0.013, -0.27, 3.1, 0.0])}
    e = ef_init(g)
    out, e2 = compressed_psum_mean(g, e, axes=(), n_members=1)
    # value is quantized...
    assert not np.allclose(np.asarray(out["w"]), np.asarray(g["w"]), atol=0)
    # ...but error feedback captures exactly what was dropped
    recon = np.asarray(out["w"]) + np.asarray(e2["w"])
    np.testing.assert_allclose(recon, np.asarray(g["w"]), rtol=1e-6, atol=1e-7)


def test_compression_accumulated_error_bounded():
    rng = np.random.default_rng(0)
    g_seq = rng.standard_normal((50, 16)).astype(np.float32)
    e = {"w": jnp.zeros((16,))}
    total_true = np.zeros(16)
    total_sent = np.zeros(16)
    for g in g_seq:
        out, e = compressed_psum_mean({"w": jnp.asarray(g)}, e, axes=(),
                                      n_members=1)
        total_true += g
        total_sent += np.asarray(out["w"])
    # error feedback keeps the cumulative drift to one quantization step
    drift = np.abs(total_true - total_sent).max()
    scale = np.abs(g_seq).max() / 127.0
    assert drift <= 2 * scale + 1e-6
