"""The three-tier (host-offload) subsystem: DP-vs-simulator exactness,
dominance over the two-tier optimum, feasibility below the two-tier memory
floor, real-array gradient equivalence, and the host pool's accounting."""

import math

import numpy as np
import pytest

from repro.core.chain import Chain, HostTransferModel
from repro.core.schedule import Schedule, simulate
from repro.core.solver import solve_min_memory, solve_optimal
from repro.offload.host_buffer import HostBuffer
from repro.offload.solver import (solve_min_device_memory,
                                  solve_optimal_offload, tree_to_schedule,
                                  tree_uses_offload)

from helpers import make_mlp_chain, random_chain, tree_allclose


def _hosted_chain(rng, max_len=5) -> Chain:
    ch = random_chain(rng, max_len=max_len)
    host = HostTransferModel(
        bandwidth_d2h=float(rng.choice([0.5, 1.0, 4.0, 100.0])),
        latency=float(rng.choice([0.0, 0.3])))
    return ch.with_host(host)


@pytest.mark.parametrize("seed", range(10))
def test_simulator_matches_dp_makespan(seed):
    """The offload DP's predicted makespan is exactly the simulator's, and
    the rebuilt tree round-trips to the same schedule semantics."""
    rng = np.random.default_rng(seed)
    ch = _hosted_chain(rng)
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    for frac in (0.3, 0.5, 0.75, 1.0):
        m = float(math.ceil(peak * frac))
        sol = solve_optimal_offload(ch, m, num_slots=int(m))
        if not sol.feasible:
            continue
        res = simulate(ch, sol.schedule, m + 1e-6)
        assert res.valid, res.error
        assert abs(res.time - sol.expected_time) < 1e-9
        res2 = simulate(ch, tree_to_schedule(sol.tree, ch.length), m + 1e-6)
        assert res2.valid and abs(res2.time - res.time) < 1e-9


@pytest.mark.parametrize("seed", range(10))
def test_offload_never_slower_than_two_tier(seed):
    """Dominance: at equal device budget the three-tier optimum is at least
    as fast as the two-tier optimum (its branch set is a superset)."""
    rng = np.random.default_rng(100 + seed)
    ch = _hosted_chain(rng)
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    for frac in (0.3, 0.5, 0.75, 1.0):
        m = float(math.ceil(peak * frac))
        two = solve_optimal(ch, m, num_slots=int(m))
        three = solve_optimal_offload(ch, m, num_slots=int(m))
        if two.feasible:
            assert three.feasible
            assert three.expected_time <= two.expected_time + 1e-9


def test_feasible_below_two_tier_floor():
    """With a fast host link, the device floor drops below the two-tier
    ``solve_min_memory`` floor, and the sub-floor schedule simulates validly
    within its reported device budget."""
    lowered = 0
    for seed in range(12):
        rng = np.random.default_rng(200 + seed)
        ch = random_chain(rng, max_len=5).with_host(
            HostTransferModel(bandwidth_d2h=100.0))
        f2 = solve_min_memory(ch, num_slots=200)
        f3 = solve_min_device_memory(ch, num_slots=200)
        assert f3.feasible
        assert f3.mem_limit <= f2.mem_limit + 1e-9
        if f3.mem_limit < f2.mem_limit - 1e-9:
            lowered += 1
            res = simulate(ch, f3.schedule, f3.mem_limit * (1 + 1e-6))
            assert res.valid, res.error
            assert tree_uses_offload(f3.tree)
            assert res.host_peak_mem > 0
    assert lowered >= 6, f"floor lowered on only {lowered}/12 chains"


def test_zero_bandwidth_falls_back_to_two_tier():
    rng = np.random.default_rng(7)
    ch = random_chain(rng, max_len=4)
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    m = float(math.ceil(peak * 0.6))
    two = solve_optimal(ch, m, num_slots=int(m))
    # no host model at all
    sol = solve_optimal_offload(ch, m, num_slots=int(m))
    assert sol.feasible == two.feasible
    if two.feasible:
        assert abs(sol.expected_time - two.expected_time) < 1e-12
    # host model with zero bandwidth behaves identically
    sol0 = solve_optimal_offload(
        ch.with_host(HostTransferModel(bandwidth_d2h=0.0)), m,
        num_slots=int(m))
    assert sol0.feasible == two.feasible
    if two.feasible:
        assert abs(sol0.expected_time - two.expected_time) < 1e-12
        assert not tree_uses_offload(sol0.tree)


def test_offload_policy_plan():
    from repro.core.policies import make_policy_plan, make_policy_tree

    rng = np.random.default_rng(3)
    ch = random_chain(rng, max_len=4)
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    # zero-bandwidth spec: two-tier fallback, expressible as a remat tree
    plan = make_policy_plan(f"optimal_offload:{peak:.0f}:0", ch)
    assert not plan.uses_offload
    tree = make_policy_tree(f"optimal_offload:{peak:.0f}:0", ch)
    assert tree is not None
    # effectively-free link at a tight budget: the host tier gets used
    ch_fast = ch.with_host(HostTransferModel(bandwidth_d2h=1e12))
    f2 = solve_min_memory(ch_fast, num_slots=200)
    f3 = solve_min_device_memory(ch_fast, num_slots=200)
    if f3.mem_limit < f2.mem_limit - 1e-9:
        budget = 0.5 * (f2.mem_limit + f3.mem_limit)
        plan = make_policy_plan(f"optimal_offload:{budget:.0f}", ch_fast,
                                num_slots=200)
        assert plan.schedule is not None
        res = simulate(plan.chain, plan.schedule, budget * (1 + 1e-6))
        assert res.valid, res.error


def test_offload_grads_match_autograd():
    """Real-array execution of an offload schedule — host copies included —
    reproduces plain autograd's gradients bit-for-bit in value."""
    from repro.core import execute_schedule, profile_stages_measured, \
        reference_grads
    from repro.core.schedule import uses_offload
    from repro.offload.executor import execute_offload_schedule

    L = 6
    stages, params, x = make_mlp_chain(L)
    chain = profile_stages_measured(stages, params, x, repeats=1)
    # price the link so that transfers are attractive but not free
    bw = sum(chain.wa) / max(float(chain.uf.sum()), 1e-9)
    chain = chain.with_host(HostTransferModel(bandwidth_d2h=bw))
    peak = simulate(chain, Schedule.store_all(L)).peak_mem
    sol = solve_optimal_offload(chain, peak * 0.35, num_slots=300)
    assert sol.feasible
    assert uses_offload(sol.schedule), "budget chosen to force the host tier"
    out_ref, g_ref, dx_ref = reference_grads(stages, params, x)
    hb = HostBuffer()
    out, grads, dx = execute_offload_schedule(sol.schedule, stages, params, x,
                                              host_buffer=hb)
    tree_allclose(grads, g_ref)
    tree_allclose(dx, dx_ref)
    assert hb.peak_bytes > 0
    assert hb.bytes_in_use == 0  # every offload was prefetched back
    # core executor transparently delegates offload-bearing schedules
    out2, g2, dx2 = execute_schedule(sol.schedule, stages, params, x)
    tree_allclose(g2, g_ref)


def test_simulator_tracks_host_peak():
    ch = Chain.homogeneous(3).with_host(HostTransferModel(bandwidth_d2h=1.0))
    # park a^0 on host while the rest runs (F_∅ consumes the device copy),
    # prefetch it back and replay stage 1 for its backward
    ops = [("Foff", 0), ("Fnone", 1), ("Fall", 2), ("Fall", 3), ("Fall", 4),
           ("B", 4), ("B", 3), ("B", 2), ("Prefetch", 0), ("Fall", 1),
           ("B", 1)]
    res = simulate(ch, Schedule(3, ops))
    assert res.valid, res.error
    assert res.host_peak_mem == float(ch.wa[0])
    # prefetch waited for nothing (offload landed long ago) but paid the copy
    assert abs(res.transfer_stall - ch.host.prefetch_time(ch.wa[0])) < 1e-12
    # offloading without a host model is invalid
    res2 = simulate(Chain.homogeneous(3), Schedule(3, ops))
    assert not res2.valid


def test_simulator_rejects_bad_offload_ops():
    ch = Chain.homogeneous(2).with_host(HostTransferModel(bandwidth_d2h=1.0))
    # prefetch without a host copy
    assert not simulate(ch, Schedule(2, [("Prefetch", 0)])).valid
    # double offload
    assert not simulate(
        ch, Schedule(2, [("Foff", 0), ("Foff", 0)])).valid
    # offload of a non-live activation
    assert not simulate(ch, Schedule(2, [("Foff", 1)])).valid


def test_host_buffer_lru_accounting():
    evicted = []
    hb = HostBuffer(capacity_bytes=100,
                    on_evict=lambda k, v: evicted.append(k))

    class Blob:
        def __init__(self, nbytes):
            self.nbytes = nbytes

    hb.put("a", Blob(40))
    hb.put("b", Blob(40))
    assert hb.bytes_in_use == 80 and hb.peak_bytes == 80
    # checkpoints must not vanish silently
    with pytest.raises(MemoryError):
        hb.put("c", Blob(40))
    # LRU eviction when explicitly allowed: "a" is oldest…
    hb.put("c", Blob(40), evict=True)
    assert evicted == ["a"] and "a" not in hb and "b" in hb
    # …but a get() refreshes recency
    hb.get("b")
    hb.put("d", Blob(40), evict=True)
    assert evicted == ["a", "c"] and "b" in hb
    assert hb.stats.evictions == 2 and hb.stats.evicted_bytes == 80
    # pop releases bytes
    hb.pop("b")
    assert hb.bytes_in_use == 40
    with pytest.raises(KeyError):
        hb.pop("b")
    # an entry larger than the pinned pool can never fit
    with pytest.raises(MemoryError):
        hb.put("x", Blob(101), evict=True)
    assert hb.peak_bytes == 80


def test_train_loop_offload_policy():
    """The runtime runs a genuinely offload-bearing schedule end-to-end and
    matches the plain-autograd loss trajectory exactly."""
    from repro.configs import smoke_config
    from repro.runtime.train_loop import TrainLoopConfig, run_training

    cfg = smoke_config("qwen1.5-4b", num_layers=8,
                       layer_kinds=("dense",) * 8, n_chunks=8,
                       scan_layer_remat="full")
    logs = []
    loop = TrainLoopConfig(steps=3, global_batch=2, seq_len=16,
                           policy="optimal_offload:x0.6:1e15", log_every=100)
    out = run_training(cfg, loop, log_fn=logs.append)
    assert any("[offload]" in line for line in logs), logs
    ref = run_training(
        cfg, TrainLoopConfig(steps=3, global_batch=2, seq_len=16,
                             policy="none", log_every=100),
        log_fn=lambda *_: None)
    np.testing.assert_allclose(out["losses"], ref["losses"], rtol=1e-6)
