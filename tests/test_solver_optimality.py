"""The paper's Theorem 1: the DP computes the optimal *persistent* schedule.

Validated against exhaustive search (Dijkstra over the full Table-1 operation
space) on random small heterogeneous chains, with exact slot discretization.
"""

import math

import numpy as np
import pytest

from repro.core.bruteforce import optimal_time
from repro.core.chain import Chain
from repro.core.schedule import Schedule, simulate
from repro.core.solver import solve_min_memory, solve_optimal, tree_to_schedule

from helpers import random_chain


def _check_chain(ch: Chain, fracs=(0.5, 0.75, 1.0)):
    sa = simulate(ch, Schedule.store_all(ch.length))
    assert sa.valid
    for frac in fracs:
        m = float(math.ceil(sa.peak_mem * frac))
        sol = solve_optimal(ch, m, num_slots=int(m))  # slot size exactly 1
        bf = optimal_time(ch, m + 1e-6, persistent_only=True)
        if not sol.feasible:
            assert not np.isfinite(bf), (
                f"DP infeasible but brute force found {bf}")
            continue
        res = simulate(ch, sol.schedule, m + 1e-6)
        assert res.valid, res.error
        # predicted time == simulated time (the model is exact)
        assert abs(res.time - sol.expected_time) < 1e-9
        # tree flattening reproduces the same schedule semantics
        res2 = simulate(ch, tree_to_schedule(sol.tree, ch.length), m + 1e-6)
        assert res2.valid and abs(res2.time - res.time) < 1e-9
        # optimality among persistent schedules
        assert abs(sol.expected_time - bf) < 1e-9, (
            f"DP={sol.expected_time} vs brute-force={bf} at m={m}")


@pytest.mark.parametrize("seed", range(12))
def test_dp_matches_bruteforce_random(seed):
    rng = np.random.default_rng(seed)
    _check_chain(random_chain(rng, max_len=4))


def _hypothesis_case(uf, wabar, wa):
    n = min(len(uf), len(wabar), len(wa))
    ch = Chain.make(uf=uf[:n], ub=[1.0] * n, wa=wa[:n], wabar=wabar[:n])
    _check_chain(ch, fracs=(0.6, 1.0))


try:
    from hypothesis import given, settings, strategies as st

    test_dp_matches_bruteforce_hypothesis = settings(
        max_examples=25, deadline=None)(
        given(st.lists(st.integers(1, 4), min_size=2, max_size=4),
              st.lists(st.integers(1, 5), min_size=2, max_size=4),
              st.lists(st.integers(1, 3), min_size=2, max_size=4))(
            _hypothesis_case))
except ImportError:  # optional test dependency — see pyproject [test] extra
    def test_dp_matches_bruteforce_hypothesis():
        pytest.importorskip("hypothesis")


def test_monotone_in_memory():
    """C_BP(1, L+1, m) is non-increasing in m."""
    rng = np.random.default_rng(3)
    ch = random_chain(rng, max_len=4)
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    prev = np.inf
    for m in range(2, int(peak) + 2):
        sol = solve_optimal(ch, float(m), num_slots=m)
        if sol.feasible:
            assert sol.expected_time <= prev + 1e-9
            prev = sol.expected_time
    assert np.isfinite(prev)


def test_large_memory_recovers_store_all():
    ch = Chain.homogeneous(6)
    sol = solve_optimal(ch, 1000.0, num_slots=500)
    assert sol.feasible
    ideal = float(ch.uf.sum() + ch.ub.sum())
    assert abs(sol.expected_time - ideal) < 1e-9


def test_solve_min_memory():
    rng = np.random.default_rng(7)
    ch = random_chain(rng, max_len=4)
    sol = solve_min_memory(ch, num_slots=200)
    assert sol.feasible
    res = simulate(ch, sol.schedule, sol.mem_limit * (1 + 1e-6))
    assert res.valid, res.error
    # a budget meaningfully below the reported minimum must be infeasible
    slot = sol.mem_limit / sol.num_slots
    tight = solve_optimal(ch, sol.mem_limit - 3 * slot, num_slots=200)
    assert (not tight.feasible) or tight.expected_time >= sol.expected_time - 1e-9


def test_revolve_never_beats_optimal():
    rng = np.random.default_rng(11)
    for _ in range(8):
        ch = random_chain(rng, max_len=4)
        peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
        for frac in (0.6, 0.9):
            m = math.ceil(peak * frac)
            full = solve_optimal(ch, float(m), num_slots=int(m))
            rev = solve_optimal(ch, float(m), num_slots=int(m),
                                allow_fall=False)
            if rev.feasible:
                assert full.feasible
                assert full.expected_time <= rev.expected_time + 1e-9
