"""The paper's Theorem 1: the DP computes the optimal *persistent* schedule.

Validated against exhaustive search (Dijkstra over the full Table-1 operation
space) on random small heterogeneous chains, with exact slot discretization.

The randomized half of the suite is property-based (``hypothesis``, a
declared dependency of the ``test`` extra and pinned in CI — always
exercised there): chain strategies draw heterogeneous integer-cost chains
(every DP quantity f32-exact) and assert, per drawn chain,

- two-tier DP optimality against brute force,
- offload-DP dominance (never slower than brute force at equal device
  budget) plus feasibility of the returned schedule under the simulator
  (device *and* host peaks within budget),
- band-exactness of the fused single-dispatch Pallas fill
  (``impl="pallas_fused"``) against the numpy banded fill, in interpret mode.

The hypothesis-driven tests carry ``@pytest.mark.slow`` — deselect locally
with ``-m "not slow"``; CI runs everything.  On an environment without
``hypothesis`` installed the property tests *skip visibly* (they never pass
vacuously) — install the ``test`` extra to run them.
"""

import math

import numpy as np
import pytest

from repro.core.bruteforce import optimal_time
from repro.core.chain import Chain, HostTransferModel
from repro.core.schedule import Schedule, simulate
from repro.core.solver import solve_min_memory, solve_optimal, tree_to_schedule

from helpers import random_chain

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover — CI always installs the test extra
    HAVE_HYPOTHESIS = False


def _check_chain(ch: Chain, fracs=(0.5, 0.75, 1.0)):
    sa = simulate(ch, Schedule.store_all(ch.length))
    assert sa.valid
    for frac in fracs:
        m = float(math.ceil(sa.peak_mem * frac))
        sol = solve_optimal(ch, m, num_slots=int(m))  # slot size exactly 1
        bf = optimal_time(ch, m + 1e-6, persistent_only=True)
        if not sol.feasible:
            assert not np.isfinite(bf), (
                f"DP infeasible but brute force found {bf}")
            continue
        res = simulate(ch, sol.schedule, m + 1e-6)
        assert res.valid, res.error
        # predicted time == simulated time (the model is exact)
        assert abs(res.time - sol.expected_time) < 1e-9
        # tree flattening reproduces the same schedule semantics
        res2 = simulate(ch, tree_to_schedule(sol.tree, ch.length), m + 1e-6)
        assert res2.valid and abs(res2.time - res.time) < 1e-9
        # optimality among persistent schedules
        assert abs(sol.expected_time - bf) < 1e-9, (
            f"DP={sol.expected_time} vs brute-force={bf} at m={m}")


@pytest.mark.parametrize("seed", range(12))
def test_dp_matches_bruteforce_random(seed):
    rng = np.random.default_rng(seed)
    _check_chain(random_chain(rng, max_len=4))


# ---------------------------------------------------------------------------
# property-based suite: randomized heterogeneous chains via hypothesis
# ---------------------------------------------------------------------------

if not HAVE_HYPOTHESIS:
    @pytest.mark.slow
    def test_property_suite_needs_hypothesis():
        pytest.importorskip("hypothesis")
else:
    @st.composite
    def chains(draw, max_len=4, max_cost=5, max_size=4):
        """A random heterogeneous chain with integer costs/sizes (f32-exact) —
        the same family the seeded tests use, but adversarially explored."""
        L = draw(st.integers(1, max_len))
        n = L + 1
        ints = lambda hi: st.lists(  # noqa: E731
            st.integers(1, hi), min_size=n, max_size=n)
        zeros = st.lists(st.integers(0, 1), min_size=n, max_size=n)
        return Chain.make(
            uf=draw(ints(max_cost)), ub=draw(ints(max_cost)),
            wa=draw(ints(max_size)), wabar=draw(ints(max_size + 2)),
            of=draw(zeros), ob=draw(zeros))


    @st.composite
    def hosts(draw):
        """Dyadic-rate host links so transfer times stay f32-exact."""
        return HostTransferModel(
            bandwidth_d2h=draw(st.sampled_from([0.25, 0.5, 1.0, 2.0, 4.0])),
            latency=draw(st.sampled_from([0.0, 0.25, 0.5])))


    @pytest.mark.slow
    @settings(max_examples=40, deadline=None)
    @given(ch=chains(), frac=st.sampled_from([0.4, 0.6, 0.8, 1.0]))
    def test_dp_matches_bruteforce_hypothesis(ch, frac):
        """Two-tier DP == brute force, plus simulator feasibility and
        tree/schedule agreement, on arbitrary drawn chains."""
        _check_chain(ch, fracs=(frac,))


    @pytest.mark.slow
    @settings(max_examples=30, deadline=None)
    @given(ch=chains(), host=hosts(), frac=st.sampled_from([0.5, 0.75, 1.0]))
    def test_offload_dp_dominates_bruteforce_hypothesis(ch, host, frac):
        """The offload DP is never slower than the *two-tier* brute-force
        optimum at equal device budget (extra tiers cannot hurt), and its
        schedule must simulate feasibly within both device and host budgets."""
        from repro.offload.solver import solve_optimal_offload

        hch = ch.with_host(host)
        sa = simulate(hch, Schedule.store_all(hch.length))
        m = float(math.ceil(sa.peak_mem * frac))
        sol = solve_optimal_offload(hch, m, num_slots=int(m))
        bf = optimal_time(ch, m + 1e-6, persistent_only=True)
        if not sol.feasible:
            # at equal device budget the offload DP dominates two-tier, so an
            # infeasible offload solve implies an infeasible two-tier problem
            assert not np.isfinite(bf)
            return
        assert sol.expected_time <= bf + 1e-9
        res = simulate(hch, sol.schedule, m + 1e-6,
                       host_mem_limit=float(np.inf))
        assert res.valid, res.error
        assert abs(res.time - sol.expected_time) < 1e-9


    @pytest.mark.slow
    @settings(max_examples=25, deadline=None)
    @given(ch=chains(max_len=5), frac=st.sampled_from([0.4, 0.7, 1.0]),
           allow_fall=st.booleans())
    def test_fused_fill_band_exact_hypothesis(ch, frac, allow_fall):
        """impl="pallas_fused" (interpret mode) is band-exact vs impl="banded"
        on any drawn f32-exact chain — the device-resident recursion as a
        hypothesis property, not just on seeded cases."""
        from repro.core import dp_kernels
        from repro.kernels.dp_fill import ops as dpo

        sa = simulate(ch, Schedule.store_all(ch.length))
        m = float(math.ceil(sa.peak_mem * frac))
        S = int(m)
        dchain = ch.discretize(m, S)
        dpo.set_interpret(True)
        try:
            band = dp_kernels.fill_two_tier(dchain, S, allow_fall=allow_fall)
            fused = dpo.fill_two_tier_fused(dchain, S, allow_fall=allow_fall)
        finally:
            dpo.set_interpret(None)
        assert np.array_equal(band.data, fused.data, equal_nan=True)


    @pytest.mark.slow
    @settings(max_examples=15, deadline=None)
    @given(ch=chains(max_len=4), host=hosts(), allow_fall=st.booleans())
    def test_fused_offload_fill_band_exact_hypothesis(ch, host, allow_fall):
        from repro.core import dp_kernels
        from repro.kernels.dp_fill import ops as dpo

        hch = ch.with_host(host)
        sa = simulate(hch, Schedule.store_all(hch.length))
        S = int(math.ceil(sa.peak_mem * 0.7))
        dchain = hch.discretize(float(S), S)
        dpo.set_interpret(True)
        try:
            tb, te = dp_kernels.fill_offload(dchain, S, allow_fall=allow_fall)
            fb, fe = dpo.fill_offload_fused(dchain, S, allow_fall=allow_fall)
        finally:
            dpo.set_interpret(None)
        assert np.array_equal(tb.data, fb.data, equal_nan=True)
        assert np.array_equal(te.data, fe.data, equal_nan=True)


def test_monotone_in_memory():
    """C_BP(1, L+1, m) is non-increasing in m."""
    rng = np.random.default_rng(3)
    ch = random_chain(rng, max_len=4)
    peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
    prev = np.inf
    for m in range(2, int(peak) + 2):
        sol = solve_optimal(ch, float(m), num_slots=m)
        if sol.feasible:
            assert sol.expected_time <= prev + 1e-9
            prev = sol.expected_time
    assert np.isfinite(prev)


def test_large_memory_recovers_store_all():
    ch = Chain.homogeneous(6)
    sol = solve_optimal(ch, 1000.0, num_slots=500)
    assert sol.feasible
    ideal = float(ch.uf.sum() + ch.ub.sum())
    assert abs(sol.expected_time - ideal) < 1e-9


def test_solve_min_memory():
    rng = np.random.default_rng(7)
    ch = random_chain(rng, max_len=4)
    sol = solve_min_memory(ch, num_slots=200)
    assert sol.feasible
    res = simulate(ch, sol.schedule, sol.mem_limit * (1 + 1e-6))
    assert res.valid, res.error
    # a budget meaningfully below the reported minimum must be infeasible
    slot = sol.mem_limit / sol.num_slots
    tight = solve_optimal(ch, sol.mem_limit - 3 * slot, num_slots=200)
    assert (not tight.feasible) or tight.expected_time >= sol.expected_time - 1e-9


def test_revolve_never_beats_optimal():
    rng = np.random.default_rng(11)
    for _ in range(8):
        ch = random_chain(rng, max_len=4)
        peak = simulate(ch, Schedule.store_all(ch.length)).peak_mem
        for frac in (0.6, 0.9):
            m = math.ceil(peak * frac)
            full = solve_optimal(ch, float(m), num_slots=int(m))
            rev = solve_optimal(ch, float(m), num_slots=int(m),
                                allow_fall=False)
            if rev.feasible:
                assert full.feasible
                assert full.expected_time <= rev.expected_time + 1e-9
