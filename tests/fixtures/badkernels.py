"""Deliberately broken Pallas kernels for `repro.check.kernel_analyzer` tests.

This module is ONLY ever parsed (by file path) by the static analyzer — it is
never imported and never executed.  Each kernel mirrors the structure and
naming contract of the shipped ``kernels/dp_fill`` kernels with one seeded
defect:

- ``_racy_fused_kernel``     — the companion rebuild reads the *current*
  band's rows (``off[d]`` instead of ``off[d-1]``), i.e. garbage that no
  earlier grid step has written: a read-before-write race across grid steps.
- ``_oob_fused_kernel``      — the band write lands past the padded row
  margin the driver allocates (``nrows = ncells + 2L + BR``).
- ``_racy_band_kernel``      — a revisited accumulator block with the
  ``j == 0`` initialization missing: the first grid step already reads the
  (uninitialized) output.
- ``_alias_band_kernel``     — correct body, but the driver's output
  BlockSpec index map varies along the innermost grid dimension, so the
  "revisited accumulator" contract is broken (and row tiles alias).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COST_DT = jnp.float32
_INT_CLAMP = 1 << 30


def _shifted_gather(blk, idx, w):
    g = jnp.take_along_axis(blk, jnp.clip(idx, 0, w - 1), axis=1)
    return jnp.where(idx < 0, jnp.float32(jnp.inf), g)


def _racy_fused_kernel(
    t0_ref,
    off_ref,
    wa_ref,
    wb_ref,
    cum_ref,
    uf_ref,
    ub_ref,
    mn_ref,
    ma_ref,
    t_ref,
    r_ref,
    lm_ref,
    *,
    L,
    W,
    BR,
    allow_fall,
):
    d = pl.program_id(0) + 1
    i = pl.program_id(1)
    r0 = i * BR
    ns = L + 1 - d
    NS0 = L + 1
    inf = jnp.float32(jnp.inf)

    @pl.when((d == 1) & (i == 0))
    def _init():
        t_ref[...] = t0_ref[...]

    @pl.when(i == 0)
    def _rebuild():
        # BUG: rebuilds companions from band d (this band's own rows, which
        # no grid step has written yet) instead of the finished band d-1.
        start = off_ref[d]
        blk = t_ref[pl.ds(start, NS0), :]
        cum = cum_ref[pl.ds(0, NS0)][:, None]
        cols = jax.lax.broadcasted_iota(jnp.int32, (NS0, W), 1)
        idx = cols - wa_ref[pl.ds(0, NS0)][:, None]
        r_ref[pl.ds(start, NS0), :] = _shifted_gather(blk, idx, W) + cum
        lm_ref[pl.ds(start, NS0), :] = blk - cum

    @pl.when(r0 < ns)
    def _compute():
        cols = jax.lax.broadcasted_iota(jnp.int32, (BR, W), 1)

        def split(j, acc):
            rrow = off_ref[d - 1 - j] + 1 + j + r0
            cand = r_ref[pl.ds(rrow, BR), :] + lm_ref[pl.ds(off_ref[j] + r0, BR), :]
            return jnp.minimum(acc, cand)

        acc = jax.lax.fori_loop(0, d, split, jnp.full((BR, W), inf, COST_DT))
        mn = pl.load(mn_ref, (pl.ds(d - 1, 1), pl.ds(r0, BR)))[0][:, None]
        res = jnp.where(cols < mn, inf, acc)
        t_ref[pl.ds(off_ref[d] + r0, BR), :] = res


def _oob_fused_kernel(
    t0_ref,
    off_ref,
    wa_ref,
    wb_ref,
    cum_ref,
    uf_ref,
    ub_ref,
    mn_ref,
    ma_ref,
    t_ref,
    r_ref,
    lm_ref,
    *,
    L,
    W,
    BR,
    allow_fall,
):
    d = pl.program_id(0) + 1
    i = pl.program_id(1)
    r0 = i * BR
    ns = L + 1 - d
    NS0 = L + 1
    inf = jnp.float32(jnp.inf)

    @pl.when((d == 1) & (i == 0))
    def _init():
        t_ref[...] = t0_ref[...]

    @pl.when(i == 0)
    def _rebuild():
        start = off_ref[d - 1]
        blk = t_ref[pl.ds(start, NS0), :]
        cum = cum_ref[pl.ds(0, NS0)][:, None]
        cols = jax.lax.broadcasted_iota(jnp.int32, (NS0, W), 1)
        idx = cols - wa_ref[pl.ds(0, NS0)][:, None]
        r_ref[pl.ds(start, NS0), :] = _shifted_gather(blk, idx, W) + cum
        lm_ref[pl.ds(start, NS0), :] = blk - cum

    @pl.when(r0 < ns)
    def _compute():
        cols = jax.lax.broadcasted_iota(jnp.int32, (BR, W), 1)

        def split(j, acc):
            rrow = off_ref[d - 1 - j] + 1 + j + r0
            cand = r_ref[pl.ds(rrow, BR), :] + lm_ref[pl.ds(off_ref[j] + r0, BR), :]
            return jnp.minimum(acc, cand)

        acc = jax.lax.fori_loop(0, d, split, jnp.full((BR, W), inf, COST_DT))
        mn = pl.load(mn_ref, (pl.ds(d - 1, 1), pl.ds(r0, BR)))[0][:, None]
        res = jnp.where(cols < mn, inf, acc)
        # BUG: the write escapes the padded row margin (nrows = ncells +
        # 2L + BR); the driver's slack absorbs at most 2L + BR - 1 rows.
        t_ref[pl.ds(off_ref[d] + r0 + 2 * L + BR + 1, BR), :] = res


def _racy_band_kernel(r_ref, lm_ref, o_ref):
    # BUG: no `pl.when(j == 0)` initialization — the first split step
    # already folds the uninitialized accumulator into the result.
    cand = r_ref[0] + lm_ref[0]
    o_ref[...] = jnp.minimum(o_ref[...], cand)


def band_racy(r, lm, *, d, block_rows, w, interpret=False):
    ns_pad = r.shape[1]
    grid = (ns_pad // block_rows, d)
    plane = pl.BlockSpec((1, block_rows, w), lambda i, j: (j, i, 0))
    return pl.pallas_call(
        _racy_band_kernel,
        grid=grid,
        in_specs=[plane, plane],
        out_specs=pl.BlockSpec((block_rows, w), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ns_pad, w), r.dtype),
        interpret=interpret,
    )(r, lm)


def _alias_band_kernel(r_ref, lm_ref, o_ref):
    j = pl.program_id(1)
    cand = r_ref[0] + lm_ref[0]

    @pl.when(j == 0)
    def _set():
        o_ref[...] = cand

    @pl.when(j != 0)
    def _acc():
        o_ref[...] = jnp.minimum(o_ref[...], cand)


def band_alias(r, lm, *, d, block_rows, w, interpret=False):
    ns_pad = r.shape[1]
    grid = (ns_pad // block_rows, d)
    plane = pl.BlockSpec((1, block_rows, w), lambda i, j: (j, i, 0))
    # BUG: the output block origin follows the *innermost* grid dimension,
    # so the accumulator is not revisited (and tiles alias across i).
    return pl.pallas_call(
        _alias_band_kernel,
        grid=grid,
        in_specs=[plane, plane],
        out_specs=pl.BlockSpec((block_rows, w), lambda i, j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((ns_pad, w), r.dtype),
        interpret=interpret,
    )(r, lm)
