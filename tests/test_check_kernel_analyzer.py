"""Kernel analyzer (`repro.check.kernel_analyzer`) tests.

The analyzer must machine-check the shipped ``kernels/dp_fill`` Pallas
kernels clean — replacing the hand proof in ``ops.py`` that padded-slice
garbage rows are always rewritten by their own band before any read — while
flagging each of the seeded defects in ``tests/fixtures/badkernels.py``
(race, out-of-bounds, missing accumulator init, aliasing grid map).

It also pins the *contract* the analyzer mirrors from ``ops._FusedOperands``
(row pad, vector length, band offsets): if the driver layout changes without
the analyzer following, these tests fail before the analyzer silently
checks the wrong shapes.
"""

import os

import numpy as np

from repro.check.kernel_analyzer import (
    DEFAULT_FUSED_CASES,
    FusedCase,
    _fused_contract,
    analyze_band_kernel,
    analyze_dp_fill,
    analyze_fused_kernel,
    cache_key,
    dp_fill_kernel_path,
)
from repro.core.solver_cache import code_fingerprint

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures",
                        "badkernels.py")


# -- shipped kernels are clean -----------------------------------------------


def test_shipped_dp_fill_kernels_analyze_clean():
    issues = analyze_dp_fill()
    assert issues == [], "\n".join(str(i) for i in issues)


def test_no_unsupported_constructs_in_shipped_kernels():
    """The analyzer models every construct the shipped kernels use — an
    `unsupported` issue would mean the gate silently stopped proving."""
    issues = analyze_dp_fill()
    assert not [i for i in issues if i.kind == "unsupported"]


def test_cache_key_is_code_fingerprint():
    assert cache_key() == code_fingerprint()


# -- contract mirroring ------------------------------------------------------


def test_fused_contract_matches_ops_driver():
    for L, BR in [(1, 1), (3, 2), (5, 3)]:
        case = FusedCase(L=L, BR=BR)
        contract = _fused_contract(case)
        sizes = [L + 1 - d for d in range(L + 1)]
        off = np.concatenate([[0], np.cumsum(sizes)]).astype(int)
        assert list(off) == contract["off"]
        ncells = int(off[-1])
        assert contract["ncells"] == ncells
        assert contract["nrows"] == ncells + 2 * L + BR
        assert contract["vec"] == 2 * L + BR + 2
        assert contract["rt"] == -(-max(L, 1) // BR)


def test_default_cases_cover_uneven_tiles():
    """The case matrix must include L not divisible by BR — that is where
    pad lanes write garbage past the band and the proof has content."""
    assert any(c.L % c.BR for c in DEFAULT_FUSED_CASES if c.BR > 1)
    assert any(c.allow_fall for c in DEFAULT_FUSED_CASES)
    assert any(not c.allow_fall for c in DEFAULT_FUSED_CASES)


# -- seeded defects are flagged ----------------------------------------------


def _kinds(issues):
    return {i.kind for i in issues}


def test_racy_fused_fixture_flagged():
    issues = analyze_fused_kernel(FIXTURES, "_racy_fused_kernel")
    assert issues, "race fixture analyzed clean"
    assert "final-invalid" in _kinds(issues)


def test_oob_fused_fixture_flagged():
    issues = analyze_fused_kernel(FIXTURES, "_oob_fused_kernel")
    assert "out-of-bounds" in _kinds(issues)


def test_racy_band_fixture_flagged():
    issues = analyze_band_kernel(FIXTURES, "band_racy", "_racy_band_kernel")
    assert issues, "missing-init fixture analyzed clean"
    assert "final-invalid" in _kinds(issues)


def test_alias_band_fixture_flagged():
    issues = analyze_band_kernel(FIXTURES, "band_alias",
                                 "_alias_band_kernel")
    assert "grid-race" in _kinds(issues)


def test_missing_kernel_reports_unsupported():
    issues = analyze_fused_kernel(FIXTURES, "_no_such_kernel")
    assert [i.kind for i in issues] == ["unsupported"]


# -- the gate ----------------------------------------------------------------


def test_check_main_gate_passes(tmp_path, monkeypatch):
    """`python -m repro.check` (the CI job) exits 0 on the current tree and
    re-uses the fingerprint stamp on the second run."""
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(dp_fill_kernel_path()),
                                     "..", "..", "..")
    env["XDG_CACHE_HOME"] = str(tmp_path)
    first = subprocess.run(
        [sys.executable, "-m", "repro.check", "--force"],
        capture_output=True, text=True, env=env)
    assert first.returncode == 0, first.stdout + first.stderr
    second = subprocess.run(
        [sys.executable, "-m", "repro.check"],
        capture_output=True, text=True, env=env)
    assert second.returncode == 0
    assert "cached ok" in second.stdout
