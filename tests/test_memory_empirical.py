"""Empirical validation of the paper's MEMORY claim: the faithful executor
tracks the real bytes of the arrays it holds (activations + vjp residuals);
rotor schedules must hold measurably less than store-all, and the measured
saved-set peaks must track the simulator's model (the XLA-CPU buffer
assignment cannot show this — DESIGN.md §8b — so this is the on-container
ground truth for the memory side of the reproduction)."""

import numpy as np
import pytest

from repro.core import (Schedule, execute_schedule, profile_stages_analytic,
                        simulate, solve_optimal)
from repro.core.solver import solve_min_memory

from helpers import make_mlp_chain, tree_allclose


@pytest.fixture(scope="module")
def chain_setup():
    # wide MLP stages so activation bytes dominate python/object overhead
    L = 6
    dims = [256, 1024, 256, 2048, 256, 1024, 128]
    stages, params, x = make_mlp_chain(L, dims=dims)
    chain = profile_stages_analytic(stages, params, x, peak_flops=1e9)
    return L, stages, params, x, chain


def test_rotor_reduces_measured_memory(chain_setup):
    L, stages, params, x, chain = chain_setup
    *_, peak_store = execute_schedule(Schedule.store_all(L), stages, params,
                                      x, track_live_bytes=True)
    floor = solve_min_memory(chain, num_slots=400)
    *_, peak_min = execute_schedule(floor.schedule, stages, params, x,
                                    track_live_bytes=True)
    assert peak_min < peak_store * 0.75, (peak_min, peak_store)


def test_measured_peak_tracks_model(chain_setup):
    """measured-peak ratios between schedules ≈ model-peak ratios (±30%:
    the model counts ā exactly; the executor also holds δ and param grads)."""
    L, stages, params, x, chain = chain_setup
    sa = simulate(chain, Schedule.store_all(L))
    *_, m_store = execute_schedule(Schedule.store_all(L), stages, params, x,
                                   track_live_bytes=True)
    for frac in (0.5, 0.7):
        sol = solve_optimal(chain, sa.peak_mem * frac, num_slots=400)
        if not sol.feasible:
            continue
        sim = simulate(chain, sol.schedule)
        out = execute_schedule(sol.schedule, stages, params, x,
                               track_live_bytes=True)
        m_rotor = out[-1]
        model_ratio = sim.peak_mem / sa.peak_mem
        meas_ratio = m_rotor / m_store
        assert abs(meas_ratio - model_ratio) < 0.30, (meas_ratio, model_ratio)
        # and the grads stay exact while memory drops
        from repro.core import reference_grads
        _, g_ref, _ = reference_grads(stages, params, x)
        tree_allclose(out[1], g_ref)


def test_tracking_does_not_change_results(chain_setup):
    L, stages, params, x, chain = chain_setup
    out1 = execute_schedule(Schedule.store_all(L), stages, params, x)
    out2 = execute_schedule(Schedule.store_all(L), stages, params, x,
                            track_live_bytes=True)
    np.testing.assert_allclose(np.asarray(out1[0]), np.asarray(out2[0]))
