"""Shared test helpers."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chain import Chain


def random_chain(rng: np.random.Generator, max_len: int = 4,
                 zero_overheads: bool = False) -> Chain:
    L = int(rng.integers(1, max_len + 1))
    n = L + 1
    z = np.zeros(n)
    return Chain.make(
        uf=rng.integers(1, 5, n).astype(float),
        ub=rng.integers(1, 5, n).astype(float),
        wa=rng.integers(1, 4, n).astype(float),
        wabar=rng.integers(1, 6, n).astype(float),
        of=z if zero_overheads else rng.integers(0, 2, n).astype(float),
        ob=z if zero_overheads else rng.integers(0, 2, n).astype(float),
    )


def make_mlp_chain(L: int, dims=None, seed: int = 0):
    """L tanh-MLP stages + a mean-square loss stage; returns
    (stages, params, x)."""
    dims = dims or [8 + 2 * i for i in range(L + 1)]
    key = jax.random.PRNGKey(seed)
    params, stages = [], []
    for i in range(L):
        w = jax.random.normal(jax.random.fold_in(key, i),
                              (dims[i], dims[i + 1])) * 0.3
        params.append({"w": w, "b": jnp.zeros((dims[i + 1],))})
        stages.append(lambda p, a: jnp.tanh(a @ p["w"] + p["b"]))
    params.append({})
    stages.append(lambda p, a: jnp.mean(a ** 2))
    x = jax.random.normal(jax.random.fold_in(key, 999), (4, dims[0]))
    return stages, params, x


def tree_allclose(a, b, rtol=1e-5, atol=1e-7):
    for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(u, dtype=np.float64),
                                   np.asarray(v, dtype=np.float64),
                                   rtol=rtol, atol=atol)
