"""Per-kernel correctness: shape/dtype sweeps vs the pure-jnp oracles, in
Pallas interpret mode (kernel bodies execute in Python on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fops, ref as fref
from repro.kernels.rmsnorm import ops as rops, ref as rref
from repro.kernels.ssd import ops as sops, ref as sref
from repro.kernels.xent import ops as xops, ref as xref


@pytest.fixture(autouse=True)
def interpret_mode():
    fops.set_interpret(True)
    rops.set_interpret(True)
    sops.set_interpret(True)
    yield
    fops.set_interpret(False)
    rops.set_interpret(False)
    sops.set_interpret(False)


# -- flash attention ---------------------------------------------------------

FLASH_CASES = [
    # (B, S, H, K, D, dtype)
    (2, 128, 4, 4, 64, jnp.float32),
    (1, 256, 4, 2, 128, jnp.float32),
    (2, 96, 6, 2, 32, jnp.float32),     # S not a block multiple, D < 128
    (1, 130, 8, 1, 128, jnp.float32),   # MQA, ragged S
    (2, 128, 4, 4, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,K,D,dtype", FLASH_CASES)
def test_flash_attention_fwd(B, S, H, K, D, dtype):
    key = jax.random.PRNGKey(S * H + D)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, K, D), dtype)
    v = jax.random.normal(ks[2], (B, S, K, D), dtype)
    out = fops.flash_attention(q, k, v, True)
    exp = fref.attention(q, k, v, True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), rtol=tol, atol=tol)


def test_flash_attention_grads():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    g1 = jax.grad(lambda q_, k_, v_: fops.flash_attention(q_, k_, v_, True).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q_, k_, v_: fref.attention(q_, k_, v_, True).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


# -- rmsnorm ------------------------------------------------------------------

@pytest.mark.parametrize("shape,dtype", [
    ((4, 37, 256), jnp.float32),
    ((3, 128), jnp.float32),
    ((2, 16, 512), jnp.bfloat16),
])
def test_rmsnorm(shape, dtype):
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, shape, dtype)
    s = (jax.random.normal(jax.random.fold_in(key, 1), shape[-1:]) * 0.1
         + 1).astype(dtype)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(rops.rms_norm(x, s), np.float32),
                               np.asarray(rref.rms_norm(x, s), np.float32),
                               rtol=tol, atol=tol)


def test_rmsnorm_grads():
    """Backward parity for the custom-VJP wrapper (fwd = Pallas kernel in
    interpret mode, bwd = recompute-from-inputs): kernel changes that skew
    the saved residuals or the recompute surface here, on CPU CI."""
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (3, 17, 128))
    s = jax.random.normal(jax.random.fold_in(key, 1), (128,)) * 0.1 + 1

    def f_k(x_, s_):
        return (rops.rms_norm(x_, s_) ** 2).sum()

    def f_r(x_, s_):
        return (rref.rms_norm(x_, s_) ** 2).sum()

    g1 = jax.grad(f_k, argnums=(0, 1))(x, s)
    g2 = jax.grad(f_r, argnums=(0, 1))(x, s)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


def test_flash_attention_non_causal():
    key = jax.random.PRNGKey(21)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    out = fops.flash_attention(q, k, v, False)
    exp = fref.attention(q, k, v, False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


# -- SSD ----------------------------------------------------------------------

SSD_CASES = [
    # (B, S, H, P, G, N, Q)
    (2, 64, 4, 16, 1, 32, 16),
    (1, 48, 2, 8, 2, 16, 16),   # grouped B/C, S not multiple of Q? 48/16=3 ok
    (1, 40, 2, 8, 1, 16, 16),   # ragged chunks (padding path)
]


@pytest.mark.parametrize("B,S,H,P,G,N,Q", SSD_CASES)
def test_ssd_kernel_vs_naive(B, S, H, P, G, N, Q):
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    y_naive, st_naive = sref.ssd_naive(x, dt, A, Bm, Cm)
    y_ref, st_ref = sref.ssd_chunked(x, dt, A, Bm, Cm, Q)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_naive),
                               rtol=2e-4, atol=2e-4)
    y_k, st_k = sops.ssd_chunked(x, dt, A, Bm, Cm, Q)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_grads():
    B, S, H, P, G, N, Q = 1, 32, 2, 8, 1, 16, 8
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3

    def f_k(x_):
        return sops.ssd_chunked(x_, dt, A, Bm, Cm, Q)[0].sum()

    def f_r(x_):
        return sref.ssd_chunked(x_, dt, A, Bm, Cm, Q)[0].sum()

    np.testing.assert_allclose(np.asarray(jax.grad(f_k)(x)),
                               np.asarray(jax.grad(f_r)(x)),
                               rtol=1e-4, atol=1e-4)


def test_ssd_state_continuation():
    """Chunked SSD over [0:S] == two calls over [0:S/2], [S/2:S] with the
    carried state — the property decode streaming relies on."""
    B, S, H, P, G, N, Q = 1, 64, 2, 8, 1, 16, 16
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    y_full, st_full = sref.ssd_chunked(x, dt, A, Bm, Cm, Q)
    h = S // 2
    y1, st1 = sref.ssd_chunked(x[:, :h], dt[:, :h], A, Bm[:, :h], Cm[:, :h], Q)
    y2, st2 = sref.ssd_chunked(x[:, h:], dt[:, h:], A, Bm[:, h:], Cm[:, h:],
                               Q, init_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=2e-4, atol=2e-4)


# -- cross-entropy -------------------------------------------------------------

@pytest.mark.parametrize("V,block", [(1000, 128), (777, 256), (64, 128)])
def test_vocab_blockwise_xent(V, block):
    B, S, d = 2, 8, 32
    key = jax.random.PRNGKey(11)
    h = jax.random.normal(key, (B, S, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, V)) * 0.1
    labels = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0, V)
    mask = (jax.random.uniform(jax.random.fold_in(key, 3), (B, S)) > 0.3
            ).astype(jnp.float32)
    l1 = xops.blockwise_xent(h, w, labels, mask, block=block)
    l2 = xref.xent_from_hidden(h, w, labels, mask)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    g1 = jax.grad(lambda *a: xops.blockwise_xent(*a, block), argnums=(0, 1))(
        h, w, labels, mask)
    g2 = jax.grad(lambda *a: xref.xent_from_hidden(*a), argnums=(0, 1))(
        h, w, labels, mask)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("block", [4, 16, 64])
def test_token_chunked_xent(block):
    B, S, d, V = 2, 10, 16, 301
    key = jax.random.PRNGKey(13)
    h = jax.random.normal(key, (B, S, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, V)) * 0.1
    labels = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0, V)
    mask = (jax.random.uniform(jax.random.fold_in(key, 3), (B, S)) > 0.2
            ).astype(jnp.float32)
    l1 = xops.token_chunked_xent(h, w, labels, mask, block=block)
    l2 = xref.xent_from_hidden(h, w, labels, mask)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    g1 = jax.grad(lambda h_, w_: xops.token_chunked_xent(
        h_, w_, labels, mask, block), argnums=(0, 1))(h, w)
    g2 = jax.grad(lambda h_, w_: xref.xent_from_hidden(
        h_, w_, labels, mask), argnums=(0, 1))(h, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
