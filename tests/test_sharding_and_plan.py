"""Unit tests: logical-axis resolution (divisibility fallbacks), policy
parsing, analytic FLOPs sanity, collective-parser, and planner behavior."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core.policies import make_policy_tree, parse_budget
from repro.core.rematerialize import tree_stage_span
from repro.launch.roofline import collective_bytes_from_hlo
from repro.models.flops import model_flops_per_step, stage_flops
from repro.models.lm import StagedLM


# -- sharding rules -----------------------------------------------------------

def test_spec_resolution_divisibility():
    from types import SimpleNamespace
    from repro.distributed import sharding as sh

    mesh = SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16})
    # divisible: sharded
    spec = sh.spec_for(("act_batch", "act_seq", "act_heads", None),
                       (256, 4096, 64, 128), mesh, sh.DEFAULT_RULES)
    assert spec[0] == ("pod", "data") and spec[2] == "model"
    # 36 heads don't divide 16 -> dropped, not an error
    spec = sh.spec_for(("act_batch", None, "act_heads", None),
                       (256, 4096, 36, 128), mesh, sh.DEFAULT_RULES)
    assert spec[2] is None
    # batch=1 (long-context decode) -> batch sharding dropped
    spec = sh.spec_for(("act_batch", "act_kv_seq", "act_kv", None),
                       (1, 524288, 32, 80), mesh, sh.LONG_CONTEXT_RULES)
    assert spec[0] is None and spec[1] is not None


def test_axes_never_reused():
    from types import SimpleNamespace
    from repro.distributed import sharding as sh

    mesh = SimpleNamespace(shape={"data": 8, "model": 8})
    # both logical axes map to "model": only the first (dim order) gets it
    spec = sh.spec_for(("act_experts", None, "act_mlp_expert"),
                       (64, 128, 1408), mesh, sh.DEFAULT_RULES)
    assert spec[0] == "model" and spec[2] is None


# -- policies ------------------------------------------------------------------

def test_parse_budget():
    assert parse_budget("1.5G", None) == 1.5e9
    assert parse_budget("800M", None) == 8e8
    assert parse_budget("123", None) == 123.0
    with pytest.raises(ValueError):
        parse_budget("x0.5", None)  # fraction needs a chain


@pytest.mark.parametrize("policy,length", [("none", 6), ("full", 6),
                                           ("periodic:3", 6)])
def test_policy_trees_span(policy, length):
    tree = make_policy_tree(policy, None, length=length)
    assert tree_stage_span(tree) == (1, length + 1)


def test_unknown_policy_raises():
    with pytest.raises(ValueError):
        make_policy_tree("magic:1", None, length=4)


# -- analytic flops -------------------------------------------------------------

def test_stage_flops_close_to_6nd():
    """Σ stage FLOPs (fwd+bwd, no remat) ≈ 6·N·D within the attention/
    routing overhead margin for a dense config."""
    import dataclasses
    cfg = dataclasses.replace(get_config("qwen1.5-4b"),
                              scan_layer_remat="none")
    B, S = 8, 2048
    fwd, bwd = stage_flops(cfg, B, S)
    total = sum(fwd) + sum(bwd)
    ideal = model_flops_per_step(cfg, B, S, train=True)
    assert 0.9 * ideal <= total <= 1.8 * ideal, (total, ideal)


def test_moe_flops_scale_with_topk():
    cfg6 = get_config("deepseek-v2-lite-16b")
    import dataclasses
    cfg2 = dataclasses.replace(cfg6, moe_top_k=2)
    f6, _ = stage_flops(cfg6, 4, 1024)
    f2, _ = stage_flops(cfg2, 4, 1024)
    assert sum(f6) > sum(f2)


# -- collective parser -----------------------------------------------------------

def test_collective_parser_semantics():
    text = """
  %ag = bf16[64,128]{1,0} all-gather(%a), replica_groups={{0,1,2,3}}
  %ar = f32[1024]{0} all-reduce(%b), replica_groups={{0,1}}
  %rs = bf16[8,16]{1,0} reduce-scatter(%c), replica_groups={{0,1,2,3,4,5,6,7}}
  %a2a = bf16[4,256]{1,0} all-to-all(%d), replica_groups={{0,1,2,3}}
  %done = bf16[64,128]{1,0} all-gather-done(%ag-start)
"""
    got = collective_bytes_from_hlo(text)
    assert got["all-gather"] == 64 * 128 * 2 / 4      # operand = result / g
    assert got["all-reduce"] == 1024 * 4
    assert got["reduce-scatter"] == 8 * 16 * 2 * 8    # operand = result × g
    assert got["all-to-all"] == 4 * 256 * 2
    assert got["total"] == sum(v for k, v in got.items() if k != "total")


# -- planner -----------------------------------------------------------------------

def test_planner_chain_monotone_stages():
    """The profiled chain has one entry per stage and positive sizes."""
    from repro.core.planner import profile_stages_analytic
    cfg = smoke_config("zamba2-2.7b")
    model = StagedLM(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 16), jnp.int32),
             "loss_mask": jax.ShapeDtypeStruct((2, 16), jnp.float32)}
    fwd, bwd = stage_flops(cfg, 2, 16)
    chain = profile_stages_analytic(model.stage_fns(),
                                    model.stage_params(params), batch,
                                    flops_fwd=fwd, flops_bwd=bwd)
    assert chain.length == model.n_stages() - 1
    assert (chain.wabar[:-1] > 0).all()
    assert (chain.wa > 0).all()


def test_rotor_auto_fits_budget():
    """rotor:auto's planned schedule respects the simulated budget."""
    from repro.core.schedule import simulate
    from repro.core.solver import solve_optimal, tree_to_schedule
    from repro.core.planner import profile_stages_analytic
    cfg = smoke_config("qwen1.5-4b", num_layers=6,
                       layer_kinds=("dense",) * 6, n_chunks=6)
    model = StagedLM(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((4, 64), jnp.int32),
             "loss_mask": jax.ShapeDtypeStruct((4, 64), jnp.float32)}
    chain = profile_stages_analytic(model.stage_fns(),
                                    model.stage_params(params), batch,
                                    peak_flops=1e12)
    from repro.core.schedule import Schedule
    peak = simulate(chain, Schedule.store_all(chain.length)).peak_mem
    sol = solve_optimal(chain, peak * 0.6, num_slots=300)
    if sol.feasible:
        res = simulate(chain, sol.schedule)
        assert res.peak_mem <= peak * 0.6 * (1 + 1 / 300) + chain.wa[0]
